"""Layer-2 JAX models: the paper's two experimental networks.

  * MNIST CNN (Fig. 4): three binarized 3x3 conv layers + one FC layer,
    trained with straight-through-estimator (STE) sign binarization.
    Kernel-level pruning masks are *runtime inputs*, so the Rust
    coordinator can prune between steps without recompiling the artifact.
  * PointNet (Fig. 5): hierarchical 1x1-conv (pointwise MLP) set-
    abstraction network for point-cloud classification. Grouping (FPS +
    ball query) is coordinate-only, so the Rust substrate precomputes the
    grouped tensors / gather indices and the JAX graph stays static.

Both forward passes route every matmul through the Layer-1 Pallas kernel
(`kernels.binary_conv.matmul`), wrapped in a custom VJP whose backward is
also Pallas matmuls — so the AOT artifact's fwd AND bwd hot paths are the
paper's kernel.

Everything here is build-time only: `aot.py` lowers the jitted train/eval
steps to HLO text once; Python never runs on the Rust request path.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import binary_conv as bc

# ---------------------------------------------------------------------------
# Differentiable Pallas matmul (custom VJP: grads are Pallas matmuls too).
# ---------------------------------------------------------------------------


@jax.custom_vjp
def pmatmul(a, b):
    return bc.matmul(a, b)


def _pmatmul_fwd(a, b):
    return bc.matmul(a, b), (a, b)


def _pmatmul_bwd(res, g):
    a, b = res
    da = bc.matmul(g, b.T)
    db = bc.matmul(a.T, g)
    return da, db


pmatmul.defvjp(_pmatmul_fwd, _pmatmul_bwd)


def binarize_ste(w):
    """Scaled sign binarization with straight-through gradient.

    Kernel bits are sign(w) in {-1,+1} — exactly what the RRAM cells store
    and the XNOR/popcount array computes. The per-kernel scale
    alpha = mean(|w|) (XNOR-Net) is a digital multiplier folded into the
    chip's shift-and-add stage; without it the binary activations blow up
    (fan-in 288-576) and training diverges.
    """
    axes = tuple(range(1, w.ndim))
    alpha = jnp.mean(jnp.abs(w), axis=axes, keepdims=True)
    wb = jnp.where(w >= 0, 1.0, -1.0).astype(w.dtype) * alpha
    return w + jax.lax.stop_gradient(wb - w)


def fake_quant_int8_ste(w):
    """Symmetric per-output-channel INT8 fake-quant with STE.

    Paper's PointNet path: INT8 weights on four 2-bit RRAM cells. The scale
    is per filter (output channel = last axis of the (in,out) matrix), just
    as each filter occupies its own RRAM rows with its own digital scale in
    the S&A stage — so pruning one filter cannot perturb another's
    quantization grid.
    """
    scale = jnp.maximum(jnp.max(jnp.abs(w), axis=0, keepdims=True), 1e-8) / 127.0
    wq = jnp.clip(jnp.round(w / scale), -128, 127) * scale
    return w + jax.lax.stop_gradient(wq - w)


def conv2d_pallas(x, w, stride=1, pad=1):
    """Conv (NCHW x OIHW) = im2col + differentiable Pallas matmul."""
    oc, ic, kh, kw = w.shape
    n = x.shape[0]
    cols, oh, ow = bc.im2col(x, kh, kw, stride, pad)  # (N, P, CK)
    flat = cols.reshape(n * oh * ow, ic * kh * kw)
    out = pmatmul(flat, w.reshape(oc, ic * kh * kw).T)
    return out.reshape(n, oh * ow, oc).transpose(0, 2, 1).reshape(n, oc, oh, ow)


def maxpool2(x):
    n, c, h, w = x.shape
    return x.reshape(n, c, h // 2, 2, w // 2, 2).max(axis=(3, 5))


def cross_entropy(logits, y, n_classes):
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    loss = jnp.mean(logz - gold)
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.int32))
    return loss, correct


# ---------------------------------------------------------------------------
# MNIST CNN (paper Fig. 4 / Methods): 32-64-32 binary 3x3 kernels + FC(1568,10)
# ---------------------------------------------------------------------------

MNIST_CHANNELS = (32, 64, 32)
MNIST_FC_IN = 32 * 7 * 7  # 28 ->pool-> 14 ->pool-> 7
MNIST_CLASSES = 10

# Flat parameter order — the Rust runtime packs Literals in exactly this
# order (see rust/src/runtime/artifacts.rs):
#   w1 (32,1,3,3)  b1 (32,)
#   w2 (64,32,3,3) b2 (64,)
#   w3 (32,64,3,3) b3 (32,)
#   wf (1568,10)   bf (10,)
# Mask order: m1 (32,), m2 (64,), m3 (32,)


def mnist_init(key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    c1, c2, c3 = MNIST_CHANNELS

    def he(k, shape, fan_in):
        return jax.random.normal(k, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)

    return (
        he(k1, (c1, 1, 3, 3), 9),
        jnp.zeros((c1,), jnp.float32),
        he(k2, (c2, c1, 3, 3), c1 * 9),
        jnp.zeros((c2,), jnp.float32),
        he(k3, (c3, c2, 3, 3), c2 * 9),
        jnp.zeros((c3,), jnp.float32),
        he(k4, (MNIST_FC_IN, MNIST_CLASSES), MNIST_FC_IN),
        jnp.zeros((MNIST_CLASSES,), jnp.float32),
    )


def mnist_forward(params, masks, x, use_pallas=True):
    """Forward pass. x: (B,1,28,28) f32 in [0,1]; returns logits (B,10).

    Conv weights are sign-binarized (STE) then masked per output kernel —
    a pruned kernel contributes exactly zero, mirroring a deactivated RRAM
    row block.
    """
    w1, b1, w2, b2, w3, b3, wf, bf = params
    m1, m2, m3 = masks
    conv = conv2d_pallas if use_pallas else (lambda x, w: bc.conv2d(x, w, use_pallas=False))

    def block(x, w, b, m, pool):
        wb = binarize_ste(w) * m[:, None, None, None]
        h = conv(x, wb) + b[None, :, None, None]
        h = jax.nn.relu(h) * m[None, :, None, None]
        return maxpool2(h) if pool else h

    h = block(x, w1, b1, m1, pool=True)  # (B,32,14,14)
    h = block(h, w2, b2, m2, pool=True)  # (B,64,7,7)
    h = block(h, w3, b3, m3, pool=False)  # (B,32,7,7)
    flat = h.reshape(x.shape[0], MNIST_FC_IN)
    if use_pallas:
        return pmatmul(flat, wf) + bf[None, :]
    return flat @ wf + bf[None, :]


def mnist_loss(params, masks, x, y, use_pallas=True):
    logits = mnist_forward(params, masks, x, use_pallas)
    loss, correct = cross_entropy(logits, y, MNIST_CLASSES)
    return loss, correct


def mnist_train_step(params, masks, x, y, lr, use_pallas=True):
    """One fused SGD step. Returns (new_params, loss, n_correct).

    Gradients of masked (pruned) kernels are themselves masked so pruned
    kernels stay frozen at their pruned state — the paper's chip simply
    stops addressing those rows.
    """
    (loss, correct), grads = jax.value_and_grad(mnist_loss, has_aux=True)(
        params, masks, x, y, use_pallas
    )
    m1, m2, m3 = masks
    gmask = (
        m1[:, None, None, None],
        m1,
        m2[:, None, None, None],
        m2,
        m3[:, None, None, None],
        m3,
        jnp.ones_like(params[6]),
        jnp.ones_like(params[7]),
    )
    new_params = tuple(
        p - lr * g * gm for p, g, gm in zip(params, grads, gmask)
    )
    return new_params, loss, correct


def mnist_eval_logits(params, masks, x, use_pallas=True):
    return mnist_forward(params, masks, x, use_pallas)


def mnist_features(params, masks, x, use_pallas=False):
    """Penultimate (flattened conv3) features for t-SNE (Fig. 4f,g)."""
    w1, b1, w2, b2, w3, b3, _, _ = params
    m1, m2, m3 = masks
    conv = conv2d_pallas if use_pallas else (lambda x, w: bc.conv2d(x, w, use_pallas=False))

    def block(x, w, b, m, pool):
        wb = binarize_ste(w) * m[:, None, None, None]
        h = conv(x, wb) + b[None, :, None, None]
        h = jax.nn.relu(h) * m[None, :, None, None]
        return maxpool2(h) if pool else h

    h = block(x, w1, b1, m1, True)
    h = block(h, w2, b2, m2, True)
    h = block(h, w3, b3, m3, False)
    return h.reshape(x.shape[0], MNIST_FC_IN)


# ---------------------------------------------------------------------------
# PointNet (paper Fig. 5): 2-level set abstraction + global pooling + head.
# Grouping tensors are produced by the Rust substrate (FPS + ball query are
# coordinate-only); layer widths are a scaled-down PointNet++ SSG.
# ---------------------------------------------------------------------------

PN_SA1 = (32, 32, 64)  # MLP over relative xyz (3 -> ...)
PN_SA2 = (64, 64, 128)  # MLP over [grouped f1 ; rel xyz] (64+3 -> ...)
PN_GLOBAL = (128, 256)  # MLP over [f2 ; center2 xyz] (128+3 -> ...)
PN_HEAD = (128,)  # FC head hidden
PN_CLASSES = 10

# Flat parameter order (w, b per layer):
#   sa1: (3,32) (32,) (32,32) (32,) (32,64) (64,)
#   sa2: (67,64) (64,) (64,64) (64,) (64,128) (128,)
#   glb: (131,128) (128,) (128,256) (256,)
#   head: (256,128) (128,) (128,10) (10,)
# Mask order (one per conv/MLP layer, over output channels):
#   m0 (32,) m1 (32,) m2 (64,) m3 (64,) m4 (64,) m5 (128,) m6 (128,) m7 (256,)

PN_LAYER_DIMS = [
    (3, 32),
    (32, 32),
    (32, 64),
    (64 + 3, 64),
    (64, 64),
    (64, 128),
    (128 + 3, 128),
    (128, 256),
    (256, 128),
    (128, PN_CLASSES),
]
PN_MASKED_LAYERS = 8  # all conv (pointwise MLP) layers; head FCs unmasked


def pointnet_init(key):
    keys = jax.random.split(key, len(PN_LAYER_DIMS))
    params = []
    for k, (fi, fo) in zip(keys, PN_LAYER_DIMS):
        params.append(
            jax.random.normal(k, (fi, fo), jnp.float32) * jnp.sqrt(2.0 / fi)
        )
        params.append(jnp.zeros((fo,), jnp.float32))
    return tuple(params)


def _dense(x, w, b, m=None, use_pallas=True, quant=True):
    """Pointwise (1x1-conv) dense layer over the last axis, channel-masked."""
    if quant:
        w = fake_quant_int8_ste(w)
    shape = x.shape
    flat = x.reshape(-1, shape[-1])
    out = pmatmul(flat, w) if use_pallas else flat @ w
    out = out + b[None, :]
    out = jax.nn.relu(out)
    if m is not None:
        out = out * m[None, :]
    return out.reshape(*shape[:-1], w.shape[1])


def pointnet_forward(params, masks, g1_xyz, g2_idx, g2_xyz, c2_xyz, use_pallas=True):
    """Forward pass.

    g1_xyz: (B,S1,K1,3) relative neighbor coords of SA1 groups
    g2_idx: (B,S2,K2) int32 indices into SA1 centers
    g2_xyz: (B,S2,K2,3) relative coords of grouped SA1 centers
    c2_xyz: (B,S2,3) absolute SA2 center coords
    Returns logits (B,10).
    """
    p = list(params)
    m = list(masks)
    b = g1_xyz.shape[0]

    # --- SA1: MLP over local geometry, max over neighbors ---
    h = g1_xyz
    h = _dense(h, p[0], p[1], m[0], use_pallas)
    h = _dense(h, p[2], p[3], m[1], use_pallas)
    h = _dense(h, p[4], p[5], m[2], use_pallas)
    f1 = h.max(axis=2)  # (B,S1,64)

    # --- SA2: gather SA1 features into groups, concat relative xyz ---
    s2, k2 = g2_idx.shape[1], g2_idx.shape[2]
    idx = g2_idx.reshape(b, s2 * k2)
    gathered = jnp.take_along_axis(f1, idx[:, :, None], axis=1)
    gathered = gathered.reshape(b, s2, k2, f1.shape[-1])
    h = jnp.concatenate([gathered, g2_xyz], axis=-1)  # (B,S2,K2,67)
    h = _dense(h, p[6], p[7], m[3], use_pallas)
    h = _dense(h, p[8], p[9], m[4], use_pallas)
    h = _dense(h, p[10], p[11], m[5], use_pallas)
    f2 = h.max(axis=2)  # (B,S2,128)

    # --- Global: concat center coords, MLP, max over centers ---
    h = jnp.concatenate([f2, c2_xyz], axis=-1)  # (B,S2,131)
    h = _dense(h, p[12], p[13], m[6], use_pallas)
    h = _dense(h, p[14], p[15], m[7], use_pallas)
    g = h.max(axis=1)  # (B,256)

    # --- Head ---
    h = _dense(g, p[16], p[17], None, use_pallas, quant=False)
    flat = h.reshape(-1, h.shape[-1])
    logits = (pmatmul(flat, p[18]) if use_pallas else flat @ p[18]) + p[19][None, :]
    return logits


def pointnet_loss(params, masks, g1, g2i, g2x, c2, y, use_pallas=True):
    logits = pointnet_forward(params, masks, g1, g2i, g2x, c2, use_pallas)
    loss, correct = cross_entropy(logits, y, PN_CLASSES)
    return loss, correct


def pointnet_train_step(params, masks, g1, g2i, g2x, c2, y, lr, use_pallas=True):
    (loss, correct), grads = jax.value_and_grad(pointnet_loss, has_aux=True)(
        params, masks, g1, g2i, g2x, c2, y, use_pallas
    )
    # Mask gradients of pruned output channels (w columns + bias entries).
    gm = []
    for li in range(len(PN_LAYER_DIMS)):
        if li < PN_MASKED_LAYERS:
            gm.append(masks[li][None, :])
            gm.append(masks[li])
        else:
            gm.append(jnp.ones((1, PN_LAYER_DIMS[li][1]), jnp.float32))
            gm.append(jnp.ones((PN_LAYER_DIMS[li][1],), jnp.float32))
    new_params = tuple(p - lr * g * m for p, g, m in zip(params, grads, gm))
    return new_params, loss, correct


def pointnet_eval_logits(params, masks, g1, g2i, g2x, c2, use_pallas=True):
    return pointnet_forward(params, masks, g1, g2i, g2x, c2, use_pallas)


def pointnet_features(params, masks, g1, g2i, g2x, c2):
    """Global 256-d feature (pre-head) for t-SNE (Fig. 5d,e)."""
    p = list(params)
    m = list(masks)
    b = g1.shape[0]
    h = g1
    h = _dense(h, p[0], p[1], m[0], False)
    h = _dense(h, p[2], p[3], m[1], False)
    h = _dense(h, p[4], p[5], m[2], False)
    f1 = h.max(axis=2)
    s2, k2 = g2i.shape[1], g2i.shape[2]
    idx = g2i.reshape(b, s2 * k2)
    gathered = jnp.take_along_axis(f1, idx[:, :, None], axis=1)
    gathered = gathered.reshape(b, s2, k2, f1.shape[-1])
    h = jnp.concatenate([gathered, g2x], axis=-1)
    h = _dense(h, p[6], p[7], m[3], False)
    h = _dense(h, p[8], p[9], m[4], False)
    h = _dense(h, p[10], p[11], m[5], False)
    f2 = h.max(axis=2)
    h = jnp.concatenate([f2, c2], axis=-1)
    h = _dense(h, p[12], p[13], m[6], False)
    h = _dense(h, p[14], p[15], m[7], False)
    return h.max(axis=1)

"""AOT compile path: lower the L2 train/eval steps + L1 kernels to HLO text.

Run once via `make artifacts` (python -m compile.aot --out-dir ../artifacts).
Emits one .hlo.txt per artifact plus manifest.txt describing the flattened
input/output signatures the Rust runtime validates against.

HLO *text* is the interchange format, NOT serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the `xla` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import hamming

# ---------------------------------------------------------------------------
# Static shapes baked into the artifacts (Rust mirrors these in
# rust/src/runtime/artifacts.rs — keep in sync).
# ---------------------------------------------------------------------------
MNIST_TRAIN_B = 64
MNIST_EVAL_B = 256
PN_TRAIN_B = 8
PN_EVAL_B = 32
PN_S1, PN_K1 = 64, 16
PN_S2, PN_K2 = 16, 8
SIM_K = 64  # max kernels compared per similarity call
SIM_BITS = 576  # max bit-width (conv3: 64ch * 3*3 = 576); pad with zeros

F32 = jnp.float32
I32 = jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def mnist_param_specs():
    c1, c2, c3 = model.MNIST_CHANNELS
    return (
        spec((c1, 1, 3, 3)),
        spec((c1,)),
        spec((c2, c1, 3, 3)),
        spec((c2,)),
        spec((c3, c2, 3, 3)),
        spec((c3,)),
        spec((model.MNIST_FC_IN, model.MNIST_CLASSES)),
        spec((model.MNIST_CLASSES,)),
    )


def mnist_mask_specs():
    c1, c2, c3 = model.MNIST_CHANNELS
    return (spec((c1,)), spec((c2,)), spec((c3,)))


def pn_param_specs():
    out = []
    for fi, fo in model.PN_LAYER_DIMS:
        out.append(spec((fi, fo)))
        out.append(spec((fo,)))
    return tuple(out)


def pn_mask_specs():
    return tuple(
        spec((model.PN_LAYER_DIMS[i][1],)) for i in range(model.PN_MASKED_LAYERS)
    )


def pn_group_specs(b):
    return (
        spec((b, PN_S1, PN_K1, 3)),  # g1_xyz
        spec((b, PN_S2, PN_K2), I32),  # g2_idx
        spec((b, PN_S2, PN_K2, 3)),  # g2_xyz
        spec((b, PN_S2, 3)),  # c2_xyz
    )


# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def artifact_table():
    """name -> (fn, arg_specs). All fns take/return flat pytrees whose
    flattened order is documented in model.py."""

    table = {}

    def add(name, fn, specs):
        table[name] = (fn, specs)

    for suffix, use_pallas in (("", True), ("_fast", False)):
        add(
            f"mnist_train{suffix}",
            functools.partial(model.mnist_train_step, use_pallas=use_pallas),
            (
                mnist_param_specs(),
                mnist_mask_specs(),
                spec((MNIST_TRAIN_B, 1, 28, 28)),
                spec((MNIST_TRAIN_B,), I32),
                spec(()),
            ),
        )
        add(
            f"mnist_eval{suffix}",
            functools.partial(model.mnist_eval_logits, use_pallas=use_pallas),
            (
                mnist_param_specs(),
                mnist_mask_specs(),
                spec((MNIST_EVAL_B, 1, 28, 28)),
            ),
        )
        add(
            f"pointnet_train{suffix}",
            functools.partial(model.pointnet_train_step, use_pallas=use_pallas),
            (
                pn_param_specs(),
                pn_mask_specs(),
                *pn_group_specs(PN_TRAIN_B),
                spec((PN_TRAIN_B,), I32),
                spec(()),
            ),
        )
        add(
            f"pointnet_eval{suffix}",
            functools.partial(model.pointnet_eval_logits, use_pallas=use_pallas),
            (
                pn_param_specs(),
                pn_mask_specs(),
                *pn_group_specs(PN_EVAL_B),
            ),
        )

    add(
        "mnist_features",
        functools.partial(model.mnist_features, use_pallas=False),
        (
            mnist_param_specs(),
            mnist_mask_specs(),
            spec((MNIST_EVAL_B, 1, 28, 28)),
        ),
    )
    add(
        "pointnet_features",
        model.pointnet_features,
        (pn_param_specs(), pn_mask_specs(), *pn_group_specs(PN_EVAL_B)),
    )
    # Search-in-memory: pairwise Hamming distance over bit-encoded kernels.
    add(
        "similarity",
        lambda bits: hamming.hamming_matrix(bits, bits),
        (spec((SIM_K, SIM_BITS), jnp.int8),),
    )
    return table


def _manifest_lines(name, specs, out_specs):
    flat_in, _ = jax.tree_util.tree_flatten(specs)
    flat_out, _ = jax.tree_util.tree_flatten(out_specs)

    def fmt(i, s):
        dims = ",".join(str(d) for d in s.shape) if s.shape else "scalar"
        return f"{i} {jnp.dtype(s.dtype).name} {dims}"

    lines = [f"artifact {name} file={name}.hlo.txt inputs={len(flat_in)} outputs={len(flat_out)}"]
    lines += [f"  in {fmt(i, s)}" for i, s in enumerate(flat_in)]
    lines += [f"  out {fmt(i, s)}" for i, s in enumerate(flat_out)]
    return lines


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    table = artifact_table()
    names = args.only.split(",") if args.only else list(table)
    manifest = []
    for name in names:
        fn, specs = table[name]
        print(f"[aot] lowering {name} ...", flush=True)
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        out_specs = jax.eval_shape(fn, *specs)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest += _manifest_lines(name, specs, out_specs)
        print(f"[aot]   wrote {path} ({len(text)} chars)", flush=True)
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"[aot] manifest: {len(names)} artifacts")


if __name__ == "__main__":
    main()

"""Layer-1 Pallas kernels: tiled matmul for binarized convolution.

The paper's chip computes binary convolution as in-array AND/XNOR logic
plus shift-and-add popcount (OUT = X AND (W (.) K), Fig. 3c). On a
TPU-shaped target the same insight — replace multiply with bit logic and
feed a wide reduction — maps onto the MXU as a sign-matmul over +-1
operands (dot(x,w) = 2*popcnt(XNOR) - n). The kernel below is the tiled
matmul that both the MNIST binary conv (via im2col) and the PointNet 1x1
conv lower onto.

BlockSpec schedule: grid (M/bm, N/bn, K/bk); the (bm,bk)x(bk,bn) tiles are
double-buffered HBM->VMEM by Pallas' pipeline; the f32 accumulator tile
lives in VMEM across the K-steps (revisiting semantics on the last grid
axis). Everything is lowered with interpret=True — the CPU PJRT client
cannot execute Mosaic custom-calls — so this code path is validated for
*numerics* on CPU and its TPU efficiency is estimated analytically in
DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM-friendly default tiles: 128x128 output tile + two 128x128 operand
# tiles = 3 * 64 KiB f32 << 16 MiB VMEM, and 128 matches the MXU lane width.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (bm, bn) output tile; accumulates over the K grid axis."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(x, multiple, axis):
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(a, b, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """Tiled Pallas matmul (f32): a (M,K) @ b (K,N) -> (M,N).

    Pads every dimension up to its tile multiple, then slices the result
    back down; zero-padding is exact for matmul.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch: {a.shape} @ {b.shape}"
    bm = min(bm, max(8, m))
    bn = min(bn, max(8, n))
    bk = min(bk, max(8, k))
    ap = _pad_to(_pad_to(a.astype(jnp.float32), bm, 0), bk, 1)
    bp = _pad_to(_pad_to(b.astype(jnp.float32), bk, 0), bn, 1)
    mp, kp = ap.shape
    _, np_ = bp.shape
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(ap, bp)
    return out[:m, :n]


def binary_matmul(a_pm, b_pm, **tiles):
    """Sign-domain matmul: operands are +-1 (already binarized).

    Equivalent to the chip's XNOR+popcount pipeline; see module docstring.
    """
    return matmul(a_pm.astype(jnp.float32), b_pm.astype(jnp.float32), **tiles)


def im2col(x, kh, kw, stride=1, pad=1):
    """im2col for NCHW input -> (N, OH*OW, C*KH*KW); mirrors ref.im2col_ref
    but uses dynamic slicing jit-friendly enough for the AOT path."""
    n, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            patch = xp[
                :, :, i : i + stride * oh : stride, j : j + stride * ow : stride
            ]
            cols.append(patch.reshape(n, c, oh * ow))
    stacked = jnp.stack(cols, axis=0).transpose(1, 3, 2, 0)
    return stacked.reshape(n, oh * ow, c * kh * kw), oh, ow


def conv2d(x, w, stride=1, pad=1, use_pallas=True):
    """Convolution (NCHW x OIHW) via im2col + the Pallas tiled matmul.

    With binarized `w` this is the software twin of the chip's CIM mode:
    one output tile per (image-patch block, kernel block) pair.
    """
    oc, ic, kh, kw = w.shape
    n = x.shape[0]
    cols, oh, ow = im2col(x, kh, kw, stride, pad)  # (N, P, CK)
    wmat = w.reshape(oc, ic * kh * kw).T  # (CK, OC)
    flat = cols.reshape(n * oh * ow, ic * kh * kw)
    if use_pallas:
        out = matmul(flat, wmat)
    else:
        out = flat @ wmat
    return out.reshape(n, oh * ow, oc).transpose(0, 2, 1).reshape(n, oc, oh, ow)

"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal for Layer 1: every Pallas kernel in
this package must match its `*_ref` twin bit-for-bit (integer ops) or to
float tolerance (matmul) across the pytest sweeps in python/tests/.
"""

import jax.numpy as jnp


def binarize_ref(w):
    """Deterministic sign binarization with sign(0) = +1 (paper's binary nets)."""
    return jnp.where(w >= 0, 1.0, -1.0).astype(w.dtype)


def matmul_ref(a, b):
    """Plain f32 matmul oracle for the tiled Pallas matmul."""
    return jnp.matmul(a, b)


def binary_matmul_ref(a_pm, b_pm):
    """Matmul over +-1 operands — what the digital CIM array computes via
    XNOR + popcount: dot(x, w) = 2 * popcnt(XNOR(x_bits, w_bits)) - n."""
    return jnp.matmul(a_pm.astype(jnp.float32), b_pm.astype(jnp.float32))


def xnor_popcount_ref(a_bits, b_bits):
    """Bit-domain formulation of binary_matmul: operands in {0,1}.
    Returns integer match counts; 2*matches - n equals the +-1 dot product."""
    a = a_bits.astype(jnp.int32)
    b = b_bits.astype(jnp.int32)
    # XNOR(a,b) = 1 - (a ^ b) = a*b + (1-a)*(1-b)
    matches = jnp.einsum("ik,jk->ij", a, b) + jnp.einsum(
        "ik,jk->ij", 1 - a, 1 - b
    )
    return matches


def hamming_ref(a_bits, b_bits):
    """Pairwise Hamming distance matrix D[i,j] = sum_k a[i,k] != b[j,k].

    This is the paper's search-in-memory primitive: the chip's XOR mode
    followed by the shift-and-add popcount.
    """
    n = a_bits.shape[-1]
    return n - xnor_popcount_ref(a_bits, b_bits)


def similarity_ref(a_bits, b_bits):
    """Normalized similarity s = 1 - d/n used by the pruning candidate list."""
    n = a_bits.shape[-1]
    return 1.0 - hamming_ref(a_bits, b_bits).astype(jnp.float32) / n


def im2col_ref(x, kh, kw, stride=1, pad=1):
    """im2col for NCHW input -> (N, OH*OW, C*KH*KW), C-major then (i,j)."""
    n, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            patch = xp[
                :, :, i : i + stride * oh : stride, j : j + stride * ow : stride
            ]
            cols.append(patch.reshape(n, c, oh * ow))
    stacked = jnp.stack(cols, axis=0)  # (KH*KW, N, C, P)
    stacked = stacked.transpose(1, 3, 2, 0)  # (N, P, C, KH*KW)
    return stacked.reshape(n, oh * ow, c * kh * kw), oh, ow


def conv2d_ref(x, w, stride=1, pad=1):
    """Reference conv (NCHW, OIHW) built on im2col + matmul."""
    oc, ic, kh, kw = w.shape
    cols, oh, ow = im2col_ref(x, kh, kw, stride, pad)
    wmat = w.reshape(oc, ic * kh * kw)
    out = jnp.einsum("npk,ok->nop", cols, wmat)
    return out.reshape(x.shape[0], oc, oh, ow)

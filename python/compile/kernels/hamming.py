"""Layer-1 Pallas kernel: pairwise XOR Hamming-distance (search-in-memory).

The chip's search-in-memory mode reads two weight rows through the
reconfigurable unit configured as XOR and popcounts the result with the
shift-and-add group — one kernel-pair distance per array pass. Here the
same computation is tiled for a vector unit: bit matrices A (Ka, n) and
B (Kb, n) in {0,1} produce D[i,j] = sum_k A[i,k] XOR B[j,k].

Tiling: grid over (Ka/bi, Kb/bj); each program holds an (bi, n) and a
(bj, n) slab in VMEM and materializes the (bi, bj, n) XOR cube only
per-tile, so VMEM stays bounded at bi*bj*n bytes (int8) regardless of the
number of kernels being compared.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BI = 32
DEFAULT_BJ = 32


def _hamming_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...].astype(jnp.int32)  # (bi, n)
    b = b_ref[...].astype(jnp.int32)  # (bj, n)
    # XOR over {0,1} == inequality; reduce the bit axis.
    diff = jnp.not_equal(a[:, None, :], b[None, :, :]).astype(jnp.int32)
    o_ref[...] = jnp.sum(diff, axis=2)


def _pad_rows(x, multiple):
    rem = (-x.shape[0]) % multiple
    if rem == 0:
        return x
    return jnp.pad(x, ((0, rem), (0, 0)))


@functools.partial(jax.jit, static_argnames=("bi", "bj"))
def hamming_matrix(a_bits, b_bits, bi=DEFAULT_BI, bj=DEFAULT_BJ):
    """Pairwise Hamming distances between rows of two {0,1} bit matrices.

    a_bits: (Ka, n) int8/int32 in {0,1};  b_bits: (Kb, n).
    Returns (Ka, Kb) int32. Row-padding with zeros is sliced back off —
    padded rows only ever produce distances that are discarded.
    """
    ka, n = a_bits.shape
    kb, n2 = b_bits.shape
    assert n == n2, f"bit-width mismatch: {a_bits.shape} vs {b_bits.shape}"
    bi = min(bi, max(1, ka))
    bj = min(bj, max(1, kb))
    ap = _pad_rows(a_bits.astype(jnp.int8), bi)
    bp = _pad_rows(b_bits.astype(jnp.int8), bj)
    grid = (ap.shape[0] // bi, bp.shape[0] // bj)
    out = pl.pallas_call(
        _hamming_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, n), lambda i, j: (i, 0)),
            pl.BlockSpec((bj, n), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((ap.shape[0], bp.shape[0]), jnp.int32),
        interpret=True,
    )(ap, bp)
    return out[:ka, :kb]


def similarity_matrix(bits, bi=DEFAULT_BI, bj=DEFAULT_BJ):
    """Self-similarity s = 1 - d/n over a set of bit-encoded kernels.

    This is exactly what the pruning scheduler consumes: Fig. 4b/4d.
    """
    n = bits.shape[-1]
    d = hamming_matrix(bits, bits, bi=bi, bj=bj)
    return 1.0 - d.astype(jnp.float32) / n

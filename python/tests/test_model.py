"""L2 correctness: model shapes, mask semantics, training behaviour."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model

jax.config.update("jax_platform_name", "cpu")

KEY = jax.random.PRNGKey(0)


def mnist_setup(batch=8):
    params = model.mnist_init(KEY)
    masks = (jnp.ones(32), jnp.ones(64), jnp.ones(32))
    x = jax.random.uniform(KEY, (batch, 1, 28, 28))
    y = jax.random.randint(KEY, (batch,), 0, 10)
    return params, masks, x, y


def pn_setup(batch=4):
    params = model.pointnet_init(KEY)
    masks = tuple(
        jnp.ones((model.PN_LAYER_DIMS[i][1],)) for i in range(model.PN_MASKED_LAYERS)
    )
    s1, k1, s2, k2 = 16, 8, 8, 4
    g1 = jax.random.normal(KEY, (batch, s1, k1, 3))
    g2i = jax.random.randint(KEY, (batch, s2, k2), 0, s1)
    g2x = jax.random.normal(KEY, (batch, s2, k2, 3))
    c2 = jax.random.normal(KEY, (batch, s2, 3))
    y = jax.random.randint(KEY, (batch,), 0, 10)
    return params, masks, g1, g2i, g2x, c2, y


# ---------------------------------------------------------------------------
# MNIST
# ---------------------------------------------------------------------------


def test_mnist_forward_shape():
    params, masks, x, _ = mnist_setup()
    logits = model.mnist_forward(params, masks, x, use_pallas=False)
    assert logits.shape == (8, 10)
    assert np.isfinite(np.asarray(logits)).all()


def test_mnist_initial_loss_near_chance():
    params, masks, x, y = mnist_setup(32)
    loss, _ = model.mnist_loss(params, masks, x, y, use_pallas=False)
    assert 1.0 < float(loss) < 6.0  # ~ln(10)=2.3 plus binarization noise


def test_mnist_pruned_kernel_is_inert():
    """Zeroing mask channel c must make the output invariant to w[c] —
    the RRAM rows of a pruned kernel are never addressed."""
    params, masks, x, _ = mnist_setup()
    m1 = masks[0].at[3].set(0.0)
    masks2 = (m1, masks[1], masks[2])
    out1 = model.mnist_forward(params, masks2, x, use_pallas=False)
    p2 = list(params)
    p2[0] = params[0].at[3].set(jax.random.normal(KEY, (1, 3, 3)) * 100.0)
    out2 = model.mnist_forward(tuple(p2), masks2, x, use_pallas=False)
    np.testing.assert_allclose(out1, out2, rtol=1e-5, atol=1e-5)


def test_mnist_train_step_freezes_pruned_kernels():
    params, masks, x, y = mnist_setup()
    m1 = masks[0].at[5].set(0.0)
    masks2 = (m1, masks[1], masks[2])
    new_params, loss, _ = model.mnist_train_step(
        params, masks2, x, y, jnp.float32(0.1), use_pallas=False
    )
    # pruned kernel 5 untouched; a live kernel must have moved
    np.testing.assert_array_equal(np.asarray(new_params[0][5]), np.asarray(params[0][5]))
    assert not np.allclose(np.asarray(new_params[0][0]), np.asarray(params[0][0]))
    assert np.isfinite(float(loss))


def test_mnist_training_reduces_loss():
    params, masks, x, y = mnist_setup(32)
    step = jax.jit(
        lambda p, m, x, y, lr: model.mnist_train_step(p, m, x, y, lr, use_pallas=False)
    )
    loss0 = None
    p = params
    for i in range(25):
        p, loss, _ = step(p, masks, x, y, jnp.float32(0.05))
        if loss0 is None:
            loss0 = float(loss)
    assert float(loss) < loss0 * 0.7, (loss0, float(loss))


def test_mnist_pallas_and_plain_forward_agree():
    params, masks, x, _ = mnist_setup(2)
    a = model.mnist_forward(params, masks, x, use_pallas=True)
    b = model.mnist_forward(params, masks, x, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-3)


def test_mnist_features_shape():
    params, masks, x, _ = mnist_setup()
    f = model.mnist_features(params, masks, x)
    assert f.shape == (8, model.MNIST_FC_IN)


# ---------------------------------------------------------------------------
# PointNet
# ---------------------------------------------------------------------------


def test_pointnet_forward_shape():
    params, masks, g1, g2i, g2x, c2, _ = pn_setup()
    logits = model.pointnet_forward(params, masks, g1, g2i, g2x, c2, use_pallas=False)
    assert logits.shape == (4, 10)
    assert np.isfinite(np.asarray(logits)).all()


def test_pointnet_pruned_filter_is_inert():
    params, masks, g1, g2i, g2x, c2, _ = pn_setup()
    m = list(masks)
    m[2] = m[2].at[7].set(0.0)  # prune SA1 layer-3 output channel 7
    out1 = model.pointnet_forward(params, tuple(m), g1, g2i, g2x, c2, use_pallas=False)
    p2 = list(params)
    p2[4] = params[4].at[:, 7].set(99.0)  # column 7 of (32,64) weight
    p2[5] = params[5].at[7].set(-42.0)
    out2 = model.pointnet_forward(tuple(p2), tuple(m), g1, g2i, g2x, c2, use_pallas=False)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-5, atol=1e-5)


def test_pointnet_train_step_freezes_pruned_filters():
    params, masks, g1, g2i, g2x, c2, y = pn_setup()
    m = list(masks)
    m[0] = m[0].at[1].set(0.0)
    new_params, loss, _ = model.pointnet_train_step(
        params, tuple(m), g1, g2i, g2x, c2, y, jnp.float32(0.05), use_pallas=False
    )
    np.testing.assert_array_equal(
        np.asarray(new_params[0][:, 1]), np.asarray(params[0][:, 1])
    )
    assert np.isfinite(float(loss))


def test_pointnet_training_reduces_loss():
    params, masks, g1, g2i, g2x, c2, y = pn_setup(8)
    step = jax.jit(
        lambda p, m, *a: model.pointnet_train_step(p, m, *a, use_pallas=False)
    )
    p = params
    loss0 = None
    for i in range(25):
        p, loss, _ = step(p, masks, g1, g2i, g2x, c2, y, jnp.float32(0.05))
        if loss0 is None:
            loss0 = float(loss)
    assert float(loss) < loss0 * 0.7


def test_pointnet_features_shape():
    params, masks, g1, g2i, g2x, c2, _ = pn_setup()
    f = model.pointnet_features(params, masks, g1, g2i, g2x, c2)
    assert f.shape == (4, 256)


def test_fake_quant_int8_levels():
    w = jax.random.normal(KEY, (64, 64))
    wq = model.fake_quant_int8_ste(w)
    scale = float(jnp.max(jnp.abs(w))) / 127.0
    levels = np.unique(np.round(np.asarray(wq) / scale))
    assert len(levels) <= 256
    np.testing.assert_allclose(levels, np.round(levels), atol=1e-4)

"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; assert_allclose (float) or exact equality
(integer bit ops) against ref.py. This is the core correctness signal for
the kernels that end up inside every AOT artifact.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import binary_conv as bc
from compile.kernels import hamming, ref

jax.config.update("jax_platform_name", "cpu")


def rng_array(seed, shape, dtype=np.float32, bits=False):
    r = np.random.default_rng(seed)
    if bits:
        return r.integers(0, 2, size=shape).astype(np.int8)
    return r.standard_normal(shape).astype(dtype)


# ---------------------------------------------------------------------------
# Tiled Pallas matmul
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 97),
    k=st.integers(1, 70),
    n=st.integers(1, 50),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    a = rng_array(seed, (m, k))
    b = rng_array(seed + 1, (k, n))
    out = bc.matmul(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(out, a @ b, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 32, 8), (128, 128, 128)])
def test_matmul_tile_invariance(bm, bn, bk):
    """Result must not depend on the BlockSpec tiling choice."""
    a = rng_array(7, (33, 29))
    b = rng_array(8, (29, 41))
    out = bc.matmul(jnp.asarray(a), jnp.asarray(b), bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(out, a @ b, rtol=1e-4, atol=1e-4)


def test_binary_matmul_equals_xnor_popcount_identity():
    """dot(x, w) over +-1 == 2*matches - n: the chip's XNOR+popcount rule."""
    r = np.random.default_rng(3)
    a_bits = r.integers(0, 2, size=(13, 57)).astype(np.int8)
    b_bits = r.integers(0, 2, size=(9, 57)).astype(np.int8)
    a_pm = (2 * a_bits - 1).astype(np.float32)
    b_pm = (2 * b_bits - 1).astype(np.float32)
    dot = bc.binary_matmul(jnp.asarray(a_pm), jnp.asarray(b_pm).T)
    matches = np.asarray(ref.xnor_popcount_ref(jnp.asarray(a_bits), jnp.asarray(b_bits)))
    np.testing.assert_allclose(dot, 2 * matches - 57, atol=1e-3)


# ---------------------------------------------------------------------------
# Hamming / similarity (search-in-memory)
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    ka=st.integers(1, 70),
    kb=st.integers(1, 70),
    n=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_hamming_matches_ref(ka, kb, n, seed):
    a = rng_array(seed, (ka, n), bits=True)
    b = rng_array(seed + 1, (kb, n), bits=True)
    d = hamming.hamming_matrix(jnp.asarray(a), jnp.asarray(b))
    expected = np.asarray(ref.hamming_ref(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(np.asarray(d), expected)


def test_hamming_properties():
    a = rng_array(11, (20, 64), bits=True)
    d = np.asarray(hamming.hamming_matrix(jnp.asarray(a), jnp.asarray(a)))
    # identity: d(i,i) = 0
    assert (np.diag(d) == 0).all()
    # symmetry
    np.testing.assert_array_equal(d, d.T)
    # bounds
    assert d.min() >= 0 and d.max() <= 64


def test_hamming_zero_padding_invariance():
    """Padding both operands with zero bits must not change distances —
    this is what lets one fixed-shape artifact serve all layers."""
    a = rng_array(5, (10, 30), bits=True)
    b = rng_array(6, (8, 30), bits=True)
    d1 = np.asarray(hamming.hamming_matrix(jnp.asarray(a), jnp.asarray(b)))
    ap = np.pad(a, ((0, 0), (0, 34)))
    bp = np.pad(b, ((0, 0), (0, 34)))
    d2 = np.asarray(hamming.hamming_matrix(jnp.asarray(ap), jnp.asarray(bp)))
    np.testing.assert_array_equal(d1, d2)


def test_similarity_range_and_self():
    a = rng_array(12, (16, 90), bits=True)
    s = np.asarray(hamming.similarity_matrix(jnp.asarray(a)))
    assert np.allclose(np.diag(s), 1.0)
    assert (s >= 0.0).all() and (s <= 1.0).all()


# ---------------------------------------------------------------------------
# Convolution path
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(1, 3),
    c=st.integers(1, 4),
    hw=st.sampled_from([6, 8, 12]),
    oc=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_matches_ref(n, c, hw, oc, seed):
    x = rng_array(seed, (n, c, hw, hw))
    w = rng_array(seed + 1, (oc, c, 3, 3))
    out = bc.conv2d(jnp.asarray(x), jnp.asarray(w))
    expected = np.asarray(ref.conv2d_ref(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_allclose(out, expected, rtol=1e-4, atol=1e-4)


def test_im2col_matches_ref():
    x = rng_array(2, (2, 3, 8, 8))
    got, oh, ow = bc.im2col(jnp.asarray(x), 3, 3)
    want, oh2, ow2 = ref.im2col_ref(jnp.asarray(x), 3, 3)
    assert (oh, ow) == (oh2, ow2) == (8, 8)
    np.testing.assert_allclose(got, want)


def test_conv2d_pallas_vs_plain():
    x = rng_array(9, (2, 4, 10, 10))
    w = rng_array(10, (6, 4, 3, 3))
    a = bc.conv2d(jnp.asarray(x), jnp.asarray(w), use_pallas=True)
    b = bc.conv2d(jnp.asarray(x), jnp.asarray(w), use_pallas=False)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

"""AOT pipeline sanity: artifact table lowers, manifest matches eval_shape."""

import jax
import jax.numpy as jnp

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


def test_artifact_table_complete():
    table = aot.artifact_table()
    expected = {
        "mnist_train", "mnist_eval", "mnist_train_fast", "mnist_eval_fast",
        "pointnet_train", "pointnet_eval", "pointnet_train_fast",
        "pointnet_eval_fast", "mnist_features", "pointnet_features",
        "similarity",
    }
    assert expected <= set(table)


def test_mnist_train_signature():
    fn, specs = aot.artifact_table()["mnist_train"]
    flat, _ = jax.tree_util.tree_flatten(specs)
    # 8 params + 3 masks + x + y + lr
    assert len(flat) == 14
    out = jax.eval_shape(fn, *specs)
    flat_out, _ = jax.tree_util.tree_flatten(out)
    assert len(flat_out) == 10  # 8 new params + loss + correct


def test_pointnet_train_signature():
    fn, specs = aot.artifact_table()["pointnet_train"]
    flat, _ = jax.tree_util.tree_flatten(specs)
    # 20 params + 8 masks + 4 group tensors + y + lr
    assert len(flat) == 34
    out = jax.eval_shape(fn, *specs)
    flat_out, _ = jax.tree_util.tree_flatten(out)
    assert len(flat_out) == 22  # 20 params + loss + correct


def test_similarity_lowering_roundtrip():
    fn, specs = aot.artifact_table()["similarity"]
    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_manifest_lines_format():
    fn, specs = aot.artifact_table()["similarity"]
    out = jax.eval_shape(fn, *specs)
    lines = aot._manifest_lines("similarity", specs, out)
    assert lines[0].startswith("artifact similarity file=similarity.hlo.txt")
    assert "inputs=1" in lines[0] and "outputs=1" in lines[0]
    assert lines[1].strip() == f"in 0 int8 {aot.SIM_K},{aot.SIM_BITS}"
    assert lines[2].strip() == f"out 0 int32 {aot.SIM_K},{aot.SIM_K}"


def test_sim_bits_covers_all_mnist_layers():
    """SIM_BITS must be >= the largest binarized-kernel bit width."""
    c1, c2, c3 = model.MNIST_CHANNELS
    widths = [1 * 9, c1 * 9, c2 * 9]
    assert max(widths) <= aot.SIM_BITS
    assert max(model.MNIST_CHANNELS) <= aot.SIM_K


def test_eval_batch_shapes():
    fn, specs = aot.artifact_table()["mnist_eval"]
    flat, _ = jax.tree_util.tree_flatten(specs)
    assert flat[-1].shape == (aot.MNIST_EVAL_B, 1, 28, 28)
    out = jax.eval_shape(fn, *specs)
    flat_out, _ = jax.tree_util.tree_flatten(out)
    assert flat_out[0].shape == (aot.MNIST_EVAL_B, 10)

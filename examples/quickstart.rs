//! Quickstart: the whole stack in one file.
//!
//! 1. fabricate + form the digital RRAM chip,
//! 2. run reconfigurable logic (Fig. 3c) in-memory,
//! 3. compute a kernel-similarity matrix three ways — chip
//!    search-in-memory, bit-packed software, and the AOT Pallas
//!    `similarity` artifact — and check they agree bit-for-bit,
//! 4. run a binary-weight dot product on the chip and against the
//!    integer reference.
//!
//! Run with: `cargo run --release --example quickstart`

// Terminal output is this target's product; the serve-code print ban
// (workspace clippy.toml `disallowed-macros`) deliberately does not
// apply outside `rust/src/serve/**`.
#![allow(clippy::disallowed_macros)]

use rram_cim::cim::mapping::RowAllocator;
use rram_cim::cim::{similarity as chip_sim, vmm};
use rram_cim::nn::quant;
use rram_cim::prelude::*;
use rram_cim::pruning::similarity::PackedKernels;

fn main() -> anyhow::Result<()> {
    rram_cim::util::logging::init();
    let mut rng = Rng::new(42);

    // --- 1. the chip ---
    let mut chip = Chip::new(ChipConfig::default(), &mut rng);
    let yields = chip.form();
    println!("chip formed: 2x 512x32 1T1R blocks, yields {yields:?}");

    // --- 2. reconfigurable logic ---
    let n = 8;
    for col in 0..n {
        chip.program_bit(0, 0, col, col % 2 == 0);
    }
    let x = vec![true; n];
    let k: Vec<bool> = (0..n).map(|c| c < 4).collect();
    for op in LogicOp::ALL {
        let out = chip.logic_pass(0, 0, op, &x, &k, false);
        println!(
            "{:<5} W=10101010 K=11110000 -> {:?}",
            op.name(),
            out[..n].iter().map(|&b| b as u8).collect::<Vec<_>>()
        );
    }

    // --- 3. similarity three ways ---
    let kernels: Vec<Vec<f32>> = (0..8)
        .map(|i| (0..64).map(|j| ((i * j + i) % 5) as f32 - 2.0).collect())
        .collect();
    let live = vec![true; 8];

    // (a) chip search-in-memory
    let mut alloc = RowAllocator::for_chip(&chip);
    let stored = chip_sim::store_kernels(&mut chip, &mut alloc, &kernels);
    let m_chip = chip_sim::similarity_matrix(&mut chip, &stored, &live);

    // (b) bit-packed software
    let m_sw = PackedKernels::from_kernels(&kernels).similarity_matrix(&live);

    // (c) the AOT Pallas artifact (XOR Hamming kernel lowered from
    //     python/compile/kernels/hamming.py)
    let mut engine = Engine::open_default()?;
    let spec = engine.manifest().get("similarity").unwrap().clone();
    let (kmax, nbits) = (spec.inputs[0].dims[0], spec.inputs[0].dims[1]);
    let mut bits = vec![0i8; kmax * nbits];
    for (i, kr) in kernels.iter().enumerate() {
        for (j, &w) in kr.iter().enumerate() {
            bits[i * nbits + j] = (w >= 0.0) as i8;
        }
    }
    let outs = engine.run("similarity", &[HostTensor::I8(bits, vec![kmax, nbits])])?;
    let d_pallas = outs[0].expect_i32("similarity");

    let mut all_equal = true;
    for i in 0..8 {
        for j in 0..8 {
            let d = m_chip.distance(i, j);
            all_equal &= d == m_sw.distance(i, j);
            all_equal &= d == d_pallas[i * kmax + j] as u32;
        }
    }
    println!(
        "\nsimilarity agreement (chip == software == Pallas artifact): {}",
        if all_equal { "EXACT" } else { "MISMATCH!" }
    );
    assert!(all_equal);

    // --- 4. binary dot product on-chip ---
    let kernel: Vec<f32> = (0..32).map(|i| if i % 3 == 0 { 0.8 } else { -0.6 }).collect();
    let (bitsv, alpha) = quant::binarize_kernel(&kernel);
    let xs: Vec<u8> = (0..32).map(|i| (i * 7 % 256) as u8).collect();
    let span = alloc.alloc(bitsv.len()).unwrap();
    rram_cim::cim::mapping::store_bits(&mut chip, &span, &bitsv);
    let got = vmm::binary_dot_u8(&mut chip, &span, &xs);
    let want = rram_cim::nn::layers::binary_mac_ref(&bitsv, &xs);
    println!(
        "binary dot on chip: {got} (reference {want}, alpha {alpha:.3}) — {}",
        if got == want { "EXACT" } else { "MISMATCH" }
    );
    assert_eq!(got, want);

    let b = chip.energy_breakdown();
    let shares = b.shares();
    println!(
        "\nchip energy: {:.2} uJ total; top consumers: {} {:.1}%, {} {:.1}%",
        b.total_pj() * 1e-6,
        shares[0].0,
        100.0 * shares[0].1,
        shares[1].0,
        100.0 * shares[1].1
    );
    println!("quickstart OK");
    Ok(())
}

//! Mixed-tenancy serving demo: ONE 4-chip pool serving the paper's BOTH
//! headline workloads concurrently — a pruned binary-MNIST CNN and a
//! pruned INT8 PointNet — through the multi-tenant engine: per-tenant
//! bounded queues with deficit-round-robin fairness, a bit-exact result
//! cache, and live wear rebalancing (shards migrate to the least-worn
//! chip mid-run, with every answered logit still bit-exact against the
//! respective software reference).
//!
//! Phase 2 repeats the run on a pool with 5x the stuck-cell fault rate:
//! placement and migration route around stuck tiles and the bit-exact
//! guarantee must hold unchanged.
//!
//! Run with: `cargo run --release --example mixed_serving`

// Terminal output is this target's product; the serve-code print ban
// (workspace clippy.toml `disallowed-macros`) deliberately does not
// apply outside `rust/src/serve/**`.
#![allow(clippy::disallowed_macros)]

use rram_cim::bench::print_table;
use rram_cim::nn::data::{mnist, modelnet, Dataset};
use rram_cim::nn::pointnet::GroupingConfig;
use rram_cim::serve::{
    AdmissionConfig, CacheConfig, Engine, EngineConfig, EngineReport, ModelBundle, PointNetBundle,
    PoolConfig, RebalanceConfig, Response, TenantConfig,
};
use std::sync::mpsc::Receiver;
use std::time::Duration;

struct Workload<'a> {
    name: &'a str,
    inputs: &'a Dataset,
    /// Reference logits per distinct input (memoized once: serving
    /// repeats inputs to earn cache hits, the oracle shouldn't recompute).
    references: Vec<Vec<f32>>,
}

fn run_phase(
    label: &str,
    stuck_fault_prob: f64,
    seed: u64,
    loads: &[Workload<'_>; 2],
    tenants: Vec<TenantConfig>,
) -> anyhow::Result<EngineReport> {
    let mut cfg = EngineConfig {
        pool: PoolConfig { chips: 4, seed, ..PoolConfig::default() },
        admission: AdmissionConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            quantum: 8,
        },
        cache: CacheConfig { capacity: 256 },
        // every 3 chip batches: diff wear snapshots, migrate up to 2 of
        // the hottest shards to the least-worn chip
        rebalance: RebalanceConfig { every_batches: 3, max_moves: 2, group_moves: 0 },
        prune: Default::default(),
        cam: Default::default(),
        obs: true,
    };
    cfg.pool.chip.device.stuck_fault_prob = stuck_fault_prob;
    let engine = Engine::start(tenants, &cfg)?;
    let ids: Vec<usize> =
        loads.iter().map(|w| engine.tenant(w.name).expect("tenant registered")).collect();

    let mut attempts = [0u64; 2];
    let mut shed = [0u64; 2];
    let mut exact = 0u64;
    let mut check = |wi: usize, which: usize, resp: Response| {
        assert_eq!(
            resp.logits, loads[wi].references[which],
            "{label}: tenant {} input {which} diverged from its software reference",
            loads[wi].name
        );
        exact += 1;
    };

    // --- warm round: sequential submit-recv pairs per distinct input.
    // The second of each pair is (usually) a cache hit; the first few
    // are guaranteed hits because no rebalance can fire that early.
    // These single-request batches also advance the rebalance clock.
    for (wi, load) in loads.iter().enumerate() {
        let warm = (load.inputs.len() / 2).max(1);
        for which in 0..warm {
            for _ in 0..2 {
                attempts[wi] += 1;
                let resp = engine.submit(ids[wi], load.inputs.sample(which).to_vec()).recv()?;
                check(wi, which, resp);
            }
        }
    }

    // --- burst round: the rest of the traffic interleaved through
    // non-blocking submits; a full tenant queue sheds (counted per
    // tenant), admitted requests are answered bit-exactly
    let mut pending: Vec<(usize, usize, Receiver<Response>)> = Vec::new();
    for _ in 0..2 {
        for (wi, load) in loads.iter().enumerate() {
            let warm = (load.inputs.len() / 2).max(1);
            for which in warm..load.inputs.len() {
                attempts[wi] += 1;
                match engine.try_submit(ids[wi], load.inputs.sample(which).to_vec()) {
                    Ok(rx) => pending.push((wi, which, rx)),
                    Err(_) => shed[wi] += 1,
                }
            }
        }
    }
    for (wi, which, rx) in pending {
        let resp = rx.recv()?;
        check(wi, which, resp);
    }
    let report = engine.shutdown();

    println!("\n=== {label} ===");
    println!(
        "{exact} answered responses, every one bit-exact; \
         {} rebalance passes migrated {} shards mid-run",
        report.rebalances, report.shards_moved
    );
    let rows: Vec<Vec<String>> = report
        .tenants
        .iter()
        .enumerate()
        .map(|(ti, t)| {
            vec![
                t.name.clone(),
                attempts[ti].to_string(),
                t.answered.to_string(),
                t.dropped.to_string(),
                t.cache_hits.to_string(),
                t.chip_batches.to_string(),
                format!("{:.2}", t.latency.p50_ms()),
                format!("{:.2}", t.latency.p99_ms()),
            ]
        })
        .collect();
    print_table(
        &format!("{label}: per-tenant stats"),
        &[
            "tenant",
            "attempts",
            "answered",
            "dropped",
            "cache hits",
            "chip batches",
            "p50 ms",
            "p99 ms",
        ],
        &rows,
    );
    let wear_rows: Vec<Vec<String>> = report
        .wear
        .iter()
        .enumerate()
        .map(|(i, w)| {
            vec![
                format!("chip {i}"),
                report.rows_used[i].to_string(),
                w.write_pulses.to_string(),
                w.wl_activations.to_string(),
            ]
        })
        .collect();
    print_table(
        &format!("{label}: per-chip rows + lifetime wear"),
        &["chip", "rows used", "write pulses", "WL activations"],
        &wear_rows,
    );
    if report.stuck_retries > 0 {
        println!("(placement/migration routed around {} stuck tiles)", report.stuck_retries);
    }

    // accounting invariant: nothing is silently lost
    for (ti, t) in report.tenants.iter().enumerate() {
        assert_eq!(
            t.answered + t.dropped,
            attempts[ti],
            "{label}: tenant {} answered + dropped must partition its attempts",
            t.name
        );
        assert_eq!(t.dropped, shed[ti], "{label}: tenant {} shed accounting", t.name);
    }
    assert!(
        report.rebalances >= 1 && report.shards_moved >= 1,
        "{label}: expected at least one wear-triggered rebalance mid-run"
    );
    assert!(report.cache_hits() > 0, "{label}: repeated inputs must hit the cache");
    Ok(report)
}

fn main() -> anyhow::Result<()> {
    rram_cim::util::logging::init();

    // --- the two tenants ---
    // a ~35%-pruned 32-64-32 binary CNN (~870 rows) and a half-pruned
    // INT8 PointNet (4 cells/weight); together they fit the 4-chip pool
    // (3968 rows) with room for the rebalancer to migrate into
    let mnist_model = ModelBundle::synthetic_mnist([32, 64, 32], 0.35, 42);
    let grouping = GroupingConfig { s1: 32, k1: 8, r1: 0.25, s2: 8, k2: 4, r2: 0.5 };
    let pn_model: ModelBundle =
        PointNetBundle::synthetic([16, 16, 32, 32, 32, 64, 64, 128], 64, 0.5, grouping, 43).into();
    println!(
        "tenant mnist:    {}/{} live filters, {} rows @ 30 data cols",
        mnist_model.live_filters(),
        mnist_model.total_filters(),
        mnist_model.rows_required(30)
    );
    println!(
        "tenant pointnet: {}/{} live channels, {} rows @ 30 data cols",
        pn_model.live_filters(),
        pn_model.total_filters(),
        pn_model.rows_required(30)
    );

    // --- traffic: a handful of distinct inputs, each served repeatedly
    let images = mnist::generate(24, 0x5eed);
    let clouds = modelnet::generate(8, 0xc10d);
    let mnist_refs: Vec<Vec<f32>> =
        (0..images.len()).map(|i| mnist_model.reference_logits(images.sample(i))).collect();
    let pn_refs: Vec<Vec<f32>> =
        (0..clouds.len()).map(|i| pn_model.reference_logits(clouds.sample(i))).collect();
    let loads = [
        Workload { name: "mnist", inputs: &images, references: mnist_refs },
        Workload { name: "pointnet", inputs: &clouds, references: pn_refs },
    ];
    let tenants = || {
        vec![
            TenantConfig::new("mnist", mnist_model.clone())
                .with_row_quota(1400)
                .with_queue_depth(64),
            TenantConfig::new("pointnet", pn_model.clone())
                .with_row_quota(2200)
                .with_queue_depth(32),
        ]
    };

    // phase 1: the default fault rate (0.2% stuck cells)
    run_phase("phase 1: default fault rate", 0.002, 0x9e11, &loads, tenants())?;

    // phase 2: 5x stuck-tile pressure — ECC + stuck-tile rerouting keep
    // every answered logit bit-exact through placement AND migration
    run_phase("phase 2: 5x stuck-tile fault injection", 0.01, 0x9e12, &loads, tenants())?;

    println!("\nmixed-tenancy serving OK: one pool, two workloads, zero wrong logits");
    Ok(())
}

//! End-to-end driver (paper Fig. 5): PointNet on synthetic ModelNet10
//! with dynamic 1x1-conv filter pruning and the INT8 four-cell chip
//! mapping. Prints the SUN/SPN/HPN comparison (Fig. 5g), MAC precision
//! (Fig. 5h), op reduction and energy rows (Fig. 5i).
//!
//!   cargo run --release --example pointnet_pruning [--mode spn] [--epochs N] [--tsne]

// Terminal output is this target's product; the serve-code print ban
// (workspace clippy.toml `disallowed-macros`) deliberately does not
// apply outside `rust/src/serve/**`.
#![allow(clippy::disallowed_macros)]

use rram_cim::bench::{print_series, print_table};
use rram_cim::metrics::energy_comparison;
use rram_cim::nn::tsne::{separation_score, tsne, TsneConfig};
use rram_cim::prelude::*;
use rram_cim::util::args::Args;

fn run_mode(
    mode: TrainMode,
    epochs: usize,
    tsne_check: bool,
) -> anyhow::Result<rram_cim::coordinator::TrainingReport> {
    let engine = Engine::open_default()?;
    let cfg = PointNetConfig { epochs, mode, ..PointNetConfig::default() };
    let mut trainer = PointNetTrainer::new(cfg, engine);
    let before = if tsne_check { Some(trainer.features()?) } else { None };
    let report = trainer.train()?;

    println!("\n--- {} ---", mode.name());
    print_series("loss", &report.epochs.iter().map(|e| e.loss).collect::<Vec<_>>());
    print_series(
        "test accuracy",
        &report.epochs.iter().map(|e| e.test_acc).collect::<Vec<_>>(),
    );
    print_series(
        "live filters",
        &report.epochs.iter().map(|e| e.live_kernels as f64).collect::<Vec<_>>(),
    );
    if mode == TrainMode::Hpn {
        if let Some(last) = report.epochs.last() {
            println!("INT8 MAC precision per on-chip layer (Fig. 5h): {:?}", last.mac_precision);
        }
    }
    println!(
        "final acc {:.2}%  prune rate {:.2}%  train-op reduction {:.2}%",
        100.0 * report.final_test_acc(),
        100.0 * report.final_prune_rate,
        100.0 * report.train_ops_reduction()
    );

    if let Some((feats_b, labels)) = before {
        let (feats_a, _) = trainer.features()?;
        let n = labels.len();
        let d = feats_b.len() / n;
        let cfg = TsneConfig { iters: 400, ..TsneConfig::default() };
        let sb = separation_score(&tsne(&feats_b, n, d, &cfg), &labels, 10);
        let sa = separation_score(&tsne(&feats_a, n, d, &cfg), &labels, 10);
        println!("t-SNE separation (Fig. 5d/e): before {sb:.2} -> after {sa:.2}");
    }
    Ok(report)
}

fn main() -> anyhow::Result<()> {
    rram_cim::util::logging::init();
    let args = Args::from_env(1).map_err(anyhow::Error::msg)?;
    let epochs: usize = args.parse_or("epochs", 12).map_err(anyhow::Error::msg)?;
    let tsne_check = args.flag("tsne");

    let modes: Vec<TrainMode> = match args.get("mode") {
        Some("sun") => vec![TrainMode::Sun],
        Some("spn") => vec![TrainMode::Spn],
        Some("hpn") => vec![TrainMode::Hpn],
        _ => vec![TrainMode::Sun, TrainMode::Spn, TrainMode::Hpn],
    };

    let mut rows = Vec::new();
    let mut pruned_report = None;
    for &mode in &modes {
        let rep = run_mode(mode, epochs, tsne_check)?;
        rows.push(vec![
            mode.name().to_string(),
            format!("{:.2}%", 100.0 * rep.final_test_acc()),
            format!("{:.2}%", 100.0 * rep.final_prune_rate),
            format!("{:.2}%", 100.0 * rep.train_ops_reduction()),
        ]);
        if mode.prunes() {
            pruned_report = Some(rep);
        }
    }
    print_table(
        "Fig. 5g: accuracy by training mode (paper: SUN 79.85 / SPN 82.16 / HPN 77.75 @ 57.13%)",
        &["mode", "test acc", "prune rate", "train-op reduction"],
        &rows,
    );

    if let Some(rep) = pruned_report {
        let rows: Vec<Vec<String>> = energy_comparison(
            rep.macs_unpruned,
            rep.macs_pruned,
            false, // INT8 mapping
            rram_cim::baselines::gpu::GpuWorkloadClass::PointCloud,
            32,
        )
        .iter()
        .map(|r| vec![r.platform.clone(), format!("{:.3}", r.energy_uj)])
        .collect();
        print_table(
            "Fig. 5i: per-cloud conv inference energy",
            &["platform", "energy (uJ)"],
            &rows,
        );
    }
    Ok(())
}

//! Input-aware CAM serving demo: the similarity front end (DESIGN.md
//! §14) in front of a duplicate-heavy stream — the same XOR/popcount
//! primitive the paper uses to rank redundant kernels for pruning,
//! pointed at incoming *requests*. Every input is quantized and packed
//! with the chip's own packing and probed against a bounded CAM of
//! recently answered inputs; exact repeats replay byte-verified cached
//! logits without touching silicon, near-duplicates identify themselves
//! before dispatch.
//!
//! Two tenants make the policy split concrete:
//!
//! * `strict` runs the default [`VerifyPolicy::Exact`]: near hits are
//!   recomputed and only *compared*, so the run asserts **zero wrong
//!   logits** — bit-exact against `reference_logits` on all answers —
//!   while still reporting how many requests the CAM identified.
//! * `trusted` opts into `VerifyPolicy::Trusted` (always reported):
//!   near hits serve straight from the cached neighbor, with a
//!   deterministic 1-in-8 audit against the declared logit-delta bound.
//!
//! The run asserts a > 30% CAM hit rate on the strict tenant — the
//! acceptance bar — and prints the full counter table.
//!
//! Run with: `cargo run --release --example cam_serving`

// Terminal output is this target's product; the serve-code print ban
// (workspace clippy.toml `disallowed-macros`) deliberately does not
// apply outside `rust/src/serve/**`.
#![allow(clippy::disallowed_macros)]

use std::time::Duration;

use rram_cim::bench::print_table;
use rram_cim::chip::ChipConfig;
use rram_cim::nn::data::mnist;
use rram_cim::serve::{
    AdmissionConfig, CacheConfig, CamConfig, Engine, EngineConfig, ModelBundle, PoolConfig,
    RebalanceConfig, TenantConfig,
};

/// Working-set size and stream length per tenant.
const BASES: usize = 6;
const STREAM: usize = 120;

/// Pin the quantization scale (pixel 0 holds the max at 1.0) so the
/// one-pixel jitter below lands a couple of packed-key bits away from
/// its base instead of rescaling every byte of the exact key.
fn pin(sample: &[f32]) -> Vec<f32> {
    let mut v: Vec<f32> = sample.iter().map(|x| x.clamp(0.0, 1.0)).collect();
    v[0] = 1.0;
    v
}

/// A near-duplicate: one mid-image pixel nudged two quantization steps.
fn jitter(base: &[f32], pixel: usize) -> Vec<f32> {
    let mut v = base.to_vec();
    v[pixel] = (v[pixel] + 2.0 / 255.0).min(1.0);
    v
}

fn main() -> anyhow::Result<()> {
    rram_cim::util::logging::init();

    let strict_model = ModelBundle::synthetic_mnist([16, 16, 16], 0.0, 0xca60);
    let trusted_model = ModelBundle::synthetic_mnist([16, 16, 16], 0.0, 0xca61);
    let cfg = EngineConfig {
        pool: PoolConfig { chips: 4, chip: ChipConfig::default(), seed: 0xca62 },
        admission: AdmissionConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            quantum: 8,
        },
        cache: CacheConfig { capacity: 0 }, // the CAM is the only fast path
        rebalance: RebalanceConfig { every_batches: 0, max_moves: 0, group_moves: 0 },
        prune: Default::default(),
        cam: CamConfig { capacity: 64, max_distance: 12 },
        obs: true,
    };
    let tenants = vec![
        TenantConfig::new("strict", strict_model.clone()), // VerifyPolicy::Exact (the default)
        TenantConfig::new("trusted", trusted_model.clone()).with_trusted_cam(0.5),
    ];
    let engine = Engine::start(tenants, &cfg)?;

    let images = mnist::generate(BASES, 0xca63);
    let bases: Vec<Vec<f32>> = (0..BASES).map(|i| pin(images.sample(i))).collect();

    // --- the duplicate-heavy stream: warm-up, then ~80% exact repeats
    //     and ~20% planted near-duplicates, identical for both tenants ---
    let mut attempts = 0u64;
    let mut strict_wrong = 0u64;
    let mut trusted_deviations = 0u64;
    let mut trusted_max_dev = 0.0f32;
    let mut ask = |input: Vec<f32>| -> anyhow::Result<()> {
        attempts += 2;
        let a = engine.submit(0, input.clone()).recv()?;
        if a.logits != strict_model.reference_logits(&input) {
            strict_wrong += 1;
        }
        let b = engine.submit(1, input.clone()).recv()?;
        let want = trusted_model.reference_logits(&input);
        let dev = b
            .logits
            .iter()
            .zip(&want)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        if dev > 0.0 {
            trusted_deviations += 1;
            trusted_max_dev = trusted_max_dev.max(dev);
        }
        Ok(())
    };
    for base in &bases {
        ask(base.clone())?; // warm-up: compute once, populate the CAM
    }
    for i in 0..STREAM {
        let base = &bases[(i * 7) % BASES];
        if i % 5 == 4 {
            ask(jitter(base, 8 + i % 32))?; // planted near-duplicate
        } else {
            ask(base.clone())?; // exact repeat
        }
    }
    let report = engine.shutdown();

    // --- the receipts ---
    let per_tenant = attempts / 2;
    let mut rows = Vec::new();
    for (name, s) in ["strict", "trusted"].iter().zip(&report.cam.per_tenant) {
        let served = s.hits + s.trusted_served;
        rows.push(vec![
            (*name).to_string(),
            format!("{}", s.hits),
            format!("{}", s.near_hits),
            format!("{}", s.trusted_served),
            format!("{} / {}", s.verify_pass, s.verify_fail),
            format!("{}", s.fallbacks),
            format!("{:.1}%", 100.0 * served as f64 / per_tenant as f64),
            if s.trusted { "yes".into() } else { "no".into() },
        ]);
    }
    print_table(
        "cam serving: one duplicate-heavy stream, two verify policies",
        &[
            "tenant",
            "exact hits",
            "near hits",
            "trusted served",
            "verify pass/fail",
            "misses",
            "served w/o silicon",
            "trusted?",
        ],
        &rows,
    );
    let strict = &report.cam.per_tenant[0];
    let trusted = &report.cam.per_tenant[1];
    print_table(
        "cam serving: what the front end saved",
        &["metric", "strict (Exact)", "trusted"],
        &[
            vec![
                "chip batches (computed on silicon)".into(),
                format!("{}", report.tenants[0].chip_batches),
                format!("{}", report.tenants[1].chip_batches),
            ],
            vec![
                "wrong logits".into(),
                format!("{strict_wrong}"),
                format!("{trusted_deviations} (max |delta| {trusted_max_dev:.4})"),
            ],
            vec![
                "max verify delta seen".into(),
                format!("{:.4}", strict.max_logit_delta_seen),
                format!("{:.4}", trusted.max_logit_delta_seen),
            ],
        ],
    );

    assert_eq!(report.answered() + report.dropped(), attempts, "accounting must balance");
    assert_eq!(report.dropped(), 0, "blocking submits never drop");
    assert_eq!(strict_wrong, 0, "Exact policy: zero wrong logits, every answer bit-exact");
    let hit_rate = strict.hits as f64 / per_tenant as f64;
    assert!(
        hit_rate > 0.30,
        "the duplicate-heavy stream must clear a 30% CAM hit rate (got {:.1}%)",
        100.0 * hit_rate
    );
    assert_eq!(
        strict.verify_pass + strict.verify_fail,
        strict.hits + strict.near_hits,
        "every hit is byte-verified and every near hit recompute-verified"
    );
    assert!(strict.trusted_served == 0 && !strict.trusted, "Exact tenants never serve trusted");
    assert!(trusted.trusted, "the Trusted opt-in is always reported");
    assert!(trusted.trusted_served > 0, "the trusted tenant must serve near hits from cache");
    println!(
        "\ncam serving OK: {} answers, {:.1}% exact-hit rate on the strict tenant with zero \
         wrong logits; the trusted tenant served {} near-duplicates from cache (max observed \
         delta {:.4}, bound 0.5)",
        report.answered(),
        100.0 * hit_rate,
        trusted.trusted_served,
        trusted_max_dev
    );
    Ok(())
}

//! PointNet INT8 serving demo: a 4-chip pool serving synthetic
//! ModelNet10 point clouds through the batched, wear-aware serve
//! subsystem — the paper's 3D workload on the same array abstraction as
//! the 2D MNIST path, with logits spot-checked bit-for-bit against the
//! software reference.
//!
//! Run with: `cargo run --release --example pointnet_serving`

// Terminal output is this target's product; the serve-code print ban
// (workspace clippy.toml `disallowed-macros`) deliberately does not
// apply outside `rust/src/serve/**`.
#![allow(clippy::disallowed_macros)]

use rram_cim::bench::print_table;
use rram_cim::nn::data::modelnet;
use rram_cim::nn::pointnet::GroupingConfig;
use rram_cim::serve::{
    BatcherConfig, ModelBundle, PointNetBundle, PoolConfig, Server, ServerConfig,
};

fn main() -> anyhow::Result<()> {
    rram_cim::util::logging::init();
    let n_requests = 100usize;
    let n_clouds = 20usize;
    let clouds = modelnet::generate(n_clouds, 0x3d5eed);

    // a 50%-pruned INT8 pointwise stack (4 RRAM cells per weight); the
    // dense model would not even fit a 2-chip pool — pruning is a
    // capacity feature on the INT8 path too
    let grouping = GroupingConfig { s1: 32, k1: 8, r1: 0.25, s2: 8, k2: 4, r2: 0.5 };
    let bundle = PointNetBundle::synthetic(
        [16, 16, 32, 32, 32, 64, 64, 128],
        64,
        0.5,
        grouping,
        0x42,
    );
    println!(
        "model: {}/{} live channels, {} array rows @ 30 data cols, {} MAC ops/cloud",
        bundle.live_filters(),
        bundle.total_filters(),
        bundle.rows_required(30),
        bundle.mac_ops_per_cloud()
    );
    let model: ModelBundle = bundle.into();

    let cfg = ServerConfig {
        pool: PoolConfig { chips: 4, ..PoolConfig::default() },
        batcher: BatcherConfig::default(),
    };
    let server = Server::start(model.clone(), &cfg)?;

    let mut pending = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        // blocking submit: full queue = wait, never drop
        pending.push(server.submit(clouds.sample(i % n_clouds).to_vec()));
    }
    let mut served = 0usize;
    let mut exact = 0usize;
    let mut class_counts = [0usize; 10];
    for (i, rx) in pending.into_iter().enumerate() {
        let resp = rx.recv()?;
        // the zero-bit-error claim, spot-checked on every request
        if resp.logits == model.reference_logits(clouds.sample(i % n_clouds)) {
            exact += 1;
        }
        let pred = resp
            .logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        class_counts[pred] += 1;
        served += 1;
    }
    let report = server.shutdown();

    assert_eq!(served, n_requests, "every request must be answered");
    assert_eq!(exact, n_requests, "all logits must match the software reference bit-for-bit");
    assert_eq!(report.stats.dropped, 0, "no drops under blocking backpressure");
    assert_eq!(report.stats.n_requests as usize, n_requests);

    let s = &report.stats;
    println!("\nserved {served} requests, 0 dropped, {exact}/{served} bit-exact vs reference");
    println!("throughput:    {:>10.1} inferences/sec", s.inferences_per_sec());
    println!(
        "latency:       p50 {:.2} ms, p95 {:.2} ms, p99 {:.2} ms",
        s.p50_ms(),
        s.p95_ms(),
        s.p99_ms()
    );
    println!(
        "energy:        {:>10.1} nJ/inference ({:.1} uJ total)",
        s.nj_per_inference(),
        s.energy_pj * 1e-6
    );
    println!("batching:      {:.1} clouds/batch over {} batches", s.mean_batch(), s.n_batches);
    println!("prediction histogram: {class_counts:?}");

    let rows: Vec<Vec<String>> = report
        .wear
        .iter()
        .enumerate()
        .map(|(i, w)| {
            vec![
                format!("chip {i}"),
                report.rows_used[i].to_string(),
                w.programmed_cells.to_string(),
                w.write_pulses.to_string(),
                w.wl_activations.to_string(),
            ]
        })
        .collect();
    print_table(
        "per-chip shard load + lifetime wear",
        &["chip", "rows", "cells programmed", "write pulses", "WL activations"],
        &rows,
    );
    if report.stuck_retries > 0 {
        println!("(placement routed around {} stuck tiles)", report.stuck_retries);
    }
    println!("\npointnet serving OK");
    Ok(())
}

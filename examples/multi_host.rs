//! Multi-host serving demo: TWO worker daemons ("hosts"), each owning
//! its own 2-chip pool behind a TCP loopback socket, form one hedged
//! replica group serving a pruned binary-MNIST tenant.
//!
//! What this exercises end to end:
//!
//! * placement over the wire — every shard payload is programmed onto
//!   BOTH hosts through `Backend::program` RPCs (byte-identical copies,
//!   each host allocating its own spans);
//! * hedged dispatch — each layer's packed windows go to one host; if
//!   it straggles past the deadline the same request (same id, same
//!   shard epoch) duplicates to the replica, the first bit-exact reply
//!   wins, and the loser is discarded by identity;
//! * a live wear rebalance on a remote host mid-run — shards migrate
//!   between the host's own chips over the transport, the tenant's
//!   shard epoch advances, and the answers stay bit-exact.
//!
//! Every response is asserted against `ModelBundle::reference_logits`:
//! zero wrong logits, by construction — the chips are digital, so a
//! fleet of them has no analogue drift to reconcile.
//!
//! Run with: `cargo run --release --example multi_host`

use std::time::Duration;

use rram_cim::bench::print_table;
use rram_cim::chip::ChipConfig;
use rram_cim::nn::data::mnist;
use rram_cim::serve::transport::{Backend, Host, HostConfig, RemoteBackend, ShardRouter};
use rram_cim::serve::{
    AdmissionConfig, CacheConfig, Engine, EngineConfig, HedgeConfig, ModelBundle, PoolConfig,
    RebalanceConfig, RouterConfig, TenantConfig,
};

fn main() -> anyhow::Result<()> {
    rram_cim::util::logging::init();

    // --- two loopback hosts, each with its own pool ---
    let pool = |seed| PoolConfig { chips: 2, chip: ChipConfig::default(), seed };
    let host_a = Host::spawn(HostConfig { pool: pool(0xa11ce) })?;
    let host_b = Host::spawn(HostConfig { pool: pool(0xb0b) })?;
    println!("host A on {}, host B on {}", host_a.addr(), host_b.addr());

    // --- one hedged replica group over both hosts ---
    // an aggressive fixed deadline so the demo visibly fires hedges;
    // production leaves `after: None` and lets the latency histogram
    // derive it (quantile(0.99) x factor)
    let router_cfg = RouterConfig {
        hedge: HedgeConfig { after: Some(Duration::from_micros(500)), ..HedgeConfig::default() },
        ..RouterConfig::default()
    };
    let backends: Vec<Box<dyn Backend>> = vec![
        Box::new(RemoteBackend::connect(host_a.addr())?),
        Box::new(RemoteBackend::connect(host_b.addr())?),
    ];
    let router = ShardRouter::replicated(backends, router_cfg)?;

    // --- one pruned tenant, placed onto BOTH hosts over the wire ---
    let model = ModelBundle::synthetic_mnist([32, 64, 32], 0.35, 42);
    println!(
        "tenant mnist: {}/{} live filters, {} rows per host @ 30 data cols",
        model.live_filters(),
        model.total_filters(),
        model.rows_required(30)
    );
    let cfg = EngineConfig {
        pool: PoolConfig::default(), // ignored: the fleet is the router's
        admission: AdmissionConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            quantum: 8,
        },
        cache: CacheConfig { capacity: 0 }, // every request hits silicon
        rebalance: RebalanceConfig { every_batches: 4, max_moves: 2 },
    };
    let engine =
        Engine::start_with_router(vec![TenantConfig::new("mnist", model.clone())], router, &cfg)?;

    // --- traffic: distinct images, every answer checked bit-exactly ---
    let images = mnist::generate(24, 0x5eed);
    let references: Vec<Vec<f32>> =
        (0..images.len()).map(|i| model.reference_logits(images.sample(i))).collect();
    let mut exact = 0u64;
    let mut pending = Vec::new();
    for round in 0..3 {
        if round == 1 {
            // mid-run: force a wear rebalance — it lands on whichever
            // REMOTE host ran hottest, over plain program RPCs
            engine.force_rebalance();
        }
        for i in 0..images.len() {
            pending.push((i, engine.submit(0, images.sample(i).to_vec())));
        }
        for (i, rx) in pending.drain(..) {
            let resp = rx.recv()?;
            assert_eq!(
                resp.logits, references[i],
                "image {i}: a hedged two-host fleet must stay bit-exact"
            );
            exact += 1;
        }
    }
    let report = engine.shutdown();

    // --- the receipts ---
    let t = &report.tenants[0];
    println!(
        "\n{exact} answered responses, every one bit-exact; \
         {} rebalance passes migrated {} shards on the remote hosts",
        report.rebalances, report.shards_moved
    );
    print_table(
        "multi_host: hedged 2-host replica group, one pruned MNIST tenant",
        &["answered", "chip batches", "p50 ms", "p99 ms", "rows/host A+B"],
        &[vec![
            t.answered.to_string(),
            t.chip_batches.to_string(),
            format!("{:.2}", t.latency.p50_ms()),
            format!("{:.2}", t.latency.p99_ms()),
            format!("{:?}", report.rows_used),
        ]],
    );
    let s = &report.transport;
    print_table(
        "multi_host: transport counters",
        &["dispatches", "hedges fired", "hedge wins", "stale discarded", "spills"],
        &[vec![
            s.dispatches.to_string(),
            s.hedges_fired.to_string(),
            s.hedge_wins.to_string(),
            s.stale_discarded.to_string(),
            s.spills.to_string(),
        ]],
    );
    let wear_rows: Vec<Vec<String>> = report
        .wear
        .iter()
        .enumerate()
        .map(|(i, w)| {
            vec![
                format!("host {} chip {}", if i < 2 { "A" } else { "B" }, i % 2),
                w.write_pulses.to_string(),
                w.wl_activations.to_string(),
            ]
        })
        .collect();
    print_table(
        "multi_host: per-chip lifetime wear across the fleet",
        &["chip", "write pulses", "WL activations"],
        &wear_rows,
    );

    assert_eq!(t.answered, exact, "nothing silently lost");
    assert_eq!(report.dropped(), 0, "blocking submits never drop");
    assert!(
        report.shards_moved >= 1,
        "the forced pass must migrate at least one shard on a remote host"
    );
    host_a.join();
    host_b.join();
    println!("\nmulti-host serving OK: two hosts, one hedged tenant, zero wrong logits");
    Ok(())
}

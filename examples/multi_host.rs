//! Multi-host serving demo: THREE worker daemons ("hosts"), each owning
//! its own 2-chip pool behind a TCP loopback socket, forming a
//! two-group fleet serving a pruned binary-MNIST tenant:
//!
//! ```text
//!   group 0: hosts A1 + A2 (hedged replica pair, byte-identical shards)
//!   group 1: host  B       (solo)
//! ```
//!
//! What this exercises end to end (the whole fleet-operations story —
//! see OPERATIONS.md for how to run this shape for real):
//!
//! * placement over the wire — layers split across the two groups, and
//!   every member of a layer's owning group gets a byte-identical copy
//!   programmed through `Backend::program` RPCs;
//! * hedged dispatch — a straggling replica's request duplicates to its
//!   sibling after the deadline; first bit-exact reply wins, the loser
//!   is discarded by request-id/epoch identity;
//! * a forced **cross-host layer migration** — a whole layer moves from
//!   one group to the other through the epoch-fenced
//!   program → fence → drain → free cutover (DESIGN.md §9), and the
//!   freed source rows return to their allocator;
//! * a **host bounce** — host B is killed mid-run and a replacement
//!   (fresh pool, fresh incarnation) takes over its address; B's client
//!   reconnects with bounded backoff, quarantines itself, and the
//!   engine re-programs it at the current epoch before it serves again;
//! * the **live prune loop** on a second, deliberately redundant tenant
//!   — the similarity monitor proposes mid-serve, the epoch-fenced
//!   prune cutover (DESIGN.md §12) commits over the same fleet, and the
//!   tenant's answers are checked against the *pruned-mask* oracle;
//! * the **observability plane** riding all of it — the operator event
//!   bus (`Engine::events`) is asserted to carry the exact transition
//!   sequence the migration, the bounce, and the prune cutover must
//!   produce (gapless seq, exactly-once per transition, plan → start →
//!   fence → commit, never an abort), and one hedged request's spans
//!   are stitched across the hosts and printed as a tree (DESIGN.md
//!   §10).
//!
//! Every response is asserted against `ModelBundle::reference_logits`:
//! zero wrong logits, by construction — the chips are digital, so a
//! fleet of them has no analogue drift to reconcile.
//!
//! Run with: `cargo run --release --example multi_host`

// Terminal output is this target's product; the serve-code print ban
// (workspace clippy.toml `disallowed-macros`) deliberately does not
// apply outside `rust/src/serve/**`.
#![allow(clippy::disallowed_macros)]

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Duration;

use rram_cim::bench::print_table;
use rram_cim::chip::ChipConfig;
use rram_cim::nn::data::mnist;
use rram_cim::pruning::PruneConfig;
use rram_cim::serve::obs::Stage;
use rram_cim::serve::transport::{
    Backend, Host, HostConfig, ReconnectPolicy, RemoteBackend, ShardRouter,
};
use rram_cim::serve::{
    AdmissionConfig, CacheConfig, Engine, EngineConfig, EventSubscriber, HedgeConfig,
    LivePruneConfig, MnistBundle, ModelBundle, ObsEvent, PoolConfig, RebalanceConfig, RouterConfig,
    TenantConfig,
};

/// Serve one request per image to the redundant tenant (tenant 1) and
/// check each answer against the pruned-mask oracle: a clone advanced
/// lazily through the `PruneCommitted` event sequence, so an answer
/// must match the masks its batch served under (the same discipline
/// `rust/tests/live_prune.rs` property-tests).
fn pruned_round(
    engine: &Engine,
    events: &EventSubscriber,
    images: &mnist::Dataset,
    oracle: &mut ModelBundle,
    commits: &mut VecDeque<(usize, Vec<usize>)>,
) -> anyhow::Result<u64> {
    let mut exact = 0u64;
    for i in 0..images.len() {
        let input = images.sample(i);
        let resp = engine.submit(1, input.to_vec()).recv()?;
        for rec in events.drain() {
            if let ObsEvent::PruneCommitted { tenant: 1, layer, filters, .. } = rec.event {
                commits.push_back((layer, filters));
            }
        }
        loop {
            if resp.logits == oracle.reference_logits(input) {
                break;
            }
            let (layer, filters) =
                commits.pop_front().expect("logits must match a committed mask state");
            for f in filters {
                oracle.prune_filter(layer, f);
            }
        }
        exact += 1;
    }
    Ok(exact)
}

fn main() -> anyhow::Result<()> {
    rram_cim::util::logging::init();

    // --- three loopback hosts, each with its own pool ---
    let pool = |seed| PoolConfig { chips: 2, chip: ChipConfig::default(), seed };
    let host_a1 = Host::spawn(HostConfig { pool: pool(0xa11ce) })?;
    let host_a2 = Host::spawn(HostConfig { pool: pool(0xa22) })?;
    let host_b = Host::spawn(HostConfig { pool: pool(0xb0b) })?;
    println!(
        "group 0 (hedged pair): {} + {}   group 1: {}",
        host_a1.addr(),
        host_a2.addr(),
        host_b.addr()
    );

    // --- the fleet: one hedged group + one solo group ---
    // hedge EVERY dispatch to the replica pair (`after: ZERO`): the
    // demo's point is the race itself, and a deterministic hedge means
    // the stitched trace printed below always shows one. production
    // leaves `after: None` and lets the latency histogram derive the
    // deadline (quantile(0.99) x factor)
    let router_cfg = RouterConfig {
        hedge: HedgeConfig { after: Some(Duration::ZERO), ..HedgeConfig::default() },
        ..RouterConfig::default()
    };
    let connect = |addr| -> anyhow::Result<Box<dyn Backend>> {
        Ok(Box::new(RemoteBackend::connect_with(addr, ReconnectPolicy::default())?))
    };
    let groups: Vec<Vec<Box<dyn Backend>>> = vec![
        vec![connect(host_a1.addr())?, connect(host_a2.addr())?],
        vec![connect(host_b.addr())?],
    ];
    let router = ShardRouter::new(groups, router_cfg)?;

    // --- one pruned tenant, layers split across the groups ---
    let model = ModelBundle::synthetic_mnist([32, 64, 32], 0.35, 42);
    println!(
        "tenant mnist: {}/{} live filters, {} rows per member @ 30 data cols",
        model.live_filters(),
        model.total_filters(),
        model.rows_required(30)
    );
    // --- plus a deliberately redundant tenant for the live prune loop:
    // every filter repeats one of two sign prototypes (similarity 1.0
    // within each class), so the monitor has guaranteed mid-run work ---
    let red_model: ModelBundle = {
        let mut red = MnistBundle::synthetic([6, 6, 6], 0.0, 77);
        for layer in &mut red.conv {
            let protos: Vec<Vec<bool>> = layer.bits[..2].to_vec();
            for (f, bits) in layer.bits.iter_mut().enumerate() {
                *bits = protos[f % 2].clone();
            }
        }
        red.into()
    };
    let cfg = EngineConfig {
        pool: PoolConfig::default(), // ignored: the fleet is the router's
        admission: AdmissionConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            quantum: 8,
        },
        cache: CacheConfig { capacity: 0 }, // every request hits silicon
        rebalance: RebalanceConfig { every_batches: 4, max_moves: 2, group_moves: 1 },
        // the live prune loop, on a serving cadence: similarity-monitor
        // every 2 chip batches, cut at most one layer over per pass
        prune: LivePruneConfig {
            every_batches: 2,
            max_layers_per_pass: 1,
            rule: PruneConfig { min_live_per_layer: 1, max_prune_rate: 1.0, ..Default::default() },
        },
        cam: Default::default(),
        obs: true,
    };
    // tenant 0 opts out (its dense reference logits anchor the
    // migration/bounce assertions); tenant 1 is the prune loop's
    let engine = Engine::start_with_router(
        vec![
            TenantConfig::new("mnist", model.clone()).without_live_prune(),
            TenantConfig::new("redundant", red_model.clone()),
        ],
        router,
        &cfg,
    )?;

    // the observability plane: a deep event subscriber (nothing may
    // overflow — the assertions below need the complete transition
    // log) plus the plane handle itself, which outlives the engine so
    // the trace ring can be rendered after shutdown
    let events = engine.events_with(4096);
    let plane = Arc::clone(engine.obs());
    // a second, independent subscriber feeds the pruned-mask oracle —
    // draining it mid-run leaves the `events` log above complete for
    // the end-of-run transition assertions
    let prune_events = engine.events_with(4096);
    let mut red_oracle = red_model.clone();
    let mut red_commits: VecDeque<(usize, Vec<usize>)> = VecDeque::new();
    let mut red_exact = 0u64;
    let red_images = mnist::generate(4, 0xbeef);

    // --- traffic: distinct images, every answer checked bit-exactly ---
    let images = mnist::generate(24, 0x5eed);
    let references: Vec<Vec<f32>> =
        (0..images.len()).map(|i| model.reference_logits(images.sample(i))).collect();
    let mut exact = 0u64;
    let round = |exact: &mut u64, label: &str| -> anyhow::Result<()> {
        let mut pending = Vec::new();
        for i in 0..images.len() {
            pending.push((i, engine.submit(0, images.sample(i).to_vec())));
        }
        for (i, rx) in pending {
            let resp = rx.recv()?;
            assert_eq!(resp.logits, references[i], "image {i}: {label} must stay bit-exact");
            *exact += 1;
        }
        Ok(())
    };
    // round 1: warm-up (builds the heat signal and latency histograms);
    // the redundant tenant's traffic advances the prune monitor, and
    // the loop starts cutting its duplicate filters over mid-round
    round(&mut exact, "a hedged two-group fleet")?;
    red_exact +=
        pruned_round(&engine, &prune_events, &red_images, &mut red_oracle, &mut red_commits)?;
    // round 2: force a rebalance pass — wear moves level the hottest
    // chips, and the capacity planner may migrate a whole layer BETWEEN
    // the groups through the epoch-fenced cutover
    engine.force_rebalance();
    round(&mut exact, "an epoch-fenced cross-host migration")?;
    red_exact +=
        pruned_round(&engine, &prune_events, &red_images, &mut red_oracle, &mut red_commits)?;
    // round 3: host B crashes; a replacement with a fresh pool takes
    // over the exact same address. B's backend reconnects with bounded
    // backoff, reports the bounce, and the engine re-programs it at the
    // current epoch before it serves a single dispatch.
    let addr = host_b.addr();
    println!("bouncing host B at {addr} …");
    host_b.shutdown();
    let replacement = Host::spawn_at(addr, HostConfig { pool: pool(0xb0b2) })?;
    println!("replacement pool live at {addr}");
    round(&mut exact, "a bounced-and-healed fleet")?;
    red_exact +=
        pruned_round(&engine, &prune_events, &red_images, &mut red_oracle, &mut red_commits)?;
    let report = engine.shutdown();

    // --- the receipts ---
    let t = &report.tenants[0];
    println!(
        "\n{exact} answered responses, every one bit-exact; \
         {} rebalance passes moved {} shards; \
         {} cross-host migrations completed; {} reconnects",
        report.rebalances,
        report.shards_moved,
        report.transport.migrations_completed,
        report.transport.reconnects
    );
    print_table(
        "multi_host: 2-group fleet (hedged pair + solo), one pruned MNIST tenant",
        &["answered", "chip batches", "p50 ms", "p99 ms", "rows/chip"],
        &[vec![
            t.answered.to_string(),
            t.chip_batches.to_string(),
            format!("{:.2}", t.latency.p50_ms()),
            format!("{:.2}", t.latency.p99_ms()),
            format!("{:?}", report.rows_used),
        ]],
    );
    let s = &report.transport;
    print_table(
        "multi_host: transport counters (the OPERATIONS.md telemetry)",
        &[
            "dispatches",
            "hedges fired",
            "hedge wins",
            "stale disc.",
            "epoch disc.",
            "spills",
            "migr started",
            "migr completed",
            "migr aborted",
            "reconnects",
        ],
        &[vec![
            s.dispatches.to_string(),
            s.hedges_fired.to_string(),
            s.hedge_wins.to_string(),
            s.stale_discarded.to_string(),
            s.epoch_discards.to_string(),
            s.spills.to_string(),
            s.migrations_started.to_string(),
            s.migrations_completed.to_string(),
            s.migrations_aborted.to_string(),
            s.reconnects.to_string(),
        ]],
    );
    let wear_rows: Vec<Vec<String>> = report
        .wear
        .iter()
        .enumerate()
        .map(|(i, w)| {
            let host = ["A1", "A1", "A2", "A2", "B", "B"][i.min(5)];
            vec![
                format!("host {host} chip {}", i % 2),
                w.write_pulses.to_string(),
                w.wl_activations.to_string(),
            ]
        })
        .collect();
    print_table(
        "multi_host: per-chip lifetime wear across the fleet",
        &["chip", "write pulses", "WL activations"],
        &wear_rows,
    );

    assert_eq!(t.answered, exact, "nothing silently lost");
    assert_eq!(report.dropped(), 0, "blocking submits never drop");
    assert!(
        report.transport.reconnects >= 1,
        "the bounced host must have been reconnected to"
    );
    assert!(
        report.transport.migrations_completed >= 1,
        "the forced pass must complete a cross-host layer migration"
    );

    // --- the live prune loop's receipts ---
    let p = &report.prune;
    let rts = &p.per_tenant[1];
    println!(
        "\nlive prune: {red_exact} redundant-tenant answers, every one bit-exact against the \
         pruned oracle; {} cutovers retired {} filters and freed {} rows",
        p.cutovers, rts.filters_pruned, rts.rows_freed
    );
    assert_eq!(red_exact, 12, "three rounds of four redundant-tenant requests");
    assert!(p.cutovers >= 1, "the redundant tenant must commit a live cutover");
    assert_eq!(p.aborted, 0, "a healthy fleet never aborts a prune cutover");
    assert!(rts.filters_pruned > 0, "committed cutovers retire filters");
    assert!(rts.rows_freed > 0, "committed cutovers free rows");
    assert_eq!(p.per_tenant[0].filters_pruned, 0, "tenant 0 opted out and stays dense");

    // --- the operator event log: the fleet's story, as transitions ---
    let log = events.drain();
    println!(
        "\noperator events ({} delivered, {} overflowed):",
        log.len(),
        events.overflowed()
    );
    for rec in &log {
        println!("  [{:>3}] {:?}", rec.seq, rec.event);
    }
    for (i, rec) in log.iter().enumerate() {
        assert_eq!(rec.seq, i as u64, "per-subscriber seq is gapless");
    }
    assert_eq!(events.overflowed(), 0, "a 4096-deep subscriber loses nothing here");
    // the forced pass: planned → started → fenced → completed, in that
    // order, exactly once, never aborted
    let find = |from: usize, pred: &dyn Fn(&ObsEvent) -> bool| {
        log[from..].iter().position(|r| pred(&r.event)).map(|i| from + i)
    };
    let planned = find(0, &|e| matches!(e, ObsEvent::RebalancePlanned { .. }))
        .expect("the forced pass announces a plan");
    let started = find(0, &|e| matches!(e, ObsEvent::MigrationStarted { .. }))
        .expect("the forced pass starts a cross-host migration");
    let layer = match &log[started].event {
        ObsEvent::MigrationStarted { layer, .. } => *layer,
        _ => unreachable!(),
    };
    let fenced = find(started, &|e| {
        matches!(e, ObsEvent::MigrationFenced { layer: l, .. } if *l == layer)
    })
    .expect("the migration fences its epoch");
    let completed = find(fenced, &|e| {
        matches!(e, ObsEvent::MigrationCompleted { layer: l, .. } if *l == layer)
    })
    .expect("the migration commits");
    assert!(
        planned < started && started < fenced && fenced < completed,
        "plan → start → fence → commit, in that order"
    );
    assert!(
        !log[started..completed]
            .iter()
            .any(|r| matches!(&r.event, ObsEvent::MigrationAborted { layer: l } if *l == layer)),
        "a committed migration never reports an abort"
    );
    assert!(
        find(completed, &|e| matches!(e, ObsEvent::RebalanceApplied { .. })).is_some(),
        "the pass reports what it applied"
    );
    // the bounce: the probe reports the reconnect, quarantines the
    // fresh incarnation, and only after re-programming lets it rejoin
    assert!(
        find(0, &|e| matches!(e, ObsEvent::Reconnect { .. })).is_some(),
        "the bounce's reconnect is reported"
    );
    let quarantined = find(0, &|e| matches!(e, ObsEvent::Quarantine { .. }))
        .expect("the bounced member is quarantined");
    let member = match &log[quarantined].event {
        ObsEvent::Quarantine { member } => *member,
        _ => unreachable!(),
    };
    find(quarantined + 1, &|e| matches!(e, ObsEvent::Rejoin { member: m } if *m == member))
        .expect("quarantine strictly precedes the re-programmed member's rejoin");
    // the prune cutover: planned → started → fenced → committed, in
    // that order, never aborted (DESIGN.md §12's only commit path)
    let pp = find(0, &|e| matches!(e, ObsEvent::PrunePlanned { tenant: 1, .. }))
        .expect("the prune loop announces a plan for the redundant tenant");
    let ps = find(pp, &|e| matches!(e, ObsEvent::PruneStarted { tenant: 1, .. }))
        .expect("a validated plan starts its cutover");
    let pf = find(ps, &|e| matches!(e, ObsEvent::PruneFenced { tenant: 1, .. }))
        .expect("the cutover fences its epoch");
    let pc = find(pf, &|e| matches!(e, ObsEvent::PruneCommitted { tenant: 1, .. }))
        .expect("the cutover commits");
    assert!(pp < ps && ps < pf && pf < pc, "plan → start → fence → commit, in that order");
    assert!(
        !log.iter().any(|r| matches!(r.event, ObsEvent::PruneAborted { .. })),
        "a live prune cutover never aborts on this fleet"
    );

    // --- one hedged request, stitched across the hosts ---
    let spans = plane.trace.spans();
    let hedged = spans
        .iter()
        .rev()
        .find(|s| s.stage == Stage::Hedge)
        .map(|s| s.ctx.trace_id)
        .expect("a zero hedge deadline guarantees hedged dispatches");
    println!("\none hedged request, stitched across the hosts:");
    print!("{}", plane.trace.render(hedged));
    let trace: Vec<_> = spans.iter().filter(|s| s.ctx.trace_id == hedged).collect();
    let mut ids: Vec<u64> = trace.iter().map(|s| s.ctx.span_id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), trace.len(), "hedge duplicates share the trace, not the span id");
    assert!(
        trace.iter().any(|s| s.stage == Stage::Dispatch),
        "the primary attempt is in the trace"
    );
    assert!(
        trace.iter().any(|s| s.stage == Stage::Execute && s.note.contains("host_ns")),
        "the execute span is stitched from the remote host's reply"
    );
    println!("\nmetrics snapshot (the scrape body benches persist as BENCH_serve.json):");
    println!("{}", plane.snapshot().render());

    host_a1.join();
    host_a2.join();
    replacement.join();
    println!(
        "\nmulti-host serving OK: three hosts, a hedged pair, an epoch-fenced cross-host \
         migration, one host bounce, a live prune cutover on a serving tenant, an asserted \
         operator-event log and a stitched hedged trace — zero wrong logits"
    );
    Ok(())
}

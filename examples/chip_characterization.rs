//! Device + chip characterization (paper Fig. 2): regenerates every
//! panel's data from the stochastic device model and prints it as
//! terminal figures.
//!
//!   cargo run --release --example chip_characterization [--seed N]

// Terminal output is this target's product; the serve-code print ban
// (workspace clippy.toml `disallowed-macros`) deliberately does not
// apply outside `rust/src/serve/**`.
#![allow(clippy::disallowed_macros)]

use rram_cim::bench::{print_series, print_table};
use rram_cim::device::{characterize, DeviceConfig};
use rram_cim::util::args::Args;
use rram_cim::util::stats;

fn main() -> anyhow::Result<()> {
    rram_cim::util::logging::init();
    let args = Args::from_env(1).map_err(anyhow::Error::msg)?;
    let seed: u64 = args.parse_or("seed", 1).map_err(anyhow::Error::msg)?;
    let cfg = DeviceConfig::default();

    // Fig. 2e: I-V hysteresis
    let iv = characterize::iv_sweep(&cfg, seed, 60);
    let current: Vec<f64> = iv.iter().map(|&(_, i)| i).collect();
    print_series("Fig. 2e  I-V sweep (current, 4 legs)", &current);

    // Fig. 2f: 128 multi-level states
    let levels = characterize::multilevel_states(&cfg, seed, 128);
    print_series("Fig. 2f  128 programmed states (kOhm)", &levels);
    println!(
        "         span {:.1} -> {:.1} kOhm, {} monotone violations",
        levels[0],
        levels[127],
        levels.windows(2).filter(|w| w[1] <= w[0]).count()
    );

    // Fig. 2g: retention
    let (times, traces) = characterize::retention_traces(&cfg, seed, 4, 16);
    for (i, tr) in traces.iter().enumerate() {
        print_series(&format!("Fig. 2g  retention state {i} (to 4e6 s)"), tr);
    }
    println!("         time span: {:.0} .. {:.1e} s", times[0], times[times.len() - 1]);

    // Fig. 2h: endurance
    let endurance = characterize::endurance_trace(&cfg, seed, 1_000_000);
    let rows: Vec<Vec<String>> = endurance
        .iter()
        .step_by(3)
        .map(|&(c, lrs, hrs)| {
            vec![format!("{c}"), format!("{lrs:.1}"), format!("{hrs:.1}"), format!("{:.1}", hrs / lrs)]
        })
        .collect();
    print_table(
        "Fig. 2h: endurance to 1e6 cycles",
        &["cycles", "LRS (kOhm)", "HRS (kOhm)", "window"],
        &rows,
    );

    // Fig. 2i: forming distribution
    let (summary, yield_frac) = characterize::forming_distribution(&cfg, seed);
    println!(
        "\nFig. 2i  V_form: mean {:.3} V, std {:.3} V, yield {:.2}% over {} cells",
        summary.mean,
        summary.std,
        100.0 * yield_frac,
        summary.n
    );
    // histogram as the paper plots it
    let all: Vec<f64> = {
        // regenerate the same distribution for the histogram
        let mut rng = rram_cim::util::rng::Rng::new(seed);
        (0..summary.n).map(|_| rng.normal_ms(1.89, 0.18)).collect()
    };
    let hist = stats::histogram(&all, 1.3, 2.5, 24);
    print_series(
        "         histogram (1.3 .. 2.5 V)",
        &hist.iter().map(|&c| c as f64).collect::<Vec<_>>(),
    );

    // Fig. 2j/k/l: programming accuracy
    let reps = characterize::programming_accuracy(&cfg, seed, &[2, 4, 8, 16]);
    let rows: Vec<Vec<String>> = reps
        .iter()
        .map(|r| {
            vec![
                format!("{}", r.levels),
                format!("{:.2}%", 100.0 * r.success_frac),
                format!("{:.4}", r.sigma_kohm),
            ]
        })
        .collect();
    print_table(
        "Fig. 2j/l: write-verify accuracy (paper: 99.8% in +-2 kOhm, sigma 0.8793)",
        &["levels", "within window", "sigma (kOhm)"],
        &rows,
    );

    // Fig. 2k: 16-state distribution summary
    let rep16 = &reps[3];
    let mut per_level: Vec<Vec<f64>> = vec![Vec::new(); 16];
    for (r, &lvl) in rep16.actual.iter().zip(&rep16.assigned) {
        per_level[lvl].push(*r);
    }
    let rows: Vec<Vec<String>> = per_level
        .iter()
        .enumerate()
        .map(|(i, rs)| {
            let s = stats::summarize(rs);
            vec![
                format!("{i}"),
                format!("{:.2}", rep16.targets[i]),
                format!("{:.2}", s.mean),
                format!("{:.3}", s.std),
            ]
        })
        .collect();
    print_table(
        "Fig. 2k: 16-state distributions",
        &["level", "target (kOhm)", "mean", "std"],
        &rows,
    );
    println!("\ncharacterization OK");
    Ok(())
}

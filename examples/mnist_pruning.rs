//! End-to-end driver (paper Fig. 4): train the binarized CNN on the
//! synthetic-MNIST dataset through the full three-layer stack —
//! Rust coordinator -> AOT JAX train-step artifacts (with the Pallas
//! sign-matmul inside) -> chip simulator for search-in-memory pruning —
//! and print the loss curve, accuracy, pruning trajectory, t-SNE
//! separability, and the energy comparison rows.
//!
//! Default run (SUN + SPN + HPN comparison, Fig. 4k):
//!   cargo run --release --example mnist_pruning
//! Flags:
//!   --mode spn|sun|hpn    run a single mode instead of all three
//!   --epochs N            (default 10)
//!   --pallas              use the Pallas-kernel artifact on the train path
//!   --pallas-steps N      additionally run N steps through the Pallas
//!                         artifact and check parity vs the fast artifact
//!   --tsne                compute before/after t-SNE separation scores

// Terminal output is this target's product; the serve-code print ban
// (workspace clippy.toml `disallowed-macros`) deliberately does not
// apply outside `rust/src/serve/**`.
#![allow(clippy::disallowed_macros)]

use rram_cim::bench::{print_series, print_table};
use rram_cim::metrics::energy_comparison;
use rram_cim::nn::tsne::{separation_score, tsne, TsneConfig};
use rram_cim::prelude::*;
use rram_cim::util::args::Args;

fn run_mode(
    mode: TrainMode,
    epochs: usize,
    use_pallas: bool,
    tsne_check: bool,
) -> anyhow::Result<rram_cim::coordinator::TrainingReport> {
    let engine = Engine::open_default()?;
    let cfg = MnistConfig { epochs, mode, use_pallas, ..MnistConfig::default() };
    let mut trainer = MnistTrainer::new(cfg, engine);

    let before = if tsne_check { Some(trainer.features()?) } else { None };
    let report = trainer.train()?;

    println!("\n--- {} ---", mode.name());
    print_series("loss", &report.epochs.iter().map(|e| e.loss).collect::<Vec<_>>());
    print_series(
        "test accuracy",
        &report.epochs.iter().map(|e| e.test_acc).collect::<Vec<_>>(),
    );
    print_series(
        "live kernels (Fig. 4i)",
        &report.epochs.iter().map(|e| e.live_kernels as f64).collect::<Vec<_>>(),
    );
    if mode == TrainMode::Hpn {
        if let Some(last) = report.epochs.last() {
            println!("MAC precision per conv layer (Fig. 4l): {:?}", last.mac_precision);
        }
    }
    println!(
        "final acc {:.2}%  prune rate {:.2}%  train-op reduction {:.2}%",
        100.0 * report.final_test_acc(),
        100.0 * report.final_prune_rate,
        100.0 * report.train_ops_reduction()
    );

    if let Some((feats_b, labels)) = before {
        let (feats_a, _) = trainer.features()?;
        let n = labels.len();
        let d = feats_b.len() / n;
        let cfg = TsneConfig { iters: 400, ..TsneConfig::default() };
        let yb = tsne(&feats_b, n, d, &cfg);
        let ya = tsne(&feats_a, n, d, &cfg);
        let sb = separation_score(&yb, &labels, 10);
        let sa = separation_score(&ya, &labels, 10);
        println!("t-SNE separation (Fig. 4f/g): before {sb:.2} -> after {sa:.2}");
    }
    Ok(report)
}

fn main() -> anyhow::Result<()> {
    rram_cim::util::logging::init();
    let args = Args::from_env(1).map_err(anyhow::Error::msg)?;
    let epochs: usize = args.parse_or("epochs", 10).map_err(anyhow::Error::msg)?;
    let use_pallas = args.flag("pallas");
    let tsne_check = args.flag("tsne");

    // Optional Pallas-parity pass: prove the Pallas train artifact (the
    // paper's Layer-1 kernel inside the fwd+bwd graph) composes with the
    // coordinator by training a few steps on it.
    let pallas_steps: usize = args.parse_or("pallas-steps", 0).map_err(anyhow::Error::msg)?;
    if pallas_steps > 0 {
        println!("=== Pallas-artifact parity check ({pallas_steps} steps) ===");
        let engine = Engine::open_default()?;
        let cfg = MnistConfig {
            epochs: 1,
            train_samples: pallas_steps * 64,
            test_samples: 256,
            use_pallas: true,
            mode: TrainMode::Sun,
            ..MnistConfig::default()
        };
        let mut tr = MnistTrainer::new(cfg, engine);
        let rep = tr.train()?;
        println!(
            "pallas path: loss {:.4}, test acc {:.2}% — artifact executes end-to-end",
            rep.epochs[0].loss,
            100.0 * rep.epochs[0].test_acc
        );
    }

    let modes: Vec<TrainMode> = match args.get("mode") {
        Some("sun") => vec![TrainMode::Sun],
        Some("spn") => vec![TrainMode::Spn],
        Some("hpn") => vec![TrainMode::Hpn],
        _ => vec![TrainMode::Sun, TrainMode::Spn, TrainMode::Hpn],
    };

    let mut rows = Vec::new();
    let mut spn_report = None;
    for &mode in &modes {
        let rep = run_mode(mode, epochs, use_pallas, tsne_check)?;
        rows.push(vec![
            mode.name().to_string(),
            format!("{:.2}%", 100.0 * rep.final_test_acc()),
            format!("{:.2}%", 100.0 * rep.final_prune_rate),
            format!("{:.2}%", 100.0 * rep.train_ops_reduction()),
        ]);
        if mode == TrainMode::Spn || (modes.len() == 1) {
            spn_report = Some(rep);
        }
    }
    print_table(
        "Fig. 4k: accuracy by training mode",
        &["mode", "test acc", "prune rate", "train-op reduction"],
        &rows,
    );

    // Fig. 4m right: inference energy comparison
    if let Some(rep) = spn_report {
        let rows: Vec<Vec<String>> = energy_comparison(
            rep.macs_unpruned,
            rep.macs_pruned,
            true,
            rram_cim::baselines::gpu::GpuWorkloadClass::SmallCnn,
            32,
        )
        .iter()
        .map(|r| vec![r.platform.clone(), format!("{:.3}", r.energy_uj)])
        .collect();
        print_table("Fig. 4m: per-image conv inference energy", &["platform", "energy (uJ)"], &rows);
    }
    Ok(())
}

//! Live in-situ pruning demo: one MNIST tenant whose kernels carry
//! planted redundancy serves traffic while the similarity-monitored
//! prune loop (DESIGN.md §12) retires the duplicates **mid-flight** —
//! XOR/popcount similarity over the programmed sign bits on a batch
//! cadence, an epoch-fenced cutover per pruned layer, freed rows back
//! to the allocator — and every single answer is asserted bit-exact
//! against the pruned-mask reference oracle. Zero wrong logits, by
//! construction: an answer either matches the masks its batch served
//! under or the run fails.
//!
//! The paper's in-situ rule removes 26.80% of conv ops on MNIST and
//! 59.94% on ModelNet10 during training; this demo plants ~30%
//! redundancy per layer and watches the serving-side loop climb to the
//! same order of reduction (the run asserts ≥ 20%) without pausing the
//! tenant.
//!
//! Run with: `cargo run --release --example live_prune`

// Terminal output is this target's product; the serve-code print ban
// (workspace clippy.toml `disallowed-macros`) deliberately does not
// apply outside `rust/src/serve/**`.
#![allow(clippy::disallowed_macros)]

use std::collections::VecDeque;
use std::time::Duration;

use rram_cim::bench::print_table;
use rram_cim::chip::ChipConfig;
use rram_cim::nn::data::mnist;
use rram_cim::pruning::PruneConfig;
use rram_cim::serve::{
    AdmissionConfig, CacheConfig, Engine, EngineConfig, EventRecord, LivePruneConfig, MnistBundle,
    ModelBundle, ObsEvent, PoolConfig, RebalanceConfig, TenantConfig,
};

/// Paper headline op reductions (training-side, Fig. 4m / Fig. 5i) —
/// the bar this serving-side demo climbs toward.
const PAPER_MNIST_REDUCTION: f64 = 26.80;
const PAPER_MODELNET_REDUCTION: f64 = 59.94;

/// An MNIST bundle with planted redundancy: the first ~30% of each
/// layer's filters share one sign prototype (similarity 1.0), the rest
/// stay random (far below the 0.75 prune threshold). The live rule
/// should retire every duplicate and nothing else.
fn redundant_mnist(channels: [usize; 3], seed: u64) -> (ModelBundle, u64) {
    let mut m = MnistBundle::synthetic(channels, 0.0, seed);
    let mut duplicates = 0u64;
    for layer in &mut m.conv {
        let k = (layer.bits.len() * 3).div_ceil(10); // ~30% of the layer
        let proto = layer.bits[0].clone();
        for bits in layer.bits.iter_mut().take(k) {
            *bits = proto.clone();
        }
        duplicates += k as u64 - 1; // the representative survives
    }
    (m.into(), duplicates)
}

/// The pruned-mask reference oracle: a model clone advanced lazily
/// through the committed-cutover event sequence, so each answer is
/// checked against exactly the masks its batch served under (see
/// `rust/tests/live_prune.rs` for the property-test version).
struct PrunedOracle {
    model: ModelBundle,
    pending: VecDeque<(usize, Vec<usize>)>,
}

impl PrunedOracle {
    fn absorb(&mut self, records: Vec<EventRecord>) {
        for rec in records {
            if let ObsEvent::PruneCommitted { tenant: 0, layer, filters, .. } = rec.event {
                self.pending.push_back((layer, filters));
            }
        }
    }

    fn check(&mut self, input: &[f32], logits: &[f32]) {
        loop {
            if logits == self.model.reference_logits(input).as_slice() {
                return;
            }
            let (layer, filters) =
                self.pending.pop_front().expect("logits must match a committed mask state");
            for f in filters {
                self.model.prune_filter(layer, f);
            }
        }
    }

    /// Fold every remaining commit in, then report the live prune rate.
    fn settle(&mut self) -> f64 {
        while let Some((layer, filters)) = self.pending.pop_front() {
            for f in filters {
                self.model.prune_filter(layer, f);
            }
        }
        1.0 - self.model.live_filters() as f64 / self.model.total_filters() as f64
    }
}

fn main() -> anyhow::Result<()> {
    rram_cim::util::logging::init();

    let (model, duplicates) = redundant_mnist([32, 64, 32], 0x11f3);
    let dense_ops = model.mac_ops_per_input();
    println!(
        "tenant mnist: {} filters, {duplicates} planted duplicates, {} MAC ops/image dense",
        model.total_filters(),
        dense_ops
    );
    let cfg = EngineConfig {
        pool: PoolConfig { chips: 4, chip: ChipConfig::default(), seed: 0x11f4 },
        admission: AdmissionConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            quantum: 8,
        },
        cache: CacheConfig { capacity: 0 }, // every request hits silicon
        rebalance: RebalanceConfig::default(),
        // the whole demo: monitor the programmed kernels at every chip
        // batch boundary and cut the redundant filters over mid-serve
        prune: LivePruneConfig {
            every_batches: 1,
            max_layers_per_pass: 1,
            rule: PruneConfig { min_live_per_layer: 1, max_prune_rate: 1.0, ..Default::default() },
        },
        cam: Default::default(),
        obs: true,
    };
    let engine = Engine::start(vec![TenantConfig::new("mnist", model.clone())], &cfg)?;
    let events = engine.events_with(4096);
    let mut oracle = PrunedOracle { model: model.clone(), pending: VecDeque::new() };

    // --- traffic: 8 rounds, every answer checked against the oracle ---
    let images = mnist::generate(16, 0x5eed);
    let mut exact = 0u64;
    let mut progress: Vec<Vec<String>> = Vec::new();
    for round in 0..8 {
        let mut pending = Vec::new();
        for i in 0..images.len() {
            pending.push((i, engine.submit(0, images.sample(i).to_vec())));
        }
        for (i, rx) in pending {
            let resp = rx.recv()?;
            oracle.absorb(events.drain());
            oracle.check(images.sample(i), &resp.logits);
            exact += 1;
        }
        // round boundary: nothing in flight, so folding the drained
        // commits in eagerly keeps the oracle exact for the next round
        oracle.absorb(events.drain());
        let rate = oracle.settle();
        let ops = oracle.model.mac_ops_per_input();
        progress.push(vec![
            format!("{round}"),
            format!("{exact}"),
            format!("{:.2}%", 100.0 * rate),
            format!("{:.2}%", 100.0 * (1.0 - ops as f64 / dense_ops as f64)),
        ]);
    }
    let report = engine.shutdown();
    oracle.absorb(events.drain());
    let final_rate = oracle.settle();

    // --- the receipts ---
    print_table(
        "live prune: the loop climbing while the tenant serves",
        &["round", "answered (all bit-exact)", "prune rate", "MAC-op reduction"],
        &progress,
    );
    let p = &report.prune;
    let ts = &p.per_tenant[0];
    let reduction = 100.0 * ts.mac_reduction();
    print_table(
        "live prune: end of run vs the paper's in-situ training rule",
        &["metric", "this run (serving)", "paper (training)"],
        &[
            vec![
                "MNIST conv-op reduction".into(),
                format!("{reduction:.2}%"),
                format!("{PAPER_MNIST_REDUCTION:.2}%"),
            ],
            vec![
                "ModelNet10 conv-op reduction".into(),
                "— (see pointnet_pruning)".into(),
                format!("{PAPER_MODELNET_REDUCTION:.2}%"),
            ],
            vec!["filters pruned".into(), format!("{}", ts.filters_pruned), "—".into()],
            vec!["cutovers committed".into(), format!("{}", p.cutovers), "—".into()],
            vec!["rows freed to allocator".into(), format!("{}", ts.rows_freed), "—".into()],
            vec!["pool rows now free".into(), format!("{}", ts.quota_headroom_rows), "—".into()],
            vec![
                "max |logit delta| at cutover".into(),
                format!("{:.3}", ts.max_logit_delta),
                "—".into(),
            ],
        ],
    );

    assert_eq!(report.answered(), exact, "nothing silently lost");
    assert_eq!(report.dropped(), 0, "blocking submits never drop");
    assert_eq!(p.aborted, 0, "an ideal pool never aborts a cutover");
    // every planted duplicate is retired; short 9-bit layer-0 kernels
    // can add a few genuine chance look-alikes above the threshold
    assert!(
        ts.filters_pruned >= duplicates,
        "the rule must retire all {duplicates} planted duplicates (got {})",
        ts.filters_pruned
    );
    let dead = ts.live_masks.iter().flatten().filter(|&&b| !b).count() as u64;
    assert_eq!(ts.filters_pruned, dead, "the report's masks account for every pruned filter");
    assert!(ts.rows_freed > 0, "committed cutovers must free rows");
    assert!(
        ts.mac_reduction() >= 0.20,
        "the live loop must cut at least 20% of MAC ops (got {reduction:.2}%)"
    );
    assert!(
        (final_rate - ts.prune_rate).abs() < 1e-9,
        "the report's prune rate matches the committed event sequence"
    );
    println!(
        "\nlive pruning OK: {exact} answers, every one bit-exact against the pruned oracle; \
         {} cutovers retired {} redundant filters mid-serve for a {reduction:.2}% MAC-op \
         reduction (paper, training-side: {PAPER_MNIST_REDUCTION:.2}%)",
        p.cutovers, ts.filters_pruned
    );
    Ok(())
}

//! Serving demo: a 4-chip pool serving 1000 synthetic MNIST requests
//! through the batched, wear-aware serve subsystem — zero drops under
//! the default (blocking) backpressure policy.
//!
//! Run with: `cargo run --release --example serving`

// Terminal output is this target's product; the serve-code print ban
// (workspace clippy.toml `disallowed-macros`) deliberately does not
// apply outside `rust/src/serve/**`.
#![allow(clippy::disallowed_macros)]

use rram_cim::bench::print_table;
use rram_cim::nn::data::mnist;
use rram_cim::serve::{BatcherConfig, ModelBundle, PoolConfig, Server, ServerConfig};

fn main() -> anyhow::Result<()> {
    rram_cim::util::logging::init();
    let n_requests = 1000usize;
    let n_images = 200usize;
    let images = mnist::generate(n_images, 0x5eed);

    // a ~35%-pruned 32-64-32 binary CNN (the dense one would not even
    // fit a single 2x512x32 chip — pruning is a capacity feature too)
    let model = ModelBundle::synthetic_mnist([32, 64, 32], 0.35, 42);
    println!(
        "model: {}/{} live filters, {} array rows @ 30 data cols",
        model.live_filters(),
        model.total_filters(),
        model.rows_required(30)
    );

    let cfg = ServerConfig {
        pool: PoolConfig { chips: 4, ..PoolConfig::default() },
        batcher: BatcherConfig::default(),
    };
    let server = Server::start(model, &cfg)?;

    let mut pending = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        // blocking submit: full queue = wait, never drop
        pending.push(server.submit(images.sample(i % n_images).to_vec()));
    }
    let mut served = 0usize;
    let mut class_counts = [0usize; 10];
    for rx in pending {
        let resp = rx.recv()?;
        let pred = resp
            .logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        class_counts[pred] += 1;
        served += 1;
    }
    let report = server.shutdown();

    assert_eq!(served, n_requests, "every request must be answered");
    assert_eq!(report.stats.dropped, 0, "no drops under blocking backpressure");
    assert_eq!(report.stats.n_requests as usize, n_requests);

    let s = &report.stats;
    println!("\nserved {served} requests, 0 dropped");
    println!("throughput:    {:>10.1} inferences/sec", s.inferences_per_sec());
    println!("latency:       p50 {:.2} ms, p95 {:.2} ms, p99 {:.2} ms", s.p50_ms(), s.p95_ms(), s.p99_ms());
    println!("energy:        {:>10.1} nJ/inference ({:.1} uJ total)", s.nj_per_inference(), s.energy_pj * 1e-6);
    println!("batching:      {:.1} images/batch over {} batches", s.mean_batch(), s.n_batches);
    println!("prediction histogram: {class_counts:?}");

    let rows: Vec<Vec<String>> = report
        .wear
        .iter()
        .enumerate()
        .map(|(i, w)| {
            vec![
                format!("chip {i}"),
                report.rows_used[i].to_string(),
                w.programmed_cells.to_string(),
                w.write_pulses.to_string(),
                w.wl_activations.to_string(),
            ]
        })
        .collect();
    print_table(
        "per-chip shard load + lifetime wear",
        &["chip", "rows", "cells programmed", "write pulses", "WL activations"],
        &rows,
    );
    if report.stuck_retries > 0 {
        println!("(placement routed around {} stuck tiles)", report.stuck_retries);
    }
    println!("\nserving OK");
    Ok(())
}

//! `cargo xtask <command>` — the project task runner. Today there is
//! one command, `lint`, which runs the five serve-fleet invariant
//! passes over `rust/src/**` (DESIGN.md §13).
#![allow(clippy::disallowed_macros)] // a CLI tool prints to stdout by design

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    match args.next().as_deref() {
        Some("lint") => lint(),
        other => {
            eprintln!(
                "usage: cargo xtask lint\n  (got {:?})\n\n\
                 lint — run the five serve invariant passes over rust/src",
                other
            );
            ExitCode::from(2)
        }
    }
}

fn lint() -> ExitCode {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../rust/src");
    let report = match xtask::lint_tree(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("xtask lint: cannot walk {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };

    for v in &report.violations {
        println!("{}:{}: [{}] {}", v.file, v.line, v.pass, v.msg);
    }
    for s in &report.stale {
        println!(
            "{}:{}: [stale-waiver] allow({}) no longer waives anything — delete it",
            s.file,
            s.line,
            s.passes.join(", ")
        );
    }
    for b in &report.bad_waivers {
        println!("{}:{}: [bad-waiver] {}", b.file, b.line, b.what);
    }

    // Waiver census: how much of each invariant is accepted debt. CI
    // logs this every run so the burn-down is visible over time.
    println!("\nwaiver census ({} files scanned):", report.files_scanned);
    for pass in xtask::PASS_NAMES {
        println!("  {:>16}: {} waived", pass, report.census.get(pass).copied().unwrap_or(0));
    }

    if report.clean() {
        println!("\nxtask lint: clean");
        ExitCode::SUCCESS
    } else {
        println!(
            "\nxtask lint: {} violation(s), {} stale waiver(s), {} bad waiver(s)",
            report.violations.len(),
            report.stale.len(),
            report.bad_waivers.len()
        );
        ExitCode::FAILURE
    }
}

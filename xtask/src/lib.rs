//! `cargo xtask lint` — the project-native invariant linter for the
//! serve fleet (DESIGN.md §13). Five passes over `rust/src/**`:
//!
//! 1. `panic-freedom`   — no panicking operators on serve hot paths
//! 2. `epoch-discipline`— shard epochs only from `ShardRouter::next_epoch`
//! 3. `fence-pairing`   — `fence_and_drain` implies rebuild-or-abort
//! 4. `lock-order`      — the static lock-acquisition graph is acyclic
//! 5. `bounded-channel` — no unbounded `mpsc::channel` in `serve/**`
//!
//! Violations are waivable per line with
//! `// lint: allow(<pass>) — <reason>`; a waiver on the line above a
//! `fn` declaration covers the whole body. Unwaived violations fail the
//! build, and so do *stale* waivers — a waiver that no longer waives
//! anything must be deleted, which keeps the census honest.

pub mod analysis;
pub mod lexer;
pub mod passes;

use std::collections::BTreeMap;
use std::path::Path;

pub const PANIC_FREEDOM: &str = "panic-freedom";
pub const EPOCH_DISCIPLINE: &str = "epoch-discipline";
pub const FENCE_PAIRING: &str = "fence-pairing";
pub const LOCK_ORDER: &str = "lock-order";
pub const BOUNDED_CHANNEL: &str = "bounded-channel";

/// Every pass name, in report order.
pub const PASS_NAMES: [&str; 5] =
    [PANIC_FREEDOM, EPOCH_DISCIPLINE, FENCE_PAIRING, LOCK_ORDER, BOUNDED_CHANNEL];

/// One raw (pre-waiver) finding.
#[derive(Clone, Debug)]
pub struct Violation {
    pub pass: &'static str,
    pub file: String,
    pub line: usize,
    pub msg: String,
}

impl Violation {
    pub fn new(pass: &'static str, file: &str, line: usize, msg: String) -> Self {
        Violation { pass, file: file.to_string(), line, msg }
    }
}

/// A waiver that waived nothing — must be deleted.
#[derive(Clone, Debug)]
pub struct StaleWaiver {
    pub file: String,
    pub line: usize,
    pub passes: Vec<String>,
}

/// A malformed `lint:` comment.
#[derive(Clone, Debug)]
pub struct BadWaiverAt {
    pub file: String,
    pub line: usize,
    pub what: String,
}

/// The full lint result: what still fires, what was waived (the
/// census), and the bookkeeping errors that are failures in their own
/// right.
#[derive(Default)]
pub struct LintReport {
    pub violations: Vec<Violation>,
    pub stale: Vec<StaleWaiver>,
    pub bad_waivers: Vec<BadWaiverAt>,
    /// pass name → count of waived findings.
    pub census: BTreeMap<&'static str, usize>,
    pub files_scanned: usize,
}

impl LintReport {
    /// Clean ⇔ CI-green.
    pub fn clean(&self) -> bool {
        self.violations.is_empty() && self.stale.is_empty() && self.bad_waivers.is_empty()
    }
}

struct FileTable {
    waivers: Vec<lexer::Waiver>,
    used: Vec<bool>,
    funs: Vec<analysis::Fun>,
    /// (decl_line → inclusive body line range) per function.
    fun_lines: Vec<(usize, (usize, usize))>,
}

/// Lint a set of already-read sources. Paths are relative to
/// `rust/src` with forward slashes — the pass scoping keys off them.
pub fn lint_sources(sources: &[(String, String)]) -> LintReport {
    let mut report = LintReport::default();
    let mut raw: Vec<Violation> = Vec::new();
    let mut tables: BTreeMap<String, FileTable> = BTreeMap::new();
    let mut all_seqs: Vec<Vec<passes::Acquisition>> = Vec::new();

    for (path, src) in sources {
        report.files_scanned += 1;
        let lexed = lexer::lex(src);
        for b in &lexed.bad_waivers {
            report.bad_waivers.push(BadWaiverAt {
                file: path.clone(),
                line: b.line,
                what: b.what.clone(),
            });
        }
        let mask = analysis::test_mask(&lexed.toks);
        let funs = analysis::functions(&lexed.toks, &mask);
        let ctx = passes::FileCtx { path, toks: &lexed.toks, mask: &mask, funs: &funs };
        passes::panic_freedom(&ctx, &mut raw);
        passes::epoch_discipline(&ctx, &mut raw);
        passes::fence_pairing(&ctx, &mut raw);
        passes::bounded_channel(&ctx, &mut raw);
        all_seqs.extend(passes::lock_sequences(&ctx));
        let fun_lines =
            funs.iter().map(|f| (f.decl_line, f.body_lines(&lexed.toks))).collect::<Vec<_>>();
        let used = vec![false; lexed.waivers.len()];
        tables.insert(path.clone(), FileTable { waivers: lexed.waivers, used, funs, fun_lines });
    }

    passes::lock_order(&all_seqs, &mut raw);

    for pass in PASS_NAMES {
        report.census.insert(pass, 0);
    }
    for v in raw {
        if let Some(t) = tables.get_mut(&v.file) {
            if waive(t, &v) {
                *report.census.entry(v.pass).or_insert(0) += 1;
                continue;
            }
        }
        report.violations.push(v);
    }

    for (path, t) in &tables {
        for (w, used) in t.waivers.iter().zip(&t.used) {
            if !used {
                report.stale.push(StaleWaiver {
                    file: path.clone(),
                    line: w.line,
                    passes: w.passes.clone(),
                });
            }
        }
    }

    report.violations.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    report.stale.sort_by(|a, b| (a.file.clone(), a.line).cmp(&(b.file.clone(), b.line)));
    report
}

/// Try to waive `v` against its file's waivers; marks the waiver used.
///
/// A waiver covers a violation when it names the pass and either
/// (a) sits on the violating line or the line just above, or
/// (b) sits on (or just above) a `fn` declaration line whose body
///     contains the violating line — the function-level form.
fn waive(t: &mut FileTable, v: &Violation) -> bool {
    let funs = &t.funs;
    let fun_lines = &t.fun_lines;
    let hit = t.waivers.iter().position(|w| {
        if !w.passes.iter().any(|p| p == v.pass) {
            return false;
        }
        let line_level = w.line == v.line || w.line + 1 == v.line;
        let fun_level = funs.iter().zip(fun_lines).any(|(f, (decl, (lo, hi)))| {
            let anchors = w.line == *decl || w.line + 1 == *decl;
            anchors && !f.test && (v.line == *decl || (*lo <= v.line && v.line <= *hi))
        });
        line_level || fun_level
    });
    match hit {
        Some(k) => {
            t.used[k] = true;
            true
        }
        None => false,
    }
}

/// Recursively collect `**/*.rs` under `root` (sorted, deterministic)
/// and lint them. Paths in the report are relative to `root`.
pub fn lint_tree(root: &Path) -> std::io::Result<LintReport> {
    let mut files: Vec<std::path::PathBuf> = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut sources = Vec::with_capacity(files.len());
    for f in &files {
        let rel = f
            .strip_prefix(root)
            .unwrap_or(f)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        sources.push((rel, std::fs::read_to_string(f)?));
    }
    Ok(lint_sources(&sources))
}

fn collect_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}

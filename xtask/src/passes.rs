//! The five serve-fleet invariant passes (DESIGN.md §13). Each pass
//! walks one file's token stream (lock-order additionally folds its
//! per-function sequences into one cross-file graph) and emits raw
//! violations; waiver resolution happens in the driver.

use std::collections::BTreeMap;

use crate::analysis::{Fun, KEYWORDS};
use crate::lexer::Tok;
use crate::{Violation, BOUNDED_CHANNEL, EPOCH_DISCIPLINE, FENCE_PAIRING, PANIC_FREEDOM};

/// One scanned file plus its derived structure.
pub struct FileCtx<'a> {
    /// Path relative to `rust/src`, forward slashes.
    pub path: &'a str,
    pub toks: &'a [Tok],
    /// Test-region mask, same length as `toks`.
    pub mask: &'a [bool],
    pub funs: &'a [Fun],
}

impl FileCtx<'_> {
    fn at(&self, i: usize) -> &str {
        self.toks.get(i).map(|t| t.text.as_str()).unwrap_or("")
    }

    fn before(&self, i: usize, back: usize) -> &str {
        i.checked_sub(back).map(|k| self.at(k)).unwrap_or("")
    }
}

/// Panic-freedom scope: the three serve subsystems whose hot paths must
/// surface faults as typed transport errors, never panics.
fn in_panic_scope(path: &str) -> bool {
    ["serve/transport/", "serve/engine/", "serve/prune/"].iter().any(|d| path.starts_with(d))
}

fn in_serve(path: &str) -> bool {
    path.starts_with("serve/")
}

/// **panic-freedom** — no `.unwrap()` / `.expect(…)` / `panic!` /
/// `todo!` / `unimplemented!` / slice-index in
/// `serve/{transport,engine,prune}` outside `#[cfg(test)]`.
/// (`unreachable!` and `assert!` stay legal: both mark *checked*
/// invariants, the documented crash-on-corruption policy.)
pub fn panic_freedom(f: &FileCtx, out: &mut Vec<Violation>) {
    if !in_panic_scope(f.path) {
        return;
    }
    for (i, t) in f.toks.iter().enumerate() {
        if f.mask[i] {
            continue;
        }
        match t.text.as_str() {
            "unwrap" | "expect" if f.before(i, 1) == "." && f.at(i + 1) == "(" => {
                out.push(Violation::new(
                    PANIC_FREEDOM,
                    f.path,
                    t.line,
                    format!(".{}() can panic on the serve hot path", t.text),
                ));
            }
            "panic" | "todo" | "unimplemented" if f.at(i + 1) == "!" => {
                out.push(Violation::new(
                    PANIC_FREEDOM,
                    f.path,
                    t.line,
                    format!("{}! is banned on the serve hot path", t.text),
                ));
            }
            "[" if i > 0 => {
                let p = f.before(i, 1);
                let is_index = p == "]"
                    || p == ")"
                    || p == "?"
                    || (f.toks[i - 1].is_ident() && !KEYWORDS.contains(&p));
                if is_index {
                    out.push(Violation::new(
                        PANIC_FREEDOM,
                        f.path,
                        t.line,
                        "slice/array index can panic; bound-check or use .get()".to_string(),
                    ));
                }
            }
            _ => {}
        }
    }
}

/// Field names that carry a shard epoch.
const EPOCH_FIELDS: [&str; 4] = ["epoch", "shard_epoch", "old_epoch", "new_epoch"];

/// **epoch-discipline** — shard epochs originate from
/// `ShardRouter::next_epoch` only: no integer literal may flow into an
/// epoch field or binding (`epoch: 3`, `route.epoch = 0`) outside
/// tests, anywhere under `serve/`.
pub fn epoch_discipline(f: &FileCtx, out: &mut Vec<Violation>) {
    if !in_serve(f.path) {
        return;
    }
    for (i, t) in f.toks.iter().enumerate() {
        if f.mask[i] || !EPOCH_FIELDS.contains(&t.text.as_str()) {
            continue;
        }
        let assigns_literal = (f.at(i + 1) == ":" || f.at(i + 1) == "=")
            && f.toks.get(i + 2).map(|n| n.is_int()).unwrap_or(false);
        if assigns_literal {
            out.push(Violation::new(
                EPOCH_DISCIPLINE,
                f.path,
                t.line,
                format!(
                    "integer literal flows into `{}`; epochs originate from \
                     ShardRouter::next_epoch",
                    t.text
                ),
            ));
        }
    }
}

/// Identifiers whose presence in a fencing function witnesses the
/// route/mask rebuild or the abort path the fence machine requires.
const FENCE_FOLLOWUPS: [&str; 4] = ["from_placement", "next_epoch", "rollback_partial", "Aborted"];

/// **fence-pairing** — a function calling `fence_and_drain` must, in
/// the same body, rebuild the route (`from_placement` / `next_epoch`)
/// or carry an abort path (`rollback…` / `Aborted` / `?` on the call).
pub fn fence_pairing(f: &FileCtx, out: &mut Vec<Violation>) {
    if !in_serve(f.path) {
        return;
    }
    for fun in f.funs.iter().filter(|fun| !fun.test) {
        let Some((lo, hi)) = fun.body else { continue };
        let body = &f.toks[lo..=hi];
        let mut call_line = None;
        let mut propagated = false;
        for (k, t) in body.iter().enumerate() {
            if t.text == "fence_and_drain"
                && body.get(k + 1).map(|n| n.text.as_str()) == Some("(")
                && k.checked_sub(1).map(|p| body[p].text.as_str()) != Some("fn")
            {
                call_line = Some(t.line);
                let close = matching_paren(body, k + 1);
                if body.get(close + 1).map(|n| n.text.as_str()) == Some("?") {
                    propagated = true;
                }
            }
        }
        let Some(line) = call_line else { continue };
        let rebuilds = body.iter().any(|t| {
            FENCE_FOLLOWUPS.contains(&t.text.as_str()) || t.text.starts_with("rollback")
        });
        if !rebuilds && !propagated {
            out.push(Violation::new(
                FENCE_PAIRING,
                f.path,
                line,
                format!(
                    "`{}` fences and drains but neither rebuilds the route/masks \
                     nor propagates an abort",
                    fun.name
                ),
            ));
        }
    }
}

/// Index of the `)` matching the `(` at `open` within `toks`.
fn matching_paren(toks: &[Tok], open: usize) -> usize {
    let mut depth = 0usize;
    for (k, t) in toks.iter().enumerate().skip(open) {
        match t.text.as_str() {
            "(" => depth += 1,
            ")" => {
                depth -= 1;
                if depth == 0 {
                    return k;
                }
            }
            _ => {}
        }
    }
    toks.len().saturating_sub(1)
}

/// One lock acquisition: the lock's stable name and where it happened.
#[derive(Clone, Debug)]
pub struct Acquisition {
    pub lock: String,
    pub file: String,
    pub line: usize,
}

/// **lock-order**, collection half — the ordered per-function lock
/// acquisition sequences of one file. Recognizes both raw
/// `receiver.lock()` and the project's `lock_unpoisoned(&receiver)`
/// helper; lock identity is `<file stem>.<receiver tail>`, so distinct
/// files can never falsely alias.
pub fn lock_sequences(f: &FileCtx) -> Vec<Vec<Acquisition>> {
    if !in_serve(f.path) {
        return Vec::new();
    }
    let stem = f.path.rsplit('/').next().unwrap_or(f.path).trim_end_matches(".rs");
    let mut seqs = Vec::new();
    for fun in f.funs.iter().filter(|fun| !fun.test) {
        let Some((lo, hi)) = fun.body else { continue };
        let body = &f.toks[lo..=hi];
        let mut seq: Vec<Acquisition> = Vec::new();
        for (k, t) in body.iter().enumerate() {
            let name = match t.text.as_str() {
                "lock"
                    if k >= 1
                        && body[k - 1].text == "."
                        && body.get(k + 1).map(|n| n.text.as_str()) == Some("(") =>
                {
                    receiver_tail(body, k - 1)
                }
                "lock_unpoisoned"
                    if body.get(k + 1).map(|n| n.text.as_str()) == Some("(") =>
                {
                    argument_tail(body, k + 1)
                }
                _ => None,
            };
            if let Some(name) = name {
                let lock = format!("{stem}.{name}");
                if !seq.iter().any(|a| a.lock == lock) {
                    seq.push(Acquisition { lock, file: f.path.to_string(), line: t.line });
                }
            }
        }
        if seq.len() > 1 {
            seqs.push(seq);
        }
    }
    seqs
}

/// Tail component of the receiver chain ending at the `.` at `dot`
/// (`self.inner.0.lock()` → `inner.0`, `ring.lock()` → `ring`).
fn receiver_tail(body: &[Tok], dot: usize) -> Option<String> {
    let last = body.get(dot.checked_sub(1)?)?;
    if last.is_int() {
        // tuple index: include the field it projects from
        if dot >= 3 && body[dot - 2].text == "." && body[dot - 3].is_ident() {
            return Some(format!("{}.{}", body[dot - 3].text, last.text));
        }
        return Some(last.text.clone());
    }
    last.is_ident().then(|| last.text.clone())
}

/// Tail identifier of a call's first argument (`&self.series[k]` →
/// `series`, `lock` → `lock`), skipping `&`/`*`/`self` and subscripts.
fn argument_tail(body: &[Tok], open: usize) -> Option<String> {
    let close = matching_paren(body, open);
    let mut tail: Option<String> = None;
    let mut k = open + 1;
    while k < close {
        match body[k].text.as_str() {
            "&" | "*" | "self" | "." => {}
            "[" => {
                // skip the subscript: the container is the lock
                let mut depth = 1usize;
                while k + 1 < close && depth > 0 {
                    k += 1;
                    match body[k].text.as_str() {
                        "[" => depth += 1,
                        "]" => depth -= 1,
                        _ => {}
                    }
                }
            }
            "," => break,
            txt => {
                if body[k].is_ident() {
                    tail = Some(txt.to_string());
                } else if body[k].is_int() {
                    tail = Some(match tail {
                        Some(prev) => format!("{prev}.{txt}"),
                        None => txt.to_string(),
                    });
                }
            }
        }
        k += 1;
    }
    tail
}

/// **lock-order**, graph half — fold every function's acquisition
/// sequence into one directed graph and reject cycles (a static
/// deadlock detector for the coordinator/router/obs triangle).
pub fn lock_order(seqs: &[Vec<Acquisition>], out: &mut Vec<Violation>) {
    // edge (a → b) with one representative site (of b's acquisition)
    let mut edges: BTreeMap<(String, String), (String, usize)> = BTreeMap::new();
    for seq in seqs {
        for i in 0..seq.len() {
            for j in (i + 1)..seq.len() {
                edges
                    .entry((seq[i].lock.clone(), seq[j].lock.clone()))
                    .or_insert((seq[j].file.clone(), seq[j].line));
            }
        }
    }
    let mut adj: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
    for (a, b) in edges.keys() {
        adj.entry(a).or_default().push(b);
        adj.entry(b).or_default();
    }
    // iterative DFS cycle detection over the deterministic adjacency
    let mut state: BTreeMap<&str, u8> = adj.keys().map(|&k| (k, 0u8)).collect();
    for &start in adj.keys() {
        if state[start] != 0 {
            continue;
        }
        let mut stack: Vec<(&str, usize)> = vec![(start, 0)];
        let mut path: Vec<&str> = vec![start];
        state.insert(start, 1);
        while let Some((node, next)) = stack.last().copied() {
            let succs = &adj[node];
            if next < succs.len() {
                if let Some(s) = stack.last_mut() {
                    s.1 += 1;
                }
                let succ = succs[next];
                if state[succ] == 1 {
                    // found a cycle: report it once, anchored at the
                    // edge that closes it
                    let from = *path.last().unwrap_or(&succ);
                    let (file, line) =
                        edges.get(&(from.to_string(), succ.to_string())).cloned().unwrap_or_else(
                            || ("<unknown>".to_string(), 0),
                        );
                    let cycle_start = path.iter().position(|&n| n == succ).unwrap_or(0);
                    let mut cycle: Vec<&str> = path[cycle_start..].to_vec();
                    cycle.push(succ);
                    out.push(Violation::new(
                        crate::LOCK_ORDER,
                        &file,
                        line,
                        format!("lock-order cycle: {}", cycle.join(" -> ")),
                    ));
                    return; // one cycle is already a build-stopper
                }
                if state[succ] == 0 {
                    state.insert(succ, 1);
                    stack.push((succ, 0));
                    path.push(succ);
                }
            } else {
                state.insert(node, 2);
                stack.pop();
                path.pop();
            }
        }
    }
}

/// **bounded-channel** — no unbounded `mpsc::channel` under `serve/`
/// outside tests: every queue is a bounded `sync_channel` or an
/// explicit ring, so backpressure is designed, never accidental.
pub fn bounded_channel(f: &FileCtx, out: &mut Vec<Violation>) {
    if !in_serve(f.path) {
        return;
    }
    for (i, t) in f.toks.iter().enumerate() {
        if f.mask[i] || t.text != "channel" {
            continue;
        }
        let next = f.at(i + 1);
        if next != "(" && next != "::" {
            continue; // an import list or a stray mention, not a call
        }
        let prev = f.before(i, 1);
        let qualified_mpsc = prev == "::" && f.before(i, 2) == "mpsc";
        let bare_call = prev != "::" && prev != "." && prev != "fn";
        if qualified_mpsc || bare_call {
            out.push(Violation::new(
                BOUNDED_CHANNEL,
                f.path,
                t.line,
                "unbounded mpsc::channel in serve code; use sync_channel or an explicit ring"
                    .to_string(),
            ));
        }
    }
}

//! Structural analysis over the token stream: `#[cfg(test)]` region
//! masking, brace matching, and per-function body extraction. All three
//! are conservative over-approximations — good enough to scope lint
//! passes, far short of real name resolution.

use crate::lexer::Tok;

/// Rust keywords that may legally precede a `[` without the bracket
/// being an index expression (`return [a, b]`, `for [x, y] in …`).
pub const KEYWORDS: [&str; 36] = [
    "as", "async", "await", "box", "break", "const", "continue", "crate", "dyn", "else", "enum",
    "extern", "false", "fn", "for", "if", "impl", "in", "let", "loop", "match", "mod", "move",
    "mut", "pub", "ref", "return", "static", "struct", "super", "trait", "true", "type", "unsafe",
    "use", "where",
];

/// Index of the `}` matching the `{` at `open` (or the last token when
/// unbalanced — a scan must never walk off the end).
pub fn match_brace(toks: &[Tok], open: usize) -> usize {
    let mut depth = 0usize;
    for (k, t) in toks.iter().enumerate().skip(open) {
        match t.text.as_str() {
            "{" => depth += 1,
            "}" => {
                depth -= 1;
                if depth == 0 {
                    return k;
                }
            }
            _ => {}
        }
    }
    toks.len().saturating_sub(1)
}

/// `mask[i] == true` ⇔ token `i` lives inside an item annotated with a
/// test attribute (`#[cfg(test)] mod tests { … }`, `#[test] fn …`).
/// Any attribute containing the bare identifier `test` counts, which
/// also covers `#[cfg(all(test, …))]`.
pub fn test_mask(toks: &[Tok]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        if toks[i].text == "#" && toks.get(i + 1).map(|t| t.text.as_str()) == Some("[") {
            let (end_attr, has_test) = scan_attribute(toks, i + 1);
            if has_test {
                let mut k = end_attr + 1;
                // skip any further attributes on the same item
                while toks.get(k).map(|t| t.text.as_str()) == Some("#")
                    && toks.get(k + 1).map(|t| t.text.as_str()) == Some("[")
                {
                    k = scan_attribute(toks, k + 1).0 + 1;
                }
                let end = item_end(toks, k);
                for m in mask.iter_mut().take((end + 1).min(toks.len())).skip(i) {
                    *m = true;
                }
                i = end + 1;
                continue;
            }
            i = end_attr + 1;
            continue;
        }
        i += 1;
    }
    mask
}

/// Scan an attribute whose `[` sits at `open`; returns (index of the
/// closing `]`, whether the bare identifier `test` appears inside).
fn scan_attribute(toks: &[Tok], open: usize) -> (usize, bool) {
    let mut depth = 0usize;
    let mut has_test = false;
    for (k, t) in toks.iter().enumerate().skip(open) {
        match t.text.as_str() {
            "[" => depth += 1,
            "]" => {
                depth -= 1;
                if depth == 0 {
                    return (k, has_test);
                }
            }
            "test" => has_test = true,
            _ => {}
        }
    }
    (toks.len().saturating_sub(1), has_test)
}

/// Index of the last token of the item starting at `k`: the matching
/// `}` of its first top-level `{`, or its terminating `;`.
fn item_end(toks: &[Tok], k: usize) -> usize {
    let mut depth = 0i64;
    let mut j = k;
    while j < toks.len() {
        match toks[j].text.as_str() {
            "(" | "[" => depth += 1,
            ")" | "]" => depth -= 1,
            ";" if depth == 0 => return j,
            "{" if depth == 0 => return match_brace(toks, j),
            _ => {}
        }
        j += 1;
    }
    toks.len().saturating_sub(1)
}

/// One extracted function.
#[derive(Clone, Debug)]
pub struct Fun {
    pub name: String,
    /// Line of the `fn` keyword (what a function-level waiver anchors to).
    pub decl_line: usize,
    /// Token range of the body (`{` ..= `}`), `None` for a bodyless
    /// trait-method signature.
    pub body: Option<(usize, usize)>,
    /// Declared inside a test region?
    pub test: bool,
}

impl Fun {
    /// Source lines the body spans (inclusive), empty range when bodyless.
    pub fn body_lines(&self, toks: &[Tok]) -> (usize, usize) {
        match self.body {
            Some((a, b)) => (toks[a].line, toks[b].line),
            None => (self.decl_line, self.decl_line),
        }
    }
}

/// Every `fn` item in the stream (including nested fns — their tokens
/// then belong to both bodies, which only ever *widens* waiver scope).
pub fn functions(toks: &[Tok], mask: &[bool]) -> Vec<Fun> {
    let mut out = Vec::new();
    for (i, t) in toks.iter().enumerate() {
        if t.text != "fn" {
            continue;
        }
        let Some(name_tok) = toks.get(i + 1) else { continue };
        if !name_tok.is_ident() {
            continue; // `fn(usize) -> T` pointer type, not an item
        }
        let mut depth = 0i64;
        let mut body = None;
        let mut j = i + 2;
        while j < toks.len() {
            match toks[j].text.as_str() {
                "(" | "[" => depth += 1,
                ")" | "]" => depth -= 1,
                ";" if depth == 0 => break,
                "{" if depth == 0 => {
                    body = Some((j, match_brace(toks, j)));
                    break;
                }
                _ => {}
            }
            j += 1;
        }
        out.push(Fun { name: name_tok.text.clone(), decl_line: t.line, body, test: mask[i] });
    }
    out
}

//! A minimal Rust token scanner: just enough structure for the lint
//! passes — identifiers, literals, and (multi-char) punctuation, with
//! string/char literals collapsed and comments diverted to the waiver
//! parser. Deliberately *not* a full lexer: the passes only need token
//! adjacency and brace/paren balance, and a hand-rolled scanner is what
//! the offline image can build without `syn`.

/// One retained token (identifier, literal, or punctuation).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Tok {
    pub text: String,
    pub line: usize,
}

impl Tok {
    /// Identifier-shaped (starts with a letter or `_`)?
    pub fn is_ident(&self) -> bool {
        self.text
            .chars()
            .next()
            .map(|c| c.is_alphabetic() || c == '_')
            .unwrap_or(false)
    }

    /// Integer-literal-shaped (starts with a digit)?
    pub fn is_int(&self) -> bool {
        self.text.chars().next().map(|c| c.is_ascii_digit()).unwrap_or(false)
    }
}

/// One parsed `// lint: allow(<pass>) — <reason>` waiver comment.
#[derive(Clone, Debug)]
pub struct Waiver {
    pub line: usize,
    pub passes: Vec<String>,
    pub reason: String,
}

/// A comment that names `lint:` but does not parse as a waiver — always
/// an error, so a typo'd waiver can never silently stop waiving.
#[derive(Clone, Debug)]
pub struct BadWaiver {
    pub line: usize,
    pub what: String,
}

/// A scanned source file.
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub waivers: Vec<Waiver>,
    pub bad_waivers: Vec<BadWaiver>,
}

/// Two-character operators kept as single tokens so the passes can
/// match `=` (assignment) without tripping over `==`, `=>`, `<=`, …
const TWO_CHAR_OPS: [&str; 14] = [
    "::", "==", "!=", "<=", ">=", "=>", "->", "+=", "-=", "*=", "/=", "&&", "||", "..",
];

/// Scan one file into tokens + waivers. Strings and chars are dropped
/// (their content can never be a call site); comments are parsed for
/// waivers and dropped.
pub fn lex(src: &str) -> Lexed {
    let b: Vec<char> = src.chars().collect();
    let mut toks: Vec<Tok> = Vec::new();
    let mut waivers = Vec::new();
    let mut bad_waivers = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < b.len() {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
        } else if c.is_whitespace() {
            i += 1;
        } else if c == '/' && b.get(i + 1) == Some(&'/') {
            let start = i;
            while i < b.len() && b[i] != '\n' {
                i += 1;
            }
            let text: String = b[start..i].iter().collect();
            parse_waiver(&text, line, &mut waivers, &mut bad_waivers);
        } else if c == '/' && b.get(i + 1) == Some(&'*') {
            i += 2;
            let mut depth = 1usize;
            while i < b.len() && depth > 0 {
                if b[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == '/' && b.get(i + 1) == Some(&'*') {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && b.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
        } else if c == '"' {
            i = skip_quoted(&b, i + 1, &mut line);
        } else if (c == 'r' || c == 'b') && string_prefix_len(&b, i) > 0 {
            i = skip_prefixed_literal(&b, i, &mut line);
        } else if c == '\'' {
            i = skip_char_or_lifetime(&b, i, &mut line);
        } else if c.is_alphanumeric() || c == '_' {
            let start = i;
            while i < b.len() && (b[i].is_alphanumeric() || b[i] == '_') {
                i += 1;
            }
            toks.push(Tok { text: b[start..i].iter().collect(), line });
        } else {
            let pair: String = b[i..(i + 2).min(b.len())].iter().collect();
            if TWO_CHAR_OPS.contains(&pair.as_str()) {
                toks.push(Tok { text: pair, line });
                i += 2;
            } else {
                toks.push(Tok { text: c.to_string(), line });
                i += 1;
            }
        }
    }
    Lexed { toks, waivers, bad_waivers }
}

/// Length of a raw/byte string prefix at `i` (`r"`, `r#`, `b"`, `b'`,
/// `br"`, `br#`), or 0 when `b[i]` starts a plain identifier.
fn string_prefix_len(b: &[char], i: usize) -> usize {
    let rest: String = b[i..(i + 3).min(b.len())].iter().collect();
    for p in ["br#", "br\"", "r#", "r\"", "b\"", "b'"] {
        if rest.starts_with(p) {
            return p.len();
        }
    }
    0
}

/// Skip a plain `"…"` body starting just *after* the opening quote;
/// returns the index just past the closing quote.
fn skip_quoted(b: &[char], mut i: usize, line: &mut usize) -> usize {
    while i < b.len() {
        match b[i] {
            // An escape consumes the next char too; `\<newline>` (the
            // line-continuation form) still ends a physical line, so
            // count it or every report past it drifts by one.
            '\\' => {
                if b.get(i + 1) == Some(&'\n') {
                    *line += 1;
                }
                i += 2;
            }
            '"' => return i + 1,
            '\n' => {
                *line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

/// Skip a raw/byte string or byte char starting at its `r`/`b` prefix.
fn skip_prefixed_literal(b: &[char], mut i: usize, line: &mut usize) -> usize {
    // consume the prefix letters
    while i < b.len() && (b[i] == 'r' || b[i] == 'b') {
        i += 1;
    }
    if b.get(i) == Some(&'\'') {
        // byte char b'…'
        return skip_char_or_lifetime(b, i, line);
    }
    let mut hashes = 0usize;
    while b.get(i) == Some(&'#') {
        hashes += 1;
        i += 1;
    }
    if b.get(i) != Some(&'"') {
        return i; // not actually a string (e.g. `r#raw_ident`)
    }
    i += 1;
    if hashes == 0 {
        // raw (no-escape) when preceded by r, else plain byte string
        // — either way escapes cannot hide the closing quote from a
        // conservative scan that also honors backslashes
        return skip_quoted(b, i, line);
    }
    // r#"…"# with `hashes` terminating hashes
    while i < b.len() {
        if b[i] == '\n' {
            *line += 1;
        }
        if b[i] == '"' && b[i + 1..].iter().take(hashes).filter(|&&c| c == '#').count() == hashes {
            return i + 1 + hashes;
        }
        i += 1;
    }
    i
}

/// Skip a `'c'` char literal or a `'lifetime` starting at the quote.
fn skip_char_or_lifetime(b: &[char], i: usize, line: &mut usize) -> usize {
    match b.get(i + 1) {
        Some('\\') => {
            // escaped char literal: skip quote, backslash, escaped
            // char, then scan to the closing quote
            let mut j = i + 3;
            while j < b.len() && b[j] != '\'' {
                if b[j] == '\n' {
                    *line += 1;
                }
                j += 1;
            }
            j + 1
        }
        Some(&ch) if (ch == '_' || ch.is_alphabetic()) && b.get(i + 2) != Some(&'\'') => {
            // lifetime: consume the identifier, no closing quote
            let mut j = i + 1;
            while j < b.len() && (b[j].is_alphanumeric() || b[j] == '_') {
                j += 1;
            }
            j
        }
        _ => {
            // plain char literal 'x'
            let mut j = i + 2;
            while j < b.len() && b[j] != '\'' {
                if b[j] == '\n' {
                    *line += 1;
                }
                j += 1;
            }
            j + 1
        }
    }
}

/// Parse one `//` comment for a waiver. Doc comments cannot carry
/// waivers (they render into rustdoc); a `lint:` mention that fails to
/// parse is reported, never ignored.
fn parse_waiver(
    comment: &str,
    line: usize,
    waivers: &mut Vec<Waiver>,
    bad: &mut Vec<BadWaiver>,
) {
    let Some(pos) = comment.find("lint:") else { return };
    if comment.starts_with("///") || comment.starts_with("//!") {
        bad.push(BadWaiver {
            line,
            what: "waivers must use a plain // comment, not a doc comment".into(),
        });
        return;
    }
    let rest = comment[pos + "lint:".len()..].trim_start();
    let Some(names) = rest.strip_prefix("allow(") else {
        bad.push(BadWaiver { line, what: "expected `lint: allow(<pass>) — <reason>`".into() });
        return;
    };
    let Some(close) = names.find(')') else {
        bad.push(BadWaiver { line, what: "unclosed `allow(`".into() });
        return;
    };
    let passes: Vec<String> =
        names[..close].split(',').map(|p| p.trim().to_string()).filter(|p| !p.is_empty()).collect();
    if passes.is_empty() || passes.iter().any(|p| !crate::PASS_NAMES.contains(&p.as_str())) {
        bad.push(BadWaiver {
            line,
            what: format!("unknown pass in allow(…); passes are {:?}", crate::PASS_NAMES),
        });
        return;
    }
    let after = names[close + 1..].trim_start();
    let reason = after
        .strip_prefix('\u{2014}')
        .or_else(|| after.strip_prefix('-'))
        .map(str::trim)
        .unwrap_or("");
    if reason.is_empty() {
        bad.push(BadWaiver {
            line,
            what: "waiver needs a reason: `lint: allow(<pass>) — <reason>`".into(),
        });
        return;
    }
    waivers.push(Waiver { line, passes, reason: reason.to_string() });
}

//! Linter acceptance tests: each pass is demonstrated by a known-bad
//! fixture that must fail and a clean fixture that must pass, the
//! waiver machinery is exercised in both directions (used and stale),
//! and — the production gate — the real `rust/src` tree lints clean.

use std::path::PathBuf;

use xtask::{lint_sources, LintReport};

fn lint_one(path: &str, src: &str) -> LintReport {
    lint_sources(&[(path.to_string(), src.to_string())])
}

fn count(report: &LintReport, pass: &str) -> usize {
    report.violations.iter().filter(|v| v.pass == pass).count()
}

#[test]
fn panic_freedom_fixture_fails() {
    let src = include_str!("fixtures/panic_freedom_bad.rs");
    let report = lint_one("serve/engine/panic_fixture.rs", src);
    assert_eq!(count(&report, "panic-freedom"), 4, "{:?}", report.violations);
    assert!(!report.clean());
}

#[test]
fn panic_freedom_scoped_to_hot_subsystems() {
    // the same snippet outside serve/{transport,engine,prune} is legal
    let src = include_str!("fixtures/panic_freedom_bad.rs");
    for path in ["cim/kernel.rs", "serve/obs/trace.rs", "util/json.rs"] {
        let report = lint_one(path, src);
        assert_eq!(count(&report, "panic-freedom"), 0, "false positive in {path}");
    }
}

#[test]
fn epoch_fixture_fails() {
    let report = lint_one("serve/router_fixture.rs", include_str!("fixtures/epoch_bad.rs"));
    assert_eq!(count(&report, "epoch-discipline"), 2, "{:?}", report.violations);
}

#[test]
fn fence_fixture_fails() {
    let report = lint_one("serve/cutover_fixture.rs", include_str!("fixtures/fence_bad.rs"));
    assert_eq!(count(&report, "fence-pairing"), 1, "{:?}", report.violations);
    assert!(report.violations[0].msg.contains("bad_cutover"));
}

#[test]
fn lock_order_fixture_fails() {
    let report = lint_one("serve/obs/lock_fixture.rs", include_str!("fixtures/lock_order_bad.rs"));
    assert_eq!(count(&report, "lock-order"), 1, "{:?}", report.violations);
    assert!(report.violations.iter().any(|v| v.msg.contains("cycle")));
}

#[test]
fn lock_order_cycle_spans_files() {
    // AB in one file, BA in another: the graph must still close the loop
    let ab = "fn f(&self) { let _a = self.alpha.lock().unwrap(); g(); \
              let _b = lock_unpoisoned(&self.beta); }";
    let ba = "fn g(&self) { let _b = self.beta.lock().unwrap(); \
              let _a = self.alpha.lock().unwrap(); }";
    // same stem on purpose — lock identity is `<stem>.<field>`
    let report = lint_sources(&[
        ("serve/a/graph.rs".to_string(), ab.to_string()),
        ("serve/b/graph.rs".to_string(), ba.to_string()),
    ]);
    assert_eq!(count(&report, "lock-order"), 1, "{:?}", report.violations);
}

#[test]
fn bounded_channel_fixture_fails() {
    let report = lint_one("serve/fleet_fixture.rs", include_str!("fixtures/channel_bad.rs"));
    assert_eq!(count(&report, "bounded-channel"), 2, "{:?}", report.violations);
}

#[test]
fn clean_fixture_passes_with_used_waiver() {
    let report = lint_one("serve/transport/clean_fixture.rs", include_str!("fixtures/clean.rs"));
    assert!(report.clean(), "violations: {:?} stale: {:?}", report.violations, report.stale);
    // the one waived finding shows up in the census, not as a violation
    assert_eq!(report.census["panic-freedom"], 2);
}

#[test]
fn stale_waiver_fails() {
    let src = "// lint: allow(bounded-channel) — obsolete\nfn quiet() {}\n";
    let report = lint_one("serve/quiet.rs", src);
    assert!(report.violations.is_empty());
    assert_eq!(report.stale.len(), 1);
    assert!(!report.clean());
}

#[test]
fn malformed_waiver_fails() {
    for src in [
        "// lint: allowed(panic-freedom) — typo\n",
        "// lint: allow(no-such-pass) — unknown\n",
        "// lint: allow(panic-freedom)\n",
        "/// lint: allow(panic-freedom) — doc comments cannot waive\n",
    ] {
        let report = lint_one("serve/w.rs", src);
        assert_eq!(report.bad_waivers.len(), 1, "src: {src}");
        assert!(!report.clean());
    }
}

#[test]
fn function_level_waiver_covers_whole_body() {
    let src = "// lint: allow(panic-freedom) — indices validated at entry\n\
               fn fold(&self, dvec: &[i32], y: &mut [i32]) {\n\
                   y[0] = dvec[1] + dvec[2];\n\
               }\n";
    let report = lint_one("serve/engine/fold.rs", src);
    assert!(report.clean(), "{:?}", report.violations);
    assert_eq!(report.census["panic-freedom"], 3);
}

#[test]
fn test_regions_are_exempt() {
    let src = "#[cfg(test)]\nmod tests {\n    fn f() { let x = v[0].unwrap(); \
               let (tx, _) = channel(); tx.send(x); }\n}\n";
    let report = lint_one("serve/engine/t.rs", src);
    assert!(report.clean(), "{:?}", report.violations);
}

#[test]
fn cam_front_end_is_in_scope_with_no_waivers() {
    // the CAM front end (serve/engine/cam.rs) is hot-path serve code:
    // the panic-freedom and bounded-channel passes must cover its path
    let src = "fn probe(&mut self) { let e = self.entries[0].unwrap(); \
               let (tx, _rx) = mpsc::channel(); tx.send(e); }";
    let report = lint_one("serve/engine/cam.rs", src);
    assert!(count(&report, "panic-freedom") >= 2, "{:?}", report.violations);
    assert!(count(&report, "bounded-channel") >= 1, "{:?}", report.violations);
    // and the real file earns that coverage without a single waiver
    let real = std::fs::read_to_string(
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../rust/src/serve/engine/cam.rs"),
    )
    .expect("read the real cam.rs");
    assert!(!real.contains("lint: allow("), "cam.rs must stay waiver-free");
}

#[test]
fn real_tree_lints_clean() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../rust/src");
    let report = xtask::lint_tree(&root).expect("walk rust/src");
    let mut diag = String::new();
    for v in &report.violations {
        diag.push_str(&format!("{}:{}: [{}] {}\n", v.file, v.line, v.pass, v.msg));
    }
    for s in &report.stale {
        diag.push_str(&format!("{}:{}: stale allow({})\n", s.file, s.line, s.passes.join(",")));
    }
    for b in &report.bad_waivers {
        diag.push_str(&format!("{}:{}: bad waiver: {}\n", b.file, b.line, b.what));
    }
    assert!(report.clean(), "rust/src must lint clean:\n{diag}");
    assert!(report.files_scanned > 20, "expected the full tree, saw {}", report.files_scanned);
}

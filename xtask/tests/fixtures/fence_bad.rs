// Fixture: must FAIL fence-pairing under serve/. The function fences
// and drains but neither rebuilds the route/masks (from_placement /
// next_epoch) nor carries an abort path (rollback… / Aborted / `?`).

impl Router {
    fn bad_cutover(&mut self, old_epoch: u64) {
        self.fence_and_drain(old_epoch);
        self.flip_masks();
    }
}

// Fixture: must FAIL lock-order under serve/. Two functions acquire
// the same two locks in opposite orders — the classic AB/BA deadlock.

impl Obs {
    fn snapshot(&self) {
        let _ring = self.ring.lock().unwrap();
        let _subs = self.subs.lock().unwrap();
    }

    fn publish(&self) {
        let _subs = self.subs.lock().unwrap();
        let _ring = self.ring.lock().unwrap();
    }
}

// Fixture: must FAIL epoch-discipline under serve/. Two violations:
// a literal assigned into an epoch field and a literal in a struct
// init.

impl Router {
    fn resurrect_route(&mut self) {
        self.route.epoch = 3;
        let _r = TenantRoute { epoch: 0, members: Vec::new() };
    }
}

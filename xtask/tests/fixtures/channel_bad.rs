// Fixture: must FAIL bounded-channel under serve/. Two violations: a
// bare `channel()` call and a fully-qualified turbofish form.

impl Fleet {
    fn spawn_workers(&mut self) {
        let (tx, _rx) = channel();
        let (_jtx, jrx) = std::sync::mpsc::channel::<Job>();
        self.wire(tx, jrx);
    }
}

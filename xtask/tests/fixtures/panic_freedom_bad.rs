// Fixture: must FAIL panic-freedom when linted under
// serve/{transport,engine,prune}. Four violations: an index, an
// unwrap, an expect, and a panic!.

impl Engine {
    fn hot_path(&self, replies: &[u32]) -> u32 {
        let first = replies[0];
        let parsed = self.peek().unwrap();
        let label = self.label().expect("always labeled");
        if first == 0 {
            panic!("empty reply");
        }
        first + parsed + label
    }
}

// Fixture: must lint CLEAN under serve/transport/. Exercises every
// near-miss the passes must not flag, plus one waived finding (so the
// census shows a used waiver, not a stale one).

use std::sync::mpsc::sync_channel;

impl Link {
    fn wire(&mut self, router: &mut ShardRouter, cfg: &Config) -> Result<()> {
        // bounded channels and non-mpsc `channel` associated fns are legal
        let (tx, rx) = sync_channel(4);
        let batch_rx = Batcher::channel(cfg);
        // epochs minted by the router are legal
        let epoch = router.next_epoch();
        self.route.epoch = epoch;
        self.attach(tx, rx, batch_rx);
        Ok(())
    }

    fn read_word(&self) -> u32 {
        // lint: allow(panic-freedom) — infallible: header length checked at frame boundary
        u32::from_le_bytes(self.buf[0..4].try_into().unwrap())
    }

    fn migrate(&mut self, old_epoch: u64) -> Result<()> {
        // fencing paired with a route rebuild and `?` propagation
        self.fence_and_drain(old_epoch)?;
        let epoch = self.router.next_epoch();
        *self.route_mut() = TenantRoute::from_placement(&self.placement, epoch);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    // everything in a test region is exempt from every pass
    #[test]
    fn exempt() {
        let route = TenantRoute { epoch: 7, members: Vec::new() };
        let (tx, _rx) = std::sync::mpsc::channel::<u32>();
        tx.send(route.members[0]).unwrap();
    }
}

//! Cross-module integration tests: the three similarity sources agree,
//! the chip VMM matches integer references under faults, and a miniature
//! end-to-end training run exercises runtime + coordinator + pruning.
//! Tests that need AOT artifacts skip gracefully when they are missing.

// Terminal output is this target's product; the serve-code print ban
// (workspace clippy.toml `disallowed-macros`) deliberately does not
// apply outside `rust/src/serve/**`.
#![allow(clippy::disallowed_macros)]

use std::path::Path;
use std::time::Duration;

use rram_cim::chip::{Chip, ChipConfig, ReadPath};
use rram_cim::cim::mapping::{store_bits, store_int8, RowAllocator};
use rram_cim::cim::{similarity as chip_sim, vmm};
use rram_cim::coordinator::mnist::{MnistConfig, MnistTrainer};
use rram_cim::coordinator::pointnet::{PointNetConfig, PointNetTrainer};
use rram_cim::coordinator::TrainMode;
use rram_cim::nn::data::{mnist, modelnet};
use rram_cim::nn::pointnet::GroupingConfig;
use rram_cim::pruning::similarity::PackedKernels;
use rram_cim::pruning::PruneConfig;
use rram_cim::runtime::{Engine, HostTensor};
use rram_cim::serve::{
    AdmissionConfig, BatcherConfig, CacheConfig, Engine as ServeEngine, EngineConfig, ModelBundle,
    PointNetBundle, PoolConfig, RebalanceConfig, Server, ServerConfig, TenantConfig,
};
use rram_cim::testing::{forall, shrink_vec};
use rram_cim::util::rng::Rng;

fn artifacts_ready() -> bool {
    // the artifacts are only runnable with the PJRT engine compiled in;
    // a default (offline) build must skip even when artifacts exist
    cfg!(feature = "pjrt")
        && Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.txt").exists()
}

/// Property: chip search-in-memory == bit-packed software similarity for
/// random kernel sets, sizes, and fault rates.
#[test]
fn prop_chip_similarity_equals_software() {
    forall(
        "chip similarity == packed similarity",
        0xC0FFEE,
        12,
        |rng| {
            let k = 2 + rng.below(6);
            let n = 8 + rng.below(80);
            let fault = if rng.chance(0.3) { 0.01 } else { 0.0 };
            let kernels: Vec<Vec<f32>> = (0..k)
                .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
                .collect();
            (kernels, fault, rng.next_u64())
        },
        |(kernels, fault, seed)| {
            let mut rng = Rng::new(*seed);
            let mut cfg = ChipConfig::small_test();
            cfg.device.stuck_fault_prob = *fault;
            let mut chip = Chip::new(cfg, &mut rng);
            chip.form();
            let mut alloc = RowAllocator::for_chip(&chip);
            let live = vec![true; kernels.len()];
            let stored = chip_sim::store_kernels(&mut chip, &mut alloc, kernels);
            let got = chip_sim::similarity_matrix(&mut chip, &stored, &live);
            let want = PackedKernels::from_kernels(kernels).similarity_matrix(&live);
            if got.dist != want.dist {
                return Err(format!("distance mismatch: {:?} vs {:?}", got.dist, want.dist));
            }
            Ok(())
        },
    );
}

/// Property: on-chip binary and INT8 dots are integer-exact vs the
/// software reference across random sizes/values/faults (ECC active).
#[test]
fn prop_chip_dots_are_exact() {
    forall(
        "chip VMM == integer reference",
        0xD07,
        12,
        |rng| {
            let n = 1 + rng.below(70);
            let bits: Vec<bool> = (0..n).map(|_| rng.chance(0.5)).collect();
            let xs_u8: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            let w_i8: Vec<i8> = (0..n).map(|_| (rng.below(256) as i16 - 128) as i8).collect();
            let x_i8: Vec<i8> = (0..n).map(|_| (rng.below(256) as i16 - 128) as i8).collect();
            (bits, xs_u8, w_i8, x_i8, rng.next_u64())
        },
        |(bits, xs_u8, w_i8, x_i8, seed)| {
            let mut rng = Rng::new(*seed);
            let mut cfg = ChipConfig::small_test();
            cfg.device.stuck_fault_prob = 0.005;
            let mut chip = Chip::new(cfg, &mut rng);
            chip.form();
            let mut alloc = RowAllocator::for_chip(&chip);
            let span = alloc.alloc(bits.len()).unwrap();
            if store_bits(&mut chip, &span, bits) != 0 {
                return Err("unrecoverable store".into());
            }
            let got = vmm::binary_dot_u8(&mut chip, &span, xs_u8);
            let want = vmm::binary_dot_ref(bits, xs_u8);
            if got != want {
                return Err(format!("binary dot {got} != {want}"));
            }
            let span2 = alloc.alloc(4 * w_i8.len()).unwrap();
            if store_int8(&mut chip, &span2, w_i8) != 0 {
                return Err("unrecoverable int8 store".into());
            }
            let got = vmm::int8_dot(&mut chip, &span2, x_i8);
            let want = vmm::int8_dot_ref(w_i8, x_i8);
            if got != want {
                return Err(format!("int8 dot {got} != {want}"));
            }
            Ok(())
        },
    );
}

/// Property: the batched INT8 VMM (the PointNet serve hot path) is
/// integer-exact vs `int8_dot_ref` and vs the unbatched `int8_dot` for
/// random kernel sizes (including single-element), batch shapes
/// (including zero windows), and ±127 extremes. A failing activation
/// vector is shrunk to a minimal counterexample before reporting.
#[test]
fn prop_int8_batched_dots_are_exact() {
    forall(
        "int8_dots_batched == int8_dot == int8_dot_ref",
        0x1278,
        10,
        |rng| {
            let n = 1 + rng.below(24);
            let extreme = rng.chance(0.25);
            let val = |rng: &mut Rng| -> i8 {
                if extreme {
                    if rng.chance(0.5) { 127 } else { -127 }
                } else {
                    (rng.below(255) as i16 - 127) as i8
                }
            };
            let w: Vec<i8> = (0..n).map(|_| val(rng)).collect();
            let n_win = rng.below(4);
            let xs: Vec<Vec<i8>> = (0..n_win).map(|_| (0..n).map(|_| val(rng)).collect()).collect();
            (w, xs, rng.next_u64())
        },
        |(w, xs, seed)| {
            // one chip runs the whole case; a fresh chip replays shrunken
            // candidates so the counterexample is self-contained
            let run = |w: &[i8], x: &[i8], seed: u64| -> Option<i64> {
                let mut rng = Rng::new(seed);
                let mut chip = Chip::new(ChipConfig::small_test(), &mut rng);
                chip.form();
                let mut alloc = RowAllocator::for_chip(&chip);
                let span = alloc.alloc(4 * w.len())?;
                if store_int8(&mut chip, &span, w) != 0 {
                    return None;
                }
                vmm::int8_dot_batch(&mut chip, &span, &[x.to_vec()]).pop()
            };
            for x in xs {
                let got = run(w, x, *seed).ok_or("store/alloc failed on ideal devices")?;
                let want = vmm::int8_dot_ref(w, x);
                if got != want {
                    // pair (w, x) elementwise so shrinking keeps them aligned
                    let pairs: Vec<(i8, i8)> = w.iter().copied().zip(x.iter().copied()).collect();
                    let minimal = shrink_vec(pairs, |cand| {
                        if cand.is_empty() {
                            return false;
                        }
                        let (cw, cx): (Vec<i8>, Vec<i8>) = cand.iter().copied().unzip();
                        run(&cw, &cx, *seed)
                            .map(|g| g != vmm::int8_dot_ref(&cw, &cx))
                            .unwrap_or(false)
                    });
                    return Err(format!(
                        "batched {got} != ref {want}; minimal failing (w,x) pairs: {minimal:?}"
                    ));
                }
                // unbatched agreement on the same stored span
                let mut rng = Rng::new(*seed);
                let mut chip = Chip::new(ChipConfig::small_test(), &mut rng);
                chip.form();
                let mut alloc = RowAllocator::for_chip(&chip);
                let span = alloc.alloc(4 * w.len()).unwrap();
                if store_int8(&mut chip, &span, w) != 0 {
                    return Err("unrecoverable store on ideal devices".into());
                }
                let unbatched = vmm::int8_dot(&mut chip, &span, x);
                if unbatched != want {
                    return Err(format!("unbatched {unbatched} != ref {want}"));
                }
            }
            Ok(())
        },
    );
}

/// The Pallas `similarity` artifact agrees with the chip on real kernels.
#[test]
fn artifact_similarity_agrees_with_chip() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut engine = Engine::open_default().unwrap();
    let spec = engine.manifest().get("similarity").unwrap().clone();
    let (kmax, nbits) = (spec.inputs[0].dims[0], spec.inputs[0].dims[1]);

    let mut rng = Rng::new(99);
    let k = 10;
    let n = 120;
    let kernels: Vec<Vec<f32>> = (0..k)
        .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
        .collect();
    // chip path
    let mut chip = Chip::new(ChipConfig::default(), &mut rng);
    chip.form();
    let mut alloc = RowAllocator::for_chip(&chip);
    let stored = chip_sim::store_kernels(&mut chip, &mut alloc, &kernels);
    let m_chip = chip_sim::similarity_matrix(&mut chip, &stored, &vec![true; k]);
    // artifact path (zero-padded to the fixed shape)
    let mut bits = vec![0i8; kmax * nbits];
    for (i, kr) in kernels.iter().enumerate() {
        for (j, &w) in kr.iter().enumerate() {
            bits[i * nbits + j] = (w >= 0.0) as i8;
        }
    }
    let outs = engine.run("similarity", &[HostTensor::I8(bits, vec![kmax, nbits])]).unwrap();
    let d = outs[0].expect_i32("similarity");
    for i in 0..k {
        for j in 0..k {
            assert_eq!(d[i * kmax + j] as u32, m_chip.distance(i, j), "({i},{j})");
        }
    }
}

/// Property: serving a model through a chip pool of any size reproduces
/// the software quantized reference bit for bit, for random model
/// shapes, prune rates, pool sizes, batch shapes, and images.
#[test]
fn prop_pool_serving_equals_reference_logits() {
    forall(
        "pool serving == quantized software reference",
        0x5e47e,
        6,
        |rng| {
            let channels = [2 + rng.below(3), 2 + rng.below(3), 2 + rng.below(3)];
            let prune = if rng.chance(0.5) { 0.3 } else { 0.0 };
            let pool = 1 + rng.below(3);
            let n_img = 1 + rng.below(3);
            let max_batch = 1 + rng.below(4);
            (channels, prune, pool, n_img, max_batch, rng.next_u64())
        },
        |&(channels, prune, pool, n_img, max_batch, seed)| {
            let model = ModelBundle::synthetic_mnist(channels, prune, seed);
            let images = mnist::generate(n_img, seed ^ 0x1111);
            let cfg = ServerConfig {
                pool: PoolConfig { chips: pool, chip: ChipConfig::small_test(), seed },
                batcher: BatcherConfig {
                    max_batch,
                    max_wait: Duration::from_millis(1),
                    queue_depth: 16,
                },
            };
            let server = Server::start(model.clone(), &cfg).map_err(|e| e.to_string())?;
            let pending: Vec<_> = (0..n_img)
                .map(|i| server.submit(images.sample(i).to_vec()))
                .collect();
            for (i, rx) in pending.into_iter().enumerate() {
                let resp = rx.recv().map_err(|e| e.to_string())?;
                let want = model.reference_logits(images.sample(i));
                if resp.logits != want {
                    return Err(format!(
                        "image {i}: served {:?} != reference {:?}",
                        resp.logits, want
                    ));
                }
            }
            let report = server.shutdown();
            if report.stats.n_requests != n_img as u64 {
                return Err(format!("served {} of {n_img}", report.stats.n_requests));
            }
            if report.stats.dropped != 0 {
                return Err("dropped requests under blocking backpressure".into());
            }
            Ok(())
        },
    );
}

/// Property (spillover accounting): over a primary + replica pair fed
/// through `try_submit_spill`, every attempt lands in exactly one of
/// {answered by primary, answered by replica, dropped} — and the drop
/// is booked once, on the primary, no matter how many queues rejected
/// the request. (The seed-era shape counted a rejection per queue, so
/// a spilled-then-dropped request could double-count.)
#[test]
fn prop_spillover_partitions_attempts() {
    forall(
        "admission spillover: attempts == answered + dropped, dropped counted once",
        0x59111,
        4,
        |rng| {
            let depth = 1 + rng.below(2);
            let flood = 8 + rng.below(24);
            (depth, flood, rng.next_u64())
        },
        |&(depth, flood, seed)| {
            let model = ModelBundle::synthetic_mnist([2, 2, 2], 0.0, seed);
            let cfg = |s| ServerConfig {
                pool: PoolConfig { chips: 1, chip: ChipConfig::small_test(), seed: s },
                batcher: BatcherConfig {
                    max_batch: 1,
                    max_wait: Duration::from_millis(1),
                    queue_depth: depth,
                },
            };
            let primary = Server::start(model.clone(), &cfg(seed ^ 1)).map_err(|e| e.to_string())?;
            let replica = Server::start(model, &cfg(seed ^ 2)).map_err(|e| e.to_string())?;
            let ds = mnist::generate(1, seed ^ 3);
            let mut receivers = Vec::new();
            let mut shed = 0u64;
            for _ in 0..flood {
                match primary.try_submit_spill(&[&replica], ds.sample(0).to_vec()) {
                    Ok((_, rx)) => receivers.push(rx),
                    Err(input) => {
                        if input.len() != 28 * 28 {
                            return Err("rejected input not returned intact".into());
                        }
                        shed += 1;
                    }
                }
            }
            let admitted = receivers.len() as u64;
            for rx in receivers {
                rx.recv().map_err(|_| "admitted request never answered".to_string())?;
            }
            let pr = primary.shutdown();
            let rr = replica.shutdown();
            if rr.stats.dropped != 0 {
                return Err("replica booked a drop that belongs to the primary".into());
            }
            if pr.stats.dropped != shed {
                return Err(format!(
                    "primary dropped {} but {} requests were terminally rejected",
                    pr.stats.dropped, shed
                ));
            }
            if pr.stats.n_requests + rr.stats.n_requests != admitted {
                return Err("answered across the pair must equal admissions".into());
            }
            if admitted + shed != flood as u64 {
                return Err(format!(
                    "attempts {} != answered {} + dropped {}",
                    flood, admitted, shed
                ));
            }
            Ok(())
        },
    );
}

fn tiny_pointnet(widths: [usize; 8], prune: f64, seed: u64) -> PointNetBundle {
    PointNetBundle::synthetic(
        widths,
        3,
        prune,
        GroupingConfig { s1: 8, k1: 4, r1: 0.3, s2: 4, k2: 2, r2: 0.6 },
        seed,
    )
}

/// Property: serving a PointNet INT8 bundle through a chip pool of any
/// size reproduces the software quantized reference bit for bit, for
/// random widths, prune rates, pool sizes, batch shapes, and clouds —
/// the INT8 twin of `prop_pool_serving_equals_reference_logits`.
#[test]
fn prop_pointnet_pool_serving_equals_reference_logits() {
    forall(
        "PointNet pool serving == quantized software reference",
        0x907e7,
        5,
        |rng| {
            let w = 2 + rng.below(2);
            let widths = [w, w, w + 1, w, w, w + 1, w, w + 2];
            let prune = if rng.chance(0.5) { 0.3 } else { 0.0 };
            let pool = 1 + rng.below(3);
            let n_clouds = 1 + rng.below(3);
            let max_batch = 1 + rng.below(4);
            (widths, prune, pool, n_clouds, max_batch, rng.next_u64())
        },
        |&(widths, prune, pool, n_clouds, max_batch, seed)| {
            let model: ModelBundle = tiny_pointnet(widths, prune, seed).into();
            let clouds = modelnet::generate(n_clouds, seed ^ 0x2222);
            let cfg = ServerConfig {
                pool: PoolConfig { chips: pool, chip: ChipConfig::small_test(), seed },
                batcher: BatcherConfig {
                    max_batch,
                    max_wait: Duration::from_millis(1),
                    queue_depth: 16,
                },
            };
            let server = Server::start(model.clone(), &cfg).map_err(|e| e.to_string())?;
            let pending: Vec<_> = (0..n_clouds)
                .map(|i| server.submit(clouds.sample(i).to_vec()))
                .collect();
            for (i, rx) in pending.into_iter().enumerate() {
                let resp = rx.recv().map_err(|e| e.to_string())?;
                let want = model.reference_logits(clouds.sample(i));
                if resp.logits != want {
                    return Err(format!(
                        "cloud {i}: served {:?} != reference {:?}",
                        resp.logits, want
                    ));
                }
            }
            let report = server.shutdown();
            if report.stats.n_requests != n_clouds as u64 {
                return Err(format!("served {} of {n_clouds}", report.stats.n_requests));
            }
            if report.stats.dropped != 0 {
                return Err("dropped requests under blocking backpressure".into());
            }
            Ok(())
        },
    );
}

/// Property: placement onto pools with randomly stuck tiles either
/// routes around the faults and serves bit-exact logits (both bundle
/// kinds), or fails with a clean placement error when the usable
/// capacity is exhausted — never silent corruption.
#[test]
fn prop_stuck_tile_placement_is_exact_or_cleanly_rejected() {
    forall(
        "stuck tiles: bit-exact serving or clean placement error",
        0xfa017,
        6,
        |rng| {
            // fault pressure up to the point where capacity loss is real;
            // spares stay at the small_test default so ECC absorbs some
            let fault = [0.0, 0.01, 0.05][rng.below(3)];
            let spares = rng.below(3);
            let pool = 1 + rng.below(2);
            let use_mnist = rng.chance(0.5);
            (fault, spares, pool, use_mnist, rng.next_u64())
        },
        |&(fault, spares, pool, use_mnist, seed)| {
            let mut chip_cfg = ChipConfig::small_test();
            chip_cfg.device.stuck_fault_prob = fault;
            chip_cfg.spares_per_row = spares;
            let model: ModelBundle = if use_mnist {
                ModelBundle::synthetic_mnist([3, 3, 3], 0.2, seed)
            } else {
                tiny_pointnet([2, 2, 3, 2, 2, 3, 2, 4], 0.2, seed).into()
            };
            let cfg = ServerConfig {
                pool: PoolConfig { chips: pool, chip: chip_cfg, seed },
                batcher: BatcherConfig {
                    max_batch: 2,
                    max_wait: Duration::from_millis(1),
                    queue_depth: 8,
                },
            };
            let server = match Server::start(model.clone(), &cfg) {
                Ok(s) => s,
                Err(e) => {
                    // capacity exhausted by faults: must be the placer's
                    // explicit verdict, not a panic or a corrupted serve
                    let msg = e.to_string();
                    return if msg.contains("placement") || msg.contains("rows") {
                        Ok(())
                    } else {
                        Err(format!("unexpected start error: {msg}"))
                    };
                }
            };
            let n = 2usize;
            let inputs: Vec<Vec<f32>> = if use_mnist {
                let ds = mnist::generate(n, seed ^ 0x3333);
                (0..n).map(|i| ds.sample(i).to_vec()).collect()
            } else {
                let ds = modelnet::generate(n, seed ^ 0x4444);
                (0..n).map(|i| ds.sample(i).to_vec()).collect()
            };
            let pending: Vec<_> = inputs.iter().map(|x| server.submit(x.clone())).collect();
            for (x, rx) in inputs.iter().zip(pending) {
                let resp = rx.recv().map_err(|e| e.to_string())?;
                if resp.logits != model.reference_logits(x) {
                    return Err("stuck tiles silently corrupted the logits".into());
                }
            }
            server.shutdown();
            Ok(())
        },
    );
}

/// Pool-of-1 serving of a *trained* model tracks `MnistTrainer::evaluate`:
/// the chip pipeline (binary weights + u8 activations) must land close to
/// the f32 artifact accuracy.
#[test]
fn serving_tracks_trained_eval_accuracy() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Engine::open_default().unwrap();
    let cfg = MnistConfig {
        epochs: 3,
        train_samples: 448,
        test_samples: 64,
        mode: TrainMode::Spn,
        prune: PruneConfig { warmup_epochs: 1, prune_interval: 1, ..PruneConfig::default() },
        ..MnistConfig::default()
    };
    let mut tr = MnistTrainer::new(cfg, engine);
    tr.train().unwrap();
    let (eval_acc, _) = tr.evaluate().unwrap();
    let bundle = tr.export_bundle();
    let test_set = tr.test_set().clone();
    // a 768-row chip fits even the unpruned 32-64-32 model on one chip
    let serve_cfg = ServerConfig {
        pool: PoolConfig {
            chips: 1,
            chip: ChipConfig { rows: 768, ..ChipConfig::default() },
            seed: 0xe7a1,
        },
        batcher: BatcherConfig::default(),
    };
    let server = Server::start(bundle, &serve_cfg).unwrap();
    let n = test_set.len();
    let pending: Vec<_> = (0..n).map(|i| server.submit(test_set.sample(i).to_vec())).collect();
    let mut correct = 0usize;
    for (i, rx) in pending.into_iter().enumerate() {
        let logits = rx.recv().unwrap().logits;
        let pred = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if pred as i32 == test_set.labels[i] {
            correct += 1;
        }
    }
    server.shutdown();
    let serve_acc = correct as f64 / n as f64;
    assert!(
        (serve_acc - eval_acc).abs() < 0.25,
        "chip serving accuracy {serve_acc:.3} drifted from artifact eval {eval_acc:.3}"
    );
}

/// Mini end-to-end: MNIST SPN training must reduce loss, prune kernels,
/// and keep pruned kernels frozen (verified via masks).
#[test]
fn e2e_mnist_training_learns_and_prunes() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Engine::open_default().unwrap();
    let cfg = MnistConfig {
        epochs: 5,
        train_samples: 448,
        test_samples: 128,
        mode: TrainMode::Spn,
        prune: PruneConfig {
            warmup_epochs: 2,
            prune_interval: 1,
            sim_threshold: 0.65,
            min_live_per_layer: 4,
            max_prune_rate: 0.3,
            ..PruneConfig::default()
        },
        ..MnistConfig::default()
    };
    let mut tr = MnistTrainer::new(cfg, engine);
    let rep = tr.train().unwrap();
    assert_eq!(rep.epochs.len(), 5);
    let first = rep.epochs.first().unwrap();
    let last = rep.epochs.last().unwrap();
    // pruning mid-run can transiently bump the loss (the paper's Fig. 4k
    // shows the same recovery dips), so assert on the best epoch + final
    // accuracy rather than strict monotonicity.
    let best = rep.epochs.iter().map(|e| e.loss).fold(f64::INFINITY, f64::min);
    assert!(best < first.loss, "never improved: first {} best {best}", first.loss);
    assert!(last.test_acc > 0.3, "accuracy too low: {}", last.test_acc);
    // at threshold 0.65 on a small net, some pruning must occur
    assert!(rep.final_prune_rate > 0.0, "nothing pruned");
    assert!(rep.macs_pruned < rep.macs_unpruned);
}

/// Mini end-to-end: HPN mode exercises the chip similarity + MAC
/// precision machinery.
#[test]
fn e2e_mnist_hpn_chip_in_the_loop() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Engine::open_default().unwrap();
    let cfg = MnistConfig {
        epochs: 2,
        train_samples: 128,
        test_samples: 64,
        mode: TrainMode::Hpn,
        hpn_check_macs: 16,
        prune: PruneConfig { warmup_epochs: 1, prune_interval: 1, ..PruneConfig::default() },
        ..MnistConfig::default()
    };
    let mut tr = MnistTrainer::new(cfg, engine);
    let rep = tr.train().unwrap();
    let last = rep.epochs.last().unwrap();
    assert_eq!(last.mac_precision.len(), 3, "3 conv layers checked");
    for (l, p) in last.mac_precision.iter().enumerate() {
        assert!(*p > 0.95, "layer {l} MAC precision {p} too low for a digital chip");
    }
    assert!(rep.chip_ms > 0.0, "chip never ran in HPN mode");
}

/// Mini end-to-end: PointNet trains through the grouped pipeline.
#[test]
fn e2e_pointnet_training_learns() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Engine::open_default().unwrap();
    let cfg = PointNetConfig {
        epochs: 3,
        train_samples: 80,
        test_samples: 40,
        mode: TrainMode::Spn,
        prune: PruneConfig { warmup_epochs: 1, prune_interval: 1, ..PruneConfig::default() },
        ..PointNetConfig::default()
    };
    let mut tr = PointNetTrainer::new(cfg, engine);
    let rep = tr.train().unwrap();
    let first = rep.epochs.first().unwrap();
    let last = rep.epochs.last().unwrap();
    assert!(last.loss < first.loss, "loss did not fall: {} -> {}", first.loss, last.loss);
    assert!(last.loss.is_finite());
}

/// Determinism: two identical SPN runs produce identical reports.
#[test]
fn e2e_training_is_deterministic() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let run = || {
        let engine = Engine::open_default().unwrap();
        let cfg = MnistConfig {
            epochs: 2,
            train_samples: 128,
            test_samples: 64,
            mode: TrainMode::Spn,
            ..MnistConfig::default()
        };
        MnistTrainer::new(cfg, engine).train().unwrap()
    };
    let a = run();
    let b = run();
    for (ea, eb) in a.epochs.iter().zip(&b.epochs) {
        assert_eq!(ea.loss.to_bits(), eb.loss.to_bits(), "nondeterministic loss");
        assert_eq!(ea.live_kernels, eb.live_kernels);
    }
}

// ---------------------------------------------------------------------------
// Multi-tenant engine properties (serve::engine): mixed tenancy, the
// bit-exact result cache, and admission fairness.
// ---------------------------------------------------------------------------

fn engine_cfg(chips: usize, seed: u64, max_batch: usize) -> EngineConfig {
    EngineConfig {
        pool: PoolConfig { chips, chip: ChipConfig::small_test(), seed },
        admission: AdmissionConfig {
            max_batch,
            max_wait: Duration::from_millis(1),
            quantum: max_batch,
        },
        cache: CacheConfig::default(),
        rebalance: RebalanceConfig::default(),
        prune: Default::default(),
        cam: Default::default(),
        obs: true,
    }
}

/// Property: one pool serving BOTH bundle kinds concurrently answers
/// every interleaved request bit-exactly against the respective software
/// reference — including under stuck-tile fault injection, where a pool
/// that cannot host both tenants must fail with a clean placement error,
/// never serve corrupted logits.
#[test]
fn prop_mixed_tenancy_serving_is_bit_exact() {
    forall(
        "mixed tenancy: interleaved MNIST + PointNet bit-exact or clean reject",
        0x7e7a57,
        5,
        |rng| {
            let chips = 3 + rng.below(2);
            let fault = [0.0, 0.01][rng.below(2)];
            let prune = [0.0, 0.3][rng.below(2)];
            (chips, fault, prune, rng.next_u64())
        },
        |&(chips, fault, prune, seed)| {
            let mnist_model = ModelBundle::synthetic_mnist([3, 4, 3], prune, seed);
            let pn_model: ModelBundle = tiny_pointnet([2, 2, 3, 2, 2, 3, 2, 4], prune, seed ^ 1).into();
            let mut cfg = engine_cfg(chips, seed ^ 2, 4);
            cfg.pool.chip.device.stuck_fault_prob = fault;
            cfg.rebalance = RebalanceConfig { every_batches: 3, max_moves: 1, group_moves: 0 };
            let tenants = vec![
                TenantConfig::new("mnist", mnist_model.clone()),
                TenantConfig::new("pointnet", pn_model.clone()),
            ];
            let engine = match ServeEngine::start(tenants, &cfg) {
                Ok(e) => e,
                Err(e) => {
                    let msg = e.to_string();
                    return if msg.contains("placement") || msg.contains("rows") {
                        Ok(()) // capacity lost to faults: explicit verdict
                    } else {
                        Err(format!("unexpected start error: {msg}"))
                    };
                }
            };
            let images = mnist::generate(3, seed ^ 3);
            let clouds = modelnet::generate(3, seed ^ 4);
            let mut pending = Vec::new();
            for i in 0..3 {
                pending.push((0usize, i, engine.submit(0, images.sample(i).to_vec())));
                pending.push((1usize, i, engine.submit(1, clouds.sample(i).to_vec())));
            }
            for (t, i, rx) in pending {
                let resp = rx.recv().map_err(|e| e.to_string())?;
                let want = if t == 0 {
                    mnist_model.reference_logits(images.sample(i))
                } else {
                    pn_model.reference_logits(clouds.sample(i))
                };
                if resp.logits != want {
                    return Err(format!("tenant {t} input {i}: mixed pool corrupted the logits"));
                }
            }
            let report = engine.shutdown();
            if report.answered() != 6 {
                return Err(format!("answered {} of 6", report.answered()));
            }
            if report.dropped() != 0 {
                return Err("blocking submits must never drop".into());
            }
            Ok(())
        },
    );
}

/// Property (cache): hits are bit-exact vs a fresh `reference_logits`
/// recompute for both bundle kinds, and a forced re-shard invalidates
/// every cached entry — the replay after it is a recompute through the
/// migrated placement, still bit-exact.
#[test]
fn prop_cache_hits_bit_exact_and_reshard_invalidates() {
    forall(
        "result cache: bit-exact replay, full invalidation on re-shard",
        0xcac4e,
        6,
        |rng| {
            let use_mnist = rng.chance(0.5);
            let n_inputs = 2 + rng.below(2);
            (use_mnist, n_inputs, rng.next_u64())
        },
        |&(use_mnist, n_inputs, seed)| {
            let model: ModelBundle = if use_mnist {
                ModelBundle::synthetic_mnist([3, 4, 3], 0.2, seed)
            } else {
                tiny_pointnet([2, 2, 3, 2, 2, 3, 2, 4], 0.2, seed).into()
            };
            let cfg = engine_cfg(2, seed ^ 5, 2);
            let engine =
                ServeEngine::start(vec![TenantConfig::new("m", model.clone())], &cfg)
                    .map_err(|e| e.to_string())?;
            let inputs: Vec<Vec<f32>> = if use_mnist {
                let ds = mnist::generate(n_inputs, seed ^ 6);
                (0..n_inputs).map(|i| ds.sample(i).to_vec()).collect()
            } else {
                let ds = modelnet::generate(n_inputs, seed ^ 7);
                (0..n_inputs).map(|i| ds.sample(i).to_vec()).collect()
            };
            // round 1: misses populate the cache
            for x in &inputs {
                let resp = engine.submit(0, x.clone()).recv().map_err(|e| e.to_string())?;
                if resp.logits != model.reference_logits(x) {
                    return Err("fresh compute diverged from reference".into());
                }
            }
            if engine.cache_len(0) != n_inputs {
                return Err(format!("expected {n_inputs} cached entries, got {}", engine.cache_len(0)));
            }
            // round 2: every answer is a replay, bit-exact vs a FRESH
            // reference recompute
            for x in &inputs {
                let resp = engine.submit(0, x.clone()).recv().map_err(|e| e.to_string())?;
                if resp.logits != model.reference_logits(x) {
                    return Err("cache hit diverged from fresh reference recompute".into());
                }
            }
            // forced re-shard: every entry must be invalidated, and the
            // recompute must flow through the migrated placement
            engine.force_rebalance();
            let resp = engine.submit(0, inputs[0].clone()).recv().map_err(|e| e.to_string())?;
            if resp.logits != model.reference_logits(&inputs[0]) {
                return Err("post-migration recompute diverged".into());
            }
            if engine.cache_invalidations(0) != n_inputs as u64 {
                return Err(format!(
                    "re-shard must flush all {n_inputs} entries, flushed {}",
                    engine.cache_invalidations(0)
                ));
            }
            let report = engine.shutdown();
            if report.shards_moved == 0 || report.rebalances != 1 {
                return Err("forced re-shard did not migrate".into());
            }
            if report.tenants[0].cache_hits != n_inputs as u64 {
                return Err(format!(
                    "round 2 must be {} hits, saw {}",
                    n_inputs, report.tenants[0].cache_hits
                ));
            }
            Ok(())
        },
    );
}

/// Property (fairness): a bursty tenant flooding `try_submit` cannot
/// starve the other tenant beyond its quota — the victim's requests are
/// answered or counted in its own `dropped`, never silently lost, and
/// FIFO order holds per tenant.
#[test]
fn prop_bursty_tenant_cannot_starve_the_other() {
    forall(
        "admission fairness: flood vs steady tenant",
        0xfa1e,
        4,
        |rng| {
            let burst_depth = 1 + rng.below(3);
            let steady_depth = 2 + rng.below(4);
            let flood = 30 + rng.below(40);
            (burst_depth, steady_depth, flood, rng.next_u64())
        },
        |&(burst_depth, steady_depth, flood, seed)| {
            let m = ModelBundle::synthetic_mnist([2, 2, 2], 0.0, seed);
            let mut cfg = engine_cfg(2, seed ^ 8, 2);
            cfg.cache = CacheConfig { capacity: 0 }; // every request costs silicon
            let tenants = vec![
                TenantConfig::new("burst", m.clone()).with_queue_depth(burst_depth),
                TenantConfig::new("steady", m.clone()).with_queue_depth(steady_depth),
            ];
            let engine = ServeEngine::start(tenants, &cfg).map_err(|e| e.to_string())?;
            let ds = mnist::generate(1, seed ^ 9);
            let x = ds.sample(0).to_vec();
            let mut rx_by_tenant: [Vec<std::sync::mpsc::Receiver<rram_cim::serve::Response>>; 2] =
                [Vec::new(), Vec::new()];
            let mut shed = [0u64; 2];
            let mut attempts = [0u64; 2];
            for i in 0..flood {
                attempts[0] += 1;
                match engine.try_submit(0, x.clone()) {
                    Ok(rx) => rx_by_tenant[0].push(rx),
                    Err(input) => {
                        if input.len() != 28 * 28 {
                            return Err("shed input not returned intact".into());
                        }
                        shed[0] += 1;
                    }
                }
                if i % 7 == 0 {
                    attempts[1] += 1;
                    match engine.try_submit(1, x.clone()) {
                        Ok(rx) => rx_by_tenant[1].push(rx),
                        Err(_) => shed[1] += 1,
                    }
                }
            }
            // every admitted request is answered exactly once, in FIFO
            // order per tenant; nothing hangs
            let mut answered = [0u64; 2];
            for (t, rxs) in rx_by_tenant.into_iter().enumerate() {
                let mut last_id = None;
                for rx in rxs {
                    let resp = rx
                        .recv()
                        .map_err(|_| format!("tenant {t}: admitted request never answered"))?;
                    if let Some(prev) = last_id {
                        if resp.id <= prev {
                            return Err(format!("tenant {t}: FIFO order broken"));
                        }
                    }
                    last_id = Some(resp.id);
                    answered[t] += 1;
                }
            }
            let report = engine.shutdown();
            for t in 0..2 {
                if report.tenants[t].answered != answered[t] {
                    return Err(format!("tenant {t}: report vs observed answers"));
                }
                if report.tenants[t].dropped != shed[t] {
                    return Err(format!("tenant {t}: report vs observed sheds"));
                }
                if report.tenants[t].answered + report.tenants[t].dropped != attempts[t] {
                    return Err(format!(
                        "tenant {t}: answered + dropped must partition attempts \
                         ({} + {} != {})",
                        report.tenants[t].answered, report.tenants[t].dropped, attempts[t]
                    ));
                }
            }
            Ok(())
        },
    );
}

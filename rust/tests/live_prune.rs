//! The live-prune suite: the similarity-monitored prune loop fired
//! **mid-serve**, end to end over real pools — every answer bit-exact
//! against the *pruned-mask* reference oracle at every point of the
//! run, at pipeline depths {1, 2, 4}, with stuck-tile fault injection,
//! and through a concurrent host bounce on a two-group TCP fleet.
//! Freed rows must come back as tenant quota headroom, and the request
//! accounting must balance (`attempts == answered + dropped`).
//!
//! The oracle discipline: a served answer is bit-exact against the
//! masks that were live *when its batch dispatched*, which is some
//! prefix of the committed-cutover sequence. The harness therefore
//! tracks `PruneCommitted` events in order and applies them to a local
//! [`ModelBundle`] clone lazily — advancing the clone one commit at a
//! time until the answer matches — so an answer that matches **no**
//! committed mask state is the failure, exactly the "silent logit
//! drift" the cutover design forbids (DESIGN.md §12).
//!
//! The cutover state machine itself (aborts, release accounting,
//! replicated groups) is unit-tested in `serve/prune/cutover.rs`; the
//! monitor's scheduling in `serve/prune/monitor.rs`; the engine wiring
//! in `serve/engine/mod.rs`. This file proves the same loop against
//! real chips, the real executor, and a real TCP fleet.

// Terminal output is this target's product; the serve-code print ban
// (workspace clippy.toml `disallowed-macros`) deliberately does not
// apply outside `rust/src/serve/**`.
#![allow(clippy::disallowed_macros)]

use std::collections::VecDeque;
use std::time::Duration;

use rram_cim::chip::ChipConfig;
use rram_cim::nn::data::mnist;
use rram_cim::pruning::PruneConfig;
use rram_cim::serve::transport::{
    Backend, Host, HostConfig, LocalBackend, ReconnectPolicy, RemoteBackend, ShardRouter,
};
use rram_cim::serve::{
    AdmissionConfig, CacheConfig, Engine, EngineConfig, EngineReport, EventRecord, LivePruneConfig,
    MnistBundle, ModelBundle, ObsEvent, PipelineConfig, PoolConfig, RebalanceConfig, RouterConfig,
    TenantConfig,
};
use rram_cim::testing::forall;

/// An MNIST bundle whose filters repeat two sign prototypes per layer —
/// similarity 1.0 within each pair class, so the paper's rule fires
/// deterministically once its warm-up passes.
fn clustered_mnist(channels: [usize; 3], seed: u64) -> ModelBundle {
    let mut m = MnistBundle::synthetic(channels, 0.0, seed);
    for layer in &mut m.conv {
        let protos: Vec<Vec<bool>> = layer.bits[..2].to_vec();
        for (f, bits) in layer.bits.iter_mut().enumerate() {
            *bits = protos[f % 2].clone();
        }
    }
    m.into()
}

fn pool_cfg(seed: u64, fault: f64) -> PoolConfig {
    let mut chip = ChipConfig::small_test();
    chip.device.stuck_fault_prob = fault;
    PoolConfig { chips: 3, chip, seed }
}

fn router_cfg(depth: usize) -> RouterConfig {
    RouterConfig { pipeline: PipelineConfig { depth }, ..RouterConfig::default() }
}

/// Prune on every batch boundary with the floors opened up, so a short
/// test run walks the clustered model all the way down.
fn prune_cfg() -> LivePruneConfig {
    LivePruneConfig {
        every_batches: 1,
        max_layers_per_pass: 1,
        rule: PruneConfig { min_live_per_layer: 1, max_prune_rate: 1.0, ..Default::default() },
    }
}

fn engine_cfg() -> EngineConfig {
    EngineConfig {
        pool: PoolConfig::default(), // ignored by start_with_router
        admission: AdmissionConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            quantum: 4,
        },
        cache: CacheConfig { capacity: 0 }, // every request hits silicon
        // the rebalancer stays off: this suite isolates the prune loop
        // (prune + migration composition rides in `examples/multi_host`)
        rebalance: RebalanceConfig { every_batches: 0, max_moves: 0, group_moves: 0 },
        prune: prune_cfg(),
        cam: Default::default(),
        obs: true,
    }
}

/// The pruned-mask reference oracle (see the module docs): a model
/// clone advanced lazily through the committed-cutover sequence.
struct PrunedOracle {
    model: ModelBundle,
    pending: VecDeque<(usize, Vec<usize>)>,
}

impl PrunedOracle {
    fn new(model: ModelBundle) -> PrunedOracle {
        PrunedOracle { model, pending: VecDeque::new() }
    }

    /// Queue every drained `PruneCommitted` (they arrive in commit
    /// order — the event bus is gapless per subscriber).
    fn absorb(&mut self, records: Vec<EventRecord>) {
        for rec in records {
            if let ObsEvent::PruneCommitted { tenant: 0, layer, filters, .. } = rec.event {
                self.pending.push_back((layer, filters));
            }
        }
    }

    /// Assert `logits` is bit-exact against the mask state its batch
    /// served under: the clone's current masks, or some later prefix of
    /// the committed sequence (a commit can land between the dispatch
    /// and this check — never the other way around, since the prune
    /// pass runs only at batch boundaries).
    fn check(&mut self, label: &str, input: &[f32], logits: &[f32]) -> Result<(), String> {
        loop {
            if logits == self.model.reference_logits(input).as_slice() {
                return Ok(());
            }
            let Some((layer, filters)) = self.pending.pop_front() else {
                return Err(format!("{label}: logits match no committed mask state"));
            };
            for f in filters {
                self.model.prune_filter(layer, f);
            }
        }
    }

    /// Fold the rest of the committed sequence into the clone (for the
    /// end-of-run mask comparison against the engine's report).
    fn apply_rest(&mut self) {
        while let Some((layer, filters)) = self.pending.pop_front() {
            for f in filters {
                assert!(self.model.prune_filter(layer, f), "a commit repeated filter {f}");
            }
        }
    }

    fn live_masks(&self) -> Vec<Vec<bool>> {
        (0..self.model.n_layers()).map(|l| self.model.live_mask(l).to_vec()).collect()
    }
}

/// `attempts == answered + dropped`, and blocking submits never drop.
fn check_accounting(report: &EngineReport, attempts: u64) -> Result<(), String> {
    if report.answered() + report.dropped() != attempts {
        return Err(format!(
            "accounting broken: {} answered + {} dropped != {attempts} attempts",
            report.answered(),
            report.dropped()
        ));
    }
    if report.dropped() != 0 {
        return Err("blocking submits must never drop".into());
    }
    Ok(())
}

/// The single-pool harness body at one pipeline depth: a clustered
/// tenant under a row quota exactly equal to its dense footprint, the
/// prune loop firing on every batch boundary, every answer checked
/// against the lazy oracle. On an ideal pool the run must commit
/// cutovers, free rows, and surface them as quota headroom; with fault
/// injection the engine may instead reject at placement — that must be
/// a clean, explicit error, never a wrong logit.
fn run_prune_harness(depth: usize, fault: f64, seed: u64) -> Result<(), String> {
    let model = clustered_mnist([6, 6, 6], seed);
    let backend =
        LocalBackend::from_pool_config(&pool_cfg(seed ^ 2, fault)).map_err(|e| e.to_string())?;
    let router =
        ShardRouter::new(vec![vec![Box::new(backend) as Box<dyn Backend>]], router_cfg(depth))
            .map_err(|e| e.to_string())?;
    // the quota is exactly the dense model's footprint: any headroom
    // the report shows can only have come from cutover-freed rows
    let quota = model.rows_required(router.data_cols());
    let tenants = vec![TenantConfig::new("mnist", model.clone()).with_row_quota(quota)];
    let engine = match Engine::start_with_router(tenants, router, &engine_cfg()) {
        Ok(e) => e,
        Err(e) => {
            let msg = e.to_string();
            return if msg.contains("placement") || msg.contains("rows") || msg.contains("quota") {
                Ok(()) // capacity lost to faults: explicit verdict
            } else {
                Err(format!("unexpected start error: {msg}"))
            };
        }
    };
    let events = engine.events_with(4096);
    let mut oracle = PrunedOracle::new(model.clone());
    let ds = mnist::generate(6, seed ^ 3);
    for i in 0..12usize {
        let input = ds.sample(i % 6);
        let resp = engine.submit(0, input.to_vec()).recv().map_err(|e| e.to_string())?;
        oracle.absorb(events.drain());
        oracle.check(&format!("depth {depth} request {i}"), input, &resp.logits)?;
    }
    let report = engine.shutdown();
    check_accounting(&report, 12)?;
    if report.transport.peak_inflight > depth as u64 {
        return Err(format!(
            "depth {depth}: peak_inflight {} exceeded the bound",
            report.transport.peak_inflight
        ));
    }
    // the report's final masks are exactly the committed sequence
    oracle.absorb(events.drain());
    oracle.apply_rest();
    let ts = &report.prune.per_tenant[0];
    if ts.live_masks != oracle.live_masks() {
        return Err("the reported live masks diverged from the committed cutovers".into());
    }
    let dead = ts.live_masks.iter().flatten().filter(|&&b| !b).count() as u64;
    if ts.filters_pruned != dead {
        return Err(format!("{} filters_pruned but {dead} dead mask slots", ts.filters_pruned));
    }
    if fault == 0.0 {
        let p = &report.prune;
        if p.cutovers == 0 {
            return Err("the clustered tenant must commit at least one cutover".into());
        }
        if p.aborted != 0 {
            return Err(format!("{} aborts on an ideal single pool", p.aborted));
        }
        if p.rows_freed == 0 {
            return Err("a committed cutover must free rows".into());
        }
        if ts.quota_headroom_rows == 0 {
            return Err("freed rows must surface as tenant quota headroom".into());
        }
        if ts.mac_ops_end >= ts.mac_ops_start {
            return Err("pruning must shrink the tenant's MAC-op cost".into());
        }
    }
    Ok(())
}

/// Property (the PR's acceptance bar, part 1): a prune cutover fired
/// mid-serve yields logits bit-exact against the pruned-mask reference
/// oracle — at pipeline depths 1, 2, and 4, with stuck-tile fault
/// injection — the accounting balances, and freed rows surface as
/// quota headroom.
#[test]
fn prop_live_prune_mid_serve_is_bit_exact_at_every_depth() {
    forall(
        "live prune: depth ∈ {1, 2, 4} serves the pruned oracle, bit for bit",
        0x112e9,
        2,
        |rng| {
            let fault = [0.0, 0.01][rng.below(2)];
            (fault, rng.next_u64())
        },
        |&(fault, seed)| {
            for depth in [1usize, 2, 4] {
                run_prune_harness(depth, fault, seed)?;
            }
            Ok(())
        },
    );
}

/// The two-group TCP harness body at depth 4: layers split across two
/// host daemons, the prune loop firing on every batch boundary, and
/// host B bounced (crash + replacement at the same address) mid-run —
/// the heal and the prune loop share the pass loop, so cutovers landing
/// around the bounce must either commit cleanly or abort explicitly
/// (quarantined owning group), never corrupt an answer.
fn run_bounce_harness(fault: f64, seed: u64) -> Result<(), String> {
    let model = clustered_mnist([6, 6, 6], seed);
    let mut hosts = Vec::new();
    let mut groups: Vec<Vec<Box<dyn Backend>>> = Vec::new();
    for s in 0..2u64 {
        let host = Host::spawn(HostConfig { pool: pool_cfg(seed ^ s, fault) })
            .map_err(|e| e.to_string())?;
        let backend = RemoteBackend::connect_with(
            host.addr(),
            ReconnectPolicy { max_attempts: 8, ..ReconnectPolicy::default() },
        )
        .map_err(|e| e.to_string())?;
        groups.push(vec![Box::new(backend) as Box<dyn Backend>]);
        hosts.push(host);
    }
    let router = ShardRouter::new(groups, router_cfg(4)).map_err(|e| e.to_string())?;
    let engine = match Engine::start_with_router(
        vec![TenantConfig::new("mnist", model.clone())],
        router,
        &engine_cfg(),
    ) {
        Ok(e) => e,
        Err(e) => {
            let msg = e.to_string();
            drop(hosts); // daemons exit on connection close
            return if msg.contains("placement") || msg.contains("rows") {
                Ok(()) // capacity lost to faults: explicit verdict
            } else {
                Err(format!("unexpected start error: {msg}"))
            };
        }
    };
    let events = engine.events_with(4096);
    let mut oracle = PrunedOracle::new(model.clone());
    let ds = mnist::generate(4, seed ^ 7);
    let serve = |i: usize, label: &str, oracle: &mut PrunedOracle| -> Result<(), String> {
        let input = ds.sample(i % 4);
        let resp = engine.submit(0, input.to_vec()).recv().map_err(|e| e.to_string())?;
        oracle.absorb(events.drain());
        oracle.check(&format!("{label} request {i}"), input, &resp.logits)
    };
    // phase 1: enough traffic that the clustered rule starts committing
    for i in 0..3 {
        serve(i, "pre-bounce", &mut oracle)?;
    }
    // phase 2: crash host B; a replacement with a fresh (empty) pool
    // binds the exact same address
    let b = hosts.pop().ok_or("host list empty")?;
    let b_addr = b.addr();
    b.shutdown();
    hosts.push(
        Host::spawn_at(b_addr, HostConfig { pool: pool_cfg(seed ^ 11, fault) })
            .map_err(|e| e.to_string())?,
    );
    // phase 3: the pass loop heals the bounced member (probe,
    // re-program the **post-prune** placement — pruned slots stay
    // empty — rejoin) while the prune loop keeps firing around it
    for i in 0..5 {
        serve(i, "post-bounce", &mut oracle)?;
    }
    let report = engine.shutdown();
    check_accounting(&report, 8)?;
    if report.transport.reconnects == 0 {
        return Err("the bounced host must have been reconnected to".into());
    }
    if report.transport.peak_inflight > 4 {
        return Err(format!("depth bound exceeded ({})", report.transport.peak_inflight));
    }
    oracle.absorb(events.drain());
    oracle.apply_rest();
    let ts = &report.prune.per_tenant[0];
    if ts.live_masks != oracle.live_masks() {
        return Err("the reported live masks diverged from the committed cutovers".into());
    }
    if fault == 0.0 && report.prune.cutovers == 0 {
        return Err("on an ideal fleet the clustered tenant must commit a cutover".into());
    }
    Ok(())
}

/// Property (the PR's acceptance bar, part 2): the prune loop rides out
/// a concurrent host bounce on a two-group TCP fleet at pipeline depth
/// 4, with fault injection — every answer still bit-exact against the
/// pruned oracle, the accounting still balanced.
#[test]
fn prop_prune_cutover_rides_out_a_host_bounce_at_depth_four() {
    forall(
        "live prune: host bounce + depth-4 fleet, bit for bit",
        0xb0b57,
        2,
        |rng| {
            let fault = [0.0, 0.01][rng.below(2)];
            (fault, rng.next_u64())
        },
        |&(fault, seed)| run_bounce_harness(fault, seed),
    );
}

/// The headroom arithmetic closes exactly: with the quota pinned to
/// the dense footprint and a single-member ideal pool, every row a
/// cutover frees reappears one-for-one as quota headroom — the
/// capacity a later placement may spend (the router-level re-place is
/// proven in `serve/prune/cutover.rs`).
#[test]
fn cutover_headroom_is_exactly_the_freed_rows() {
    let model = clustered_mnist([6, 6, 6], 0x9a7e);
    let backend = LocalBackend::from_pool_config(&pool_cfg(0x9a7f, 0.0)).unwrap();
    let router =
        ShardRouter::new(vec![vec![Box::new(backend) as Box<dyn Backend>]], router_cfg(2))
            .unwrap();
    let quota = model.rows_required(router.data_cols());
    let engine = Engine::start_with_router(
        vec![TenantConfig::new("mnist", model.clone()).with_row_quota(quota)],
        router,
        &engine_cfg(),
    )
    .unwrap();
    let ds = mnist::generate(4, 0x9a80);
    for i in 0..8 {
        engine.submit(0, ds.sample(i % 4).to_vec()).recv().unwrap();
    }
    let report = engine.shutdown();
    let ts = &report.prune.per_tenant[0];
    assert!(report.prune.cutovers > 0, "the clustered tenant must prune");
    assert!(ts.rows_freed > 0, "committed cutovers must free rows");
    assert_eq!(
        ts.quota_headroom_rows, ts.rows_freed,
        "every freed row reappears one-for-one as quota headroom"
    );
    assert_eq!(report.prune.rows_retired, 0, "an ideal pool retires nothing");
    assert_eq!(report.prune.per_tenant.len(), 1);
}

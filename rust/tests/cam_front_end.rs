//! The CAM front-end suite: the input-aware similarity probe
//! (DESIGN.md §14) exercised end to end over real chips — streams with
//! planted near-duplicates stay bit-exact under [`VerifyPolicy::Exact`]
//! at pipeline depths {1, 2, 4} with stuck-tile fault injection, every
//! placement transition (forced re-shard, cross-group migration,
//! committed prune cutover) flushes the CAM exactly once, and the
//! opt-in Trusted policy serves near hits from cache while reporting
//! itself. The probe/verify/insert mechanics are unit-tested in
//! `engine/cam.rs`; this file proves the same properties with real
//! pools, the real executor, and the real invalidation paths.

// Terminal output is this target's product; the serve-code print ban
// (workspace clippy.toml `disallowed-macros`) deliberately does not
// apply outside `rust/src/serve/**`.
#![allow(clippy::disallowed_macros)]

use std::collections::VecDeque;
use std::time::Duration;

use rram_cim::chip::ChipConfig;
use rram_cim::nn::data::{mnist, modelnet};
use rram_cim::nn::pointnet::GroupingConfig;
use rram_cim::pruning::PruneConfig;
use rram_cim::serve::transport::{Backend, LocalBackend, ShardRouter};
use rram_cim::serve::{
    AdmissionConfig, CacheConfig, CamConfig, Engine, EngineConfig, EventRecord, LivePruneConfig,
    MnistBundle, ModelBundle, ObsEvent, PipelineConfig, PointNetBundle, PoolConfig,
    RebalanceConfig, RouterConfig, TenantConfig,
};
use rram_cim::testing::forall;

fn pool_cfg(seed: u64, fault: f64) -> PoolConfig {
    let mut chip = ChipConfig::small_test();
    chip.device.stuck_fault_prob = fault;
    PoolConfig { chips: 3, chip, seed }
}

fn router_cfg(depth: usize) -> RouterConfig {
    RouterConfig { pipeline: PipelineConfig { depth }, ..RouterConfig::default() }
}

/// The suite's engine baseline: result cache off (the CAM is the only
/// fast path, so every hit below is a CAM hit), rebalancing off (no
/// background pass may flush the CAM and skew the exact counter
/// arithmetic — the invalidation tests turn transitions back on one at
/// a time), CAM as given.
fn engine_cfg(cam: CamConfig) -> EngineConfig {
    EngineConfig {
        pool: PoolConfig::default(), // ignored by start_with_router
        admission: AdmissionConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            quantum: 4,
        },
        cache: CacheConfig { capacity: 0 },
        rebalance: RebalanceConfig { every_batches: 0, max_moves: 0, group_moves: 0 },
        prune: Default::default(),
        cam,
        obs: true,
    }
}

fn tiny_pointnet(prune: f64, seed: u64) -> PointNetBundle {
    PointNetBundle::synthetic(
        [2, 2, 3, 2, 2, 3, 2, 4],
        3,
        prune,
        GroupingConfig { s1: 8, k1: 4, r1: 0.3, s2: 4, k2: 2, r2: 0.6 },
        seed,
    )
}

/// A base MNIST image whose quantization scale is pinned: pixel 0 holds
/// the max at exactly 1.0, pixel 7 sits mid-range, everything clamped
/// to [0, 1]. The pin makes the one-pixel nudge in [`near_image`] move
/// exactly one quantized byte (so the packed keys land a couple of bits
/// apart) instead of rescaling every byte in the exact key.
fn base_image(sample: &[f32]) -> Vec<f32> {
    let mut v: Vec<f32> = sample.iter().map(|x| x.clamp(0.0, 1.0)).collect();
    v[0] = 1.0;
    v[7] = 0.5;
    v
}

/// The planted near-duplicate: one pixel two quantization steps off the
/// base — a near CAM hit (Hamming distance of one changed byte), never
/// an exact one.
fn near_image(sample: &[f32]) -> Vec<f32> {
    let mut v = base_image(sample);
    v[7] = 0.5 + 2.0 / 255.0;
    v
}

/// The planted PointNet near-duplicate: flip the lowest mantissa bit of
/// one coordinate. The exact key is the raw f32 bytes, so the packed
/// keys differ in exactly one bit.
fn near_cloud(sample: &[f32], coord: usize) -> Vec<f32> {
    let mut v = sample.to_vec();
    v[coord] = f32::from_bits(v[coord].to_bits() ^ 1);
    v
}

/// One engine run at one pipeline depth: both model paths behind a CAM,
/// a stream of (base, exact repeat, planted near-duplicate) triples per
/// tenant, every submission synchronous so each lands in its own batch
/// and the CAM state between requests is fully determined.
fn run_cam_harness(depth: usize, fault: f64, seed: u64) -> Result<(), String> {
    let mnist_model = ModelBundle::synthetic_mnist([3, 4, 3], 0.3, seed);
    let pn_model: ModelBundle = tiny_pointnet(0.3, seed ^ 1).into();
    let backend =
        LocalBackend::from_pool_config(&pool_cfg(seed ^ 2, fault)).map_err(|e| e.to_string())?;
    let router =
        ShardRouter::new(vec![vec![Box::new(backend) as Box<dyn Backend>]], router_cfg(depth))
            .map_err(|e| e.to_string())?;
    let tenants = vec![
        TenantConfig::new("mnist", mnist_model.clone()), // VerifyPolicy::Exact by default
        TenantConfig::new("pointnet", pn_model.clone()),
    ];
    let cfg = engine_cfg(CamConfig { capacity: 32, max_distance: 12 });
    let engine = match Engine::start_with_router(tenants, router, &cfg) {
        Ok(e) => e,
        Err(e) => {
            let msg = e.to_string();
            return if msg.contains("placement") || msg.contains("rows") {
                Ok(()) // capacity lost to faults: explicit verdict
            } else {
                Err(format!("unexpected start error: {msg}"))
            };
        }
    };
    let images = mnist::generate(3, seed ^ 3);
    let clouds = modelnet::generate(3, seed ^ 4);
    let mut attempts = 0u64;
    let mut ask = |t: usize, input: Vec<f32>| -> Result<(), String> {
        let want = if t == 0 {
            mnist_model.reference_logits(&input)
        } else {
            pn_model.reference_logits(&input)
        };
        attempts += 1;
        let resp = engine.submit(t, input).recv().map_err(|e| e.to_string())?;
        if resp.logits != want {
            return Err(format!("depth {depth}: tenant {t} diverged from the reference"));
        }
        Ok(())
    };
    for i in 0..3 {
        let img = base_image(images.sample(i));
        let cloud = clouds.sample(i).to_vec();
        // base: CAM miss, computed, inserted
        ask(0, img.clone())?;
        ask(1, cloud.clone())?;
        // exact repeat: a byte-verified distance-0 hit
        ask(0, img.clone())?;
        ask(1, cloud.clone())?;
        // planted near-duplicate: a near hit that must recompute under
        // Exact — the reference check above is the bit-exactness proof
        ask(0, near_image(images.sample(i)))?;
        ask(1, near_cloud(&cloud, 4))?;
    }
    let report = engine.shutdown();
    if report.answered() + report.dropped() != attempts {
        return Err(format!(
            "accounting broken: {} answered + {} dropped != {attempts} attempts",
            report.answered(),
            report.dropped()
        ));
    }
    if report.dropped() != 0 {
        return Err("blocking submits must never drop".into());
    }
    if report.cam.per_tenant.len() != 2 {
        return Err("one CAM stats row per tenant".into());
    }
    for (t, s) in report.cam.per_tenant.iter().enumerate() {
        if s.hits != 3 || s.near_hits != 3 || s.fallbacks != 3 {
            return Err(format!(
                "depth {depth} tenant {t}: expected 3 hits / 3 near / 3 fallbacks, \
                 got {} / {} / {}",
                s.hits, s.near_hits, s.fallbacks
            ));
        }
        // every hit is byte-verified and every Exact near hit is
        // recompute-verified: the verdicts partition the hits
        if s.verify_pass + s.verify_fail != s.hits + s.near_hits {
            return Err(format!(
                "depth {depth} tenant {t}: verdicts {} + {} don't cover {} + {} probes",
                s.verify_pass, s.verify_fail, s.hits, s.near_hits
            ));
        }
        if s.trusted || s.trusted_served != 0 {
            return Err(format!("depth {depth} tenant {t}: Exact tenants never serve trusted"));
        }
        if s.flushes != 0 {
            return Err(format!("depth {depth} tenant {t}: nothing here may flush the CAM"));
        }
        if report.tenants[t].cache_hits != 0 {
            return Err("the result cache is off: CAM hits must not count as cache hits".into());
        }
    }
    if report.cam.served() != 6 {
        return Err(format!("6 exact hits must skip silicon, got {}", report.cam.served()));
    }
    if report.transport.peak_inflight > depth as u64 {
        return Err(format!(
            "depth {depth}: peak_inflight {} exceeded the bound",
            report.transport.peak_inflight
        ));
    }
    Ok(())
}

/// Property (the PR's acceptance bar): forall streams with planted
/// near-duplicates across both model paths, every answer under
/// [`VerifyPolicy::Exact`] is bit-exact against `reference_logits` —
/// at pipeline depths 1, 2, and 4, with stuck-tile fault injection —
/// the CAM counters are exactly determined, and
/// `attempts == answered + dropped`.
#[test]
fn prop_cam_serving_is_bit_exact_with_planted_near_duplicates() {
    forall(
        "cam: near-duplicate streams at depth ∈ {1, 2, 4} serve bit-exactly",
        0xca34,
        2,
        |rng| {
            let fault = [0.0, 0.01][rng.below(2)];
            (fault, rng.next_u64())
        },
        |&(fault, seed)| {
            for depth in [1usize, 2, 4] {
                run_cam_harness(depth, fault, seed)?;
            }
            Ok(())
        },
    );
}

fn count_cam_flushes(records: &[EventRecord]) -> (usize, u64) {
    let mut n = 0usize;
    let mut entries = 0u64;
    for rec in records {
        if let ObsEvent::CamFlush { entries: e, .. } = rec.event {
            n += 1;
            entries += e;
        }
    }
    (n, entries)
}

/// A forced intra-group re-shard flushes the CAM exactly once: the
/// pre-move entry is dropped (one `CamFlush`, one entry), the first
/// post-move probe recomputes through the migrated placement, and the
/// repeat hits again — with zero verify failures, because an
/// exact-duplicate stream never has a stale candidate to disagree with.
#[test]
fn forced_reshard_flushes_the_cam_exactly_once() {
    let model = ModelBundle::synthetic_mnist([3, 4, 3], 0.3, 91);
    let mut cfg = engine_cfg(CamConfig { capacity: 16, max_distance: 8 });
    cfg.pool = PoolConfig { chips: 2, chip: ChipConfig::small_test(), seed: 92 };
    cfg.rebalance = RebalanceConfig::default(); // forced pass, max_moves 2
    let engine = Engine::start(vec![TenantConfig::new("mnist", model.clone())], &cfg).unwrap();
    let events = engine.events_with(4096);
    let ds = mnist::generate(1, 93);
    let reference = model.reference_logits(ds.sample(0));
    let ask = || {
        let resp = engine.submit(0, ds.sample(0).to_vec()).recv().unwrap();
        assert_eq!(resp.logits, reference, "every answer is bit-exact across the re-shard");
    };
    ask(); // computed, inserted
    ask(); // exact CAM hit
    engine.force_rebalance();
    ask(); // the pass ran at this batch boundary: flush, then recompute
    ask(); // repopulated: exact CAM hit again
    let report = engine.shutdown();
    assert!(report.shards_moved >= 1, "the forced pass must move a shard");
    let s = &report.cam.per_tenant[0];
    assert_eq!(s.hits, 2, "one hit before the re-shard, one after repopulation");
    assert_eq!(s.verify_fail, 0, "an exact-duplicate stream never fails a verify");
    assert_eq!(s.flushes, 1, "one transition, one flush");
    assert_eq!(s.entries_flushed, 1);
    assert_eq!(report.tenants[0].chip_batches, 2, "only the two misses touched silicon");
    let (flush_events, entries) = count_cam_flushes(&events.drain());
    assert_eq!(flush_events, 1, "CamFlush is emitted exactly once per transition");
    assert_eq!(entries, 1);
}

/// A forced cross-group layer migration (epoch-fenced, two single-member
/// groups) shares the same invalidation: exactly one `CamFlush`, the
/// post-move recompute is bit-exact, and the CAM repopulates against
/// the migrated placement.
#[test]
fn cross_group_migration_flushes_the_cam_exactly_once() {
    let model = ModelBundle::synthetic_mnist([3, 4, 3], 0.0, 0x3197);
    let mut groups: Vec<Vec<Box<dyn Backend>>> = Vec::new();
    for s in 0..2u64 {
        let backend = LocalBackend::from_pool_config(&pool_cfg(0x3198 ^ s, 0.0)).unwrap();
        groups.push(vec![Box::new(backend) as Box<dyn Backend>]);
    }
    let router = ShardRouter::new(groups, router_cfg(4)).unwrap();
    let mut cfg = engine_cfg(CamConfig { capacity: 16, max_distance: 8 });
    cfg.rebalance = RebalanceConfig { every_batches: 0, max_moves: 0, group_moves: 1 };
    let engine =
        Engine::start_with_router(vec![TenantConfig::new("mnist", model.clone())], router, &cfg)
            .unwrap();
    let events = engine.events_with(4096);
    let ds = mnist::generate(1, 0x3199);
    let reference = model.reference_logits(ds.sample(0));
    let ask = || {
        let resp = engine.submit(0, ds.sample(0).to_vec()).recv().unwrap();
        assert_eq!(resp.logits, reference, "every answer is bit-exact across the migration");
    };
    ask(); // computed, inserted
    ask(); // exact CAM hit
    engine.force_rebalance();
    ask(); // fence drained, layer moved: flush, then recompute
    ask(); // exact CAM hit against the migrated placement
    let report = engine.shutdown();
    let t = &report.transport;
    assert!(t.migrations_started >= 1, "the forced pass must attempt a migration");
    assert!(t.migrations_completed >= 1, "an ideal fleet must complete it");
    let s = &report.cam.per_tenant[0];
    assert_eq!(s.hits, 2);
    assert_eq!(s.verify_fail, 0);
    assert_eq!(s.flushes, 1, "one migration, one flush");
    let (flush_events, entries) = count_cam_flushes(&events.drain());
    assert_eq!(flush_events, 1, "CamFlush is emitted exactly once per transition");
    assert_eq!(entries, 1);
}

/// An MNIST bundle with planted redundancy (the live-prune bait): the
/// first three filters of each layer share one sign prototype, so the
/// similarity rule has cutovers to commit while the CAM serves.
fn redundant_mnist(seed: u64) -> ModelBundle {
    let mut m = MnistBundle::synthetic([6, 6, 6], 0.0, seed);
    for layer in &mut m.conv {
        let proto = layer.bits[0].clone();
        for bits in layer.bits.iter_mut().take(3) {
            *bits = proto.clone();
        }
    }
    m.into()
}

/// The pruned-mask reference oracle (see `tests/live_prune.rs`): a
/// model clone advanced lazily through the committed-cutover sequence.
struct PrunedOracle {
    model: ModelBundle,
    pending: VecDeque<(usize, Vec<usize>)>,
}

impl PrunedOracle {
    fn absorb(&mut self, records: &[EventRecord]) {
        for rec in records {
            if let ObsEvent::PruneCommitted { tenant: 0, layer, ref filters, .. } = rec.event {
                self.pending.push_back((layer, filters.clone()));
            }
        }
    }

    fn check(&mut self, label: &str, input: &[f32], logits: &[f32]) {
        loop {
            if logits == self.model.reference_logits(input).as_slice() {
                return;
            }
            let Some((layer, filters)) = self.pending.pop_front() else {
                panic!("{label}: logits match no committed mask state — a stale CAM replay");
            };
            for f in filters {
                self.model.prune_filter(layer, f);
            }
        }
    }
}

/// Committed prune cutovers flush the CAM mid-serve: an exact-duplicate
/// stream against a redundant tenant with the prune loop on every batch
/// boundary. Every answer matches the pruned-mask oracle (a stale CAM
/// entry would replay pre-cutover logits and fail it), `CamFlush` fires
/// exactly once per counted flush transition and never more often than
/// the commits that cause them, and once the rule runs dry the CAM
/// serves the repeats.
#[test]
fn committed_prune_cutover_flushes_the_cam_and_stays_oracle_exact() {
    let model = redundant_mnist(0xca40);
    let mut cfg = engine_cfg(CamConfig { capacity: 16, max_distance: 8 });
    cfg.pool = PoolConfig { chips: 3, chip: ChipConfig::small_test(), seed: 0xca41 };
    // warm-up 1 / interval 1: the very first monitor pass proposes.
    // That matters here: CAM-served batches don't advance the fleet
    // batch counter, so a long warm-up under an exact-duplicate stream
    // would starve the prune loop of passes entirely.
    cfg.prune = LivePruneConfig {
        every_batches: 1,
        max_layers_per_pass: 1,
        rule: PruneConfig {
            warmup_epochs: 1,
            prune_interval: 1,
            min_live_per_layer: 1,
            max_prune_rate: 1.0,
            ..Default::default()
        },
    };
    let engine = Engine::start(vec![TenantConfig::new("mnist", model.clone())], &cfg).unwrap();
    let events = engine.events_with(4096);
    let mut oracle = PrunedOracle { model: model.clone(), pending: VecDeque::new() };
    let ds = mnist::generate(1, 0xca42);
    let input = ds.sample(0);
    let mut all_records: Vec<EventRecord> = Vec::new();
    for i in 0..12 {
        let resp = engine.submit(0, input.to_vec()).recv().unwrap();
        let recs = events.drain();
        oracle.absorb(&recs);
        all_records.extend(recs);
        oracle.check(&format!("request {i}"), input, &resp.logits);
    }
    let report = engine.shutdown();
    all_records.extend(events.drain());
    let commits = all_records
        .iter()
        .filter(|r| matches!(r.event, ObsEvent::PruneCommitted { .. }))
        .count();
    let (flush_events, _) = count_cam_flushes(&all_records);
    assert!(report.prune.cutovers >= 1, "the planted duplicates must commit a cutover");
    assert_eq!(commits as u64, report.prune.cutovers);
    let s = &report.cam.per_tenant[0];
    assert!(flush_events >= 1, "a committed cutover with a live CAM entry must flush");
    assert!(
        flush_events <= commits,
        "{flush_events} CamFlush events from only {commits} commits"
    );
    assert_eq!(
        flush_events as u64, s.flushes,
        "every counted flush transition is emitted exactly once"
    );
    assert_eq!(s.verify_fail, 0, "an exact-duplicate stream never fails a verify");
    assert!(s.hits >= 1, "once the rule runs dry the repeats must hit the CAM");
    assert_eq!(report.answered(), 12);
    assert_eq!(report.dropped(), 0);
}

/// The opt-in [`VerifyPolicy::Trusted`] end to end: near hits are served
/// from cached logits without a recompute (except the deterministic
/// first-after-flush audit), the answers equal the cached neighbor's
/// bit-exact logits, and the report flags the tenant as trusted.
#[test]
fn trusted_policy_serves_near_hits_from_cache_and_reports_it() {
    let pn_model: ModelBundle = tiny_pointnet(0.0, 0xca50).into();
    let backend = LocalBackend::from_pool_config(&pool_cfg(0xca51, 0.0)).unwrap();
    let router =
        ShardRouter::new(vec![vec![Box::new(backend) as Box<dyn Backend>]], router_cfg(2))
            .unwrap();
    // a deliberately huge delta bound: the audits always pass, so the
    // counters below are exactly determined (breach flushing is
    // unit-tested in engine/cam.rs)
    let tenants =
        vec![TenantConfig::new("pointnet", pn_model.clone()).with_trusted_cam(1e30)];
    let cfg = engine_cfg(CamConfig { capacity: 16, max_distance: 8 });
    let engine = Engine::start_with_router(tenants, router, &cfg).unwrap();
    let clouds = modelnet::generate(1, 0xca52);
    let base = clouds.sample(0).to_vec();
    let base_ref = pn_model.reference_logits(&base);
    // base: computed and inserted
    let resp = engine.submit(0, base.clone()).recv().unwrap();
    assert_eq!(resp.logits, base_ref);
    // first near variant: the audit serve — recomputed, so bit-exact
    // against its own reference
    let v1 = near_cloud(&base, 4);
    let resp = engine.submit(0, v1.clone()).recv().unwrap();
    assert_eq!(resp.logits, pn_model.reference_logits(&v1), "audit serves recompute");
    // further near variants: served straight from the cached neighbor
    // (the base, at packed distance 1) without touching silicon
    for coord in [7usize, 10] {
        let v = near_cloud(&base, coord);
        let resp = engine.submit(0, v).recv().unwrap();
        assert_eq!(resp.logits, base_ref, "trusted serves replay the cached neighbor");
    }
    let report = engine.shutdown();
    let s = &report.cam.per_tenant[0];
    assert!(s.trusted, "the opt-in is always reported");
    assert_eq!(s.near_hits, 3, "audit + two trusted serves are all near hits");
    assert_eq!(s.trusted_served, 2, "the audit serve is excluded from trusted_served");
    assert_eq!(s.hits, 0);
    assert_eq!(s.verify_fail, 0, "a huge bound means the audit must pass");
    assert_eq!(s.flushes, 0, "no transition, no broken trust: nothing flushes");
    assert_eq!(
        report.cam.served(),
        2,
        "the two trusted serves skipped silicon (and the energy denominator)"
    );
    assert_eq!(report.tenants[0].chip_batches, 2, "base + audit are the only computes");
}

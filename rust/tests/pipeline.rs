//! The dispatch-pipeline suite: the depth-bounded pack/dispatch overlap
//! ([`PipelineConfig`]) exercised end to end over real chips — the
//! depth bound is never exceeded, out-of-order collection works against
//! a live backend, logits are bit-exact at depths {1, 2, 4} with
//! stuck-tile fault injection, and a mid-run cross-group migration
//! (whose fence must drain the whole pipeline) never corrupts an
//! answer. The router-internal mechanics (stash accounting, fence
//! invalidation, post-fence collect errors) are unit-tested in
//! `router.rs`; this file proves the same properties with real pools
//! and the real executor.

// Terminal output is this target's product; the serve-code print ban
// (workspace clippy.toml `disallowed-macros`) deliberately does not
// apply outside `rust/src/serve/**`.
#![allow(clippy::disallowed_macros)]

use std::sync::Arc;
use std::time::Duration;

use rram_cim::chip::ChipConfig;
use rram_cim::cim::mapping::segment_widths;
use rram_cim::cim::vmm;
use rram_cim::nn::data::{mnist, modelnet};
use rram_cim::nn::pointnet::GroupingConfig;
use rram_cim::serve::transport::{
    Backend, LayerRoute, LocalBackend, OwnedPayload, ShardRef, ShardRouter, TenantRoute,
    WireWindows,
};
use rram_cim::serve::{
    AdmissionConfig, CacheConfig, Engine, EngineConfig, HedgeConfig, ModelBundle, PipelineConfig,
    PointNetBundle, PoolConfig, RebalanceConfig, RouterConfig, TenantConfig,
};
use rram_cim::testing::forall;

fn pool_cfg(seed: u64, fault: f64) -> PoolConfig {
    let mut chip = ChipConfig::small_test();
    chip.device.stuck_fault_prob = fault;
    PoolConfig { chips: 3, chip, seed }
}

fn router_cfg(depth: usize) -> RouterConfig {
    RouterConfig {
        pipeline: PipelineConfig { depth },
        ..RouterConfig::default()
    }
}

fn engine_cfg() -> EngineConfig {
    EngineConfig {
        pool: PoolConfig::default(), // ignored by start_with_router
        admission: AdmissionConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            quantum: 4,
        },
        cache: CacheConfig::default(),
        rebalance: RebalanceConfig { every_batches: 2, max_moves: 1, group_moves: 0 },
        prune: Default::default(),
        cam: Default::default(),
        obs: true,
    }
}

fn tiny_pointnet(prune: f64, seed: u64) -> PointNetBundle {
    PointNetBundle::synthetic(
        [2, 2, 3, 2, 2, 3, 2, 4],
        3,
        prune,
        GroupingConfig { s1: 8, k1: 4, r1: 0.3, s2: 4, k2: 2, r2: 0.6 },
        seed,
    )
}

/// The depth bound and out-of-order collection against a *real* pool:
/// submissions park in the pending set until collected (replies stash),
/// the `depth + 1`-th submission is refused, collection order is the
/// caller's choice, and every collected dot vector is bit-exact.
#[test]
fn submissions_fill_the_depth_bound_and_collect_in_any_order() {
    let backend = LocalBackend::from_pool_config(&pool_cfg(0x9199, 0.0)).unwrap();
    let mut router =
        ShardRouter::new(vec![vec![Box::new(backend) as Box<dyn Backend>]], router_cfg(4))
            .unwrap();
    let bits: Vec<bool> = (0..11).map(|i| i % 3 != 1).collect();
    let rep = router.program(0, 0, OwnedPayload::Binary(bits.clone())).unwrap();
    assert_eq!(rep.failures, 0);
    let shards = Arc::new(vec![ShardRef { chip: 0, filter: 0, span: rep.span.unwrap() }]);
    let epoch = router.next_epoch();
    let route = TenantRoute { epoch, layers: vec![LayerRoute { group: 0, shards }] };
    let widths = segment_widths(bits.len(), router.data_cols());
    // four distinct micro-batches, one dispatch each
    let flats: Vec<Vec<u8>> = (0..4u64)
        .map(|k| (0..bits.len()).map(|i| ((i as u64 * 31 + k * 7) % 256) as u8).collect())
        .collect();
    let mut pendings: Vec<Option<_>> = Vec::new();
    for (k, flat) in flats.iter().enumerate() {
        let pw = Arc::new(vmm::pack_windows(flat, &widths).unwrap());
        let trace = router.begin_trace();
        let pd = router.submit_layer(&route, 0, WireWindows::Binary(pw), trace).unwrap();
        pendings.push(Some(pd));
        assert_eq!(router.pending_dispatches(), k + 1, "pending grows per submission");
    }
    // the bound: a fifth submission must be refused, not queued
    let pw = Arc::new(vmm::pack_windows(&flats[0], &widths).unwrap());
    let trace = router.begin_trace();
    let err = match router.submit_layer(&route, 0, WireWindows::Binary(pw), trace) {
        Ok(_) => panic!("depth 4 must refuse a fifth in-flight dispatch"),
        Err(e) => e,
    };
    assert!(err.to_string().contains("depth 4 exhausted"), "got: {err}");
    // collect out of order: 2, 0, 3, 1 — replies for not-yet-collected
    // dispatches stash instead of being discarded
    for k in [2usize, 0, 3, 1] {
        let pd = pendings[k].take().expect("each dispatch is collected once");
        let dots = router.collect(pd).unwrap();
        let want = vec![(0, vec![vmm::binary_dot_ref(&bits, &flats[k])])];
        assert_eq!(dots, want, "dispatch {k} diverged");
    }
    assert_eq!(router.pending_dispatches(), 0);
    let s = router.stats();
    assert_eq!(s.stale_discarded, 0, "stashed replies are answers, not strays");
    assert_eq!(s.epoch_discards, 0);
    assert!(s.peak_inflight <= 4, "depth bound exceeded: {}", s.peak_inflight);
    router.finish().unwrap();
}

/// Logits are bit-exact at every pipeline depth — serial (1), the
/// default (2), and the full micro-batch split (4) — for both model
/// paths, with stuck-tile fault injection, over one engine run each.
/// The depth bound holds fleet-wide: `peak_inflight` never exceeds the
/// configured depth (no hedging is possible on a single-member group).
#[test]
fn prop_logits_are_bit_exact_at_depths_one_two_and_four() {
    forall(
        "pipeline: depth ∈ {1, 2, 4} serves bit-exactly",
        0x91be,
        2,
        |rng| {
            let fault = [0.0, 0.01][rng.below(2)];
            (fault, rng.next_u64())
        },
        |&(fault, seed)| {
            for depth in [1usize, 2, 4] {
                run_depth_harness(depth, fault, seed)?;
            }
            Ok(())
        },
    );
}

fn run_depth_harness(depth: usize, fault: f64, seed: u64) -> Result<(), String> {
    let mnist_model = ModelBundle::synthetic_mnist([3, 4, 3], 0.3, seed);
    let pn_model: ModelBundle = tiny_pointnet(0.3, seed ^ 1).into();
    let backend = LocalBackend::from_pool_config(&pool_cfg(seed ^ 2, fault))
        .map_err(|e| e.to_string())?;
    let router =
        ShardRouter::new(vec![vec![Box::new(backend) as Box<dyn Backend>]], router_cfg(depth))
            .map_err(|e| e.to_string())?;
    let tenants = vec![
        TenantConfig::new("mnist", mnist_model.clone()),
        TenantConfig::new("pointnet", pn_model.clone()),
    ];
    let engine = match Engine::start_with_router(tenants, router, &engine_cfg()) {
        Ok(e) => e,
        Err(e) => {
            let msg = e.to_string();
            return if msg.contains("placement") || msg.contains("rows") {
                Ok(()) // capacity lost to faults: explicit verdict
            } else {
                Err(format!("unexpected start error: {msg}"))
            };
        }
    };
    let images = mnist::generate(4, seed ^ 3);
    let clouds = modelnet::generate(4, seed ^ 4);
    let mut pending = Vec::new();
    for i in 0..4 {
        pending.push((0usize, i, engine.submit(0, images.sample(i).to_vec())));
        pending.push((1usize, i, engine.submit(1, clouds.sample(i).to_vec())));
    }
    for (t, i, rx) in pending {
        let resp = rx.recv().map_err(|e| e.to_string())?;
        let want = if t == 0 {
            mnist_model.reference_logits(images.sample(i))
        } else {
            pn_model.reference_logits(clouds.sample(i))
        };
        if resp.logits != want {
            return Err(format!("depth {depth}: tenant {t} input {i}: pipelining broke logits"));
        }
    }
    let report = engine.shutdown();
    if report.answered() != 8 {
        return Err(format!("depth {depth}: answered {} of 8", report.answered()));
    }
    if report.transport.peak_inflight > depth as u64 {
        return Err(format!(
            "depth {depth}: peak_inflight {} exceeded the bound",
            report.transport.peak_inflight
        ));
    }
    Ok(())
}

/// At depth 4 with a coalesced batch the executor genuinely overlaps:
/// at least two dispatches were in flight at once (`peak_inflight >=
/// 2`), and still never more than the depth bound.
#[test]
fn pipelined_batches_overlap_dispatches_within_the_depth_bound() {
    let model = ModelBundle::synthetic_mnist([3, 4, 3], 0.0, 0x0e71);
    let backend = LocalBackend::from_pool_config(&pool_cfg(0x0e72, 0.0)).unwrap();
    let router =
        ShardRouter::new(vec![vec![Box::new(backend) as Box<dyn Backend>]], router_cfg(4))
            .unwrap();
    let mut cfg = engine_cfg();
    // a generous coalescing window: the 8 back-to-back submissions below
    // land well inside it, so batches of >= 2 images actually form and
    // the executor splits them into concurrent micro-batches
    cfg.admission.max_wait = Duration::from_millis(50);
    cfg.cache = CacheConfig { capacity: 0 }; // every request hits silicon
    let engine = Engine::start_with_router(
        vec![TenantConfig::new("mnist", model.clone())],
        router,
        &cfg,
    )
    .unwrap();
    let ds = mnist::generate(4, 0x0e73);
    let mut pending = Vec::new();
    for r in 0..8 {
        pending.push((r % 4, engine.submit(0, ds.sample(r % 4).to_vec())));
    }
    for (i, rx) in pending {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.logits, model.reference_logits(ds.sample(i)), "image {i} diverged");
    }
    let report = engine.shutdown();
    assert_eq!(report.answered(), 8);
    let peak = report.transport.peak_inflight;
    assert!(peak >= 2, "coalesced batches at depth 4 never overlapped (peak {peak})");
    assert!(peak <= 4, "depth bound exceeded (peak {peak})");
}

/// A forced cross-group layer migration mid-run at depth 4: the fence
/// drains the whole pipeline before the cutover (anything less would
/// fold pre-cutover dots into post-cutover answers), so logits stay
/// bit-exact through the move and the migration completes.
#[test]
fn mid_run_migration_at_depth_four_stays_bit_exact() {
    let model = ModelBundle::synthetic_mnist([3, 4, 3], 0.0, 0x3197);
    let mut groups: Vec<Vec<Box<dyn Backend>>> = Vec::new();
    for s in 0..2u64 {
        let backend = LocalBackend::from_pool_config(&pool_cfg(0x3198 ^ s, 0.0)).unwrap();
        groups.push(vec![Box::new(backend) as Box<dyn Backend>]);
    }
    let router = ShardRouter::new(groups, router_cfg(4)).unwrap();
    let mut cfg = engine_cfg();
    cfg.cache = CacheConfig { capacity: 0 }; // every request hits silicon
    cfg.rebalance = RebalanceConfig { every_batches: 0, max_moves: 0, group_moves: 1 };
    let engine = Engine::start_with_router(
        vec![TenantConfig::new("mnist", model.clone())],
        router,
        &cfg,
    )
    .unwrap();
    let ds = mnist::generate(4, 0x3199);
    let check = |i: usize, resp: rram_cim::serve::Response| {
        assert_eq!(
            resp.logits,
            model.reference_logits(ds.sample(i)),
            "image {i} diverged across the migration"
        );
    };
    for i in 0..2 {
        check(i, engine.submit(0, ds.sample(i).to_vec()).recv().unwrap());
    }
    engine.force_rebalance();
    for i in 0..4 {
        check(i, engine.submit(0, ds.sample(i).to_vec()).recv().unwrap());
    }
    let report = engine.shutdown();
    assert_eq!(report.answered(), 6);
    assert_eq!(report.dropped(), 0);
    let t = &report.transport;
    assert!(t.migrations_started >= 1, "the forced pass must attempt a migration");
    assert!(t.migrations_completed >= 1, "an ideal fleet must complete it");
    assert!(t.peak_inflight <= 4, "depth bound exceeded ({})", t.peak_inflight);
}

/// Hedging composes with the pipeline: a 2-replica group at depth 4
/// with `after == 0` (hedge every collected dispatch) still answers
/// bit-exactly, fires hedges, and never double-replies.
#[test]
fn hedged_replicas_at_depth_four_stay_bit_exact() {
    let model = ModelBundle::synthetic_mnist([3, 4, 3], 0.0, 0x4ed6);
    let mut backends: Vec<Box<dyn Backend>> = Vec::new();
    for s in 0..2u64 {
        let b = LocalBackend::from_pool_config(&pool_cfg(0x4ed7 ^ s, 0.0)).unwrap();
        backends.push(Box::new(b));
    }
    let cfg = RouterConfig {
        hedge: HedgeConfig { after: Some(Duration::ZERO), ..HedgeConfig::default() },
        pipeline: PipelineConfig { depth: 4 },
        ..RouterConfig::default()
    };
    let router = ShardRouter::replicated(backends, cfg).unwrap();
    let engine = Engine::start_with_router(
        vec![TenantConfig::new("mnist", model.clone())],
        router,
        &engine_cfg(),
    )
    .unwrap();
    let ds = mnist::generate(5, 0x4ed8);
    let mut pending = Vec::new();
    for i in 0..5 {
        pending.push((i, engine.submit(0, ds.sample(i).to_vec())));
    }
    for (i, rx) in pending {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.logits, model.reference_logits(ds.sample(i)), "image {i} diverged");
        assert!(rx.try_recv().is_err(), "image {i} answered twice (hedge duplicate leaked)");
    }
    let report = engine.shutdown();
    assert_eq!(report.answered(), 5);
    assert!(report.transport.hedges_fired > 0, "after == 0 must hedge");
}

//! The TCP-loopback transport suite: the serving stack's bit-exactness
//! property harness run over every backend combination — in-process
//! [`LocalBackend`], [`RemoteBackend`] against a [`Host`] daemon on
//! loopback, and a hedged 2-replica [`ShardRouter`] of two hosts — with
//! stuck-tile fault injection, a live wear rebalance on a remote host,
//! an epoch-fenced **cross-host layer migration**, and a **host
//! bounce** (crash + replacement at the same address) healed by
//! reconnect + re-program + rejoin, all mid-test. Plus protocol
//! robustness: a garbage frame must get an error reply, never kill the
//! host, and a dropped connection must never lose the pool.
//!
//! CI runs this file as its own job (`cargo test --test
//! transport_remote`) under a timeout.

// Terminal output is this target's product; the serve-code print ban
// (workspace clippy.toml `disallowed-macros`) deliberately does not
// apply outside `rust/src/serve/**`.
#![allow(clippy::disallowed_macros)]

use std::sync::Arc;
use std::time::Duration;

use rram_cim::chip::ChipConfig;
use rram_cim::cim::mapping::segment_widths;
use rram_cim::cim::vmm;
use rram_cim::nn::data::{mnist, modelnet};
use rram_cim::nn::pointnet::GroupingConfig;
use rram_cim::serve::transport::{
    frame, Backend, Host, HostConfig, LocalBackend, OwnedPayload, ProgramRequest, ReconnectPolicy,
    RemoteBackend, ShardRef, ShardRouter, TenantRoute, WireWindows,
};
use rram_cim::serve::{
    AdmissionConfig, CacheConfig, Engine, EngineConfig, HedgeConfig, ModelBundle, PointNetBundle,
    PoolConfig, RebalanceConfig, RouterConfig, TenantConfig,
};
use rram_cim::testing::forall;

#[derive(Clone, Copy, Debug)]
enum Topology {
    /// One in-process pool behind the router.
    Local,
    /// One TCP-loopback host daemon owning the pool.
    Remote,
    /// Two host daemons forming a hedged replica group (hedge fires on
    /// every dispatch: `after == 0`).
    Hedged,
}

fn tiny_pointnet(prune: f64, seed: u64) -> PointNetBundle {
    PointNetBundle::synthetic(
        [2, 2, 3, 2, 2, 3, 2, 4],
        3,
        prune,
        GroupingConfig { s1: 8, k1: 4, r1: 0.3, s2: 4, k2: 2, r2: 0.6 },
        seed,
    )
}

fn pool_cfg(seed: u64, fault: f64) -> PoolConfig {
    let mut chip = ChipConfig::small_test();
    chip.device.stuck_fault_prob = fault;
    PoolConfig { chips: 3, chip, seed }
}

fn engine_cfg() -> EngineConfig {
    EngineConfig {
        pool: PoolConfig::default(), // ignored by start_with_router
        admission: AdmissionConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            quantum: 4,
        },
        cache: CacheConfig::default(),
        rebalance: RebalanceConfig { every_batches: 2, max_moves: 1, group_moves: 0 },
        prune: Default::default(),
        cam: Default::default(),
        obs: true,
    }
}

/// Build the topology's router (and keep its host daemons alive).
fn build_router(
    top: Topology,
    seed: u64,
    fault: f64,
    hosts: &mut Vec<Host>,
) -> Result<ShardRouter, String> {
    let remote = |seed, hosts: &mut Vec<Host>| -> Result<RemoteBackend, String> {
        let host = Host::spawn(HostConfig { pool: pool_cfg(seed, fault) })
            .map_err(|e| e.to_string())?;
        let backend = RemoteBackend::connect(host.addr()).map_err(|e| e.to_string())?;
        hosts.push(host);
        Ok(backend)
    };
    match top {
        Topology::Local => {
            let backend =
                LocalBackend::from_pool_config(&pool_cfg(seed, fault)).map_err(|e| e.to_string())?;
            ShardRouter::single(Box::new(backend)).map_err(|e| e.to_string())
        }
        Topology::Remote => {
            let backend = remote(seed, hosts)?;
            ShardRouter::single(Box::new(backend)).map_err(|e| e.to_string())
        }
        Topology::Hedged => {
            let a = remote(seed, hosts)?;
            let b = remote(seed ^ 0x5117, hosts)?;
            let cfg = RouterConfig {
                hedge: HedgeConfig { after: Some(Duration::ZERO), ..HedgeConfig::default() },
                ..RouterConfig::default()
            };
            ShardRouter::replicated(vec![Box::new(a), Box::new(b)], cfg)
                .map_err(|e| e.to_string())
        }
    }
}

/// The harness body: both bundles as tenants of one engine over the
/// given topology, interleaved traffic, a forced rebalance mid-run, and
/// a bit-exactness check on every answer. With fault injection the
/// engine may instead reject at placement — that must be a clean,
/// explicit error.
fn run_harness(top: Topology, fault: f64, seed: u64) -> Result<(), String> {
    let mnist_model = ModelBundle::synthetic_mnist([3, 4, 3], 0.3, seed);
    let pn_model: ModelBundle = tiny_pointnet(0.3, seed ^ 1).into();
    let mut hosts = Vec::new();
    let router = build_router(top, seed ^ 2, fault, &mut hosts)?;
    let tenants = vec![
        TenantConfig::new("mnist", mnist_model.clone()),
        TenantConfig::new("pointnet", pn_model.clone()),
    ];
    let engine = match Engine::start_with_router(tenants, router, &engine_cfg()) {
        Ok(e) => e,
        Err(e) => {
            let msg = e.to_string();
            drop(hosts); // daemons exit on connection close
            return if msg.contains("placement") || msg.contains("rows") {
                Ok(()) // capacity lost to faults: explicit verdict
            } else {
                Err(format!("unexpected start error: {msg}"))
            };
        }
    };
    let images = mnist::generate(4, seed ^ 3);
    let clouds = modelnet::generate(4, seed ^ 4);
    let check = |t: usize, i: usize, resp: rram_cim::serve::Response| -> Result<(), String> {
        let want = if t == 0 {
            mnist_model.reference_logits(images.sample(i))
        } else {
            pn_model.reference_logits(clouds.sample(i))
        };
        if resp.logits != want {
            return Err(format!("{top:?}: tenant {t} input {i}: transport corrupted the logits"));
        }
        Ok(())
    };
    // phase 1: interleaved traffic (advances the rebalance clock)
    let mut pending = Vec::new();
    for i in 0..3 {
        pending.push((0usize, i, engine.submit(0, images.sample(i).to_vec())));
        pending.push((1usize, i, engine.submit(1, clouds.sample(i).to_vec())));
    }
    for (t, i, rx) in pending {
        check(t, i, rx.recv().map_err(|e| e.to_string())?)?;
    }
    // phase 2: force a rebalance (on the remote host for Remote/Hedged
    // topologies), then serve more traffic through the migrated
    // placement — still bit-exact
    engine.force_rebalance();
    for i in 0..4 {
        let resp = engine.submit(0, images.sample(i).to_vec()).recv().map_err(|e| e.to_string())?;
        check(0, i, resp)?;
        let resp = engine.submit(1, clouds.sample(i).to_vec()).recv().map_err(|e| e.to_string())?;
        check(1, i, resp)?;
    }
    let report = engine.shutdown();
    if report.answered() != 14 {
        return Err(format!("{top:?}: answered {} of 14", report.answered()));
    }
    if report.dropped() != 0 {
        return Err(format!("{top:?}: blocking submits must never drop"));
    }
    if fault == 0.0 && report.shards_moved == 0 {
        return Err(format!(
            "{top:?}: the forced pass must migrate at least one shard on an ideal pool"
        ));
    }
    if let Topology::Hedged = top {
        if report.transport.hedges_fired == 0 {
            return Err("hedged topology must fire hedges with after == 0".into());
        }
    }
    for host in hosts {
        host.join();
    }
    Ok(())
}

/// Property: the bit-exactness harness (both bundles, fault injection,
/// mid-run rebalance) passes identically over a local pool, a TCP
/// host, and a hedged 2-replica fleet of hosts.
#[test]
fn prop_harness_is_bit_exact_over_every_backend_combination() {
    forall(
        "transport: local == remote == hedged, bit for bit",
        0x77a9,
        2,
        |rng| {
            let fault = [0.0, 0.01][rng.below(2)];
            (fault, rng.next_u64())
        },
        |&(fault, seed)| {
            for top in [Topology::Local, Topology::Remote, Topology::Hedged] {
                run_harness(top, fault, seed)?;
            }
            Ok(())
        },
    );
}

/// A hedged replica group can never answer a request twice: every
/// submitted request yields exactly one response, ids are unique, and
/// the losing duplicates show up only as discarded-stale counts.
#[test]
fn hedged_duplicates_never_double_reply() {
    let model = ModelBundle::synthetic_mnist([3, 4, 3], 0.0, 0xd0b1e);
    let mut hosts = Vec::new();
    let router = build_router(Topology::Hedged, 0xd0b1e, 0.0, &mut hosts).unwrap();
    let engine = Engine::start_with_router(
        vec![TenantConfig::new("mnist", model.clone())],
        router,
        &engine_cfg(),
    )
    .unwrap();
    let ds = mnist::generate(6, 0xd0b2e);
    let reference: Vec<Vec<f32>> =
        (0..6).map(|i| model.reference_logits(ds.sample(i))).collect();
    let mut pending = Vec::new();
    for _round in 0..3 {
        for i in 0..6 {
            pending.push((i, engine.submit(0, ds.sample(i).to_vec())));
        }
    }
    let mut ids = Vec::new();
    for (i, rx) in pending {
        let resp = rx.recv().expect("every request answered exactly once");
        assert_eq!(resp.logits, reference[i], "hedged serving diverged on input {i}");
        ids.push(resp.id);
        // the channel must hold exactly one response — a duplicate
        // reply would surface here as a second pending message
        assert!(
            rx.try_recv().is_err(),
            "request {i} received a second response (hedge duplicate leaked)"
        );
    }
    let mut deduped = ids.clone();
    deduped.sort_unstable();
    deduped.dedup();
    assert_eq!(deduped.len(), ids.len(), "duplicate response ids");
    let report = engine.shutdown();
    assert_eq!(report.answered(), 18);
    assert!(report.transport.hedges_fired > 0, "after == 0 must hedge");
    for host in hosts {
        host.join();
    }
}

/// One tenant's layers split across two single-member groups (two
/// hosts): both hosts compute, logits stay bit-exact.
#[test]
fn layers_shard_across_two_hosts_bit_exactly() {
    let model = ModelBundle::synthetic_mnist([3, 4, 3], 0.0, 0x2b057);
    let mut hosts = Vec::new();
    let mut groups: Vec<Vec<Box<dyn Backend>>> = Vec::new();
    for s in 0..2u64 {
        let host = Host::spawn(HostConfig { pool: pool_cfg(0x2b057 ^ s, 0.0) }).unwrap();
        groups.push(vec![Box::new(RemoteBackend::connect(host.addr()).unwrap())]);
        hosts.push(host);
    }
    let router = ShardRouter::new(groups, RouterConfig::default()).unwrap();
    assert_eq!(router.n_groups(), 2);
    let engine = Engine::start_with_router(
        vec![TenantConfig::new("mnist", model.clone())],
        router,
        &engine_cfg(),
    )
    .unwrap();
    let ds = mnist::generate(5, 0x2b058);
    for i in 0..5 {
        let resp = engine.submit(0, ds.sample(i).to_vec()).recv().unwrap();
        assert_eq!(
            resp.logits,
            model.reference_logits(ds.sample(i)),
            "cross-host sharding diverged on image {i}"
        );
    }
    let report = engine.shutdown();
    assert_eq!(report.wear.len(), 6, "three chips per host, two hosts");
    let host_wl =
        |r: &[rram_cim::chip::WearLedger]| r.iter().map(|w| w.wl_activations).sum::<u64>();
    assert!(host_wl(&report.wear[..3]) > 0, "host 0 never computed");
    assert!(host_wl(&report.wear[3..]) > 0, "host 1 never computed");
    for host in hosts {
        host.join();
    }
}

/// The pool outlives a dropped connection: shards programmed over one
/// session are served (bit-exactly) over the next, and the incarnation
/// is stable — the reconnect story's foundation.
#[test]
fn host_pool_survives_a_dropped_connection() {
    let host = Host::spawn(HostConfig { pool: pool_cfg(0x5e55, 0.0) }).unwrap();
    let bits: Vec<bool> = (0..17).map(|i| i % 3 == 0).collect();
    let (incarnation, span) = {
        let mut first = RemoteBackend::connect(host.addr()).unwrap();
        let info = first.describe().unwrap();
        let rep = first
            .program(ProgramRequest { chip: 0, payload: OwnedPayload::Binary(bits.clone()) })
            .unwrap();
        assert_eq!(rep.failures, 0);
        (info.incarnation, rep.span.unwrap())
        // `first` drops here: the session ends WITHOUT Finish
    };
    // a second session reaches the same pool, same incarnation, and the
    // shard programmed by the first session still computes exact dots
    let mut second = RemoteBackend::connect(host.addr()).unwrap();
    let info = second.describe().unwrap();
    assert_eq!(info.incarnation, incarnation, "same pool across sessions");
    let widths = segment_widths(bits.len(), info.data_cols as usize);
    let flat: Vec<u8> = (0..2 * bits.len()).map(|i| (i * 13 % 256) as u8).collect();
    let pw = Arc::new(vmm::pack_windows(&flat, &widths).unwrap());
    let reply = second
        .dispatch(rram_cim::serve::transport::DispatchRequest {
            request_id: 1,
            shard_epoch: 1,
            layer: 0,
            trace: rram_cim::serve::TraceContext {
                trace_id: 0xace,
                parent_span: 3,
                span_id: 4,
            },
            shards: Arc::new(vec![ShardRef { chip: 0, filter: 0, span }]),
            windows: WireWindows::Binary(pw),
        })
        .unwrap();
    let want: Vec<i64> =
        flat.chunks(bits.len()).map(|w| vmm::binary_dot_ref(&bits, w)).collect();
    assert_eq!(reply.dots, vec![(0, want)], "cross-session dots diverged");
    assert_eq!(
        (reply.trace.trace_id, reply.trace.parent_span, reply.trace.span_id),
        (0xace, 3, 4),
        "trace context must survive the TCP frame round-trip"
    );
    assert!(reply.host_ns > 0, "the host stamps its boundary time on the reply");
    assert_eq!(second.reconnects(), 0, "nothing dropped mid-call here");
    second.finish().unwrap();
    host.join();
}

/// Epoch fencing over real TCP: a hedge loser still in flight when the
/// cutover fences its epoch is discarded by the drain and counted in
/// `epoch_discards` exactly once — never double-counted, never folded.
#[test]
fn fenced_stale_reply_over_tcp_is_counted_exactly_once() {
    use rram_cim::serve::transport::LayerRoute;

    let mut hosts = Vec::new();
    let mut backends: Vec<Box<dyn Backend>> = Vec::new();
    for s in 0..2u64 {
        let host = Host::spawn(HostConfig { pool: pool_cfg(0xfe7ce ^ s, 0.0) }).unwrap();
        backends.push(Box::new(RemoteBackend::connect(host.addr()).unwrap()));
        hosts.push(host);
    }
    let cfg = RouterConfig {
        hedge: HedgeConfig { after: Some(Duration::ZERO), ..HedgeConfig::default() },
        ..RouterConfig::default()
    };
    let mut router = ShardRouter::replicated(backends, cfg).unwrap();
    // one shard programmed onto each replica (its own span)
    let bits: Vec<bool> = (0..9).map(|i| i % 2 == 0).collect();
    let mut shards = Vec::new();
    for m in 0..2 {
        let rep = router.program(m, 0, OwnedPayload::Binary(bits.clone())).unwrap();
        assert_eq!(rep.failures, 0);
        shards.push(Arc::new(vec![ShardRef { chip: 0, filter: 0, span: rep.span.unwrap() }]));
    }
    let epoch = router.next_epoch();
    let route = TenantRoute { epoch, layers: vec![LayerRoute { group: 0, shards }] };
    let widths = segment_widths(bits.len(), router.data_cols());
    let flat: Vec<u8> = (0..bits.len()).map(|i| (i * 7 % 256) as u8).collect();
    let pw = Arc::new(vmm::pack_windows(&flat, &widths).unwrap());
    let dots = router.dispatch_layer(&route, 0, WireWindows::Binary(pw)).unwrap();
    assert_eq!(dots, vec![(0, vec![vmm::binary_dot_ref(&bits, &flat)])]);
    // hedge fired on every dispatch (after == 0): exactly one loser is
    // still in flight; fence its epoch and drain it
    assert_eq!(router.stats().hedges_fired, 1);
    router.fence_and_drain(epoch).unwrap();
    let s = router.stats();
    assert_eq!(s.epoch_discards, 1, "the fenced loser is counted exactly once");
    assert_eq!(s.stale_discarded, 0, "…and never also as a plain stale");
    router.finish().unwrap();
    for host in hosts {
        host.join();
    }
}

/// The reconnect lifecycle end to end: layers split across two hosts,
/// a completed cross-host layer migration, then host B crashes and a
/// replacement takes over its address. B's backend reconnects, reports
/// the bounce, and the engine re-programs it with the **current**
/// (post-migration) placement at the **current** epoch before it serves
/// a single dispatch — so every answer stays bit-exact and the missed
/// migration can never resurface pre-cutover shard addresses.
#[test]
fn reconnecting_host_that_missed_a_migration_is_reprogrammed_before_serving() {
    let model = ModelBundle::synthetic_mnist([3, 4, 3], 0.0, 0x9ec0);
    let mut hosts = Vec::new();
    let mut groups: Vec<Vec<Box<dyn Backend>>> = Vec::new();
    for s in 0..2u64 {
        let host = Host::spawn(HostConfig { pool: pool_cfg(0x9ec0 ^ s, 0.0) }).unwrap();
        let backend = RemoteBackend::connect_with(
            host.addr(),
            ReconnectPolicy { max_attempts: 8, ..ReconnectPolicy::default() },
        )
        .unwrap();
        groups.push(vec![Box::new(backend)]);
        hosts.push(host);
    }
    let router = ShardRouter::new(groups, RouterConfig::default()).unwrap();
    let mut cfg = engine_cfg();
    cfg.cache = CacheConfig { capacity: 0 }; // every request hits silicon
    cfg.rebalance = RebalanceConfig { every_batches: 0, max_moves: 0, group_moves: 1 };
    let engine = Engine::start_with_router(
        vec![TenantConfig::new("mnist", model.clone())],
        router,
        &cfg,
    )
    .unwrap();
    let ds = mnist::generate(5, 0x9ec1);
    let check = |i: usize, resp: rram_cim::serve::Response| {
        assert_eq!(
            resp.logits,
            model.reference_logits(ds.sample(i)),
            "image {i} diverged"
        );
    };
    // phase 1: traffic, then a forced cross-host layer migration
    for i in 0..2 {
        check(i, engine.submit(0, ds.sample(i).to_vec()).recv().unwrap());
    }
    engine.force_rebalance();
    for i in 0..3 {
        check(i, engine.submit(0, ds.sample(i).to_vec()).recv().unwrap());
    }
    // phase 2: host B crashes; a replacement binds the same address
    // with a fresh (empty) pool and a fresh incarnation
    let b = hosts.pop().unwrap();
    let b_addr = b.addr();
    b.shutdown();
    hosts.push(Host::spawn_at(b_addr, HostConfig { pool: pool_cfg(0x9ec2, 0.0) }).unwrap());
    // phase 3: traffic again — B's first touched dispatch fails fast
    // (client-side bounce quarantine), the engine heals (probe,
    // re-program to the post-migration placement, rejoin), and every
    // answer is still bit-exact
    for i in 0..5 {
        check(i, engine.submit(0, ds.sample(i).to_vec()).recv().unwrap());
    }
    let report = engine.shutdown();
    assert_eq!(report.answered(), 10);
    assert_eq!(report.dropped(), 0);
    let t = &report.transport;
    assert!(t.migrations_started >= 1, "the forced pass must attempt a migration");
    assert!(t.migrations_completed >= 1, "an ideal fleet must complete it");
    assert!(t.reconnects >= 1, "host B must have been reconnected to");
    for host in hosts {
        host.join();
    }
}

/// Property (the PR's acceptance bar): logits stay bit-exact through a
/// host bounce and a cross-host layer migration landing at the **same
/// pass boundary**, with stuck-tile fault injection on every pool. The
/// pass heals first (probe → re-program the bounced member at the
/// current epoch → rejoin), then the forced migration walks
/// program → fence → drain → free against the healed fleet; a
/// destination dying mid-program instead takes the documented ABORT
/// edge (unit-tested in `router.rs`). If faults make any of it
/// impossible, the failure is a clean, explicit error, never a wrong
/// logit.
#[test]
fn prop_migration_with_mid_flight_host_bounce_stays_bit_exact() {
    forall(
        "transport: host bounce + cross-host migration, bit for bit",
        0xb0517,
        2,
        |rng| {
            let fault = [0.0, 0.01][rng.below(2)];
            (fault, rng.next_u64())
        },
        |&(fault, seed)| run_bounce_harness(fault, seed),
    );
}

fn run_bounce_harness(fault: f64, seed: u64) -> Result<(), String> {
    let model = ModelBundle::synthetic_mnist([3, 4, 3], 0.2, seed);
    let mut hosts = Vec::new();
    let mut groups: Vec<Vec<Box<dyn Backend>>> = Vec::new();
    for s in 0..2u64 {
        let host = Host::spawn(HostConfig { pool: pool_cfg(seed ^ s, fault) })
            .map_err(|e| e.to_string())?;
        let backend = RemoteBackend::connect_with(
            host.addr(),
            ReconnectPolicy { max_attempts: 8, ..ReconnectPolicy::default() },
        )
        .map_err(|e| e.to_string())?;
        groups.push(vec![Box::new(backend) as Box<dyn Backend>]);
        hosts.push(host);
    }
    let router = ShardRouter::new(groups, RouterConfig::default()).map_err(|e| e.to_string())?;
    let mut cfg = engine_cfg();
    cfg.cache = CacheConfig { capacity: 0 };
    cfg.rebalance = RebalanceConfig { every_batches: 0, max_moves: 0, group_moves: 1 };
    let engine = match Engine::start_with_router(
        vec![TenantConfig::new("mnist", model.clone())],
        router,
        &cfg,
    ) {
        Ok(e) => e,
        Err(e) => {
            let msg = e.to_string();
            drop(hosts);
            return if msg.contains("placement") || msg.contains("rows") {
                Ok(()) // capacity lost to faults: explicit verdict
            } else {
                Err(format!("unexpected start error: {msg}"))
            };
        }
    };
    let ds = mnist::generate(4, seed ^ 7);
    let check = |i: usize, resp: rram_cim::serve::Response| -> Result<(), String> {
        if resp.logits != model.reference_logits(ds.sample(i)) {
            return Err(format!("image {i}: migration/bounce corrupted the logits"));
        }
        Ok(())
    };
    // warm-up (builds the heat signal)
    for i in 0..2 {
        check(i, engine.submit(0, ds.sample(i).to_vec()).recv().map_err(|e| e.to_string())?)?;
    }
    // crash host B and bring its replacement up at the same address,
    // then force a pass: it heals the bounced member first (probe,
    // re-program at the current epoch, rejoin) and then completes the
    // cross-host migration against the healed fleet
    let b = hosts.pop().ok_or("host list empty")?;
    let b_addr = b.addr();
    b.shutdown();
    hosts.push(
        Host::spawn_at(b_addr, HostConfig { pool: pool_cfg(seed ^ 11, fault) })
            .map_err(|e| e.to_string())?,
    );
    engine.force_rebalance();
    for i in 0..4 {
        check(i, engine.submit(0, ds.sample(i).to_vec()).recv().map_err(|e| e.to_string())?)?;
    }
    let report = engine.shutdown();
    if report.answered() != 6 {
        return Err(format!("answered {} of 6", report.answered()));
    }
    if report.dropped() != 0 {
        return Err("blocking submits must never drop".into());
    }
    if report.transport.reconnects == 0 {
        return Err("the bounced host must have been reconnected to".into());
    }
    if fault == 0.0 && report.transport.migrations_completed == 0 {
        return Err(
            "on an ideal fleet the forced pass must complete a cross-host migration \
             even with a bounced member in the fleet"
                .into(),
        );
    }
    for host in hosts {
        host.join();
    }
    Ok(())
}

/// Protocol robustness: a garbage frame gets an error reply and the
/// connection survives — the next well-formed request still works.
#[test]
fn garbage_frames_get_error_replies_not_a_dead_host() {
    use std::net::TcpStream;

    let host = Host::spawn(HostConfig { pool: pool_cfg(0xbad, 0.0) }).unwrap();
    let mut stream = TcpStream::connect(host.addr()).unwrap();
    stream.set_nodelay(true).unwrap();
    // a frame whose payload is not a valid request
    frame::write_frame(&mut stream, &[0x7f, 0x00, 0x01]).unwrap();
    let reply = frame::read_frame(&mut stream).unwrap();
    match frame::decode_reply(&reply).unwrap() {
        frame::WireReply::Err(msg) => assert!(msg.contains("bad request"), "{msg}"),
        other => panic!("garbage must be answered with Err, got {other:?}"),
    }
    // the session is still alive: a proper Describe round-trips
    frame::write_frame(&mut stream, &frame::encode_request(&frame::WireRequest::Describe))
        .unwrap();
    let reply = frame::read_frame(&mut stream).unwrap();
    match frame::decode_reply(&reply).unwrap() {
        frame::WireReply::Describe(info) => {
            assert_eq!(info.chips, 3);
            assert!(info.data_cols > 0);
        }
        other => panic!("expected Describe reply, got {other:?}"),
    }
    // a Finish ends the session cleanly
    frame::write_frame(&mut stream, &frame::encode_request(&frame::WireRequest::Finish)).unwrap();
    let reply = frame::read_frame(&mut stream).unwrap();
    assert!(matches!(frame::decode_reply(&reply).unwrap(), frame::WireReply::Finish(_)));
    drop(stream);
    host.join();
}

//! The fully digital reconfigurable RRAM CIM chip (Fig. 3a): two 512x32
//! 1T1R blocks plus WRC/BSIC drivers, Rref readout, reconfigurable units,
//! shift-and-add groups, an accumulator bank, ECC, and energy/area/timing
//! ledgers. [`Chip`] exposes the three operating modes of the paper —
//! forming, programming, computation — and the per-row logic pass that
//! [`crate::cim`] builds convolution and similarity search on.

pub mod area;
pub mod datapath;
pub mod ecc;
pub mod energy;
pub mod logic;
pub mod periphery;
pub mod rr;
pub mod ru;
pub mod timing;

pub use area::AreaModel;
pub use energy::{EnergyBreakdown, EnergyLedger, EnergyModel};
pub use logic::LogicOp;
pub use timing::{TimingLedger, TimingModel};

use crate::device::{Array1T1R, DeviceConfig};
use crate::util::rng::Rng;

use datapath::{Accumulator, ShiftAdder};

/// Upper bound on physical columns, sized for stack buffers on the
/// compute hot path (the fabricated chip has 32).
pub const MAX_COLS: usize = 64;
use ecc::Ecc;
use periphery::{BlDriver, WlDriver};
use ru::ReconfigurableUnit;

/// How the compute path senses stored bits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadPath {
    /// Full electrical simulation: every read goes through the device
    /// model (resistance + noise + divider). Used for characterization
    /// and BER studies.
    Electrical,
    /// Digital shadow state captured at program time. Behaviourally
    /// identical for the zero-BER digital design (margins >> noise) and
    /// ~40x faster; stuck-at faults still flow through ECC. This is the
    /// §Perf hot-path option used during training loops.
    Digital,
}

/// Chip-level configuration.
#[derive(Clone, Debug)]
pub struct ChipConfig {
    pub rows: usize,
    pub cols: usize,
    pub blocks: usize,
    pub spares_per_row: usize,
    pub backup_rows: usize,
    pub device: DeviceConfig,
    pub read_path: ReadPath,
    pub energy: EnergyModel,
    pub timing: TimingModel,
}

impl Default for ChipConfig {
    fn default() -> Self {
        ChipConfig {
            rows: 512,
            cols: 32,
            blocks: 2,
            spares_per_row: 2,
            backup_rows: 16,
            device: DeviceConfig::default(),
            read_path: ReadPath::Digital,
            energy: EnergyModel::default(),
            timing: TimingModel::default(),
        }
    }
}

impl ChipConfig {
    /// Small chip for unit tests.
    pub fn small_test() -> Self {
        ChipConfig {
            rows: 64,
            cols: 32,
            blocks: 1,
            backup_rows: 4,
            device: DeviceConfig::ideal(),
            ..ChipConfig::default()
        }
    }

    /// Usable data columns per row after the ECC spare reservation.
    pub fn data_cols(&self) -> usize {
        self.cols - self.spares_per_row
    }

    /// Usable logical rows per block after the backup region reservation.
    pub fn logical_rows(&self) -> usize {
        self.rows - self.backup_rows
    }
}

/// Lifetime wear counters for endurance-aware scheduling. Unlike the
/// energy/timing ledgers these are **never reset** by
/// [`Chip::reset_ledgers`]: the serve placer ranks chips by them to
/// spread programming wear across a pool ([`crate::serve::placement`]).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct WearLedger {
    /// Write-verify pulses applied over the chip's lifetime (forming +
    /// programming) — the quantity RRAM endurance is specified against.
    pub write_pulses: u64,
    /// Logical cells successfully (re)programmed.
    pub programmed_cells: u64,
    /// Word-line activations (read/compute wear is negligible for RRAM
    /// but the count sizes the WRC duty cycle).
    pub wl_activations: u64,
}

impl WearLedger {
    /// Per-counter wear accrued since an `earlier` snapshot of the same
    /// chip — the rebalancer's hotness signal
    /// ([`crate::serve::engine::rebalance`]). Saturating, so comparing
    /// snapshots from unrelated chips cannot underflow.
    pub fn delta(&self, earlier: &WearLedger) -> WearLedger {
        WearLedger {
            write_pulses: self.write_pulses.saturating_sub(earlier.write_pulses),
            programmed_cells: self.programmed_cells.saturating_sub(earlier.programmed_cells),
            wl_activations: self.wl_activations.saturating_sub(earlier.wl_activations),
        }
    }

    /// True when no counter has gone backwards since `earlier` — the
    /// invariant every pair of same-chip snapshots must satisfy (wear is
    /// lifetime state, never reset).
    pub fn is_monotone_since(&self, earlier: &WearLedger) -> bool {
        self.write_pulses >= earlier.write_pulses
            && self.programmed_cells >= earlier.programmed_cells
            && self.wl_activations >= earlier.wl_activations
    }
}

/// One RRAM block with its periphery state.
struct Block {
    array: Array1T1R,
    ecc: Ecc,
    wl: WlDriver,
    bl: BlDriver,
    stuck_map: Vec<Vec<usize>>,
    /// Digital shadow of programmed 2-bit values (data written through
    /// the ECC plan, indexed by PHYSICAL row/col).
    shadow: Vec<u8>,
}

/// The chip: blocks + shared compute datapath + ledgers.
pub struct Chip {
    cfg: ChipConfig,
    blocks: Vec<Block>,
    ru: ReconfigurableUnit,
    sa: ShiftAdder,
    acc: Accumulator,
    pub energy: EnergyLedger,
    pub timing: TimingLedger,
    pub wear: WearLedger,
    area: AreaModel,
    formed: bool,
}

// The serve subsystem moves chips into per-worker threads; keep `Chip`
// (and everything it owns) `Send`.
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<Chip>();
};

impl Chip {
    pub fn new(cfg: ChipConfig, rng: &mut Rng) -> Self {
        let blocks = (0..cfg.blocks)
            .map(|b| {
                let array = Array1T1R::fabricate(
                    cfg.rows,
                    cfg.cols,
                    cfg.device.clone(),
                    &mut rng.fork(0xb10c + b as u64),
                );
                Block {
                    stuck_map: array.stuck_map(),
                    ecc: Ecc::new(cfg.rows, cfg.cols, cfg.spares_per_row, cfg.backup_rows),
                    wl: WlDriver::new(cfg.rows),
                    bl: BlDriver::new(cfg.cols),
                    shadow: vec![0u8; cfg.rows * cfg.cols],
                    array,
                }
            })
            .collect();
        let cols = cfg.cols;
        Chip {
            ru: ReconfigurableUnit::new(LogicOp::And),
            sa: ShiftAdder::new(),
            acc: Accumulator::new(cols),
            energy: EnergyLedger::default(),
            timing: TimingLedger::default(),
            wear: WearLedger::default(),
            area: AreaModel::default(),
            formed: false,
            blocks,
            cfg,
        }
    }

    pub fn cfg(&self) -> &ChipConfig {
        &self.cfg
    }

    pub fn area(&self) -> &AreaModel {
        &self.area
    }

    pub fn is_formed(&self) -> bool {
        self.formed
    }

    /// Forming mode: electroform all blocks; returns per-block yield.
    pub fn form(&mut self) -> Vec<f64> {
        let mut yields = Vec::new();
        for b in &mut self.blocks {
            let rep = b.array.form_all();
            // forming pulses: one write-class pulse per cell
            self.energy.rram_write_pulses += (self.cfg.rows * self.cfg.cols) as u64;
            self.wear.write_pulses += (self.cfg.rows * self.cfg.cols) as u64;
            self.timing.program_cycles +=
                (self.cfg.rows * self.cfg.cols) as u64 * self.cfg.timing.write_pulse_cycles;
            yields.push(rep.yield_frac);
        }
        self.formed = true;
        yields
    }

    /// Program one logical cell of a block to a 2-bit value through the
    /// ECC plan. Returns false if the cell could not be placed.
    pub fn program_2bit(&mut self, block: usize, row: usize, col: usize, value: u8) -> bool {
        assert!(self.formed, "program before forming");
        assert!(col < self.cfg.data_cols(), "col {col} beyond data columns");
        let b = &mut self.blocks[block];
        let Some(plan) = b.ecc.plan_row(row, &b.stuck_map) else {
            return false;
        };
        let (pr, pc) = (plan.phys_row, plan.col_map[col]);
        let target = rr::target_for_2bit(value, b.array.cfg());
        // WRC walks to the row serially; BSIC decodes the column.
        self.energy.wrc_shifts += pr as u64 / 8; // shift-register stride of 8 in program mode
        self.energy.wrc_activations += 1;
        b.bl.select(pc);
        self.energy.bsic_drives += 1;
        let pulses = b.array.program_cell(pr, pc, target);
        let used = pulses.unwrap_or(b.array.cfg().prog_max_iters) as u64;
        self.energy.rram_write_pulses += used;
        self.wear.write_pulses += used;
        self.timing.program_cycles += used * self.cfg.timing.write_pulse_cycles;
        if pulses.is_some() {
            b.shadow[pr * self.cfg.cols + pc] = value;
            self.wear.programmed_cells += 1;
            true
        } else {
            false
        }
    }

    /// Program a binary bit (1 = LRS). Uses the 2-bit extremes for margin.
    pub fn program_bit(&mut self, block: usize, row: usize, col: usize, bit: bool) -> bool {
        self.program_2bit(block, row, col, if bit { 3 } else { 0 })
    }

    /// Read back one logical 2-bit value through ECC + the configured
    /// read path.
    pub fn read_2bit(&mut self, block: usize, row: usize, col: usize) -> u8 {
        let read_path = self.cfg.read_path;
        let cols = self.cfg.cols;
        let b = &mut self.blocks[block];
        let plan = b
            .ecc
            .plan_row(row, &b.stuck_map)
            .expect("read of unmapped row");
        let (pr, pc) = (plan.phys_row, plan.col_map[col]);
        self.energy.rram_reads += 1;
        self.energy.rr_senses += 2; // successive approximation: 2 compares
        match read_path {
            ReadPath::Digital => b.shadow[pr * cols + pc],
            ReadPath::Electrical => rr::read_2bit(&mut b.array, pr, pc, &self.cfg.device).value,
        }
    }

    pub fn read_bit(&mut self, block: usize, row: usize, col: usize) -> bool {
        self.read_2bit(block, row, col) >= 2
    }

    /// One word-line logic pass (the chip's fundamental compute step):
    /// activate logical row `row`, broadcast X on the bit lines, feed K
    /// into the input logic, and return OUT[col] = X[col] AND (W[col] (.) K[col])
    /// for all data columns. W[col] is the *binary* stored bit.
    ///
    /// `with_acc` engages the accumulator (VMM mode) vs. S&A-only
    /// (Hadamard mode) — mirroring Fig. 3a's description.
    pub fn logic_pass(
        &mut self,
        block: usize,
        row: usize,
        op: LogicOp,
        x: &[bool],
        k: &[bool],
        with_acc: bool,
    ) -> Vec<bool> {
        assert!(self.formed, "compute before forming");
        let n = self.cfg.data_cols();
        debug_assert!(n <= MAX_COLS, "data columns exceed stack buffers");
        let read_path = self.cfg.read_path;
        let cols = self.cfg.cols;
        let rref = self.cfg.device.rref_1bit();
        self.ru.configure(op);

        // sense all data columns in one WL activation (stack buffer, no
        // per-pass heap traffic — §Perf)
        let mut w_bits = [false; MAX_COLS];
        {
            let b = &mut self.blocks[block];
            let plan = b.ecc.plan_row_ref(row, &b.stuck_map).expect("unmapped row");
            b.wl.select(plan.phys_row);
            b.bl.note_broadcast();
            match read_path {
                ReadPath::Digital => {
                    let base = plan.phys_row * cols;
                    for (i, &pc) in plan.col_map.iter().enumerate() {
                        w_bits[i] = b.shadow[base + pc] >= 2;
                    }
                }
                ReadPath::Electrical => {
                    let phys_row = plan.phys_row;
                    // split the borrow: copy the col_map head we need
                    let mut map = [0usize; MAX_COLS];
                    map[..plan.col_map.len()].copy_from_slice(&plan.col_map);
                    let n_map = plan.col_map.len();
                    let all = b.array.read_row_bits(phys_row, rref);
                    for (i, &pc) in map[..n_map].iter().enumerate() {
                        w_bits[i] = all[pc];
                    }
                }
            }
        }

        let mut out = Vec::with_capacity(n);
        let mut pop: i64 = 0;
        for col in 0..n {
            let xx = x.get(col).copied().unwrap_or(false);
            let kk = k.get(col).copied().unwrap_or(false);
            let o = self.ru.cycle(xx, w_bits[col], kk);
            pop += o as i64; // S&A popcount folded into the pass
            out.push(o);
        }
        self.sa.note_ops(n as u64);
        if with_acc {
            for (lane, &o) in out.iter().enumerate() {
                self.acc.add(lane, o as i64);
            }
        }
        self.energy.compute_cycle(n as u64, with_acc);
        self.timing.compute_cycles += 1;
        self.wear.wl_activations += 1;
        let _ = pop;
        out
    }

    /// Sense one logical row's data columns in a single WL activation and
    /// return them bit-packed (bit `i` = data column `i`). This is the
    /// read half of a batched row-parallel burst: the word line stays
    /// selected while the caller streams many X vectors against the
    /// returned word, accounting the column-side events with
    /// [`Chip::account_batched_passes`]. Behaviourally identical to
    /// reading the bits through [`Chip::read_bit`] (ECC plan included).
    pub fn sense_row_packed(&mut self, block: usize, row: usize) -> u64 {
        assert!(self.formed, "sense before forming");
        let n = self.cfg.data_cols();
        debug_assert!(n <= 64, "packed sense needs <= 64 data columns");
        let read_path = self.cfg.read_path;
        let cols = self.cfg.cols;
        let rref = self.cfg.device.rref_1bit();
        let mut word = 0u64;
        {
            let b = &mut self.blocks[block];
            let plan = b.ecc.plan_row_ref(row, &b.stuck_map).expect("unmapped row");
            b.wl.select(plan.phys_row);
            b.bl.note_broadcast();
            match read_path {
                ReadPath::Digital => {
                    let base = plan.phys_row * cols;
                    for (i, &pc) in plan.col_map.iter().enumerate() {
                        if b.shadow[base + pc] >= 2 {
                            word |= 1u64 << i;
                        }
                    }
                }
                ReadPath::Electrical => {
                    let phys_row = plan.phys_row;
                    let mut map = [0usize; MAX_COLS];
                    map[..plan.col_map.len()].copy_from_slice(&plan.col_map);
                    let n_map = plan.col_map.len();
                    let all = b.array.read_row_bits(phys_row, rref);
                    for (i, &pc) in map[..n_map].iter().enumerate() {
                        if all[pc] {
                            word |= 1u64 << i;
                        }
                    }
                }
            }
        }
        self.energy.sense_cycle(n as u64);
        self.timing.compute_cycles += 1;
        self.wear.wl_activations += 1;
        word
    }

    /// Sense one logical row's data columns as 2-bit values in a single
    /// WL activation, returned as two packed bit planes `(lo, hi)` —
    /// bit `i` of `lo`/`hi` is bit 0/1 of data column `i`'s stored 2-bit
    /// value (ECC plan included). The INT8 counterpart of
    /// [`Chip::sense_row_packed`]: the word line stays selected while the
    /// batched VMM streams offset-encoded activation planes against the
    /// returned words, accounting the column-side events with
    /// [`Chip::account_batched_passes`].
    pub fn sense_row_2bit_packed(&mut self, block: usize, row: usize) -> (u64, u64) {
        assert!(self.formed, "sense before forming");
        let n = self.cfg.data_cols();
        debug_assert!(n <= 64, "packed sense needs <= 64 data columns");
        let read_path = self.cfg.read_path;
        let cols = self.cfg.cols;
        let dev = self.cfg.device.clone();
        let (mut lo, mut hi) = (0u64, 0u64);
        {
            let b = &mut self.blocks[block];
            let plan = b.ecc.plan_row_ref(row, &b.stuck_map).expect("unmapped row");
            b.wl.select(plan.phys_row);
            b.bl.note_broadcast();
            match read_path {
                ReadPath::Digital => {
                    let base = plan.phys_row * cols;
                    for (i, &pc) in plan.col_map.iter().enumerate() {
                        let v = b.shadow[base + pc];
                        lo |= ((v & 1) as u64) << i;
                        hi |= (((v >> 1) & 1) as u64) << i;
                    }
                }
                ReadPath::Electrical => {
                    let phys_row = plan.phys_row;
                    let mut map = [0usize; MAX_COLS];
                    map[..plan.col_map.len()].copy_from_slice(&plan.col_map);
                    let n_map = plan.col_map.len();
                    for (i, &pc) in map[..n_map].iter().enumerate() {
                        let v = rr::read_2bit(&mut b.array, phys_row, pc, &dev).value;
                        lo |= ((v & 1) as u64) << i;
                        hi |= (((v >> 1) & 1) as u64) << i;
                    }
                }
            }
        }
        self.energy.sense_cycle(n as u64);
        self.energy.rr_senses += n as u64; // 2-bit sense = 2 comparisons
        self.timing.compute_cycles += 1;
        self.wear.wl_activations += 1;
        (lo, hi)
    }

    /// Account a row-parallel batched burst: `passes` X vectors streamed
    /// over `cols` columns of an already-selected row (the WRC walk was
    /// paid by the preceding [`Chip::sense_row_packed`]). The batched VMM
    /// in [`crate::cim::vmm`] computes on the packed sensed word and
    /// charges the chip through this hook, so ledgers stay faithful while
    /// the simulation runs at popcount speed (§Perf, same philosophy as
    /// [`ReadPath::Digital`]).
    pub fn account_batched_passes(&mut self, cols: u64, passes: u64, with_acc: bool) {
        self.energy.batched_passes(cols, passes, with_acc);
        self.timing.compute_cycles += passes;
    }

    /// Search-in-memory pass: XOR a stored row against another stored row
    /// and return the Hamming distance over the first `width` data
    /// columns. Row B's bits are read out and fed back through the Input
    /// Logic as K (they may live in the other block), so one pass costs a
    /// read cycle plus a compute cycle — exactly the paper's
    /// search-in-memory flow. This is the primitive the pruning
    /// similarity matrix is built from.
    pub fn search_pass(
        &mut self,
        block_a: usize,
        row_a: usize,
        block_b: usize,
        row_b: usize,
        width: usize,
    ) -> u32 {
        assert!(self.formed, "search before forming");
        let n = width.min(self.cfg.data_cols());
        // read row_b's bits in ONE word-line activation to feed as K
        let mut k_bits = [false; MAX_COLS];
        {
            let read_path = self.cfg.read_path;
            let cols = self.cfg.cols;
            let rref = self.cfg.device.rref_1bit();
            let b = &mut self.blocks[block_b];
            let plan = b.ecc.plan_row_ref(row_b, &b.stuck_map).expect("unmapped row");
            b.wl.select(plan.phys_row);
            match read_path {
                ReadPath::Digital => {
                    let base = plan.phys_row * cols;
                    for (i, &pc) in plan.col_map.iter().take(n).enumerate() {
                        k_bits[i] = b.shadow[base + pc] >= 2;
                    }
                }
                ReadPath::Electrical => {
                    let phys_row = plan.phys_row;
                    let mut map = [0usize; MAX_COLS];
                    map[..plan.col_map.len()].copy_from_slice(&plan.col_map);
                    let n_map = plan.col_map.len().min(n);
                    let all = b.array.read_row_bits(phys_row, rref);
                    for (i, &pc) in map[..n_map].iter().enumerate() {
                        k_bits[i] = all[pc];
                    }
                }
            }
            self.energy.rram_reads += n as u64;
            self.energy.rr_senses += n as u64;
        }
        self.wear.wl_activations += 1; // row B's read activation
        let x = [true; MAX_COLS]; // X=1 exposes W xor K directly
        let out = self.logic_pass(block_a, row_a, LogicOp::Xor, &x[..n], &k_bits[..n], false);
        self.timing.search_cycles += 1;
        out.iter().take(n).map(|&b| b as u32).sum()
    }

    /// VMM pass for 2-bit cells (INT8 path): activate logical row `row`,
    /// broadcast the X bit-plane, and return each data column's stored
    /// 2-bit value gated by X (0 where X=0). The RR performs the 2-bit
    /// successive-approximation sense; the S&A group applies the slice
    /// shift downstream (see [`crate::cim::vmm::int8_dot`]).
    pub fn vmm_pass_2bit(&mut self, block: usize, row: usize, x: &[bool]) -> Vec<u8> {
        assert!(self.formed, "compute before forming");
        let n = self.cfg.data_cols();
        let read_path = self.cfg.read_path;
        let cols = self.cfg.cols;
        let dev = self.cfg.device.clone();
        let b = &mut self.blocks[block];
        let mut out = Vec::with_capacity(n);
        {
            let plan = b.ecc.plan_row_ref(row, &b.stuck_map).expect("unmapped row");
            b.wl.select(plan.phys_row);
            b.bl.note_broadcast();
            match read_path {
                ReadPath::Digital => {
                    let base = plan.phys_row * cols;
                    for (col, &pc) in plan.col_map.iter().enumerate() {
                        let v = b.shadow[base + pc];
                        out.push(if x.get(col).copied().unwrap_or(false) { v } else { 0 });
                    }
                }
                ReadPath::Electrical => {
                    let phys_row = plan.phys_row;
                    let mut map = [0usize; MAX_COLS];
                    map[..plan.col_map.len()].copy_from_slice(&plan.col_map);
                    let n_map = plan.col_map.len();
                    for (col, &pc) in map[..n_map].iter().enumerate() {
                        let v = rr::read_2bit(&mut b.array, phys_row, pc, &dev).value;
                        out.push(if x.get(col).copied().unwrap_or(false) { v } else { 0 });
                    }
                }
            }
        }
        self.energy.compute_cycle(n as u64, true);
        self.energy.rr_senses += n as u64; // 2-bit sense = 2 comparisons
        self.timing.compute_cycles += 1;
        self.wear.wl_activations += 1;
        out
    }

    /// Zero all energy/timing counters (e.g. after forming/programming,
    /// so a measurement window covers only the compute phase). The
    /// lifetime [`WearLedger`] is deliberately *not* reset.
    pub fn reset_ledgers(&mut self) {
        self.energy = EnergyLedger::default();
        self.timing = TimingLedger::default();
    }

    /// Reset accumulator lanes (between VMM output tiles).
    pub fn acc_clear(&mut self) {
        self.acc.clear();
    }

    pub fn acc_lanes(&self) -> &[i64] {
        self.acc.lanes()
    }

    /// Energy breakdown snapshot (Fig. 3e).
    pub fn energy_breakdown(&self) -> EnergyBreakdown {
        self.energy.breakdown(&self.cfg.energy)
    }

    /// Total stuck cells across blocks (pre-ECC fault pressure).
    pub fn stuck_cells(&self) -> usize {
        self.blocks.iter().map(|b| b.array.stuck_count()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_chip(seed: u64) -> Chip {
        let mut rng = Rng::new(seed);
        let mut chip = Chip::new(ChipConfig::small_test(), &mut rng);
        chip.form();
        chip
    }

    #[test]
    fn wear_delta_and_monotonicity() {
        let mut chip = test_chip(77);
        let before = chip.wear.clone();
        assert!(chip.program_2bit(0, 0, 0, 3));
        let after = chip.wear.clone();
        assert!(after.is_monotone_since(&before), "programming only adds wear");
        let d = after.delta(&before);
        assert!(d.write_pulses > 0 && d.programmed_cells > 0);
        // deltas never underflow, even against a later snapshot
        let rev = before.delta(&after);
        assert_eq!(rev.write_pulses, 0);
        assert!(!before.is_monotone_since(&after));
    }

    #[test]
    fn program_and_read_roundtrip_2bit() {
        let mut chip = test_chip(1);
        for v in 0u8..4 {
            assert!(chip.program_2bit(0, 0, v as usize, v));
            assert_eq!(chip.read_2bit(0, 0, v as usize), v);
        }
    }

    #[test]
    fn electrical_and_digital_paths_agree_when_ideal() {
        let mut rng = Rng::new(2);
        let mut cfg = ChipConfig::small_test();
        cfg.read_path = ReadPath::Electrical;
        let mut chip_e = Chip::new(cfg.clone(), &mut rng.fork(1));
        cfg.read_path = ReadPath::Digital;
        let mut chip_d = Chip::new(cfg, &mut rng.fork(1));
        chip_e.form();
        chip_d.form();
        for col in 0..16 {
            let v = (col % 4) as u8;
            chip_e.program_2bit(0, 5, col, v);
            chip_d.program_2bit(0, 5, col, v);
        }
        for col in 0..16 {
            assert_eq!(chip_e.read_2bit(0, 5, col), chip_d.read_2bit(0, 5, col));
        }
    }

    #[test]
    fn logic_pass_matches_truth_table() {
        let mut chip = test_chip(3);
        let n = chip.cfg().data_cols();
        // store alternating bits in row 7
        for col in 0..n {
            assert!(chip.program_bit(0, 7, col, col % 2 == 0));
        }
        let x = vec![true; n];
        let k: Vec<bool> = (0..n).map(|c| c % 3 == 0).collect();
        for op in LogicOp::ALL {
            let out = chip.logic_pass(0, 7, op, &x, &k, false);
            for col in 0..n {
                let w = col % 2 == 0;
                assert_eq!(out[col], op.apply(w, k[col]), "{op:?} col {col}");
            }
        }
    }

    #[test]
    fn x_zero_masks_everything() {
        let mut chip = test_chip(4);
        let n = chip.cfg().data_cols();
        for col in 0..n {
            chip.program_bit(0, 1, col, true);
        }
        let out = chip.logic_pass(0, 1, LogicOp::Or, &vec![false; n], &vec![true; n], false);
        assert!(out.iter().all(|&b| !b));
    }

    #[test]
    fn search_pass_computes_hamming_distance() {
        let mut chip = test_chip(5);
        let n = 16;
        // row 2: 1111_0000..., row 3: 1010_1010...
        for col in 0..n {
            chip.program_bit(0, 2, col, col < 8);
            chip.program_bit(0, 3, col, col % 2 == 0);
        }
        let d = chip.search_pass(0, 2, 0, 3, n);
        // expected: popcount((col<8) ^ (col%2==0)) over 16 cols
        let expected: u32 = (0..n).map(|c| ((c < 8) ^ (c % 2 == 0)) as u32).sum();
        assert_eq!(d, expected);
    }

    #[test]
    fn energy_accrues_with_compute() {
        let mut chip = test_chip(6);
        let n = chip.cfg().data_cols();
        for col in 0..n {
            chip.program_bit(0, 0, col, true);
        }
        chip.reset_ledgers(); // measure the compute window only (Fig. 3e)
        let before = chip.energy_breakdown().total_pj();
        for _ in 0..100 {
            chip.logic_pass(0, 0, LogicOp::And, &vec![true; n], &vec![true; n], true);
        }
        let after = chip.energy_breakdown().total_pj();
        assert!(after > before);
        // WRC must dominate (Fig. 3e)
        let shares = chip.energy_breakdown().shares();
        assert_eq!(shares[0].0, "WRC");
    }

    #[test]
    fn sense_row_packed_matches_read_bits() {
        let mut chip = test_chip(8);
        let n = chip.cfg().data_cols();
        for col in 0..n {
            assert!(chip.program_bit(0, 9, col, (col * 7) % 3 == 0));
        }
        let word = chip.sense_row_packed(0, 9);
        for col in 0..n {
            assert_eq!((word >> col) & 1 == 1, chip.read_bit(0, 9, col), "col {col}");
        }
        // columns beyond the data width must be zero
        assert_eq!(word >> n, 0);
    }

    #[test]
    fn sense_row_packed_agrees_across_read_paths() {
        let mut rng = Rng::new(9);
        let mut cfg = ChipConfig::small_test();
        cfg.read_path = ReadPath::Electrical;
        let mut chip_e = Chip::new(cfg.clone(), &mut rng.fork(1));
        cfg.read_path = ReadPath::Digital;
        let mut chip_d = Chip::new(cfg, &mut rng.fork(1));
        chip_e.form();
        chip_d.form();
        for col in 0..16 {
            chip_e.program_bit(0, 4, col, col % 3 != 0);
            chip_d.program_bit(0, 4, col, col % 3 != 0);
        }
        assert_eq!(chip_e.sense_row_packed(0, 4), chip_d.sense_row_packed(0, 4));
    }

    #[test]
    fn wear_ledger_survives_reset_and_tracks_programming() {
        let mut chip = test_chip(10);
        let after_forming = chip.wear.write_pulses;
        assert!(after_forming > 0, "forming must wear the array");
        chip.program_bit(0, 0, 0, true);
        assert!(chip.wear.write_pulses > after_forming);
        assert_eq!(chip.wear.programmed_cells, 1);
        let wear = chip.wear.clone();
        chip.reset_ledgers();
        assert_eq!(chip.wear.write_pulses, wear.write_pulses, "reset must keep wear");
        assert_eq!(chip.energy.rram_write_pulses, 0);
    }

    #[test]
    fn batched_pass_accounting_is_cheaper_than_unbatched() {
        let mut chip = test_chip(11);
        let n = chip.cfg().data_cols();
        for col in 0..n {
            chip.program_bit(0, 3, col, true);
        }
        chip.reset_ledgers();
        let _ = chip.sense_row_packed(0, 3);
        chip.account_batched_passes(n as u64, 200, true);
        let batched = chip.energy_breakdown().total_pj();
        chip.reset_ledgers();
        for _ in 0..200 {
            chip.logic_pass(0, 3, LogicOp::And, &vec![true; n], &vec![true; n], true);
        }
        let unbatched = chip.energy_breakdown().total_pj();
        assert!(
            batched < unbatched * 0.5,
            "batched {batched} pJ !<< unbatched {unbatched} pJ"
        );
    }

    #[test]
    fn faulty_cells_are_healed_by_ecc() {
        let mut rng = Rng::new(7);
        let mut cfg = ChipConfig::small_test();
        cfg.device.stuck_fault_prob = 0.01;
        let mut chip = Chip::new(cfg, &mut rng);
        chip.form();
        assert!(chip.stuck_cells() > 0, "want faults for this test");
        let n = chip.cfg().data_cols();
        let mut failures = 0;
        for row in 0..chip.cfg().logical_rows() {
            for col in 0..n {
                let bit = (row + col) % 2 == 0;
                if !chip.program_bit(0, row, col, bit) {
                    failures += 1;
                } else if chip.read_bit(0, row, col) != bit {
                    failures += 1;
                }
            }
        }
        assert_eq!(failures, 0, "ECC must absorb all stuck-at faults");
    }
}

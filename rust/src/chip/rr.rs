//! Rref Read (RR) module: the resistive-divider readout of Fig. 3b.
//!
//! Each bit line carries a divider formed by the selected 1T1R cell and a
//! tunable reference resistor (three NMOS legs, Vtran1..3 select which
//! Rref is active). The divider midpoint runs through three inverters to
//! restore a clean digital level:  bit = (R_cell < R_ref).
//!
//! A 2-bit cell is read by successive approximation over the three
//! reference levels — this is why the RR block needs exactly three
//! transistor-selectable references for INT2 storage.

use crate::device::{Array1T1R, DeviceConfig};

/// Readout result of a 2-bit successive-approximation read.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Read2Bit {
    /// Decoded 2-bit value in 0..=3 (3 = lowest resistance / strongest).
    pub value: u8,
    /// Number of divider comparisons performed (1 or 2).
    pub comparisons: u8,
}

/// Single-reference binary read of one cell on an array.
/// `true` = logic 1 = low-resistance state.
pub fn read_bit(array: &mut Array1T1R, row: usize, col: usize, rref_kohm: f64) -> bool {
    array.read_cell(row, col) < rref_kohm
}

/// Word-parallel binary read of a whole row (one WL activation).
pub fn read_row(array: &mut Array1T1R, row: usize, rref_kohm: f64) -> Vec<bool> {
    array.read_row_bits(row, rref_kohm)
}

/// Successive-approximation 2-bit read of one cell: first compare against
/// the middle reference, then against the low/high one. Encoding follows
/// [`DeviceConfig::levels_2bit`]: ascending resistance = descending value.
pub fn read_2bit(array: &mut Array1T1R, row: usize, col: usize, cfg: &DeviceConfig) -> Read2Bit {
    let rrefs = cfg.rrefs_2bit();
    let r = array.read_cell(row, col);
    if r < rrefs[1] {
        // below mid: value 3 (R < rrefs[0]) or 2
        if r < rrefs[0] {
            Read2Bit { value: 3, comparisons: 2 }
        } else {
            Read2Bit { value: 2, comparisons: 2 }
        }
    } else if r < rrefs[2] {
        Read2Bit { value: 1, comparisons: 2 }
    } else {
        Read2Bit { value: 0, comparisons: 2 }
    }
}

/// Map a 2-bit value to its programming target resistance.
pub fn target_for_2bit(value: u8, cfg: &DeviceConfig) -> f64 {
    let levels = cfg.levels_2bit();
    levels[3 - value as usize % 4]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn formed_array(seed: u64, cfg: DeviceConfig) -> Array1T1R {
        let mut rng = Rng::new(seed);
        let mut a = Array1T1R::fabricate(16, 32, cfg, &mut rng);
        a.form_all();
        a
    }

    #[test]
    fn two_bit_roundtrip_all_values() {
        let cfg = DeviceConfig::ideal();
        let mut a = formed_array(1, cfg.clone());
        for v in 0u8..4 {
            let t = target_for_2bit(v, &cfg);
            assert!(a.program_cell(0, v as usize, t).is_some());
            let got = read_2bit(&mut a, 0, v as usize, &cfg);
            assert_eq!(got.value, v, "2-bit roundtrip failed for {v}");
            assert_eq!(got.comparisons, 2);
        }
    }

    #[test]
    fn two_bit_roundtrip_with_realistic_noise() {
        // the digital margins must absorb sigma = 0.8793 kOhm completely:
        // this is the paper's zero-BER claim for INT2 storage.
        let cfg = DeviceConfig { stuck_fault_prob: 0.0, transient_read_flip_prob: 0.0, ..DeviceConfig::default() };
        let mut a = formed_array(2, cfg.clone());
        let mut errors = 0;
        for trial in 0..400 {
            let v = (trial % 4) as u8;
            let (r, c) = (trial / 32 % 16, trial % 32);
            if a.program_cell(r, c, target_for_2bit(v, &cfg)).is_none() {
                continue;
            }
            if read_2bit(&mut a, r, c, &cfg).value != v {
                errors += 1;
            }
        }
        assert_eq!(errors, 0, "INT2 storage must be zero-BER");
    }

    #[test]
    fn binary_read_row_matches_programmed_pattern() {
        let cfg = DeviceConfig::ideal();
        let mut a = formed_array(3, cfg.clone());
        for col in 0..32 {
            let bit = (col * 7 % 3) == 0;
            let t = if bit { 5.0 } else { 120.0 };
            a.program_cell(2, col, t);
        }
        let bits = read_row(&mut a, 2, cfg.rref_1bit());
        for col in 0..32 {
            assert_eq!(bits[col], (col * 7 % 3) == 0, "col {col}");
        }
    }
}

//! Activity-based energy accounting (Fig. 3e + Supplementary Table 1).
//!
//! Every architectural event (WL shift, RR sense, RU eval, S&A op, ACC op,
//! BSIC drive, RRAM cell read/write) increments a counter; energy is
//! counter x unit-cost. The unit costs below are calibrated so that a
//! steady-state compute workload (one WL activation reading 32 columns
//! through RU/S&A/ACC per cycle) reproduces the paper's measured power
//! breakdown:
//!
//! |  module | share (Fig. 3e) |
//! |---------|-----------------|
//! |  WRC    | 67.40 %         |
//! |  ACC    | 22.72 %         |
//! |  S&A    |  6.74 %         |
//! |  BSIC   |  1.50 %         |
//! |  RR     |  1.00 %         |
//! |  RU     |  0.63 %         |
//! |  RRAM   |  0.01 %         |
//!
//! With the canonical cycle (1 WL + 32 of each column event) the per-cycle
//! energy is 100 pJ, i.e. ~3.1 pJ per bitwise array op — the number the
//! baseline comparisons in [`crate::baselines`] are normalized against.

/// Per-event unit energies in picojoules.
#[derive(Clone, Debug)]
pub struct EnergyModel {
    pub wrc_activation_pj: f64,
    pub wrc_shift_pj: f64,
    pub acc_op_pj: f64,
    pub sa_op_pj: f64,
    pub bsic_drive_pj: f64,
    pub rr_sense_pj: f64,
    pub ru_eval_pj: f64,
    pub rram_read_pj: f64,
    pub rram_write_pulse_pj: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        // Calibration: canonical cycle = 1 activation + 1 shift + 32 col
        // events of each kind + 1 broadcast; targets the table above.
        EnergyModel {
            wrc_activation_pj: 47.40,
            wrc_shift_pj: 20.00,
            acc_op_pj: 22.72 / 32.0,
            sa_op_pj: 6.74 / 32.0,
            bsic_drive_pj: 1.50,
            rr_sense_pj: 1.00 / 32.0,
            ru_eval_pj: 0.63 / 32.0,
            rram_read_pj: 0.01 / 32.0,
            // write-verify pulses are rare; cost dominated by the driver
            rram_write_pulse_pj: 15.0,
        }
    }
}

/// Event counters, one ledger per chip instance.
#[derive(Clone, Debug, Default)]
pub struct EnergyLedger {
    pub wrc_activations: u64,
    pub wrc_shifts: u64,
    pub acc_ops: u64,
    pub sa_ops: u64,
    pub bsic_drives: u64,
    pub rr_senses: u64,
    pub ru_evals: u64,
    pub rram_reads: u64,
    pub rram_write_pulses: u64,
}

/// Energy split by module, in picojoules.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub wrc_pj: f64,
    pub acc_pj: f64,
    pub sa_pj: f64,
    pub bsic_pj: f64,
    pub rr_pj: f64,
    pub ru_pj: f64,
    pub rram_pj: f64,
}

impl EnergyBreakdown {
    pub fn total_pj(&self) -> f64 {
        self.wrc_pj + self.acc_pj + self.sa_pj + self.bsic_pj + self.rr_pj + self.ru_pj + self.rram_pj
    }

    /// (module name, share-of-total) rows sorted descending — the Fig. 3e pie.
    pub fn shares(&self) -> Vec<(&'static str, f64)> {
        let t = self.total_pj().max(1e-12);
        let mut rows = vec![
            ("WRC", self.wrc_pj / t),
            ("ACC", self.acc_pj / t),
            ("S&A", self.sa_pj / t),
            ("BSIC", self.bsic_pj / t),
            ("RR", self.rr_pj / t),
            ("RU", self.ru_pj / t),
            ("RRAM", self.rram_pj / t),
        ];
        rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        rows
    }
}

impl EnergyLedger {
    pub fn breakdown(&self, m: &EnergyModel) -> EnergyBreakdown {
        EnergyBreakdown {
            wrc_pj: self.wrc_activations as f64 * m.wrc_activation_pj
                + self.wrc_shifts as f64 * m.wrc_shift_pj,
            acc_pj: self.acc_ops as f64 * m.acc_op_pj,
            sa_pj: self.sa_ops as f64 * m.sa_op_pj,
            bsic_pj: self.bsic_drives as f64 * m.bsic_drive_pj,
            rr_pj: self.rr_senses as f64 * m.rr_sense_pj,
            ru_pj: self.ru_evals as f64 * m.ru_eval_pj,
            rram_pj: self.rram_reads as f64 * m.rram_read_pj
                + self.rram_write_pulses as f64 * m.rram_write_pulse_pj,
        }
    }

    pub fn merge(&mut self, other: &EnergyLedger) {
        self.wrc_activations += other.wrc_activations;
        self.wrc_shifts += other.wrc_shifts;
        self.acc_ops += other.acc_ops;
        self.sa_ops += other.sa_ops;
        self.bsic_drives += other.bsic_drives;
        self.rr_senses += other.rr_senses;
        self.ru_evals += other.ru_evals;
        self.rram_reads += other.rram_reads;
        self.rram_write_pulses += other.rram_write_pulses;
    }

    /// Record one canonical compute cycle over `cols` columns.
    pub fn compute_cycle(&mut self, cols: u64, with_acc: bool) {
        self.wrc_activations += 1;
        self.wrc_shifts += 1;
        self.bsic_drives += 1;
        self.rram_reads += cols;
        self.rr_senses += cols;
        self.ru_evals += cols;
        self.sa_ops += cols;
        if with_acc {
            self.acc_ops += cols;
        }
    }

    /// Record a pure sense cycle: one WL selection plus column reads, no
    /// RU / S&A / ACC activity (the read half of a batched burst).
    pub fn sense_cycle(&mut self, cols: u64) {
        self.wrc_activations += 1;
        self.wrc_shifts += 1;
        self.rram_reads += cols;
        self.rr_senses += cols;
    }

    /// Record a row-parallel batched burst: the word line stays selected
    /// (its WRC walk was paid by the preceding [`EnergyLedger::sense_cycle`])
    /// while `passes` X vectors stream over `cols` columns. Amortizing the
    /// dominant WRC cost across a batch is the serving subsystem's main
    /// energy lever (WRC is 67% of a canonical cycle, Fig. 3e).
    pub fn batched_passes(&mut self, cols: u64, passes: u64, with_acc: bool) {
        self.bsic_drives += passes;
        self.rram_reads += cols * passes;
        self.rr_senses += cols * passes;
        self.ru_evals += cols * passes;
        self.sa_ops += cols * passes;
        if with_acc {
            self.acc_ops += cols * passes;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_cycle_reproduces_fig3e_shares() {
        let m = EnergyModel::default();
        let mut l = EnergyLedger::default();
        for _ in 0..10_000 {
            l.compute_cycle(32, true);
        }
        let b = l.breakdown(&m);
        let t = b.total_pj();
        assert!((b.wrc_pj / t - 0.6740).abs() < 0.005, "WRC {}", b.wrc_pj / t);
        assert!((b.acc_pj / t - 0.2272).abs() < 0.005, "ACC {}", b.acc_pj / t);
        assert!((b.sa_pj / t - 0.0674).abs() < 0.005, "S&A {}", b.sa_pj / t);
        assert!(b.rram_pj / t < 0.0002, "RRAM {}", b.rram_pj / t);
    }

    #[test]
    fn canonical_cycle_costs_100pj() {
        let m = EnergyModel::default();
        let mut l = EnergyLedger::default();
        l.compute_cycle(32, true);
        assert!((l.breakdown(&m).total_pj() - 100.0).abs() < 0.5);
    }

    #[test]
    fn shares_sorted_descending() {
        let m = EnergyModel::default();
        let mut l = EnergyLedger::default();
        l.compute_cycle(32, true);
        let shares = l.breakdown(&m).shares();
        assert_eq!(shares[0].0, "WRC");
        assert!(shares.windows(2).all(|w| w[0].1 >= w[1].1));
        let sum: f64 = shares.iter().map(|s| s.1).sum();
        assert!((sum - 1.0).abs() < 1e-9);
    }

    #[test]
    fn batched_passes_amortize_the_wrc_walk() {
        let m = EnergyModel::default();
        // 100 unbatched cycles vs 1 sense + 100 batched passes
        let mut unbatched = EnergyLedger::default();
        for _ in 0..100 {
            unbatched.compute_cycle(32, true);
        }
        let mut batched = EnergyLedger::default();
        batched.sense_cycle(32);
        batched.batched_passes(32, 100, true);
        let eu = unbatched.breakdown(&m).total_pj();
        let eb = batched.breakdown(&m).total_pj();
        assert!(eb < eu * 0.5, "batched {eb} pJ !<< unbatched {eu} pJ");
        // column-side work is identical
        assert_eq!(unbatched.ru_evals, batched.ru_evals);
        assert_eq!(unbatched.acc_ops, batched.acc_ops);
    }

    #[test]
    fn merge_adds_counters() {
        let mut a = EnergyLedger::default();
        let mut b = EnergyLedger::default();
        a.compute_cycle(32, true);
        b.compute_cycle(32, false);
        a.merge(&b);
        assert_eq!(a.wrc_activations, 2);
        assert_eq!(a.acc_ops, 32); // only one cycle used the ACC
    }
}

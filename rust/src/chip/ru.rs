//! Reconfigurable Unit (RU): the five-NMOS dynamic-logic cell of Fig. 3b,
//! modeled at switch level with explicit pre-charge / compute phases
//! (Fig. 3f). One RU hangs off every bit-line's readout chain.
//!
//! Switch-level structure we model:
//!
//! ```text
//!            precharge (phi=PRE)           compute (phi=EVAL)
//!   node ----o PMOS-ish keeper      node pulled down through the
//!            |                      W-controlled branch pair:
//!   W  ---[M1]--- INL path            W=1   -> node := INL
//!   !W ---[M2]--- INR path            W=0   -> node := INR
//!   X  ---[M5] output AND gate      OUT = X AND node
//! ```
//!
//! (M3/M4 are the inverter deriving !W from the RR chain.) The behavioral
//! contract — `OUT = X AND (W (.) K)` for the op-dependent (INL, INR)
//! encoding — is locked down by exhaustive tests against
//! [`crate::chip::logic`].

use super::logic::{input_logic, CtrlLine, LogicOp};

/// Evaluation phases of the dynamic RU (Fig. 3f).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Precharge,
    Compute,
}

/// One reconfigurable unit instance. Stateless between cycles except for
/// the dynamic node, which is only valid after a full PRE->EVAL sequence.
#[derive(Clone, Debug)]
pub struct ReconfigurableUnit {
    op: LogicOp,
    inl: CtrlLine,
    inr: CtrlLine,
    node: bool,
    phase: Phase,
    evals: u64,
}

impl ReconfigurableUnit {
    pub fn new(op: LogicOp) -> Self {
        let (inl, inr) = input_logic(op);
        ReconfigurableUnit { op, inl, inr, node: true, phase: Phase::Precharge, evals: 0 }
    }

    /// Reconfigure to another op (the chip does this between the
    /// compute-in-memory and search-in-memory passes).
    pub fn configure(&mut self, op: LogicOp) {
        self.op = op;
        let (inl, inr) = input_logic(op);
        self.inl = inl;
        self.inr = inr;
    }

    pub fn op(&self) -> LogicOp {
        self.op
    }

    /// Pre-charge phase: dynamic node goes high.
    pub fn precharge(&mut self) {
        self.node = true;
        self.phase = Phase::Precharge;
    }

    /// Compute phase: the W-selected branch drives the node, then the
    /// output transistor gates it with X. Panics in debug builds if the
    /// pre-charge was skipped (a real dynamic cell would produce garbage).
    pub fn compute(&mut self, x: bool, w: bool, k: bool) -> bool {
        debug_assert_eq!(self.phase, Phase::Precharge, "RU evaluated without precharge");
        self.phase = Phase::Compute;
        self.evals += 1;
        let branch = if w { self.inl } else { self.inr };
        self.node = branch.eval(k);
        x && self.node
    }

    /// Full cycle helper: precharge then compute.
    #[inline]
    pub fn cycle(&mut self, x: bool, w: bool, k: bool) -> bool {
        self.precharge();
        self.compute(x, w, k)
    }

    /// Number of compute evaluations performed (for the energy ledger).
    pub fn evals(&self) -> u64 {
        self.evals
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chip::logic::ternary_out;

    #[test]
    fn ru_matches_truth_table_for_all_ops() {
        for op in LogicOp::ALL {
            let mut ru = ReconfigurableUnit::new(op);
            for x in [false, true] {
                for w in [false, true] {
                    for k in [false, true] {
                        assert_eq!(
                            ru.cycle(x, w, k),
                            ternary_out(op, x, w, k),
                            "{op:?} x={x} w={w} k={k}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn reconfigure_switches_semantics() {
        let mut ru = ReconfigurableUnit::new(LogicOp::And);
        assert!(!ru.cycle(true, true, false)); // AND: 1&0 = 0
        ru.configure(LogicOp::Or);
        assert!(ru.cycle(true, true, false)); // OR: 1|0 = 1
        assert_eq!(ru.op(), LogicOp::Or);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "without precharge")]
    fn double_eval_without_precharge_panics() {
        let mut ru = ReconfigurableUnit::new(LogicOp::Xor);
        ru.precharge();
        ru.compute(true, true, true);
        ru.compute(true, true, true); // second eval without precharge
    }

    #[test]
    fn eval_counter_increments() {
        let mut ru = ReconfigurableUnit::new(LogicOp::Xor);
        for _ in 0..5 {
            ru.cycle(true, false, true);
        }
        assert_eq!(ru.evals(), 5);
    }
}

//! Chip floorplan / area model (Fig. 3d). The fabricated chip measures
//! 5.016 mm^2 in 180 nm; the per-module split below reproduces the
//! paper's breakdown. Baseline architectures reuse these numbers at
//! iso-node, iso-capacity (see [`crate::baselines`]).

/// Area of one module in mm^2 at 180 nm.
#[derive(Clone, Debug)]
pub struct AreaModel {
    pub rram_mm2: f64,
    pub acc_mm2: f64,
    pub wrc_mm2: f64,
    pub bsic_mm2: f64,
    pub rr_mm2: f64,
    pub ru_mm2: f64,
    pub sa_mm2: f64,
}

/// Total die area of the fabricated chip (mm^2).
pub const CHIP_AREA_MM2: f64 = 5.016;

impl Default for AreaModel {
    fn default() -> Self {
        // Fig. 3d: RRAM 61.76 %, ACC 17.91 %, WRC 12.21 %; remainder split
        // across BSIC / RR / RU / S&A.
        AreaModel {
            rram_mm2: CHIP_AREA_MM2 * 0.6176,
            acc_mm2: CHIP_AREA_MM2 * 0.1791,
            wrc_mm2: CHIP_AREA_MM2 * 0.1221,
            bsic_mm2: CHIP_AREA_MM2 * 0.0400,
            rr_mm2: CHIP_AREA_MM2 * 0.0212,
            ru_mm2: CHIP_AREA_MM2 * 0.0120,
            sa_mm2: CHIP_AREA_MM2 * 0.0080,
        }
    }
}

impl AreaModel {
    pub fn total_mm2(&self) -> f64 {
        self.rram_mm2 + self.acc_mm2 + self.wrc_mm2 + self.bsic_mm2 + self.rr_mm2
            + self.ru_mm2 + self.sa_mm2
    }

    /// (module, share) rows sorted descending — the Fig. 3d pie.
    pub fn shares(&self) -> Vec<(&'static str, f64)> {
        let t = self.total_mm2();
        let mut rows = vec![
            ("RRAM", self.rram_mm2 / t),
            ("ACC", self.acc_mm2 / t),
            ("WRC", self.wrc_mm2 / t),
            ("BSIC", self.bsic_mm2 / t),
            ("RR", self.rr_mm2 / t),
            ("RU", self.ru_mm2 / t),
            ("S&A", self.sa_mm2 / t),
        ];
        rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        rows
    }

    /// Storage density in bits/mm^2 for the 2x 512x32 INT2 arrays.
    pub fn density_bits_per_mm2(&self) -> f64 {
        let bits = 2.0 * 512.0 * 32.0 * 2.0; // two blocks, 2 bits/cell
        bits / self.total_mm2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_matches_fabricated_die() {
        let a = AreaModel::default();
        assert!((a.total_mm2() - CHIP_AREA_MM2).abs() < 1e-9);
    }

    #[test]
    fn shares_match_fig3d() {
        let a = AreaModel::default();
        let shares = a.shares();
        assert_eq!(shares[0], ("RRAM", a.rram_mm2 / a.total_mm2()));
        assert!((shares[0].1 - 0.6176).abs() < 1e-6);
        assert!((shares[1].1 - 0.1791).abs() < 1e-6);
        assert!((shares[2].1 - 0.1221).abs() < 1e-6);
    }

    #[test]
    fn density_positive() {
        assert!(AreaModel::default().density_bits_per_mm2() > 1e4);
    }
}

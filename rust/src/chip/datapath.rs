//! Digital reduction datapath behind the RU outputs: the Shift-and-Add
//! (S&A) groups and the Accumulator (ACC) of Fig. 3a.
//!
//! * For element-wise (Hadamard) results only the S&A group runs: it
//!   popcounts / weights the RU output bits of one word-line pass.
//! * For vector-matrix multiplication the ACC additionally integrates
//!   partial products across input bit-planes and weight bit-slices with
//!   the appropriate power-of-two shifts (bit-serial digital CIM).

/// Shift-and-add group over one array pass (32 RU outputs).
#[derive(Clone, Debug, Default)]
pub struct ShiftAdder {
    ops: u64,
}

impl ShiftAdder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Popcount the RU output bits (weight 1 per bit).
    pub fn popcount(&mut self, bits: &[bool]) -> u32 {
        self.ops += bits.len() as u64;
        bits.iter().map(|&b| b as u32).sum()
    }

    /// Weighted sum with a shift per bit *slice*: sum(bit_i) << shift.
    pub fn shifted_popcount(&mut self, bits: &[bool], shift: u32) -> i64 {
        (self.popcount(bits) as i64) << shift
    }

    /// Per-lane partial product: each RU output bit contributes its
    /// lane's 2-bit cell value << shift (used by the INT8 path where a
    /// lane carries a decoded 2-bit slice rather than a single bit).
    pub fn lane_partials(&mut self, values: &[u8], shift: u32) -> Vec<i64> {
        self.ops += values.len() as u64;
        values.iter().map(|&v| (v as i64) << shift).collect()
    }

    pub fn ops(&self) -> u64 {
        self.ops
    }

    /// Account ops whose popcount was folded into the caller's loop
    /// (hot path — §Perf).
    #[inline]
    pub fn note_ops(&mut self, n: u64) {
        self.ops += n;
    }
}

/// Accumulator bank: one signed running sum per output lane.
#[derive(Clone, Debug)]
pub struct Accumulator {
    lanes: Vec<i64>,
    ops: u64,
}

impl Accumulator {
    pub fn new(n_lanes: usize) -> Self {
        Accumulator { lanes: vec![0; n_lanes], ops: 0 }
    }

    pub fn clear(&mut self) {
        self.lanes.iter_mut().for_each(|l| *l = 0);
    }

    /// Add a scalar partial into one lane.
    pub fn add(&mut self, lane: usize, value: i64) {
        self.lanes[lane] += value;
        self.ops += 1;
    }

    /// Add a vector of partials lane-wise.
    pub fn add_all(&mut self, values: &[i64]) {
        assert_eq!(values.len(), self.lanes.len(), "lane mismatch");
        for (l, v) in self.lanes.iter_mut().zip(values) {
            *l += v;
        }
        self.ops += values.len() as u64;
    }

    pub fn lanes(&self) -> &[i64] {
        &self.lanes
    }

    pub fn ops(&self) -> u64 {
        self.ops
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn popcount_counts() {
        let mut sa = ShiftAdder::new();
        assert_eq!(sa.popcount(&[true, false, true, true]), 3);
        assert_eq!(sa.ops(), 4);
    }

    #[test]
    fn shifted_popcount_shifts() {
        let mut sa = ShiftAdder::new();
        assert_eq!(sa.shifted_popcount(&[true, true, true], 4), 3 << 4);
    }

    #[test]
    fn lane_partials_shift_each_value() {
        let mut sa = ShiftAdder::new();
        assert_eq!(sa.lane_partials(&[0, 1, 2, 3], 2), vec![0, 4, 8, 12]);
    }

    #[test]
    fn accumulator_integrates_lanewise() {
        let mut acc = Accumulator::new(3);
        acc.add_all(&[1, 2, 3]);
        acc.add_all(&[10, 20, 30]);
        acc.add(2, 100);
        assert_eq!(acc.lanes(), &[11, 22, 133]);
        assert_eq!(acc.ops(), 7);
        acc.clear();
        assert_eq!(acc.lanes(), &[0, 0, 0]);
    }

    #[test]
    #[should_panic(expected = "lane mismatch")]
    fn accumulator_lane_mismatch_panics() {
        Accumulator::new(2).add_all(&[1, 2, 3]);
    }
}

//! Reconfigurable ternary logic of the paper's Fig. 3c:
//!
//! ```text
//!     OUT = X AND (W (.) K)        (.) in {NAND, AND, XOR, OR}
//! ```
//!
//! where `X` is the bit-line input, `W` the bit read from the RRAM cell,
//! and `K` the secondary input processed by the Input Logic module into
//! the (INL, INR) control pair that configures the Reconfigurable Unit.
//!
//! Our RU realization (see [`crate::chip::ru`]) is a W-controlled
//! selector: `node = W ? INL : INR`. The encodings below make that
//! selector compute each of the four ops — this is the repo's concrete
//! rendering of the paper's lower truth table in Fig. 3c.

/// The four reconfigurable array operations.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LogicOp {
    Nand,
    And,
    Xor,
    Or,
}

impl LogicOp {
    pub const ALL: [LogicOp; 4] = [LogicOp::Nand, LogicOp::And, LogicOp::Xor, LogicOp::Or];

    /// Ground-truth boolean semantics of `W (.) K`.
    #[inline]
    pub fn apply(self, w: bool, k: bool) -> bool {
        match self {
            LogicOp::Nand => !(w && k),
            LogicOp::And => w && k,
            LogicOp::Xor => w ^ k,
            LogicOp::Or => w || k,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            LogicOp::Nand => "NAND",
            LogicOp::And => "AND",
            LogicOp::Xor => "XOR",
            LogicOp::Or => "OR",
        }
    }
}

/// Control line value fed to the RU: constant 0/1, K, or its complement.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CtrlLine {
    Zero,
    One,
    K,
    NotK,
}

impl CtrlLine {
    #[inline]
    pub fn eval(self, k: bool) -> bool {
        match self {
            CtrlLine::Zero => false,
            CtrlLine::One => true,
            CtrlLine::K => k,
            CtrlLine::NotK => !k,
        }
    }
}

/// The Input Logic module: maps the selected op to the (INL, INR)
/// configuration (Fig. 3c lower table, our encoding).
#[inline]
pub fn input_logic(op: LogicOp) -> (CtrlLine, CtrlLine) {
    match op {
        // node = W ? INL : INR
        LogicOp::And => (CtrlLine::K, CtrlLine::Zero), // W?K:0  = W AND K
        LogicOp::Or => (CtrlLine::One, CtrlLine::K),   // W?1:K  = W OR K
        LogicOp::Xor => (CtrlLine::NotK, CtrlLine::K), // W?!K:K = W XOR K
        LogicOp::Nand => (CtrlLine::NotK, CtrlLine::One), // W?!K:1 = !(W AND K)
    }
}

/// Full ternary gate including the bit-line operand X (Fig. 3c upper
/// table): `OUT = X AND (W (.) K)`.
#[inline]
pub fn ternary_out(op: LogicOp, x: bool, w: bool, k: bool) -> bool {
    x && op.apply(w, k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_semantics_exhaustive() {
        for &(w, k) in &[(false, false), (false, true), (true, false), (true, true)] {
            assert_eq!(LogicOp::And.apply(w, k), w & k);
            assert_eq!(LogicOp::Or.apply(w, k), w | k);
            assert_eq!(LogicOp::Xor.apply(w, k), w ^ k);
            assert_eq!(LogicOp::Nand.apply(w, k), !(w & k));
        }
    }

    #[test]
    fn input_logic_encoding_realizes_every_op() {
        for op in LogicOp::ALL {
            let (inl, inr) = input_logic(op);
            for &w in &[false, true] {
                for &k in &[false, true] {
                    let node = if w { inl.eval(k) } else { inr.eval(k) };
                    assert_eq!(node, op.apply(w, k), "{op:?} w={w} k={k}");
                }
            }
        }
    }

    #[test]
    fn ternary_out_gates_on_x() {
        for op in LogicOp::ALL {
            for &w in &[false, true] {
                for &k in &[false, true] {
                    assert!(!ternary_out(op, false, w, k), "X=0 must force OUT=0");
                    assert_eq!(ternary_out(op, true, w, k), op.apply(w, k));
                }
            }
        }
    }
}

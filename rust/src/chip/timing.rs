//! Cycle/phase timing model (Fig. 3f): every array operation is a
//! pre-charge phase followed by a compute phase. The model tracks cycle
//! counts per operation class and converts them to wall-clock using the
//! chip's clock period, enabling latency rows in the benches.

/// Timing constants for the 180 nm chip.
#[derive(Clone, Debug)]
pub struct TimingModel {
    /// Core clock period (ns) — one pre-charge + compute pair per cycle.
    pub cycle_ns: f64,
    /// Extra cycles per WL shift during programming-mode row selection.
    pub shift_cycles: u64,
    /// Cycles per write-verify pulse (program + settle + verify read).
    pub write_pulse_cycles: u64,
}

impl Default for TimingModel {
    fn default() -> Self {
        TimingModel { cycle_ns: 10.0, shift_cycles: 1, write_pulse_cycles: 12 }
    }
}

/// Cycle counters per operation class.
#[derive(Clone, Debug, Default)]
pub struct TimingLedger {
    pub compute_cycles: u64,
    pub search_cycles: u64,
    pub program_cycles: u64,
}

/// A trace entry for rendering Fig. 3f-style waveforms in the benches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PhaseEvent {
    Precharge,
    Compute,
}

impl TimingLedger {
    pub fn total_cycles(&self) -> u64 {
        self.compute_cycles + self.search_cycles + self.program_cycles
    }

    pub fn wallclock_us(&self, m: &TimingModel) -> f64 {
        self.total_cycles() as f64 * m.cycle_ns * 1e-3
    }

    pub fn merge(&mut self, other: &TimingLedger) {
        self.compute_cycles += other.compute_cycles;
        self.search_cycles += other.search_cycles;
        self.program_cycles += other.program_cycles;
    }
}

/// Generate the waveform of one dynamic-logic op for the Fig. 3f panel:
/// a (phase, node-level, out-level) sequence for given inputs.
pub fn waveform(op: crate::chip::LogicOp, x: bool, w: bool, k: bool) -> Vec<(PhaseEvent, bool, bool)> {
    let mut ru = crate::chip::ru::ReconfigurableUnit::new(op);
    ru.precharge();
    let pre = (PhaseEvent::Precharge, true, false); // node high, out not valid yet
    let out = ru.compute(x, w, k);
    let post = (PhaseEvent::Compute, op.apply(w, k), out);
    vec![pre, post]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chip::LogicOp;

    #[test]
    fn wallclock_scales_with_cycles() {
        let m = TimingModel::default();
        let l = TimingLedger { compute_cycles: 1000, search_cycles: 0, program_cycles: 0 };
        assert!((l.wallclock_us(&m) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn waveform_has_precharge_then_compute() {
        let wf = waveform(LogicOp::Xor, true, true, false);
        assert_eq!(wf.len(), 2);
        assert_eq!(wf[0].0, PhaseEvent::Precharge);
        assert!(wf[0].1, "node must be precharged high");
        assert_eq!(wf[1].0, PhaseEvent::Compute);
        assert!(wf[1].2, "XOR(1,0) under X=1 must emit 1");
    }

    #[test]
    fn merge_sums() {
        let mut a = TimingLedger { compute_cycles: 1, search_cycles: 2, program_cycles: 3 };
        a.merge(&TimingLedger { compute_cycles: 10, search_cycles: 20, program_cycles: 30 });
        assert_eq!(a.total_cycles(), 66);
    }
}

//! Redundancy-aware error correction (paper Fig. 4l): two mechanisms that
//! together drive the post-correction BER to zero.
//!
//! 1. **Spare columns** — two of every 32 1T1R cells in a row are reserved
//!    as spares; a stuck data cell is remapped to a spare at map time.
//! 2. **Backup region** — rows whose stuck-cell count exceeds the spare
//!    budget are relocated wholesale to a reserved backup region at the
//!    top of the array.

/// Column remap plan for one logical row.
#[derive(Clone, Debug, Default)]
pub struct RowPlan {
    /// logical data column -> physical column (identity unless remapped).
    pub col_map: Vec<usize>,
    /// physical row actually hosting the data (backup rows differ).
    pub phys_row: usize,
    /// true if the row had to be relocated to backup.
    pub relocated: bool,
}

/// ECC configuration and allocator state.
#[derive(Clone, Debug)]
pub struct Ecc {
    pub cols: usize,
    pub spares_per_row: usize,
    /// rows reserved at the top of the array as the backup region
    pub backup_rows: usize,
    total_rows: usize,
    next_backup: usize,
    /// dense plan cache, indexed by logical row (hot path: no hashing,
    /// no cloning — see `plan_row_ref`).
    plans: Vec<Option<RowPlan>>,
}

/// Number of *data* columns available per physical row.
pub fn data_cols(cols: usize, spares: usize) -> usize {
    cols - spares
}

impl Ecc {
    /// `total_rows` includes the backup region; the usable logical rows
    /// are `total_rows - backup_rows`.
    pub fn new(total_rows: usize, cols: usize, spares_per_row: usize, backup_rows: usize) -> Self {
        assert!(spares_per_row < cols);
        assert!(backup_rows < total_rows);
        Ecc {
            cols,
            spares_per_row,
            backup_rows,
            total_rows,
            next_backup: total_rows - backup_rows,
            plans: vec![None; total_rows - backup_rows],
        }
    }

    pub fn logical_rows(&self) -> usize {
        self.total_rows - self.backup_rows
    }

    pub fn data_cols(&self) -> usize {
        data_cols(self.cols, self.spares_per_row)
    }

    /// Build (and cache) the remap plan for a logical row given the
    /// stuck-cell map of the physical array. Returns None only if the
    /// row is unusable AND the backup region is exhausted.
    pub fn plan_row(&mut self, row: usize, stuck_map: &[Vec<usize>]) -> Option<RowPlan> {
        self.plan_row_ref(row, stuck_map).cloned()
    }

    /// Reference-returning variant of [`Ecc::plan_row`] — the compute hot
    /// path uses this to avoid cloning the col_map on every word-line
    /// pass (§Perf: ~1.5x on `logic_pass`).
    pub fn plan_row_ref(&mut self, row: usize, stuck_map: &[Vec<usize>]) -> Option<&RowPlan> {
        assert!(row < self.logical_rows(), "row {row} beyond logical rows");
        if self.plans[row].is_none() {
            let plan = self.build_plan(row, stuck_map).or_else(|| {
                // relocate to the next backup row that CAN host the data
                while self.next_backup < self.total_rows {
                    let candidate = self.next_backup;
                    self.next_backup += 1;
                    if let Some(mut p) = self.build_plan(candidate, stuck_map) {
                        p.relocated = true;
                        return Some(p);
                    }
                }
                None
            })?;
            self.plans[row] = Some(plan);
        }
        self.plans[row].as_ref()
    }

    /// Try to place `data_cols` data bits into physical row `phys`,
    /// steering around its stuck cells using the spare budget.
    fn build_plan(&self, phys: usize, stuck_map: &[Vec<usize>]) -> Option<RowPlan> {
        let stuck = &stuck_map[phys];
        if stuck.len() > self.spares_per_row {
            return None; // more faults than spares: row unusable
        }
        let is_stuck = |c: usize| stuck.contains(&c);
        let n_data = self.data_cols();
        let mut col_map = Vec::with_capacity(n_data);
        let mut phys_col = 0usize;
        for _ in 0..n_data {
            while phys_col < self.cols && is_stuck(phys_col) {
                phys_col += 1;
            }
            if phys_col >= self.cols {
                return None;
            }
            col_map.push(phys_col);
            phys_col += 1;
        }
        Some(RowPlan { col_map, phys_row: phys, relocated: false })
    }

    /// Fraction of backup capacity consumed so far.
    pub fn backup_utilization(&self) -> f64 {
        let used = self.next_backup - (self.total_rows - self.backup_rows);
        used as f64 / self.backup_rows.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn no_faults(rows: usize) -> Vec<Vec<usize>> {
        vec![Vec::new(); rows]
    }

    #[test]
    fn identity_plan_without_faults() {
        let mut ecc = Ecc::new(16, 32, 2, 2);
        let plan = ecc.plan_row(3, &no_faults(16)).unwrap();
        assert_eq!(plan.phys_row, 3);
        assert!(!plan.relocated);
        assert_eq!(plan.col_map, (0..30).collect::<Vec<_>>());
    }

    #[test]
    fn spare_remap_skips_stuck_columns() {
        let mut ecc = Ecc::new(16, 32, 2, 2);
        let mut stuck = no_faults(16);
        stuck[5] = vec![0, 17];
        let plan = ecc.plan_row(5, &stuck).unwrap();
        assert!(!plan.relocated);
        assert_eq!(plan.col_map.len(), 30);
        assert!(!plan.col_map.contains(&0));
        assert!(!plan.col_map.contains(&17));
        // still strictly increasing physical columns
        assert!(plan.col_map.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn over_budget_row_relocates_to_backup() {
        let mut ecc = Ecc::new(16, 32, 2, 2);
        let mut stuck = no_faults(16);
        stuck[7] = vec![1, 2, 3]; // 3 faults > 2 spares
        let plan = ecc.plan_row(7, &stuck).unwrap();
        assert!(plan.relocated);
        assert_eq!(plan.phys_row, 14); // first backup row
        assert!(ecc.backup_utilization() > 0.0);
    }

    #[test]
    fn backup_exhaustion_returns_none() {
        let mut ecc = Ecc::new(16, 32, 0, 2);
        let mut stuck = no_faults(16);
        // three bad logical rows but only two backup rows
        stuck[1] = vec![4];
        stuck[2] = vec![9];
        stuck[3] = vec![11];
        assert!(ecc.plan_row(1, &stuck).is_some());
        assert!(ecc.plan_row(2, &stuck).is_some());
        assert!(ecc.plan_row(3, &stuck).is_none());
    }

    #[test]
    fn faulty_backup_rows_are_skipped() {
        let mut ecc = Ecc::new(16, 32, 1, 3);
        let mut stuck = no_faults(16);
        stuck[0] = vec![1, 2]; // needs relocation
        stuck[13] = vec![3, 4]; // first backup row is itself bad
        let plan = ecc.plan_row(0, &stuck).unwrap();
        assert!(plan.relocated);
        assert_eq!(plan.phys_row, 14);
    }

    #[test]
    fn plans_are_cached() {
        let mut ecc = Ecc::new(16, 32, 2, 2);
        let p1 = ecc.plan_row(0, &no_faults(16)).unwrap();
        let p2 = ecc.plan_row(0, &no_faults(16)).unwrap();
        assert_eq!(p1.col_map, p2.col_map);
    }
}

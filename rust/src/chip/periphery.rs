//! Driver periphery: the WL Driver & RU Controller (WRC) and the BL/SL
//! Driver Circuits & Input Controller (BSIC) of Fig. 3a.
//!
//! * WRC — a shift-register chain selects word lines for programming and
//!   walks them sequentially during compute. It is the chip's dominant
//!   power consumer (67.40 %, Fig. 3e) because every cycle toggles the
//!   512-stage register and drives a long poly word line.
//! * BSIC — decodes a single BL during programming, or broadcasts the
//!   input vector X to all bit lines during compute.

/// Shift-register word-line selector.
#[derive(Clone, Debug)]
pub struct WlDriver {
    rows: usize,
    /// Current one-hot position (None = chain cleared).
    position: Option<usize>,
    shifts: u64,
    activations: u64,
}

impl WlDriver {
    pub fn new(rows: usize) -> Self {
        WlDriver { rows, position: None, shifts: 0, activations: 0 }
    }

    /// Load the token at row 0 (start of a pass).
    pub fn reset(&mut self) {
        self.position = Some(0);
        self.shifts += 1;
    }

    /// Shift the token to the next row; wraps to None at the end.
    pub fn shift(&mut self) {
        self.shifts += 1;
        self.position = match self.position {
            Some(p) if p + 1 < self.rows => Some(p + 1),
            _ => None,
        };
    }

    /// Drive the currently selected word line; returns the row index.
    pub fn activate(&mut self) -> Option<usize> {
        if self.position.is_some() {
            self.activations += 1;
        }
        self.position
    }

    /// Random-access select (programming mode): serially shifts the token
    /// to `row`, costing `row+1` shifts — faithful to a shift-register
    /// WRC, and the reason programming is slower than compute. The shift
    /// count is accounted arithmetically (no O(row) loop — §Perf).
    pub fn select(&mut self, row: usize) -> usize {
        assert!(row < self.rows, "row {row} out of range {}", self.rows);
        self.shifts += row as u64 + 1; // reset + `row` shifts
        self.position = Some(row);
        self.activations += 1;
        row
    }

    pub fn shifts(&self) -> u64 {
        self.shifts
    }

    pub fn activations(&self) -> u64 {
        self.activations
    }
}

/// BL/SL driver + input controller.
#[derive(Clone, Debug)]
pub struct BlDriver {
    cols: usize,
    broadcasts: u64,
    selects: u64,
}

impl BlDriver {
    pub fn new(cols: usize) -> Self {
        BlDriver { cols, broadcasts: 0, selects: 0 }
    }

    /// Compute mode: broadcast the X input bits onto all bit lines.
    /// Returns the driven pattern, padded/truncated to the column count.
    pub fn broadcast<'a>(&mut self, x: &'a [bool]) -> Vec<bool> {
        self.broadcasts += 1;
        (0..self.cols).map(|i| x.get(i).copied().unwrap_or(false)).collect()
    }

    /// Account a broadcast without materializing the driven pattern
    /// (hot path uses the caller's slice directly — §Perf).
    #[inline]
    pub fn note_broadcast(&mut self) {
        self.broadcasts += 1;
    }

    /// Programming mode: decode a single column.
    pub fn select(&mut self, col: usize) -> usize {
        assert!(col < self.cols, "col {col} out of range {}", self.cols);
        self.selects += 1;
        col
    }

    pub fn broadcasts(&self) -> u64 {
        self.broadcasts
    }

    pub fn selects(&self) -> u64 {
        self.selects
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wl_walks_all_rows_in_order() {
        let mut wl = WlDriver::new(4);
        wl.reset();
        let mut seen = Vec::new();
        while let Some(r) = wl.activate() {
            seen.push(r);
            wl.shift();
        }
        assert_eq!(seen, vec![0, 1, 2, 3]);
        assert_eq!(wl.activations(), 4);
        assert_eq!(wl.shifts(), 5); // reset + 4 shifts (last one exits)
    }

    #[test]
    fn wl_random_select_costs_serial_shifts() {
        let mut wl = WlDriver::new(512);
        let before = wl.shifts();
        assert_eq!(wl.select(100), 100);
        assert_eq!(wl.shifts() - before, 101); // reset + 100 shifts
    }

    #[test]
    fn bl_broadcast_pads_and_truncates() {
        let mut bl = BlDriver::new(4);
        assert_eq!(bl.broadcast(&[true, false]), vec![true, false, false, false]);
        assert_eq!(
            bl.broadcast(&[true; 8]),
            vec![true, true, true, true]
        );
        assert_eq!(bl.broadcasts(), 2);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn bl_select_bounds() {
        BlDriver::new(4).select(4);
    }
}

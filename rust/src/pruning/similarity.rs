//! Bit-packed software similarity: the SPN-mode (and performance hot
//! path) twin of the chip's search-in-memory. Kernel sign bits are packed
//! 64-per-u64 and distances use XOR + `count_ones`, giving ~64x the
//! throughput of the boolean path while remaining bit-exact against both
//! the chip and the Pallas artifact.

use crate::cim::mapping::WeightCodec;
use crate::cim::similarity::SimilarityMatrix;

/// Kernels packed into u64 lanes.
#[derive(Clone, Debug)]
pub struct PackedKernels {
    pub k: usize,
    pub n_bits: usize,
    words_per_kernel: usize,
    words: Vec<u64>,
}

/// Pack a boolean bit vector into u64 words (LSB-first).
pub fn pack_bits(bits: &[bool]) -> Vec<u64> {
    let mut words = vec![0u64; bits.len().div_ceil(64)];
    for (i, &b) in bits.iter().enumerate() {
        if b {
            words[i / 64] |= 1u64 << (i % 64);
        }
    }
    words
}

/// Hamming distance between two packed vectors of equal length.
#[inline]
pub fn packed_hamming(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| (x ^ y).count_ones()).sum()
}

impl PackedKernels {
    /// Binarize and pack a set of equal-length float kernels.
    pub fn from_kernels(kernels: &[Vec<f32>]) -> Self {
        assert!(!kernels.is_empty());
        let n_bits = kernels[0].len();
        let wpk = n_bits.div_ceil(64);
        let mut words = Vec::with_capacity(kernels.len() * wpk);
        for kr in kernels {
            assert_eq!(kr.len(), n_bits, "kernels must share a width");
            let bits = WeightCodec::kernel_bits(kr);
            words.extend(pack_bits(&bits));
        }
        PackedKernels { k: kernels.len(), n_bits, words_per_kernel: wpk, words }
    }

    #[inline]
    pub fn kernel(&self, i: usize) -> &[u64] {
        &self.words[i * self.words_per_kernel..(i + 1) * self.words_per_kernel]
    }

    /// Pairwise distance matrix over the live subset; pruned entries are
    /// u32::MAX (matches the chip path's convention).
    pub fn similarity_matrix(&self, live: &[bool]) -> SimilarityMatrix {
        assert_eq!(live.len(), self.k);
        let k = self.k;
        let mut dist = vec![u32::MAX; k * k];
        for i in 0..k {
            if !live[i] {
                continue;
            }
            dist[i * k + i] = 0;
            for j in (i + 1)..k {
                if !live[j] {
                    continue;
                }
                let d = packed_hamming(self.kernel(i), self.kernel(j));
                dist[i * k + j] = d;
                dist[j * k + i] = d;
            }
        }
        SimilarityMatrix { k, n_bits: self.n_bits, dist }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::similarity::similarity_matrix_ref;
    use crate::util::rng::Rng;

    fn random_kernels(k: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..k)
            .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
            .collect()
    }

    #[test]
    fn pack_roundtrip() {
        let bits: Vec<bool> = (0..130).map(|i| i % 7 == 0).collect();
        let words = pack_bits(&bits);
        assert_eq!(words.len(), 3);
        for (i, &b) in bits.iter().enumerate() {
            assert_eq!((words[i / 64] >> (i % 64)) & 1 == 1, b, "bit {i}");
        }
    }

    #[test]
    fn packed_matches_boolean_oracle() {
        let kernels = random_kernels(12, 100, 3);
        let live = vec![true; 12];
        let packed = PackedKernels::from_kernels(&kernels);
        let got = packed.similarity_matrix(&live);
        let want = similarity_matrix_ref(&kernels, &live);
        assert_eq!(got.dist, want.dist);
        assert_eq!(got.n_bits, 100);
    }

    #[test]
    fn packed_respects_live_mask() {
        let kernels = random_kernels(5, 64, 4);
        let packed = PackedKernels::from_kernels(&kernels);
        let m = packed.similarity_matrix(&[true, true, false, true, true]);
        assert_eq!(m.distance(0, 2), u32::MAX);
        assert_ne!(m.distance(0, 1), u32::MAX);
    }

    #[test]
    fn hamming_edge_cases() {
        assert_eq!(packed_hamming(&[0], &[0]), 0);
        assert_eq!(packed_hamming(&[u64::MAX], &[0]), 64);
        assert_eq!(packed_hamming(&[0b1010], &[0b0101]), 4);
    }
}

//! Bit-packed software similarity: the SPN-mode (and performance hot
//! path) twin of the chip's search-in-memory. Kernel sign bits are packed
//! 64-per-u64 and distances use XOR + `count_ones`, giving ~64x the
//! throughput of the boolean path while remaining bit-exact against both
//! the chip and the Pallas artifact.

use crate::cim::mapping::WeightCodec;
use crate::cim::similarity::SimilarityMatrix;

/// Kernels packed into u64 lanes.
#[derive(Clone, Debug)]
pub struct PackedKernels {
    pub k: usize,
    pub n_bits: usize,
    words_per_kernel: usize,
    words: Vec<u64>,
}

/// Pack a boolean bit vector into u64 words (LSB-first).
pub fn pack_bits(bits: &[bool]) -> Vec<u64> {
    let mut words = vec![0u64; bits.len().div_ceil(64)];
    for (i, &b) in bits.iter().enumerate() {
        if b {
            words[i / 64] |= 1u64 << (i % 64);
        }
    }
    words
}

/// Hamming distance between two packed vectors of equal length.
#[inline]
pub fn packed_hamming(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| (x ^ y).count_ones()).sum()
}

impl PackedKernels {
    /// Binarize and pack a set of equal-length float kernels. An empty
    /// set (a fully-pruned or zero-kernel layer) packs to an empty
    /// matrix rather than panicking.
    pub fn from_kernels(kernels: &[Vec<f32>]) -> Self {
        let bits: Vec<Vec<bool>> =
            kernels.iter().map(|kr| WeightCodec::kernel_bits(kr)).collect();
        Self::from_bit_kernels(&bits)
    }

    /// Pack kernels that are *already* sign bits — a served
    /// [`crate::serve::ConvLayer`]'s stored `bits`, or an INT8 layer's
    /// `w >= 0` signs — without re-binarizing. This is what the live
    /// prune monitor feeds: the exact bit pattern programmed on chip.
    pub fn from_bit_kernels(kernels: &[Vec<bool>]) -> Self {
        let n_bits = kernels.first().map_or(0, |k| k.len());
        let wpk = n_bits.div_ceil(64);
        let mut words = Vec::with_capacity(kernels.len() * wpk);
        for kr in kernels {
            assert_eq!(kr.len(), n_bits, "kernels must share a width");
            words.extend(pack_bits(kr));
        }
        PackedKernels { k: kernels.len(), n_bits, words_per_kernel: wpk, words }
    }

    #[inline]
    pub fn kernel(&self, i: usize) -> &[u64] {
        &self.words[i * self.words_per_kernel..(i + 1) * self.words_per_kernel]
    }

    /// Pairwise distance matrix over the live subset; pruned entries are
    /// u32::MAX (matches the chip path's convention).
    pub fn similarity_matrix(&self, live: &[bool]) -> SimilarityMatrix {
        assert_eq!(live.len(), self.k);
        let k = self.k;
        let mut dist = vec![u32::MAX; k * k];
        for i in 0..k {
            if !live[i] {
                continue;
            }
            dist[i * k + i] = 0;
            for j in (i + 1)..k {
                if !live[j] {
                    continue;
                }
                let d = packed_hamming(self.kernel(i), self.kernel(j));
                dist[i * k + j] = d;
                dist[j * k + i] = d;
            }
        }
        SimilarityMatrix { k, n_bits: self.n_bits, dist }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::similarity::similarity_matrix_ref;
    use crate::util::rng::Rng;

    fn random_kernels(k: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..k)
            .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
            .collect()
    }

    #[test]
    fn pack_roundtrip() {
        let bits: Vec<bool> = (0..130).map(|i| i % 7 == 0).collect();
        let words = pack_bits(&bits);
        assert_eq!(words.len(), 3);
        for (i, &b) in bits.iter().enumerate() {
            assert_eq!((words[i / 64] >> (i % 64)) & 1 == 1, b, "bit {i}");
        }
    }

    #[test]
    fn packed_matches_boolean_oracle() {
        let kernels = random_kernels(12, 100, 3);
        let live = vec![true; 12];
        let packed = PackedKernels::from_kernels(&kernels);
        let got = packed.similarity_matrix(&live);
        let want = similarity_matrix_ref(&kernels, &live);
        assert_eq!(got.dist, want.dist);
        assert_eq!(got.n_bits, 100);
    }

    #[test]
    fn packed_respects_live_mask() {
        let kernels = random_kernels(5, 64, 4);
        let packed = PackedKernels::from_kernels(&kernels);
        let m = packed.similarity_matrix(&[true, true, false, true, true]);
        assert_eq!(m.distance(0, 2), u32::MAX);
        assert_ne!(m.distance(0, 1), u32::MAX);
    }

    #[test]
    fn hamming_edge_cases() {
        assert_eq!(packed_hamming(&[0], &[0]), 0);
        assert_eq!(packed_hamming(&[u64::MAX], &[0]), 64);
        assert_eq!(packed_hamming(&[0b1010], &[0b0101]), 4);
    }

    #[test]
    fn bit_kernels_pack_identically_to_float_kernels() {
        let kernels = random_kernels(7, 90, 8);
        let bits: Vec<Vec<bool>> =
            kernels.iter().map(|kr| WeightCodec::kernel_bits(kr)).collect();
        let live = vec![true; 7];
        let from_float = PackedKernels::from_kernels(&kernels).similarity_matrix(&live);
        let from_bits = PackedKernels::from_bit_kernels(&bits).similarity_matrix(&live);
        assert_eq!(from_float.dist, from_bits.dist);
    }

    #[test]
    fn empty_kernel_set_packs_to_an_empty_matrix() {
        // a fully-pruned / zero-kernel layer is a legal degenerate input
        let packed = PackedKernels::from_kernels(&[]);
        assert_eq!(packed.k, 0);
        let m = packed.similarity_matrix(&[]);
        assert_eq!(m.k, 0);
        assert!(m.dist.is_empty());
    }

    /// The float cosine of the ±1 sign vectors, computed the slow
    /// geometric way — the oracle the packed XOR+popcount path must
    /// reproduce through `cos = (n − 2d)/n`.
    fn cosine_oracle(a: &[f32], b: &[f32]) -> f64 {
        let sign = |v: f32| if v >= 0.0 { 1.0f64 } else { -1.0 };
        let (mut dot, mut na, mut nb) = (0.0f64, 0.0f64, 0.0f64);
        for (&x, &y) in a.iter().zip(b) {
            let (sx, sy) = (sign(x), sign(y));
            dot += sx * sy;
            na += sx * sx;
            nb += sy * sy;
        }
        dot / (na.sqrt() * nb.sqrt())
    }

    #[test]
    fn prop_packed_hamming_matches_float_cosine_oracle() {
        crate::testing::forall(
            "similarity: (n−2d)/n == float cosine of sign vectors",
            0xc051e,
            8,
            |rng| {
                let k = 2 + rng.below(6);
                // widths deliberately include 1 (single-bit kernels)
                // and non-multiples of 64 (tail-word masking)
                let n = [1, 2, 63, 64, 65, 100][rng.below(6)];
                let mut kernels: Vec<Vec<f32>> = (0..k)
                    .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
                    .collect();
                // plant one all-zero kernel: binarization maps 0.0 to
                // the +1 sign, a row a fully-pruned layer also produces
                kernels[0] = vec![0.0; n];
                kernels
            },
            |kernels| {
                let k = kernels.len();
                let live = vec![true; k];
                let m = PackedKernels::from_kernels(kernels).similarity_matrix(&live);
                for i in 0..k {
                    for j in (i + 1)..k {
                        let want = cosine_oracle(&kernels[i], &kernels[j]);
                        let got = m.signed_cosine(i, j);
                        if (got - want).abs() > 1e-9 {
                            return Err(format!(
                                "kernels {i},{j}: packed cosine {got} != oracle {want}"
                            ));
                        }
                        // and similarity is the affine map of the same quantity
                        let s = m.similarity(i, j);
                        if (s - (1.0 + want) / 2.0).abs() > 1e-9 {
                            return Err(format!("similarity {s} inconsistent with cosine"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn single_bit_kernels_hit_both_cosine_poles() {
        let kernels = vec![vec![1.0f32], vec![-1.0], vec![0.0]];
        let m = PackedKernels::from_kernels(&kernels).similarity_matrix(&[true; 3]);
        // +1 vs −1: distance 1 of 1 bit -> cosine −1
        assert_eq!(m.distance(0, 1), 1);
        assert!((m.signed_cosine(0, 1) + 1.0).abs() < 1e-12);
        // 0.0 binarizes to the +1 sign -> identical to kernel 0
        assert_eq!(m.distance(0, 2), 0);
        assert!((m.signed_cosine(0, 2) - 1.0).abs() < 1e-12);
        assert!((m.similarity(0, 2) - 1.0).abs() < 1e-12);
    }
}

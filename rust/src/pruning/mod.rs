//! The paper's real-time dynamic weight-pruning algorithm (Fig. 1a,
//! Fig. 4b): during training, monitor pairwise kernel similarity
//! (Hamming distance over binarized kernels), collect a candidate list of
//! overly similar pairs, count each kernel's appearance frequency, and
//! prune kernels whose frequency crosses the threshold — while always
//! keeping one representative of every similar cluster alive.
//!
//! The similarity matrix can come from three interchangeable sources that
//! agree bit-for-bit:
//! * the chip's search-in-memory XOR passes ([`crate::cim::similarity`]) — HPN mode,
//! * the AOT Pallas `similarity` artifact ([`crate::runtime`]),
//! * the bit-packed software path ([`similarity`] below) — SPN mode.

pub mod scheduler;
pub mod similarity;

pub use scheduler::{PruneConfig, PruneEvent, PruningScheduler};
pub use similarity::{pack_bits, packed_hamming, PackedKernels};

//! The dynamic pruning scheduler (paper Fig. 4b):
//!
//! 1. every `prune_interval` epochs (after a warm-up), build the pairwise
//!    similarity matrix of each layer's *live* kernels;
//! 2. kernel pairs whose normalized similarity exceeds `sim_threshold`
//!    enter the candidate list;
//! 3. kernels whose candidate-list frequency exceeds `freq_threshold`
//!    are pruned — except that one representative of every similar
//!    cluster is always retained, and per-layer / global floors cap the
//!    total pruning rate.
//!
//! NOTE (paper discrepancy): the text says "distances exceeding a
//! predefined threshold" join the candidate list, but Fig. 4d marks
//! *excessive similarity* as the prune trigger; we implement similarity
//! above threshold (see DESIGN.md §4).

use crate::cim::similarity::SimilarityMatrix;

/// Scheduler configuration.
#[derive(Clone, Debug)]
pub struct PruneConfig {
    /// Normalized similarity above which a pair becomes a candidate.
    pub sim_threshold: f64,
    /// Candidate-list frequency (number of similar partners) above which
    /// a kernel may be pruned.
    pub freq_threshold: usize,
    /// Epochs between prune evaluations.
    pub prune_interval: usize,
    /// Epochs before the first evaluation (let weights differentiate).
    pub warmup_epochs: usize,
    /// Hard floor of live kernels per layer.
    pub min_live_per_layer: usize,
    /// Global cap on the pruned fraction (0..1).
    pub max_prune_rate: f64,
}

impl Default for PruneConfig {
    fn default() -> Self {
        PruneConfig {
            sim_threshold: 0.75,
            freq_threshold: 1,
            prune_interval: 2,
            warmup_epochs: 2,
            min_live_per_layer: 4,
            max_prune_rate: 0.60,
        }
    }
}

/// What happened at one prune evaluation.
#[derive(Clone, Debug, Default)]
pub struct PruneEvent {
    pub epoch: usize,
    /// (layer, kernel) pairs pruned at this event.
    pub pruned: Vec<(usize, usize)>,
    /// candidate-list sizes per layer (diagnostics / Fig. 4e).
    pub candidates_per_layer: Vec<usize>,
}

/// Per-layer live masks + pruning bookkeeping.
#[derive(Clone, Debug)]
pub struct PruningScheduler {
    cfg: PruneConfig,
    /// live[layer][kernel]
    live: Vec<Vec<bool>>,
    /// weights (parameter count) per kernel of each layer, for the
    /// Fig. 4i "total weights" curve.
    weights_per_kernel: Vec<usize>,
    events: Vec<PruneEvent>,
    /// Highest epoch already evaluated: replaying it (or anything
    /// earlier) is a no-op, so a caller that retries a pass never
    /// double-prunes.
    last_evaluated: Option<usize>,
}

impl PruningScheduler {
    /// `layer_sizes[(kernels, weights_per_kernel)]` per prunable layer.
    pub fn new(cfg: PruneConfig, layer_sizes: &[(usize, usize)]) -> Self {
        PruningScheduler {
            cfg,
            live: layer_sizes.iter().map(|&(k, _)| vec![true; k]).collect(),
            weights_per_kernel: layer_sizes.iter().map(|&(_, w)| w).collect(),
            events: Vec::new(),
            last_evaluated: None,
        }
    }

    /// A scheduler whose live masks start from an *already pruned*
    /// model (the serve-side live-prune monitor seeds one from
    /// [`crate::serve::ModelBundle`] masks each pass, so the global
    /// rate cap counts export-time pruning too).
    pub fn from_live_masks(
        cfg: PruneConfig,
        masks: &[Vec<bool>],
        weights_per_kernel: &[usize],
    ) -> Self {
        assert_eq!(masks.len(), weights_per_kernel.len(), "one weight count per layer");
        PruningScheduler {
            cfg,
            live: masks.to_vec(),
            weights_per_kernel: weights_per_kernel.to_vec(),
            events: Vec::new(),
            last_evaluated: None,
        }
    }

    pub fn cfg(&self) -> &PruneConfig {
        &self.cfg
    }

    pub fn n_layers(&self) -> usize {
        self.live.len()
    }

    pub fn live_mask(&self, layer: usize) -> &[bool] {
        &self.live[layer]
    }

    /// Float masks (1.0 live / 0.0 pruned) in the artifact's layout.
    pub fn mask_f32(&self, layer: usize) -> Vec<f32> {
        self.live[layer].iter().map(|&b| if b { 1.0 } else { 0.0 }).collect()
    }

    /// All live masks, one per layer — the export format the serve
    /// placer consumes ([`crate::serve::ModelBundle::from_params`]).
    pub fn live_masks(&self) -> Vec<Vec<bool>> {
        self.live.clone()
    }

    pub fn live_count(&self, layer: usize) -> usize {
        self.live[layer].iter().filter(|&&b| b).count()
    }

    pub fn total_kernels(&self) -> usize {
        self.live.iter().map(|l| l.len()).sum()
    }

    pub fn total_live(&self) -> usize {
        self.live.iter().map(|l| l.iter().filter(|&&b| b).count()).sum()
    }

    /// Live parameter count (Fig. 4i right axis).
    pub fn total_live_weights(&self) -> usize {
        self.live
            .iter()
            .zip(&self.weights_per_kernel)
            .map(|(l, &w)| l.iter().filter(|&&b| b).count() * w)
            .sum()
    }

    /// Fraction of kernels pruned so far. A scheduler over zero kernels
    /// (no prunable layers, or every layer empty) has pruned nothing:
    /// the rate is 0.0, not the 1.0 the naive ratio would report.
    pub fn prune_rate(&self) -> f64 {
        let total = self.total_kernels();
        if total == 0 {
            return 0.0;
        }
        1.0 - self.total_live() as f64 / total as f64
    }

    pub fn events(&self) -> &[PruneEvent] {
        &self.events
    }

    /// Is `epoch` a prune-evaluation epoch?
    pub fn is_prune_epoch(&self, epoch: usize) -> bool {
        epoch >= self.cfg.warmup_epochs
            && (epoch - self.cfg.warmup_epochs) % self.cfg.prune_interval == 0
    }

    /// Run one prune evaluation given per-layer similarity matrices of
    /// the *current* live kernels (entries for pruned kernels must be
    /// u32::MAX, as all three similarity sources produce).
    ///
    /// Idempotent on repeated epochs: re-evaluating an epoch already
    /// evaluated (or any earlier one) returns an empty event and
    /// mutates nothing, so a retried training step or serve pass never
    /// double-prunes.
    pub fn evaluate(&mut self, epoch: usize, sims: &[SimilarityMatrix]) -> PruneEvent {
        assert_eq!(sims.len(), self.live.len(), "one matrix per layer");
        if matches!(self.last_evaluated, Some(e) if epoch <= e) {
            return PruneEvent {
                epoch,
                candidates_per_layer: vec![0; self.live.len()],
                ..Default::default()
            };
        }
        self.last_evaluated = Some(epoch);
        let mut event = PruneEvent { epoch, ..Default::default() };
        let total = self.total_kernels();
        for (layer, sim) in sims.iter().enumerate() {
            let k = sim.k;
            assert_eq!(k, self.live[layer].len(), "layer {layer} size");
            // 1) candidate pairs + per-kernel frequency
            let mut freq = vec![0usize; k];
            let mut pairs: Vec<(usize, usize, f64)> = Vec::new();
            for i in 0..k {
                for j in (i + 1)..k {
                    if sim.dist[i * k + j] == u32::MAX {
                        continue;
                    }
                    let s = sim.similarity(i, j);
                    if s > self.cfg.sim_threshold {
                        freq[i] += 1;
                        freq[j] += 1;
                        pairs.push((i, j, s));
                    }
                }
            }
            event.candidates_per_layer.push(pairs.len());
            // 2) prune by descending frequency, most-redundant first
            let mut order: Vec<usize> = (0..k).collect();
            order.sort_by(|&a, &b| freq[b].cmp(&freq[a]).then(b.cmp(&a)));
            for &i in &order {
                if freq[i] < self.cfg.freq_threshold || !self.live[layer][i] {
                    continue;
                }
                // floors: per-layer minimum (never below one — a layer
                // must keep a live representative even when the config
                // says 0) and the global rate cap
                if self.live_count(layer) <= self.cfg.min_live_per_layer.max(1) {
                    break;
                }
                let rate_after = 1.0 - (self.total_live() - 1) as f64 / total as f64;
                if rate_after > self.cfg.max_prune_rate {
                    break;
                }
                // cluster representative: keep i alive if every similar
                // partner of i is already pruned
                let partners_alive = pairs
                    .iter()
                    .filter(|&&(a, b, _)| a == i || b == i)
                    .any(|&(a, b, _)| {
                        let other = if a == i { b } else { a };
                        self.live[layer][other]
                    });
                if !partners_alive {
                    continue;
                }
                self.live[layer][i] = false;
                event.pruned.push((layer, i));
            }
        }
        self.events.push(event.clone());
        event
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::similarity::PackedKernels;
    use crate::util::rng::Rng;

    /// Build kernels where groups share the same sign pattern.
    fn clustered_kernels(groups: &[usize], n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        let mut out = Vec::new();
        for (g, &count) in groups.iter().enumerate() {
            let proto: Vec<f32> = (0..n)
                .map(|i| if (i + g) % (g + 2) == 0 { 1.0 } else { -1.0 })
                .collect();
            for c in 0..count {
                // tiny magnitude jitter, same signs -> similarity 1.0
                let k: Vec<f32> = proto
                    .iter()
                    .map(|&v| v * (1.0 + 0.1 * rng.f32()))
                    .collect();
                let _ = c;
                out.push(k);
            }
        }
        out
    }

    fn sim_of(kernels: &[Vec<f32>], live: &[bool]) -> SimilarityMatrix {
        PackedKernels::from_kernels(kernels).similarity_matrix(live)
    }

    #[test]
    fn prunes_duplicates_but_keeps_representative() {
        let kernels = clustered_kernels(&[4, 3, 1], 64, 1);
        let mut sched = PruningScheduler::new(
            PruneConfig { min_live_per_layer: 1, max_prune_rate: 1.0, ..Default::default() },
            &[(8, 64)],
        );
        let sim = sim_of(&kernels, sched.live_mask(0));
        let ev = sched.evaluate(2, &[sim]);
        assert!(!ev.pruned.is_empty());
        // exactly one representative per cluster must survive
        assert_eq!(sched.live_count(0), 3, "live: {:?}", sched.live_mask(0));
        // cluster of size 1 (last kernel) must survive
        assert!(sched.live_mask(0)[7]);
    }

    #[test]
    fn respects_min_live_floor() {
        let kernels = clustered_kernels(&[6], 64, 2); // all identical-ish
        let mut sched = PruningScheduler::new(
            PruneConfig { min_live_per_layer: 4, ..Default::default() },
            &[(6, 64)],
        );
        let sim = sim_of(&kernels, sched.live_mask(0));
        sched.evaluate(2, &[sim]);
        assert!(sched.live_count(0) >= 4);
    }

    #[test]
    fn respects_global_rate_cap() {
        let kernels = clustered_kernels(&[10], 64, 3);
        let mut sched = PruningScheduler::new(
            PruneConfig {
                min_live_per_layer: 1,
                max_prune_rate: 0.30,
                ..Default::default()
            },
            &[(10, 64)],
        );
        let sim = sim_of(&kernels, sched.live_mask(0));
        sched.evaluate(2, &[sim]);
        assert!(sched.prune_rate() <= 0.30 + 1e-9);
    }

    #[test]
    fn dissimilar_kernels_are_untouched() {
        let mut rng = Rng::new(4);
        let kernels: Vec<Vec<f32>> = (0..8)
            .map(|_| (0..128).map(|_| rng.normal() as f32).collect())
            .collect();
        let mut sched = PruningScheduler::new(PruneConfig::default(), &[(8, 128)]);
        let sim = sim_of(&kernels, sched.live_mask(0));
        let ev = sched.evaluate(2, &[sim]);
        // random 128-bit kernels essentially never reach 0.75 similarity
        assert!(ev.pruned.is_empty(), "pruned {:?}", ev.pruned);
        assert_eq!(sched.prune_rate(), 0.0);
    }

    #[test]
    fn prune_epoch_schedule() {
        let sched = PruningScheduler::new(
            PruneConfig { warmup_epochs: 3, prune_interval: 2, ..Default::default() },
            &[(4, 9)],
        );
        let epochs: Vec<usize> = (0..10).filter(|&e| sched.is_prune_epoch(e)).collect();
        assert_eq!(epochs, vec![3, 5, 7, 9]);
    }

    #[test]
    fn weights_accounting_tracks_pruning() {
        let kernels = clustered_kernels(&[5, 1], 32, 5);
        let mut sched = PruningScheduler::new(
            PruneConfig { min_live_per_layer: 1, ..Default::default() },
            &[(6, 32)],
        );
        assert_eq!(sched.total_live_weights(), 6 * 32);
        let sim = sim_of(&kernels, sched.live_mask(0));
        sched.evaluate(2, &[sim]);
        assert_eq!(sched.total_live_weights(), sched.total_live() * 32);
        assert!(sched.total_live() < 6);
    }

    #[test]
    fn zero_kernel_layers_report_zero_prune_rate() {
        // no layers at all
        let empty = PruningScheduler::new(PruneConfig::default(), &[]);
        assert_eq!(empty.prune_rate(), 0.0, "nothing to prune is a 0% rate, not 100%");
        assert_eq!(empty.total_live(), 0);
        // a zero-kernel layer next to a real one: evaluate must not
        // panic, and the rate only counts the real kernels
        let kernels = clustered_kernels(&[2], 16, 9);
        let mut sched = PruningScheduler::new(PruneConfig::default(), &[(0, 16), (2, 16)]);
        assert_eq!(sched.prune_rate(), 0.0);
        let empty_sim = sim_of(&Vec::new(), &[]);
        let real_sim = sim_of(&kernels, sched.live_mask(1));
        let ev = sched.evaluate(2, &[empty_sim, real_sim]);
        assert_eq!(ev.candidates_per_layer[0], 0);
        assert_eq!(sched.live_mask(0).len(), 0);
    }

    #[test]
    fn never_prunes_a_layers_last_live_kernel() {
        // two byte-identical kernels and a config that says "no floor":
        // the scheduler must still keep one representative alive
        let kernels = clustered_kernels(&[2], 32, 10);
        let mut sched = PruningScheduler::new(
            PruneConfig { min_live_per_layer: 0, max_prune_rate: 1.0, ..Default::default() },
            &[(2, 32)],
        );
        let sim = sim_of(&kernels, sched.live_mask(0));
        sched.evaluate(2, &[sim]);
        assert_eq!(sched.live_count(0), 1, "one survivor, even with a zero floor");
        // and a second pass over the sole survivor is a no-op
        let sim2 = sim_of(&kernels, sched.live_mask(0));
        let ev2 = sched.evaluate(4, &[sim2]);
        assert!(ev2.pruned.is_empty());
        assert_eq!(sched.live_count(0), 1);
    }

    #[test]
    fn evaluate_is_idempotent_on_repeated_epochs() {
        let kernels = clustered_kernels(&[4], 64, 11);
        let mut sched = PruningScheduler::new(
            PruneConfig { min_live_per_layer: 1, ..Default::default() },
            &[(4, 64)],
        );
        let sim = sim_of(&kernels, sched.live_mask(0));
        let first = sched.evaluate(2, &[sim.clone()]);
        assert!(!first.pruned.is_empty());
        let live_after = sched.total_live();
        let events_after = sched.events().len();
        // replaying the same epoch (e.g. a retried pass) changes nothing
        let replay = sched.evaluate(2, &[sim.clone()]);
        assert!(replay.pruned.is_empty(), "replay must not double-prune");
        assert_eq!(sched.total_live(), live_after);
        assert_eq!(sched.events().len(), events_after, "replays are not recorded");
        // nor does an *earlier* epoch arriving late
        let stale = sched.evaluate(1, &[sim]);
        assert!(stale.pruned.is_empty());
        assert_eq!(sched.total_live(), live_after);
    }

    #[test]
    fn from_live_masks_seeds_already_pruned_state() {
        let masks = vec![vec![true, false, true], vec![false, true]];
        let sched = PruningScheduler::from_live_masks(PruneConfig::default(), &masks, &[9, 9]);
        assert_eq!(sched.total_kernels(), 5);
        assert_eq!(sched.total_live(), 3);
        assert_eq!(sched.live_mask(0), &[true, false, true]);
        assert!((sched.prune_rate() - 0.4).abs() < 1e-12);
        assert_eq!(sched.total_live_weights(), 3 * 9);
    }

    #[test]
    fn second_evaluation_skips_pruned_kernels() {
        let kernels = clustered_kernels(&[4], 64, 6);
        let mut sched = PruningScheduler::new(
            PruneConfig { min_live_per_layer: 1, ..Default::default() },
            &[(4, 64)],
        );
        let sim = sim_of(&kernels, sched.live_mask(0));
        sched.evaluate(2, &[sim]);
        let live_after_first = sched.total_live();
        // re-evaluate with the updated live mask: sole survivor stays
        let sim2 = sim_of(&kernels, sched.live_mask(0));
        let ev2 = sched.evaluate(4, &[sim2]);
        assert!(ev2.pruned.is_empty());
        assert_eq!(sched.total_live(), live_after_first);
    }
}

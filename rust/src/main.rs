//! `rram-cim` CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands:
//!   train-mnist     train the binary CNN (Fig. 4) in SUN/SPN/HPN mode
//!   train-pointnet  train the PointNet (Fig. 5) in SUN/SPN/HPN mode
//!   characterize    regenerate the device panels of Fig. 2
//!   chip-demo       exercise the reconfigurable logic + search-in-memory
//!   energy-report   print the Fig. 3d/e/g/h/i comparison rows
//!
//! Run `rram-cim help` for options.

// Terminal output is this target's product; the serve-code print ban
// (workspace clippy.toml `disallowed-macros`) deliberately does not
// apply outside `rust/src/serve/**`.
#![allow(clippy::disallowed_macros)]

use anyhow::{anyhow, Result};

use rram_cim::baselines::{self, analog_cim, gpu, sram_cim, Workload};
use rram_cim::bench::print_table;
use rram_cim::chip::{AreaModel, Chip, ChipConfig, LogicOp};
use rram_cim::cim::mapping::RowAllocator;
use rram_cim::cim::similarity as chip_sim;
use rram_cim::coordinator::mnist::{MnistConfig, MnistTrainer};
use rram_cim::coordinator::pointnet::{PointNetConfig, PointNetTrainer};
use rram_cim::coordinator::TrainMode;
use rram_cim::device::{characterize, DeviceConfig};
use rram_cim::pruning::PruneConfig;
use rram_cim::runtime::Engine;
use rram_cim::util::args::Args;
use rram_cim::util::logging;
use rram_cim::util::rng::Rng;

const USAGE: &str = "\
rram-cim — reconfigurable digital RRAM CIM with in-situ pruning (paper repro)

usage: rram-cim <subcommand> [options]

subcommands:
  train-mnist      --mode sun|spn|hpn --epochs N --seed S [--pallas]
                   [--train-samples N] [--test-samples N] [--lr F]
                   [--sim-threshold F] [--max-prune-rate F] [--json PATH]
  train-pointnet   same options as train-mnist
  characterize     --seed S   (regenerates the Fig. 2 device panels)
  chip-demo        --seed S   (logic truth tables + search-in-memory demo)
  energy-report    (Fig. 3 architecture comparison rows)
  run              --config configs/<file>.toml [--json PATH]
";

fn parse_mode(s: &str) -> Result<TrainMode> {
    match s.to_ascii_lowercase().as_str() {
        "sun" => Ok(TrainMode::Sun),
        "spn" => Ok(TrainMode::Spn),
        "hpn" => Ok(TrainMode::Hpn),
        other => Err(anyhow!("unknown mode {other:?} (want sun|spn|hpn)")),
    }
}

fn main() -> Result<()> {
    logging::init();
    let sub = std::env::args().nth(1).unwrap_or_default();
    let args = Args::from_env(2).map_err(|e| anyhow!(e))?;
    match sub.as_str() {
        "train-mnist" => train_mnist(&args),
        "train-pointnet" => train_pointnet(&args),
        "characterize" => characterize_cmd(&args),
        "chip-demo" => chip_demo(&args),
        "energy-report" => energy_report(),
        "run" => run_config(&args),
        "" | "help" | "--help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            eprint!("unknown subcommand {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
    }
}

/// Config-file launcher: sweeps live in checked-in TOML files.
fn run_config(args: &Args) -> Result<()> {
    use rram_cim::util::config::Config;
    let path = args.get("config").ok_or_else(|| anyhow!("--config required"))?;
    let c = Config::load(path).map_err(|e| anyhow!("{path}: {e}"))?;
    let mode = parse_mode(&c.str_or("train.mode", "spn"))?;
    let prune = PruneConfig {
        sim_threshold: c.float_or("prune.sim_threshold", 0.70),
        freq_threshold: c.int_or("prune.freq_threshold", 1) as usize,
        prune_interval: c.int_or("prune.prune_interval", 2) as usize,
        warmup_epochs: c.int_or("prune.warmup_epochs", 2) as usize,
        min_live_per_layer: c.int_or("prune.min_live_per_layer", 4) as usize,
        max_prune_rate: c.float_or("prune.max_prune_rate", 0.6),
    };
    let engine = Engine::open_default()?;
    let report = match c.str_or("task", "mnist").as_str() {
        "mnist" => {
            let cfg = MnistConfig {
                epochs: c.int_or("train.epochs", 10) as usize,
                train_samples: c.int_or("train.train_samples", 1920) as usize,
                test_samples: c.int_or("train.test_samples", 512) as usize,
                lr: c.float_or("train.lr", 0.05) as f32,
                seed: c.int_or("train.seed", 42) as u64,
                mode,
                prune,
                use_pallas: c.bool_or("train.pallas", false),
                hpn_check_macs: c.int_or("train.hpn_check_macs", 64) as usize,
            };
            MnistTrainer::new(cfg, engine).train()?
        }
        "pointnet" => {
            let base = PointNetConfig::default();
            let cfg = PointNetConfig {
                epochs: c.int_or("train.epochs", 12) as usize,
                train_samples: c.int_or("train.train_samples", 320) as usize,
                test_samples: c.int_or("train.test_samples", 96) as usize,
                lr: c.float_or("train.lr", 0.05) as f32,
                seed: c.int_or("train.seed", 7) as u64,
                mode,
                prune,
                use_pallas: c.bool_or("train.pallas", false),
                grouping: base.grouping,
                hpn_check_macs: c.int_or("train.hpn_check_macs", 32) as usize,
            };
            PointNetTrainer::new(cfg, engine).train()?
        }
        other => return Err(anyhow!("unknown task {other:?}")),
    };
    println!("final test accuracy: {:.2}%", 100.0 * report.final_test_acc());
    println!("prune rate: {:.2}%", 100.0 * report.final_prune_rate);
    maybe_dump(args, report.to_json())
}

fn prune_cfg_from(args: &Args, base: PruneConfig) -> Result<PruneConfig> {
    Ok(PruneConfig {
        sim_threshold: args.parse_or("sim-threshold", base.sim_threshold).map_err(|e| anyhow!(e))?,
        freq_threshold: args.parse_or("freq-threshold", base.freq_threshold).map_err(|e| anyhow!(e))?,
        prune_interval: args.parse_or("prune-interval", base.prune_interval).map_err(|e| anyhow!(e))?,
        warmup_epochs: args.parse_or("warmup-epochs", base.warmup_epochs).map_err(|e| anyhow!(e))?,
        min_live_per_layer: args.parse_or("min-live", base.min_live_per_layer).map_err(|e| anyhow!(e))?,
        max_prune_rate: args.parse_or("max-prune-rate", base.max_prune_rate).map_err(|e| anyhow!(e))?,
    })
}

fn maybe_dump(args: &Args, json: rram_cim::util::json::Json) -> Result<()> {
    if let Some(path) = args.get("json") {
        std::fs::write(path, json.render())?;
        log::info!("wrote report to {path}");
    }
    Ok(())
}

fn train_mnist(args: &Args) -> Result<()> {
    let base = MnistConfig::default();
    let cfg = MnistConfig {
        epochs: args.parse_or("epochs", base.epochs).map_err(|e| anyhow!(e))?,
        train_samples: args.parse_or("train-samples", base.train_samples).map_err(|e| anyhow!(e))?,
        test_samples: args.parse_or("test-samples", base.test_samples).map_err(|e| anyhow!(e))?,
        lr: args.parse_or("lr", base.lr).map_err(|e| anyhow!(e))?,
        seed: args.parse_or("seed", base.seed).map_err(|e| anyhow!(e))?,
        mode: parse_mode(&args.get_or("mode", "spn"))?,
        prune: prune_cfg_from(args, base.prune)?,
        use_pallas: args.flag("pallas"),
        hpn_check_macs: args.parse_or("hpn-check-macs", base.hpn_check_macs).map_err(|e| anyhow!(e))?,
    };
    let engine = Engine::open_default()?;
    let mut tr = MnistTrainer::new(cfg, engine);
    let report = tr.train()?;
    println!("\nfinal test accuracy: {:.2}%", 100.0 * report.final_test_acc());
    println!("prune rate: {:.2}%", 100.0 * report.final_prune_rate);
    println!("training conv-op reduction: {:.2}%", 100.0 * report.train_ops_reduction());
    println!("\nconfusion matrix (rows = truth):\n{}", report.confusion.render());
    maybe_dump(args, report.to_json())
}

fn train_pointnet(args: &Args) -> Result<()> {
    let base = PointNetConfig::default();
    let cfg = PointNetConfig {
        epochs: args.parse_or("epochs", base.epochs).map_err(|e| anyhow!(e))?,
        train_samples: args.parse_or("train-samples", base.train_samples).map_err(|e| anyhow!(e))?,
        test_samples: args.parse_or("test-samples", base.test_samples).map_err(|e| anyhow!(e))?,
        lr: args.parse_or("lr", base.lr).map_err(|e| anyhow!(e))?,
        seed: args.parse_or("seed", base.seed).map_err(|e| anyhow!(e))?,
        mode: parse_mode(&args.get_or("mode", "spn"))?,
        prune: prune_cfg_from(args, base.prune)?,
        use_pallas: args.flag("pallas"),
        grouping: base.grouping,
        hpn_check_macs: args.parse_or("hpn-check-macs", base.hpn_check_macs).map_err(|e| anyhow!(e))?,
    };
    let engine = Engine::open_default()?;
    let mut tr = PointNetTrainer::new(cfg, engine);
    let report = tr.train()?;
    println!("\nfinal test accuracy: {:.2}%", 100.0 * report.final_test_acc());
    println!("prune rate: {:.2}%", 100.0 * report.final_prune_rate);
    println!("training conv-op reduction: {:.2}%", 100.0 * report.train_ops_reduction());
    println!("\nconfusion matrix (rows = truth):\n{}", report.confusion.render());
    maybe_dump(args, report.to_json())
}

fn characterize_cmd(args: &Args) -> Result<()> {
    let seed: u64 = args.parse_or("seed", 1u64).map_err(|e| anyhow!(e))?;
    let cfg = DeviceConfig::default();
    println!("== Fig. 2i: forming distribution over 512x32x2 cells ==");
    let (summary, yld) = characterize::forming_distribution(&cfg, seed);
    println!(
        "V_form mean {:.3} V, std {:.3} V, yield {:.1}%  (paper: 1.89 / 0.18 / 100%)",
        summary.mean,
        summary.std,
        100.0 * yld
    );
    println!("\n== Fig. 2j/l: programming accuracy (32x32 subarray) ==");
    for rep in characterize::programming_accuracy(&cfg, seed, &[2, 4, 8, 16]) {
        println!(
            "{:>3} levels: {:.2}% in +-2 kOhm window, sigma {:.4} kOhm",
            rep.levels,
            100.0 * rep.success_frac,
            rep.sigma_kohm
        );
    }
    println!("(paper: 99.8% within window, sigma 0.8793 kOhm)");
    Ok(())
}

fn chip_demo(args: &Args) -> Result<()> {
    let seed: u64 = args.parse_or("seed", 3u64).map_err(|e| anyhow!(e))?;
    let mut rng = Rng::new(seed);
    let mut chip = Chip::new(ChipConfig::default(), &mut rng);
    let yields = chip.form();
    println!("formed {} blocks, yields: {yields:?}", yields.len());
    // truth-table demo (Fig. 3c)
    let n = 4;
    let w_pattern = [true, false, true, false];
    for (col, &bit) in w_pattern.iter().enumerate() {
        chip.program_bit(0, 0, col, bit);
    }
    chip.reset_ledgers(); // measure the compute window, not forming
    let x = vec![true; n];
    let k = vec![true, true, false, false];
    let mut rows = Vec::new();
    for op in LogicOp::ALL {
        let out = chip.logic_pass(0, 0, op, &x, &k, false);
        rows.push(vec![
            op.name().to_string(),
            format!("{:?}", w_pattern.iter().map(|&b| b as u8).collect::<Vec<_>>()),
            format!("{:?}", k.iter().map(|&b| b as u8).collect::<Vec<_>>()),
            format!("{:?}", out[..n].iter().map(|&b| b as u8).collect::<Vec<_>>()),
        ]);
    }
    print_table("Fig. 3c: OUT = X AND (W (.) K), X=1", &["op", "W", "K", "OUT"], &rows);
    // search-in-memory demo
    let kernels: Vec<Vec<f32>> = (0..4)
        .map(|i| (0..16).map(|j| if (i * j) % 3 == 0 { 1.0 } else { -1.0 }).collect())
        .collect();
    let mut alloc = RowAllocator::for_chip(&chip);
    let stored = chip_sim::store_kernels(&mut chip, &mut alloc, &kernels);
    let m = chip_sim::similarity_matrix(&mut chip, &stored, &[true; 4]);
    let rows: Vec<Vec<String>> = (0..4)
        .map(|i| {
            let mut r = vec![format!("kernel {i}")];
            r.extend((0..4).map(|j| format!("{:.2}", m.similarity(i, j))));
            r
        })
        .collect();
    print_table(
        "search-in-memory similarity (XOR + popcount)",
        &["", "k0", "k1", "k2", "k3"],
        &rows,
    );
    let b = chip.energy_breakdown();
    let s = b.shares();
    println!(
        "\nenergy so far: {:.1} nJ (top: {} {:.1}%, {} {:.1}%)",
        b.total_pj() * 1e-3,
        s[0].0,
        100.0 * s[0].1,
        s[1].0,
        100.0 * s[1].1
    );
    Ok(())
}

fn energy_report() -> Result<()> {
    let area = AreaModel::default();
    let rows: Vec<Vec<String>> = area
        .shares()
        .iter()
        .map(|(m, s)| vec![m.to_string(), format!("{:.2}%", 100.0 * s)])
        .collect();
    print_table("Fig. 3d: area breakdown (5.016 mm^2)", &["module", "share"], &rows);

    let w = Workload::from_macs(1_000_000, 32);
    let ours = baselines::digital_rram_energy_pj(&w);
    let gpu_e = gpu::energy_pj(1_000_000, gpu::GpuWorkloadClass::SmallCnn);
    let rows = vec![
        vec!["digital RRAM (this work)".into(), format!("{:.1}", ours * 1e-6), "1.00x".into(), "0%".into()],
        vec![
            "analog RRAM CIM".into(),
            format!("{:.1}", analog_cim::energy_pj(&w) * 1e-6),
            format!("{:.2}x", analog_cim::energy_pj(&w) / ours),
            format!("{:.2}%", 100.0 * analog_cim::average_error_rate(7)),
        ],
        vec![
            "digital SRAM CIM".into(),
            format!("{:.1}", sram_cim::energy_pj(&w) * 1e-6),
            format!("{:.2}x", sram_cim::energy_pj(&w) / ours),
            "0%".into(),
        ],
        vec![
            "RTX 4090 (normalized)".into(),
            format!("{:.1}", gpu_e * 1e-6),
            format!("{:.2}x", gpu_e / ours),
            "0%".into(),
        ],
    ];
    print_table(
        "Fig. 3g/i: energy per 1M INT8 MACs + bit error",
        &["architecture", "energy (uJ)", "vs ours", "bit error"],
        &rows,
    );
    println!(
        "\nFig. 3h areas: ours {:.2} mm^2, analog {:.2} mm^2 ({:.2}x), SRAM {:.2} mm^2 ({:.2}x)",
        rram_cim::chip::area::CHIP_AREA_MM2,
        analog_cim::area_mm2(),
        analog_cim::area_mm2() / rram_cim::chip::area::CHIP_AREA_MM2,
        sram_cim::area_mm2(),
        sram_cim::area_mm2() / rram_cim::chip::area::CHIP_AREA_MM2,
    );
    Ok(())
}

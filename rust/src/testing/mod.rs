//! Property-testing driver (proptest is not in the offline vendored crate
//! set): generates N random cases from a seeded generator and reports the
//! failing seed for reproduction.

use crate::util::rng::Rng;

/// Run `cases` random property checks. `gen` builds an input from an Rng;
/// `prop` returns Err(description) on violation. Panics with the case
/// seed on failure so the case can be replayed exactly.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(why) = prop(&input) {
            panic!(
                "property {name:?} failed on case {case} (seed {case_seed:#x}):\n  {why}\n  input: {input:?}"
            );
        }
    }
}

/// Shrinking helper for vec inputs: try removing chunks while the
/// property still fails, to report a smaller counterexample.
pub fn shrink_vec<T: Clone + std::fmt::Debug>(
    mut input: Vec<T>,
    mut fails: impl FnMut(&[T]) -> bool,
) -> Vec<T> {
    let mut chunk = input.len() / 2;
    while chunk > 0 {
        let mut i = 0;
        while i + chunk <= input.len() {
            let mut candidate = input.clone();
            candidate.drain(i..i + chunk);
            if fails(&candidate) {
                input = candidate;
            } else {
                i += chunk;
            }
        }
        chunk /= 2;
    }
    input
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_valid_property() {
        forall(
            "abs is non-negative",
            1,
            100,
            |rng| rng.normal(),
            |x| {
                if x.abs() >= 0.0 {
                    Ok(())
                } else {
                    Err("negative abs".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property")]
    fn forall_reports_failures() {
        forall(
            "always fails",
            2,
            10,
            |rng| rng.below(10),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn shrink_finds_minimal_failing_vec() {
        // property fails iff the vec contains a 7
        let input = vec![1, 2, 7, 3, 4, 5, 6];
        let out = shrink_vec(input, |v| v.contains(&7));
        assert_eq!(out, vec![7]);
    }
}

//! Statistics helpers used by device characterization (Fig. 2) and the
//! bench harness: summary moments, percentiles, histograms, and a basic
//! linear fit.

/// Summary statistics of a sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

/// Compute mean/std/min/max of a sample. Returns zeros for empty input.
pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0 };
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    Summary {
        n: xs.len(),
        mean,
        std: var.sqrt(),
        min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
        max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    }
}

/// p-th percentile by linear interpolation on the sorted sample.
///
/// `p` is a percentile **rank on the 0..=100 scale** (`50.0` is the
/// median) — not the `0..=1` *fraction* taken by the quantile family
/// ([`crate::serve::stats::LatencyHistogram::quantile`] and the
/// `quantile` knob of [`crate::serve::transport::HedgeConfig`]). A
/// fraction passed here silently reads as a sub-1st-percentile rank,
/// so debug builds assert the range.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty sample");
    debug_assert!(
        (0.0..=100.0).contains(&p),
        "percentile rank {p} is outside 0..=100 — \
         for a 0..=1 fraction use the quantile family instead"
    );
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Fixed-width histogram over [lo, hi) with `bins` buckets.
/// Out-of-range samples clamp into the edge buckets.
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    assert!(bins > 0 && hi > lo);
    let mut counts = vec![0usize; bins];
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        let mut b = ((x - lo) / w).floor() as isize;
        b = b.clamp(0, bins as isize - 1);
        counts[b as usize] += 1;
    }
    counts
}

/// Ordinary least squares y = a + b*x. Returns (a, b, r2).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let syy: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (a, b, r2)
}

/// Render a unicode sparkline of a series (for terminal figure output).
pub fn sparkline(xs: &[f64]) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    if xs.is_empty() {
        return String::new();
    }
    let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = if hi > lo { hi - lo } else { 1.0 };
    xs.iter()
        .map(|x| BARS[(((x - lo) / span) * 7.0).round() as usize])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.std - (1.25f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
    }

    #[test]
    fn histogram_counts_and_clamps() {
        let h = histogram(&[0.1, 0.2, 0.9, -5.0, 5.0], 0.0, 1.0, 2);
        assert_eq!(h, vec![3, 2]);
        assert_eq!(h.iter().sum::<usize>(), 5);
    }

    #[test]
    fn linear_fit_exact_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sparkline_len() {
        assert_eq!(sparkline(&[1.0, 2.0, 3.0]).chars().count(), 3);
    }
}

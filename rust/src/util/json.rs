//! Minimal JSON *writer* for metrics/experiment dumps (no serde offline).
//! Only what the repo needs: objects, arrays, numbers, strings, bools.

use std::fmt::Write as _;

/// A JSON value tree built imperatively.
#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut fields) = self {
            fields.push((key.to_string(), val.into()));
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null"); // JSON has no NaN/inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json> + Clone> From<&[T]> for Json {
    fn from(xs: &[T]) -> Json {
        Json::Arr(xs.iter().cloned().map(Into::into).collect())
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested_object() {
        let j = Json::obj()
            .set("name", "fig4")
            .set("epochs", 30usize)
            .set("loss", vec![2.3f64, 1.1, 0.6])
            .set("pruned", true);
        assert_eq!(
            j.render(),
            r#"{"name":"fig4","epochs":30,"loss":[2.3,1.1,0.6],"pruned":true}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }
}

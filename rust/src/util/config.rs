//! Config-file parser: a pragmatic TOML subset (the offline image has no
//! `serde`/`toml`). Supports `[section]` headers, `key = value` pairs with
//! string/bool/int/float/array values, `#` comments, and dotted lookup
//! (`section.key`). Used by the experiment launcher so sweeps live in
//! checked-in config files rather than code.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Bool(bool),
    Int(i64),
    Float(f64),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "{s:?}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Array(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// A parsed config: flat map from "section.key" (or bare "key") to value.
#[derive(Clone, Debug, Default)]
pub struct Config {
    entries: BTreeMap<String, Value>,
}

#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    #[error("line {0}: {1}")]
    Parse(usize, String),
    #[error("missing key {0:?}")]
    Missing(String),
    #[error("key {0:?} has wrong type (found {1})")]
    Type(String, String),
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

fn parse_scalar(raw: &str, line_no: usize) -> Result<Value, ConfigError> {
    let t = raw.trim();
    if t.starts_with('"') && t.ends_with('"') && t.len() >= 2 {
        return Ok(Value::Str(t[1..t.len() - 1].to_string()));
    }
    if t == "true" {
        return Ok(Value::Bool(true));
    }
    if t == "false" {
        return Ok(Value::Bool(false));
    }
    if let Ok(i) = t.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = t.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    // bare strings allowed for ergonomics (mode = hpn)
    if !t.is_empty() && !t.contains(['[', ']', '=']) {
        return Ok(Value::Str(t.to_string()));
    }
    Err(ConfigError::Parse(line_no, format!("cannot parse value {t:?}")))
}

fn parse_value(raw: &str, line_no: usize) -> Result<Value, ConfigError> {
    let t = raw.trim();
    if t.starts_with('[') {
        if !t.ends_with(']') {
            return Err(ConfigError::Parse(line_no, "unterminated array".into()));
        }
        let inner = &t[1..t.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                items.push(parse_scalar(part, line_no)?);
            }
        }
        return Ok(Value::Array(items));
    }
    parse_scalar(t, line_no)
}

impl Config {
    pub fn parse(text: &str) -> Result<Self, ConfigError> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (i, raw_line) in text.lines().enumerate() {
            let line_no = i + 1;
            let line = match raw_line.find('#') {
                // a '#' inside quotes would be nice to keep, but config
                // strings here never contain '#'
                Some(pos) => &raw_line[..pos],
                None => raw_line,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(ConfigError::Parse(line_no, "bad section header".into()));
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| ConfigError::Parse(line_no, format!("expected key = value, got {line:?}")))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            cfg.entries.insert(key, parse_value(v, line_no)?);
        }
        Ok(cfg)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self, ConfigError> {
        Config::parse(&std::fs::read_to_string(path)?)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(Value::as_str)
            .unwrap_or(default)
            .to_string()
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn int_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_int).unwrap_or(default)
    }

    pub fn float_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_float).unwrap_or(default)
    }

    pub fn require_float(&self, key: &str) -> Result<f64, ConfigError> {
        self.get(key)
            .ok_or_else(|| ConfigError::Missing(key.into()))?
            .as_float()
            .ok_or_else(|| ConfigError::Type(key.into(), "non-float".into()))
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }

    /// Merge another config over this one (other wins).
    pub fn merge(&mut self, other: Config) {
        self.entries.extend(other.entries);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
seed = 42
[chip]
rows = 512
cols = 32
vform_mean = 1.89      # volts
levels = [2, 4, 8, 16]
name = "block-one"
mode = hpn
digital = true
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.int_or("seed", 0), 42);
        assert_eq!(c.int_or("chip.rows", 0), 512);
        assert!((c.float_or("chip.vform_mean", 0.0) - 1.89).abs() < 1e-12);
        assert_eq!(c.str_or("chip.name", ""), "block-one");
        assert_eq!(c.str_or("chip.mode", ""), "hpn");
        assert!(c.bool_or("chip.digital", false));
        let levels = c.get("chip.levels").unwrap().as_array().unwrap();
        assert_eq!(levels.len(), 4);
        assert_eq!(levels[3].as_int(), Some(16));
    }

    #[test]
    fn int_promotes_to_float() {
        let c = Config::parse("x = 3").unwrap();
        assert_eq!(c.float_or("x", 0.0), 3.0);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Config::parse("nonsense without equals").is_err());
        assert!(Config::parse("[unclosed\nx=1").is_err());
        assert!(Config::parse("a = [1, 2").is_err());
    }

    #[test]
    fn merge_overrides() {
        let mut a = Config::parse("x = 1\ny = 2").unwrap();
        let b = Config::parse("y = 3").unwrap();
        a.merge(b);
        assert_eq!(a.int_or("x", 0), 1);
        assert_eq!(a.int_or("y", 0), 3);
    }

    #[test]
    fn missing_key_errors() {
        let c = Config::parse("x = 1").unwrap();
        assert!(c.require_float("nope").is_err());
        assert!(c.require_float("x").is_ok());
    }
}

//! Tiny env-filtered logger backing the `log` facade (no tracing offline).
//! Level comes from `RRAM_LOG` (error|warn|info|debug|trace), default info.

use log::{Level, LevelFilter, Log, Metadata, Record};
use std::io::Write;
use std::time::Instant;

struct Logger {
    start: Instant,
}

impl Log for Logger {
    fn enabled(&self, _: &Metadata) -> bool {
        true
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let mut err = std::io::stderr().lock();
        let _ = writeln!(err, "[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Install the logger once; subsequent calls are no-ops.
pub fn init() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let level = match std::env::var("RRAM_LOG").as_deref() {
            Ok("error") => LevelFilter::Error,
            Ok("warn") => LevelFilter::Warn,
            Ok("debug") => LevelFilter::Debug,
            Ok("trace") => LevelFilter::Trace,
            _ => LevelFilter::Info,
        };
        let logger = Box::leak(Box::new(Logger { start: Instant::now() }));
        let _ = log::set_logger(logger);
        log::set_max_level(level);
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke test");
    }
}

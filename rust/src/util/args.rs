//! Minimal CLI argument parser (the offline image has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! and subcommands; generates usage text from registered options.

use std::collections::HashMap;

/// Declarative option spec for usage text.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_flag: bool,
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    opts: HashMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    specs: Vec<OptSpec>,
}

impl Args {
    /// Parse a raw token list (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Self, String> {
        let mut a = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if rest.is_empty() {
                    // `--` terminates option parsing
                    a.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = rest.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    a.opts.insert(rest.to_string(), v);
                } else {
                    a.flags.push(rest.to_string());
                }
            } else {
                a.positional.push(tok);
            }
        }
        Ok(a)
    }

    /// Parse the process arguments after the given number of leading
    /// tokens (1 = skip argv[0], 2 = skip argv[0] + subcommand).
    pub fn from_env(skip: usize) -> Result<Self, String> {
        Args::parse(std::env::args().skip(skip))
    }

    pub fn describe(&mut self, specs: Vec<OptSpec>) -> &mut Self {
        self.specs = specs;
        self
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || self
                .opts
                .get(name)
                .map(|v| v == "true" || v == "1")
                .unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: cannot parse {v:?}")),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// All parsed option keys (for unknown-option validation).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.opts.keys().map(|s| s.as_str()).chain(self.flags.iter().map(|s| s.as_str()))
    }

    /// Error if any provided option is not in `allowed`.
    pub fn reject_unknown(&self, allowed: &[&str]) -> Result<(), String> {
        for k in self.keys() {
            if !allowed.contains(&k) {
                return Err(format!(
                    "unknown option --{k}; allowed: {}",
                    allowed.iter().map(|a| format!("--{a}")).collect::<Vec<_>>().join(", ")
                ));
            }
        }
        Ok(())
    }

    pub fn usage(&self, prog: &str) -> String {
        let mut s = format!("usage: {prog} [options]\n");
        for spec in &self.specs {
            let kind = if spec.is_flag { "" } else { " <value>" };
            let def = spec
                .default
                .as_ref()
                .map(|d| format!(" (default: {d})"))
                .unwrap_or_default();
            s.push_str(&format!("  --{}{kind}\t{}{def}\n", spec.name, spec.help));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_key_value_and_flags() {
        // NOTE: a bare `--flag` followed by a non-option token would
        // consume it as a value; flags therefore come last or use
        // `--flag=true`. Subcommands are parsed before options anyway.
        let a = Args::parse(toks("run --epochs 30 --seed=42 --verbose")).unwrap();
        assert_eq!(a.get("epochs"), Some("30"));
        assert_eq!(a.get("seed"), Some("42"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["run".to_string()]);
    }

    #[test]
    fn parse_or_with_defaults() {
        let a = Args::parse(toks("--lr 0.05")).unwrap();
        assert_eq!(a.parse_or("lr", 0.1f64).unwrap(), 0.05);
        assert_eq!(a.parse_or("epochs", 30usize).unwrap(), 30);
        assert!(a.parse_or::<usize>("lr", 1).is_err());
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = Args::parse(toks("--x 1 -- --not-an-option")).unwrap();
        assert_eq!(a.get("x"), Some("1"));
        assert_eq!(a.positional(), &["--not-an-option".to_string()]);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = Args::parse(toks("--fast")).unwrap();
        assert!(a.flag("fast"));
        assert!(!a.flag("slow"));
    }

    #[test]
    fn reject_unknown_options() {
        let a = Args::parse(toks("--good 1 --bad 2")).unwrap();
        assert!(a.reject_unknown(&["good"]).is_err());
        assert!(a.reject_unknown(&["good", "bad"]).is_ok());
    }
}

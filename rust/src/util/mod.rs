//! Small self-contained substrates this repo ships in place of the crates
//! that are unavailable in the offline image (clap/serde/rand/tracing):
//! a deterministic PRNG, a CLI argument parser, a config-file parser, a
//! statistics toolkit, a tiny JSON writer, and an env-filtered logger.

pub mod args;
pub mod config;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod sync;

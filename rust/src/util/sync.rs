//! Poison-tolerant lock helpers — the only sanctioned way to acquire a
//! `Mutex` or wait on a `Condvar` in serve code (enforced by clippy's
//! `disallowed_methods` and by `cargo xtask lint`'s lock-order pass,
//! which recognizes `lock_unpoisoned` call sites).
//!
//! # Poisoning policy
//!
//! A poisoned mutex means some thread panicked while holding the guard.
//! Every shared structure in the serve plane is either (a) a
//! monotonically-updated observability buffer (trace rings, metric
//! series, event subscriber lists) where a half-applied update is
//! benign, or (b) a state machine (admission ledger, shard caches)
//! whose invariants are re-validated by the next operation. In both
//! cases continuing with the inner value is strictly better than
//! cascading the panic into every thread that touches the lock — the
//! serve loop's unit of failure is the *request*, not the process.
//! Code that genuinely cannot tolerate a torn update must not use these
//! helpers; it should hold no lock across fallible work instead.

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError, WaitTimeoutResult};
use std::time::Duration;

/// Acquire `m`, recovering the guard if a previous holder panicked.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Block on `cv`, recovering the re-acquired guard across poisoning.
pub fn wait_unpoisoned<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(PoisonError::into_inner)
}

/// Block on `cv` with a timeout, recovering the guard across poisoning.
pub fn wait_timeout_unpoisoned<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    cv.wait_timeout(guard, dur).unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Condvar, Mutex};

    #[test]
    fn lock_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*lock_unpoisoned(&m), 7);
        *lock_unpoisoned(&m) = 8;
        assert_eq!(*lock_unpoisoned(&m), 8);
    }

    #[test]
    fn wait_timeout_passes_through() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let g = lock_unpoisoned(&pair.0);
        let (g, to) = wait_timeout_unpoisoned(&pair.1, g, Duration::from_millis(5));
        assert!(to.timed_out());
        assert!(!*g);
    }
}

//! Evaluation metrics: confusion matrices, accuracy, operation counting,
//! and the cross-architecture energy report rows (Figs. 4h/4m, 5f/5i).

use crate::baselines::{self, gpu, Workload};

/// Normalized confusion matrix over `n` classes.
#[derive(Clone, Debug)]
pub struct ConfusionMatrix {
    pub n: usize,
    counts: Vec<u64>,
}

impl ConfusionMatrix {
    pub fn new(n: usize) -> Self {
        ConfusionMatrix { n, counts: vec![0; n * n] }
    }

    pub fn record(&mut self, truth: usize, pred: usize) {
        self.counts[truth * self.n + pred] += 1;
    }

    pub fn count(&self, truth: usize, pred: usize) -> u64 {
        self.counts[truth * self.n + pred]
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn accuracy(&self) -> f64 {
        let correct: u64 = (0..self.n).map(|i| self.count(i, i)).sum();
        correct as f64 / self.total().max(1) as f64
    }

    /// Row-normalized matrix (Fig. 4h / 5f rendering).
    pub fn normalized(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.n * self.n];
        for t in 0..self.n {
            let row: u64 = (0..self.n).map(|p| self.count(t, p)).sum();
            if row > 0 {
                for p in 0..self.n {
                    out[t * self.n + p] = self.count(t, p) as f64 / row as f64;
                }
            }
        }
        out
    }

    /// Terminal rendering with shaded cells.
    pub fn render(&self) -> String {
        let norm = self.normalized();
        let mut s = String::new();
        for t in 0..self.n {
            for p in 0..self.n {
                let v = norm[t * self.n + p];
                let ch = match (v * 4.0) as usize {
                    0 => "  ",
                    1 => "░░",
                    2 => "▒▒",
                    3 => "▓▓",
                    _ => "██",
                };
                s.push_str(ch);
            }
            s.push('\n');
        }
        s
    }
}

/// Per-layer MAC meter for conv stacks under pruning masks.
#[derive(Clone, Debug, Default)]
pub struct OpsCounter {
    /// (layer name, macs) rows
    pub layers: Vec<(String, u64)>,
}

impl OpsCounter {
    pub fn add(&mut self, name: &str, macs: u64) {
        self.layers.push((name.to_string(), macs));
    }

    pub fn total(&self) -> u64 {
        self.layers.iter().map(|&(_, m)| m).sum()
    }
}

/// One row of the energy comparison (Fig. 4m right / Fig. 5i right).
#[derive(Clone, Debug)]
pub struct EnergyRow {
    pub platform: String,
    pub energy_uj: f64,
}

/// Build the three-platform comparison for a conv workload.
/// `binary_weights` selects the MNIST (binary) vs PointNet (INT8) cell
/// mapping; `gpu_class` the 4090 utilization class.
pub fn energy_comparison(
    macs_unpruned: u64,
    macs_pruned: u64,
    binary_weights: bool,
    gpu_class: gpu::GpuWorkloadClass,
    parallelism: usize,
) -> Vec<EnergyRow> {
    let wl = |macs| {
        if binary_weights {
            Workload::from_binary_macs(macs, parallelism)
        } else {
            Workload::from_macs(macs, parallelism)
        }
    };
    vec![
        EnergyRow {
            platform: "RTX 4090 (180nm-normalized)".into(),
            energy_uj: gpu::energy_pj(macs_unpruned, gpu_class) * 1e-6,
        },
        EnergyRow {
            platform: "digital RRAM (unpruned)".into(),
            energy_uj: baselines::digital_rram_energy_pj(&wl(macs_unpruned)) * 1e-6,
        },
        EnergyRow {
            platform: "digital RRAM (pruned)".into(),
            energy_uj: baselines::digital_rram_energy_pj(&wl(macs_pruned)) * 1e-6,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn confusion_accuracy() {
        let mut c = ConfusionMatrix::new(3);
        c.record(0, 0);
        c.record(1, 1);
        c.record(2, 0);
        c.record(2, 2);
        assert!((c.accuracy() - 0.75).abs() < 1e-12);
        let norm = c.normalized();
        assert!((norm[2 * 3] - 0.5).abs() < 1e-12);
        assert_eq!(c.total(), 4);
    }

    #[test]
    fn render_has_rows() {
        let mut c = ConfusionMatrix::new(2);
        c.record(0, 0);
        c.record(1, 1);
        assert_eq!(c.render().lines().count(), 2);
    }

    #[test]
    fn ops_counter_sums() {
        let mut o = OpsCounter::default();
        o.add("conv1", 100);
        o.add("conv2", 200);
        assert_eq!(o.total(), 300);
    }

    #[test]
    fn energy_rows_ordering() {
        let rows = energy_comparison(1_000_000, 700_000, true, gpu::GpuWorkloadClass::SmallCnn, 32);
        assert_eq!(rows.len(), 3);
        // pruned RRAM must be the cheapest; GPU the most expensive
        assert!(rows[2].energy_uj < rows[1].energy_uj);
        assert!(rows[1].energy_uj < rows[0].energy_uj);
        // headline shape: pruned RRAM well below the 4090
        let reduction = 1.0 - rows[2].energy_uj / rows[0].energy_uj;
        assert!(reduction > 0.6, "reduction {reduction}");
    }
}

//! PointNet training coordinator (paper Fig. 5): point-cloud
//! classification with dynamic 1x1-convolution-filter pruning and the
//! INT8 / four-2-bit-cell chip mapping.

use std::time::Instant;

use anyhow::Result;

use crate::chip::{Chip, ChipConfig, ReadPath};
use crate::cim::mapping::{store_int8, RowAllocator};
use crate::cim::similarity as chip_sim;
use crate::cim::vmm;
use crate::metrics::ConfusionMatrix;
use crate::nn::data::{modelnet, Dataset};
use crate::nn::pointnet::{group_cloud, Grouped, GroupingConfig};
use crate::nn::quant;
use crate::pruning::similarity::PackedKernels;
use crate::pruning::{PruneConfig, PruningScheduler};
use crate::runtime::{Engine, HostTensor};
use crate::util::rng::Rng;

use super::experiment::{EpochRecord, TrainingReport};
use super::params::{Param, ParamSet};
use super::TrainMode;

pub const TRAIN_BATCH: usize = 8;
pub const EVAL_BATCH: usize = 32;

/// (fan_in, fan_out) per layer — must mirror model.PN_LAYER_DIMS.
pub const LAYER_DIMS: [(usize, usize); 10] = [
    (3, 32),
    (32, 32),
    (32, 64),
    (67, 64),
    (64, 64),
    (64, 128),
    (131, 128),
    (128, 256),
    (256, 128),
    (128, 10),
];
pub const MASKED_LAYERS: usize = 8;

#[derive(Clone, Debug)]
pub struct PointNetConfig {
    pub epochs: usize,
    pub train_samples: usize,
    pub test_samples: usize,
    pub lr: f32,
    pub seed: u64,
    pub mode: TrainMode,
    pub prune: PruneConfig,
    pub use_pallas: bool,
    pub grouping: GroupingConfig,
    /// HPN: INT8 dots sampled per layer per epoch (Fig. 5h).
    pub hpn_check_macs: usize,
}

impl Default for PointNetConfig {
    fn default() -> Self {
        PointNetConfig {
            epochs: 12,
            train_samples: 320, // 40 steps/epoch at batch 8
            test_samples: 96,
            lr: 0.05,
            seed: 7,
            mode: TrainMode::Spn,
            prune: PruneConfig {
                sim_threshold: 0.68,
                max_prune_rate: 0.60,
                min_live_per_layer: 4,
                warmup_epochs: 2,
                prune_interval: 2,
                ..PruneConfig::default()
            },
            use_pallas: false,
            grouping: GroupingConfig::default(),
            hpn_check_macs: 32,
        }
    }
}

/// Pre-grouped dataset: clouds + grouping tensors + labels.
struct GroupedSet {
    groups: Vec<Grouped>,
    labels: Vec<i32>,
}

impl GroupedSet {
    fn build(ds: &Dataset, g: &GroupingConfig) -> Self {
        let groups = (0..ds.len()).map(|i| group_cloud(ds.sample(i), g)).collect();
        GroupedSet { groups, labels: ds.labels.clone() }
    }

    fn len(&self) -> usize {
        self.labels.len()
    }
}

pub struct PointNetTrainer {
    cfg: PointNetConfig,
    engine: Engine,
    params: ParamSet,
    sched: PruningScheduler,
    train_set: GroupedSet,
    test_set: GroupedSet,
    rng: Rng,
    sim_chip: Option<Chip>,
    ber_chip: Option<Chip>,
    artifact_ms: f64,
    chip_ms: f64,
}

impl PointNetTrainer {
    pub fn new(cfg: PointNetConfig, engine: Engine) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let params = init_params(&mut rng.fork(1));
        let sched = PruningScheduler::new(
            cfg.prune.clone(),
            &LAYER_DIMS[..MASKED_LAYERS]
                .iter()
                .map(|&(fi, fo)| (fo, fi))
                .collect::<Vec<_>>(),
        );
        let train_raw = modelnet::generate(cfg.train_samples, cfg.seed ^ 0x706e);
        let test_raw = modelnet::generate(cfg.test_samples, cfg.seed ^ 0x7465);
        let train_set = GroupedSet::build(&train_raw, &cfg.grouping);
        let test_set = GroupedSet::build(&test_raw, &cfg.grouping);
        let (sim_chip, ber_chip) = if cfg.mode == TrainMode::Hpn {
            let mut chip_rng = rng.fork(2);
            let mut sim = Chip::new(ChipConfig::default(), &mut chip_rng);
            let mut ber = Chip::new(
                ChipConfig { read_path: ReadPath::Electrical, ..ChipConfig::default() },
                &mut chip_rng,
            );
            sim.form();
            ber.form();
            (Some(sim), Some(ber))
        } else {
            (None, None)
        };
        PointNetTrainer {
            cfg,
            engine,
            params,
            sched,
            train_set,
            test_set,
            rng,
            sim_chip,
            ber_chip,
            artifact_ms: 0.0,
            chip_ms: 0.0,
        }
    }

    pub fn scheduler(&self) -> &PruningScheduler {
        &self.sched
    }

    /// Export the current (trained, pruned) parameters as a servable
    /// bundle for the [`crate::serve`] subsystem: per-channel
    /// INT8-quantized pointwise kernels (`w0..w7`, 4 RRAM cells per
    /// weight) with the scheduler's live masks, plus the `w8`/`w9` host
    /// head — parity with `MnistTrainer::export_bundle`.
    pub fn export_bundle(&self) -> crate::serve::ModelBundle {
        crate::serve::PointNetBundle::from_params(
            &self.params,
            &self.sched.live_masks(),
            &self.cfg.grouping,
        )
        .into()
    }

    fn train_artifact(&self) -> &'static str {
        if self.cfg.use_pallas { "pointnet_train" } else { "pointnet_train_fast" }
    }

    fn eval_artifact(&self) -> &'static str {
        if self.cfg.use_pallas { "pointnet_eval" } else { "pointnet_eval_fast" }
    }

    fn masks(&self) -> Vec<HostTensor> {
        (0..MASKED_LAYERS)
            .map(|l| HostTensor::F32(self.sched.mask_f32(l), vec![LAYER_DIMS[l].1]))
            .collect()
    }

    /// Pack a batch of grouped samples into the artifact input tensors.
    fn batch_tensors(&self, set: &GroupedSet, idx: &[usize], b: usize) -> Vec<HostTensor> {
        let g = &self.cfg.grouping;
        let mut g1 = Vec::with_capacity(b * g.s1 * g.k1 * 3);
        let mut g2i = Vec::with_capacity(b * g.s2 * g.k2);
        let mut g2x = Vec::with_capacity(b * g.s2 * g.k2 * 3);
        let mut c2 = Vec::with_capacity(b * g.s2 * 3);
        for bi in 0..b {
            // pad short batches by repeating the first sample
            let gi = &set.groups[*idx.get(bi).unwrap_or(&idx[0])];
            g1.extend_from_slice(&gi.g1_xyz);
            g2i.extend_from_slice(&gi.g2_idx);
            g2x.extend_from_slice(&gi.g2_xyz);
            c2.extend_from_slice(&gi.c2_xyz);
        }
        vec![
            HostTensor::F32(g1, vec![b, g.s1, g.k1, 3]),
            HostTensor::I32(g2i, vec![b, g.s2, g.k2]),
            HostTensor::F32(g2x, vec![b, g.s2, g.k2, 3]),
            HostTensor::F32(c2, vec![b, g.s2, 3]),
        ]
    }

    fn train_step(&mut self, idx: &[usize]) -> Result<(f64, usize)> {
        let mut inputs = self.params.to_host();
        inputs.extend(self.masks());
        inputs.extend(self.batch_tensors(&self.train_set, idx, TRAIN_BATCH));
        let ys: Vec<i32> = idx.iter().map(|&i| self.train_set.labels[i]).collect();
        inputs.push(HostTensor::I32(ys, vec![TRAIN_BATCH]));
        inputs.push(HostTensor::scalar_f32(self.cfg.lr));
        let t0 = Instant::now();
        let name = self.train_artifact();
        let outs = self.engine.run(name, &inputs)?;
        self.artifact_ms += t0.elapsed().as_secs_f64() * 1e3;
        self.params.update_from(&outs[..20]);
        let loss = outs[20].expect_f32("loss")[0] as f64;
        let correct = outs[21].expect_i32("correct")[0] as usize;
        Ok((loss, correct))
    }

    pub fn evaluate(&mut self) -> Result<(f64, ConfusionMatrix)> {
        let mut confusion = ConfusionMatrix::new(10);
        let n = self.test_set.len();
        let mut i = 0;
        while i < n {
            let count = EVAL_BATCH.min(n - i);
            let idx: Vec<usize> = (i..i + count).collect();
            let mut inputs = self.params.to_host();
            inputs.extend(self.masks());
            inputs.extend(self.batch_tensors(&self.test_set, &idx, EVAL_BATCH));
            let t0 = Instant::now();
            let name = self.eval_artifact();
            let outs = self.engine.run(name, &inputs)?;
            self.artifact_ms += t0.elapsed().as_secs_f64() * 1e3;
            let logits = outs[0].expect_f32("logits");
            for (b, &gi) in idx.iter().enumerate() {
                let row = &logits[b * 10..(b + 1) * 10];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                confusion.record(self.test_set.labels[gi] as usize, pred);
            }
            i += count;
        }
        Ok((confusion.accuracy(), confusion))
    }

    /// Global 256-d features for the t-SNE panels (Fig. 5d/e).
    pub fn features(&mut self) -> Result<(Vec<f32>, Vec<i32>)> {
        let n = EVAL_BATCH.min(self.test_set.len());
        let idx: Vec<usize> = (0..n).collect();
        let mut inputs = self.params.to_host();
        inputs.extend(self.masks());
        inputs.extend(self.batch_tensors(&self.test_set, &idx, EVAL_BATCH));
        let outs = self.engine.run("pointnet_features", &inputs)?;
        let feats = outs[0].expect_f32("features")[..n * 256].to_vec();
        Ok((feats, self.test_set.labels[..n].to_vec()))
    }

    fn layer_name(l: usize) -> String {
        format!("w{l}")
    }

    fn similarity_matrices(&mut self) -> Vec<crate::cim::similarity::SimilarityMatrix> {
        let mut out = Vec::new();
        for layer in 0..MASKED_LAYERS {
            let kernels = self.params.kernels_of(&Self::layer_name(layer));
            let live: Vec<bool> = self.sched.live_mask(layer).to_vec();
            let t0 = Instant::now();
            let m = match (&mut self.sim_chip, self.cfg.mode) {
                (Some(chip), TrainMode::Hpn) => {
                    // Paper: "Due to hardware constraints, only a subset
                    // of convolutional layers is deployed on-chip." A
                    // layer whose kernels exceed the two 512x32 blocks is
                    // evaluated in software (bit-exact with the chip).
                    let mut alloc = RowAllocator::for_chip(chip);
                    let per_row = alloc.data_cols;
                    let rows_needed: usize = kernels
                        .iter()
                        .map(|k| k.len().div_ceil(per_row))
                        .sum();
                    if rows_needed <= alloc.capacity_rows() {
                        let stored = chip_sim::store_kernels(chip, &mut alloc, &kernels);
                        chip_sim::similarity_matrix(chip, &stored, &live)
                    } else {
                        log::debug!("layer {layer}: {rows_needed} rows exceed chip; software path");
                        PackedKernels::from_kernels(&kernels).similarity_matrix(&live)
                    }
                }
                _ => PackedKernels::from_kernels(&kernels).similarity_matrix(&live),
            };
            self.chip_ms += t0.elapsed().as_secs_f64() * 1e3;
            out.push(m);
        }
        out
    }

    /// INT8 chip-in-the-loop precision per layer (Fig. 5h): store the
    /// quantized filter on the electrical chip (4 cells per weight) and
    /// compare `int8_dot` against the exact integer reference.
    fn mac_precision(&mut self) -> Vec<f64> {
        let Some(chip) = self.ber_chip.as_mut() else {
            return Vec::new();
        };
        let t0 = Instant::now();
        let mut rng = self.rng.fork(0x1b7);
        let mut precisions = Vec::new();
        for layer in 0..3 {
            // the paper deploys a subset of conv layers on-chip
            let kernels = self.params.kernels_of(&Self::layer_name(layer));
            let mut alloc = RowAllocator::for_chip(chip);
            let mut ok = 0;
            let mut total = 0;
            for _ in 0..self.cfg.hpn_check_macs {
                let k_idx = rng.below(kernels.len());
                if !self.sched.live_mask(layer)[k_idx] {
                    continue;
                }
                let (wq, _scale) = quant::quantize_channel_int8(&kernels[k_idx]);
                // input vector: geometry-derived for layer 0, random
                // activation-like int8 for deeper layers
                let x: Vec<i8> = if layer == 0 {
                    let g = &self.train_set.groups[rng.below(self.train_set.len())];
                    let (q, _) = quant::quantize_activations_i8(&g.g1_xyz[..wq.len().min(g.g1_xyz.len())]);
                    let mut v = q;
                    while v.len() < wq.len() {
                        v.push(0);
                    }
                    v
                } else {
                    (0..wq.len()).map(|_| (rng.below(200) as i16 - 100) as i8).collect()
                };
                let Some(span) = alloc.alloc(4 * wq.len()) else {
                    alloc.reset();
                    continue;
                };
                if store_int8(chip, &span, &wq) > 0 {
                    continue;
                }
                let got = vmm::int8_dot(chip, &span, &x);
                let want = vmm::int8_dot_ref(&wq, &x);
                total += 1;
                if got == want {
                    ok += 1;
                }
            }
            precisions.push(if total == 0 { 1.0 } else { ok as f64 / total as f64 });
        }
        self.chip_ms += t0.elapsed().as_secs_f64() * 1e3;
        precisions
    }

    fn epoch_train_macs(&self) -> u64 {
        let live: Vec<usize> = (0..MASKED_LAYERS).map(|l| self.sched.live_count(l)).collect();
        per_cloud_macs(&self.cfg.grouping, &live) * 3 * self.cfg.train_samples as u64
    }

    pub fn train(&mut self) -> Result<TrainingReport> {
        let steps = self.train_set.len() / TRAIN_BATCH;
        assert!(steps > 0, "train set smaller than one batch");
        let mut epochs = Vec::new();
        let mut confusion = ConfusionMatrix::new(10);
        for epoch in 0..self.cfg.epochs {
            let train_macs = self.epoch_train_macs();
            let mut order: Vec<usize> = (0..self.train_set.len()).collect();
            self.rng.shuffle(&mut order);
            let mut loss_sum = 0.0;
            let mut correct = 0usize;
            for s in 0..steps {
                let idx = &order[s * TRAIN_BATCH..(s + 1) * TRAIN_BATCH];
                let (loss, corr) = self.train_step(idx)?;
                loss_sum += loss;
                correct += corr;
            }
            if self.cfg.mode.prunes() && self.sched.is_prune_epoch(epoch) {
                let sims = self.similarity_matrices();
                let ev = self.sched.evaluate(epoch, &sims);
                if !ev.pruned.is_empty() {
                    log::info!(
                        "epoch {epoch}: pruned {} filters (rate {:.1}%)",
                        ev.pruned.len(),
                        100.0 * self.sched.prune_rate()
                    );
                }
            }
            let (test_acc, conf) = self.evaluate()?;
            confusion = conf;
            let mac_precision = if self.cfg.mode == TrainMode::Hpn && self.cfg.hpn_check_macs > 0 {
                self.mac_precision()
            } else {
                Vec::new()
            };
            let rec = EpochRecord {
                epoch,
                loss: loss_sum / steps as f64,
                train_acc: correct as f64 / (steps * TRAIN_BATCH) as f64,
                test_acc,
                live_kernels: self.sched.total_live(),
                live_weights: self.sched.total_live_weights(),
                train_macs,
                mac_precision,
            };
            log::info!(
                "[{}] epoch {epoch}: loss {:.4} train {:.3} test {:.3} live {}",
                self.cfg.mode.name(),
                rec.loss,
                rec.train_acc,
                rec.test_acc,
                rec.live_kernels
            );
            epochs.push(rec);
        }
        let live: Vec<usize> = (0..MASKED_LAYERS).map(|l| self.sched.live_count(l)).collect();
        let full: Vec<usize> = LAYER_DIMS[..MASKED_LAYERS].iter().map(|&(_, fo)| fo).collect();
        Ok(TrainingReport {
            mode: self.cfg.mode.name().into(),
            epochs,
            confusion,
            final_prune_rate: self.sched.prune_rate(),
            macs_pruned: per_cloud_macs(&self.cfg.grouping, &live),
            macs_unpruned: per_cloud_macs(&self.cfg.grouping, &full),
            artifact_ms: self.artifact_ms,
            chip_ms: self.chip_ms,
        })
    }
}

/// Per-cloud inference MACs of the pointwise-conv stack given live filter
/// counts (the 1x1-conv layers the paper's Fig. 5i meters).
pub fn per_cloud_macs(g: &GroupingConfig, live: &[usize]) -> u64 {
    assert_eq!(live.len(), MASKED_LAYERS);
    // effective input width per layer: geometry dims are never pruned;
    // feature dims shrink to the previous layer's live count
    let fi = [
        3,
        live[0],
        live[1],
        live[2] + 3,
        live[3],
        live[4],
        live[5] + 3,
        live[6],
    ];
    let points = [
        g.s1 * g.k1,
        g.s1 * g.k1,
        g.s1 * g.k1,
        g.s2 * g.k2,
        g.s2 * g.k2,
        g.s2 * g.k2,
        g.s2,
        g.s2,
    ];
    (0..MASKED_LAYERS)
        .map(|l| (points[l] * fi[l] * live[l]) as u64)
        .sum()
}

fn init_params(rng: &mut Rng) -> ParamSet {
    let mut p = ParamSet::default();
    for (l, &(fi, fo)) in LAYER_DIMS.iter().enumerate() {
        p.push(Param::he(&format!("w{l}"), vec![fi, fo], fi, rng));
        p.push(Param::zeros(&format!("b{l}"), vec![fo]));
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_ready() -> bool {
        cfg!(feature = "pjrt")
            && std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
                .join("artifacts/manifest.txt")
                .exists()
    }

    #[test]
    fn macs_shrink_with_pruning() {
        let g = GroupingConfig::default();
        let full: Vec<usize> = LAYER_DIMS[..MASKED_LAYERS].iter().map(|&(_, fo)| fo).collect();
        let half: Vec<usize> = full.iter().map(|&f| f / 2).collect();
        assert!(per_cloud_macs(&g, &half) < per_cloud_macs(&g, &full) / 2);
    }

    #[test]
    fn param_count_matches_artifact() {
        let mut rng = Rng::new(1);
        let p = init_params(&mut rng);
        assert_eq!(p.len(), 20);
        assert_eq!(p.get("w3").dims, vec![67, 64]);
        assert_eq!(p.get("w9").dims, vec![128, 10]);
    }

    #[test]
    fn init_params_export_as_servable_bundle() {
        // export parity does not need a trained engine: the bundle is a
        // pure function of params + masks + grouping
        let mut rng = Rng::new(5);
        let params = init_params(&mut rng);
        let live: Vec<Vec<bool>> = LAYER_DIMS[..MASKED_LAYERS]
            .iter()
            .map(|&(_, fo)| vec![true; fo])
            .collect();
        let grouping = GroupingConfig::default();
        let bundle =
            crate::serve::PointNetBundle::from_params(&params, &live, &grouping);
        bundle.validate().unwrap();
        assert_eq!(bundle.total_filters(), bundle.live_filters());
        assert_eq!(bundle.n_classes, 10);
        // per-channel quantization matches the HPN precision-check path
        let kernels = params.kernels_of("w0");
        let (q, s) = quant::quantize_channel_int8(&kernels[0]);
        assert_eq!(bundle.layers[0].w_q[0], q);
        assert_eq!(bundle.layers[0].w_scale[0], s);
        // masked export drops rows
        let mut masked = live.clone();
        for m in masked[7].iter_mut().take(128) {
            *m = false;
        }
        let pruned = crate::serve::PointNetBundle::from_params(&params, &masked, &grouping);
        assert!(pruned.rows_required(30) < bundle.rows_required(30));
        assert!(pruned.mac_ops_per_cloud() < bundle.mac_ops_per_cloud());
    }

    #[test]
    fn one_epoch_spn_smoke() {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let engine = Engine::open_default().unwrap();
        let cfg = PointNetConfig {
            epochs: 2,
            train_samples: 32,
            test_samples: 32,
            prune: PruneConfig { warmup_epochs: 1, prune_interval: 1, ..PruneConfig::default() },
            ..PointNetConfig::default()
        };
        let mut tr = PointNetTrainer::new(cfg, engine);
        let report = tr.train().unwrap();
        assert_eq!(report.epochs.len(), 2);
        assert!(report.epochs.iter().all(|e| e.loss.is_finite()));
    }
}

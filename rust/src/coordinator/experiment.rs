//! Experiment records: everything a bench/example needs to print a
//! paper panel, plus JSON dumps for EXPERIMENTS.md.

use crate::metrics::ConfusionMatrix;
use crate::util::json::Json;

/// Per-epoch snapshot.
#[derive(Clone, Debug)]
pub struct EpochRecord {
    pub epoch: usize,
    pub loss: f64,
    pub train_acc: f64,
    pub test_acc: f64,
    /// live kernels across prunable layers (Fig. 4i left axis)
    pub live_kernels: usize,
    /// live weights across prunable layers (Fig. 4i right axis)
    pub live_weights: usize,
    /// training conv MACs spent this epoch (Fig. 4m left)
    pub train_macs: u64,
    /// chip-in-the-loop MAC precision per layer (HPN; Fig. 4l / 5h)
    pub mac_precision: Vec<f64>,
}

/// Full training run record.
#[derive(Clone, Debug)]
pub struct TrainingReport {
    pub mode: String,
    pub epochs: Vec<EpochRecord>,
    pub confusion: ConfusionMatrix,
    pub final_prune_rate: f64,
    /// inference conv MACs of the final model vs the unpruned model
    pub macs_pruned: u64,
    pub macs_unpruned: u64,
    /// wall-clock spent in artifact execution vs chip sim (perf split)
    pub artifact_ms: f64,
    pub chip_ms: f64,
}

impl TrainingReport {
    pub fn final_test_acc(&self) -> f64 {
        self.epochs.last().map(|e| e.test_acc).unwrap_or(0.0)
    }

    pub fn total_train_macs(&self) -> u64 {
        self.epochs.iter().map(|e| e.train_macs).sum()
    }

    /// Fractional op reduction vs an unpruned run of the same length
    /// (Fig. 4m left / Fig. 5i left).
    pub fn train_ops_reduction(&self) -> f64 {
        let full: u64 = self.epochs.len() as u64 * self.epochs.first().map(|e| e.train_macs).unwrap_or(0);
        if full == 0 {
            return 0.0;
        }
        1.0 - self.total_train_macs() as f64 / full as f64
    }

    pub fn to_json(&self) -> Json {
        let loss: Vec<f64> = self.epochs.iter().map(|e| e.loss).collect();
        let test_acc: Vec<f64> = self.epochs.iter().map(|e| e.test_acc).collect();
        let live: Vec<usize> = self.epochs.iter().map(|e| e.live_kernels).collect();
        let weights: Vec<usize> = self.epochs.iter().map(|e| e.live_weights).collect();
        Json::obj()
            .set("mode", self.mode.as_str())
            .set("epochs", self.epochs.len())
            .set("loss", loss)
            .set("test_acc", test_acc)
            .set("live_kernels", live)
            .set("live_weights", weights)
            .set("final_test_acc", self.final_test_acc())
            .set("final_prune_rate", self.final_prune_rate)
            .set("train_ops_reduction", self.train_ops_reduction())
            .set("macs_pruned", self.macs_pruned)
            .set("macs_unpruned", self.macs_unpruned)
            .set("artifact_ms", self.artifact_ms)
            .set("chip_ms", self.chip_ms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(epoch: usize, macs: u64) -> EpochRecord {
        EpochRecord {
            epoch,
            loss: 1.0,
            train_acc: 0.5,
            test_acc: 0.6,
            live_kernels: 100,
            live_weights: 1000,
            train_macs: macs,
            mac_precision: vec![],
        }
    }

    #[test]
    fn ops_reduction_computed_vs_first_epoch() {
        let rep = TrainingReport {
            mode: "SPN".into(),
            epochs: vec![record(0, 100), record(1, 80), record(2, 60)],
            confusion: ConfusionMatrix::new(10),
            final_prune_rate: 0.4,
            macs_pruned: 60,
            macs_unpruned: 100,
            artifact_ms: 0.0,
            chip_ms: 0.0,
        };
        // full = 3 * 100; spent = 240 -> reduction 0.2
        assert!((rep.train_ops_reduction() - 0.2).abs() < 1e-12);
        assert_eq!(rep.total_train_macs(), 240);
    }

    #[test]
    fn json_renders() {
        let rep = TrainingReport {
            mode: "SUN".into(),
            epochs: vec![record(0, 10)],
            confusion: ConfusionMatrix::new(10),
            final_prune_rate: 0.0,
            macs_pruned: 10,
            macs_unpruned: 10,
            artifact_ms: 1.5,
            chip_ms: 0.0,
        };
        let s = rep.to_json().render();
        assert!(s.contains("\"mode\":\"SUN\""));
        assert!(s.contains("\"final_prune_rate\":0"));
    }
}

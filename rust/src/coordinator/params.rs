//! Host-side parameter store: the flat tensor lists whose order must
//! match the AOT artifacts' flattened signatures (documented in
//! `python/compile/model.py`). Initialization mirrors the Python He-init
//! so Rust-initialized weights behave like `model.mnist_init` /
//! `model.pointnet_init`.

use crate::runtime::HostTensor;
use crate::util::rng::Rng;

/// A named, shaped f32 parameter.
#[derive(Clone, Debug)]
pub struct Param {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Param {
    pub fn he(name: &str, dims: Vec<usize>, fan_in: usize, rng: &mut Rng) -> Self {
        let n: usize = dims.iter().product();
        let scale = (2.0 / fan_in as f64).sqrt();
        let data = (0..n).map(|_| (rng.normal() * scale) as f32).collect();
        Param { name: name.to_string(), dims, data }
    }

    pub fn zeros(name: &str, dims: Vec<usize>) -> Self {
        let n: usize = dims.iter().product();
        Param { name: name.to_string(), dims, data: vec![0.0; n] }
    }

    pub fn to_host(&self) -> HostTensor {
        HostTensor::F32(self.data.clone(), self.dims.clone())
    }
}

/// Parameter list with artifact-order packing / unpacking.
#[derive(Clone, Debug, Default)]
pub struct ParamSet {
    pub params: Vec<Param>,
}

impl ParamSet {
    pub fn push(&mut self, p: Param) {
        self.params.push(p);
    }

    pub fn len(&self) -> usize {
        self.params.len()
    }

    pub fn is_empty(&self) -> bool {
        self.params.is_empty()
    }

    pub fn get(&self, name: &str) -> &Param {
        self.params
            .iter()
            .find(|p| p.name == name)
            .unwrap_or_else(|| panic!("no param {name:?}"))
    }

    /// Pack all params as HostTensors in declaration order.
    pub fn to_host(&self) -> Vec<HostTensor> {
        self.params.iter().map(Param::to_host).collect()
    }

    /// Overwrite values from artifact outputs (same order, same shapes).
    pub fn update_from(&mut self, outs: &[HostTensor]) {
        assert!(outs.len() >= self.params.len(), "not enough outputs");
        for (p, o) in self.params.iter_mut().zip(outs) {
            let data = o.expect_f32(&p.name);
            assert_eq!(o.dims(), p.dims.as_slice(), "{}: shape drift", p.name);
            p.data.clear();
            p.data.extend_from_slice(data);
        }
    }

    /// Extract the kernels of a conv/linear layer as flat vectors for
    /// similarity analysis: for a 4-d (O,I,KH,KW) weight each output
    /// channel is one kernel; for a 2-d (I,O) weight each *column* is one.
    pub fn kernels_of(&self, name: &str) -> Vec<Vec<f32>> {
        let p = self.get(name);
        match p.dims.len() {
            4 => {
                let (o, rest) = (p.dims[0], p.dims[1] * p.dims[2] * p.dims[3]);
                (0..o).map(|i| p.data[i * rest..(i + 1) * rest].to_vec()).collect()
            }
            2 => {
                let (i_dim, o) = (p.dims[0], p.dims[1]);
                (0..o)
                    .map(|c| (0..i_dim).map(|r| p.data[r * o + c]).collect())
                    .collect()
            }
            _ => panic!("{name}: unsupported kernel rank {:?}", p.dims),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn he_init_statistics() {
        let mut rng = Rng::new(1);
        let p = Param::he("w", vec![64, 64], 64, &mut rng);
        let mean: f32 = p.data.iter().sum::<f32>() / p.data.len() as f32;
        let std: f32 = (p.data.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / p.data.len() as f32)
            .sqrt();
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((std - (2.0f32 / 64.0).sqrt()).abs() < 0.02, "std {std}");
    }

    #[test]
    fn update_from_replaces_data() {
        let mut set = ParamSet::default();
        set.push(Param::zeros("a", vec![2, 2]));
        let outs = vec![HostTensor::F32(vec![1., 2., 3., 4.], vec![2, 2])];
        set.update_from(&outs);
        assert_eq!(set.get("a").data, vec![1., 2., 3., 4.]);
    }

    #[test]
    fn kernels_of_conv_layout() {
        let mut set = ParamSet::default();
        set.push(Param {
            name: "w".into(),
            dims: vec![2, 1, 2, 2],
            data: (0..8).map(|i| i as f32).collect(),
        });
        let ks = set.kernels_of("w");
        assert_eq!(ks.len(), 2);
        assert_eq!(ks[0], vec![0., 1., 2., 3.]);
        assert_eq!(ks[1], vec![4., 5., 6., 7.]);
    }

    #[test]
    fn kernels_of_linear_columns() {
        let mut set = ParamSet::default();
        // (I=3, O=2) row-major: columns are kernels
        set.push(Param {
            name: "w".into(),
            dims: vec![3, 2],
            data: vec![1., 10., 2., 20., 3., 30.],
        });
        let ks = set.kernels_of("w");
        assert_eq!(ks, vec![vec![1., 2., 3.], vec![10., 20., 30.]]);
    }

    #[test]
    #[should_panic(expected = "shape drift")]
    fn update_shape_mismatch_panics() {
        let mut set = ParamSet::default();
        set.push(Param::zeros("a", vec![2]));
        set.update_from(&[HostTensor::F32(vec![0.0; 3], vec![3])]);
    }
}

//! MNIST CNN training coordinator (paper Fig. 4): drives the AOT
//! `mnist_train` / `mnist_eval` artifacts, the pruning scheduler, and —
//! in HPN mode — the chip simulator for search-in-memory similarity and
//! chip-in-the-loop MAC-precision checks.

use std::time::Instant;

use anyhow::Result;

use crate::chip::{Chip, ChipConfig, ReadPath};
use crate::cim::mapping::{store_bits, RowAllocator};
use crate::cim::similarity as chip_sim;
use crate::cim::vmm;
use crate::metrics::ConfusionMatrix;
use crate::nn::data::{mnist, Dataset};
use crate::nn::layers;
use crate::nn::quant;
use crate::nn::tensor::Tensor;
use crate::pruning::{PruneConfig, PruningScheduler};
use crate::pruning::similarity::PackedKernels;
use crate::runtime::{Engine, HostTensor};
use crate::util::rng::Rng;

use super::experiment::{EpochRecord, TrainingReport};
use super::params::{Param, ParamSet};
use super::TrainMode;

pub const TRAIN_BATCH: usize = 64;
pub const EVAL_BATCH: usize = 256;
const CHANNELS: [usize; 3] = [32, 64, 32];
const FC_IN: usize = 32 * 7 * 7;

/// Trainer configuration.
#[derive(Clone, Debug)]
pub struct MnistConfig {
    pub epochs: usize,
    pub train_samples: usize,
    pub test_samples: usize,
    pub lr: f32,
    pub seed: u64,
    pub mode: TrainMode,
    pub prune: PruneConfig,
    /// Use the Pallas-kernel artifact (`mnist_train`) instead of the fast
    /// jnp one (`mnist_train_fast`). Numerically equivalent; the Pallas
    /// path is the paper's kernel and ~100x slower under interpret mode.
    pub use_pallas: bool,
    /// HPN: MAC positions sampled per layer per epoch for the Fig. 4l
    /// precision panel (0 disables).
    pub hpn_check_macs: usize,
}

impl Default for MnistConfig {
    fn default() -> Self {
        MnistConfig {
            epochs: 10,
            train_samples: 1920, // 30 steps/epoch at batch 64
            test_samples: 512,
            lr: 0.05,
            seed: 42,
            mode: TrainMode::Spn,
            prune: PruneConfig {
                sim_threshold: 0.70,
                max_prune_rate: 0.35,
                min_live_per_layer: 6,
                ..PruneConfig::default()
            },
            use_pallas: false,
            hpn_check_macs: 64,
        }
    }
}

/// The trainer. Owns datasets, parameters, scheduler, and (HPN) chips.
pub struct MnistTrainer {
    cfg: MnistConfig,
    engine: Engine,
    params: ParamSet,
    sched: PruningScheduler,
    train_set: Dataset,
    test_set: Dataset,
    rng: Rng,
    /// HPN similarity chip (digital read path, fast).
    sim_chip: Option<Chip>,
    /// HPN precision chip (electrical read path: real sensing noise).
    ber_chip: Option<Chip>,
    artifact_ms: f64,
    chip_ms: f64,
}

impl MnistTrainer {
    pub fn new(cfg: MnistConfig, engine: Engine) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let params = init_params(&mut rng.fork(1));
        let sched = PruningScheduler::new(
            cfg.prune.clone(),
            &[
                (CHANNELS[0], 9),
                (CHANNELS[1], CHANNELS[0] * 9),
                (CHANNELS[2], CHANNELS[1] * 9),
            ],
        );
        let train_set = mnist::generate(cfg.train_samples, cfg.seed ^ 0x7261);
        let test_set = mnist::generate(cfg.test_samples, cfg.seed ^ 0x7465);
        let (sim_chip, ber_chip) = if cfg.mode == TrainMode::Hpn {
            let mut chip_rng = rng.fork(2);
            let mut sim = Chip::new(ChipConfig::default(), &mut chip_rng);
            let mut ber = Chip::new(
                ChipConfig { read_path: ReadPath::Electrical, ..ChipConfig::default() },
                &mut chip_rng,
            );
            sim.form();
            ber.form();
            (Some(sim), Some(ber))
        } else {
            (None, None)
        };
        MnistTrainer {
            cfg,
            engine,
            params,
            sched,
            train_set,
            test_set,
            rng,
            sim_chip,
            ber_chip,
            artifact_ms: 0.0,
            chip_ms: 0.0,
        }
    }

    pub fn params(&self) -> &ParamSet {
        &self.params
    }

    pub fn scheduler(&self) -> &PruningScheduler {
        &self.sched
    }

    pub fn test_set(&self) -> &Dataset {
        &self.test_set
    }

    /// Export the current (trained, pruned) parameters as a servable
    /// bundle for the [`crate::serve`] subsystem: binarized conv filters
    /// with their digital scales plus the live masks and FC head.
    pub fn export_bundle(&self) -> crate::serve::ModelBundle {
        crate::serve::ModelBundle::from_params(&self.params, &self.sched.live_masks())
    }

    fn train_artifact(&self) -> &'static str {
        if self.cfg.use_pallas { "mnist_train" } else { "mnist_train_fast" }
    }

    fn eval_artifact(&self) -> &'static str {
        if self.cfg.use_pallas { "mnist_eval" } else { "mnist_eval_fast" }
    }

    fn masks(&self) -> Vec<HostTensor> {
        (0..3)
            .map(|l| HostTensor::F32(self.sched.mask_f32(l), vec![CHANNELS[l]]))
            .collect()
    }

    /// Run one SGD step; returns (loss, n_correct).
    fn train_step(&mut self, xs: Vec<f32>, ys: Vec<i32>) -> Result<(f64, usize)> {
        let mut inputs = self.params.to_host();
        inputs.extend(self.masks());
        inputs.push(HostTensor::F32(xs, vec![TRAIN_BATCH, 1, 28, 28]));
        inputs.push(HostTensor::I32(ys, vec![TRAIN_BATCH]));
        inputs.push(HostTensor::scalar_f32(self.cfg.lr));
        let t0 = Instant::now();
        let name = self.train_artifact();
        let outs = self.engine.run(name, &inputs)?;
        self.artifact_ms += t0.elapsed().as_secs_f64() * 1e3;
        self.params.update_from(&outs[..8]);
        let loss = outs[8].expect_f32("loss")[0] as f64;
        let correct = outs[9].expect_i32("correct")[0] as usize;
        Ok((loss, correct))
    }

    /// Evaluate on the test set; returns (accuracy, confusion).
    pub fn evaluate(&mut self) -> Result<(f64, ConfusionMatrix)> {
        let mut confusion = ConfusionMatrix::new(10);
        let n = self.test_set.len();
        let mut i = 0;
        while i < n {
            // batch of EVAL_BATCH, wrapping the tail with zero-padding
            let mut xs = vec![0.0f32; EVAL_BATCH * 784];
            let mut count = 0;
            let mut ys = Vec::with_capacity(EVAL_BATCH);
            while count < EVAL_BATCH && i + count < n {
                let idx = i + count;
                xs[count * 784..(count + 1) * 784].copy_from_slice(self.test_set.sample(idx));
                ys.push(self.test_set.labels[idx]);
                count += 1;
            }
            let mut inputs = self.params.to_host();
            inputs.extend(self.masks());
            inputs.push(HostTensor::F32(xs, vec![EVAL_BATCH, 1, 28, 28]));
            let t0 = Instant::now();
            let name = self.eval_artifact();
            let outs = self.engine.run(name, &inputs)?;
            self.artifact_ms += t0.elapsed().as_secs_f64() * 1e3;
            let logits = outs[0].expect_f32("logits");
            for (b, &y) in ys.iter().enumerate() {
                let row = &logits[b * 10..(b + 1) * 10];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                confusion.record(y as usize, pred);
            }
            i += count;
        }
        Ok((confusion.accuracy(), confusion))
    }

    /// Final-layer features of the first test batch (t-SNE panels).
    pub fn features(&mut self) -> Result<(Vec<f32>, Vec<i32>)> {
        let n = EVAL_BATCH.min(self.test_set.len());
        let mut xs = vec![0.0f32; EVAL_BATCH * 784];
        for b in 0..n {
            xs[b * 784..(b + 1) * 784].copy_from_slice(self.test_set.sample(b));
        }
        let mut inputs = self.params.to_host();
        inputs.extend(self.masks());
        inputs.push(HostTensor::F32(xs, vec![EVAL_BATCH, 1, 28, 28]));
        let outs = self.engine.run("mnist_features", &inputs)?;
        let feats = outs[0].expect_f32("features")[..n * FC_IN].to_vec();
        Ok((feats, self.test_set.labels[..n].to_vec()))
    }

    /// Per-layer similarity matrices of the current kernels.
    fn similarity_matrices(&mut self) -> Vec<crate::cim::similarity::SimilarityMatrix> {
        let names = ["w1", "w2", "w3"];
        let mut out = Vec::new();
        for (layer, name) in names.iter().enumerate() {
            let kernels = self.params.kernels_of(name);
            let live: Vec<bool> = self.sched.live_mask(layer).to_vec();
            let t0 = Instant::now();
            let m = match (&mut self.sim_chip, self.cfg.mode) {
                (Some(chip), TrainMode::Hpn) => {
                    // search-in-memory: program kernel bits, XOR passes.
                    // Layers too large for the two blocks fall back to the
                    // bit-exact software path (paper: only a subset of
                    // layers is deployed on-chip).
                    let mut alloc = RowAllocator::for_chip(chip);
                    let per_row = alloc.data_cols;
                    let rows_needed: usize =
                        kernels.iter().map(|k| k.len().div_ceil(per_row)).sum();
                    if rows_needed <= alloc.capacity_rows() {
                        let stored = chip_sim::store_kernels(chip, &mut alloc, &kernels);
                        chip_sim::similarity_matrix(chip, &stored, &live)
                    } else {
                        PackedKernels::from_kernels(&kernels).similarity_matrix(&live)
                    }
                }
                _ => PackedKernels::from_kernels(&kernels).similarity_matrix(&live),
            };
            self.chip_ms += t0.elapsed().as_secs_f64() * 1e3;
            out.push(m);
        }
        out
    }

    /// Chip-in-the-loop MAC precision per conv layer (Fig. 4l): sample
    /// output positions, run the binary dot on the (noisy, electrical)
    /// chip, compare with the exact integer reference.
    fn mac_precision(&mut self) -> Vec<f64> {
        let Some(chip) = self.ber_chip.as_mut() else {
            return Vec::new();
        };
        let t0 = Instant::now();
        let samples = self.cfg.hpn_check_macs;
        let image = Tensor::new(vec![1, 1, 28, 28], self.test_set.sample(0).to_vec());
        // reference forward pass (binarized+scaled weights) to produce
        // each layer's input activations
        let acts = forward_activations(&self.params, &self.sched, &image);
        let names = ["w1", "w2", "w3"];
        let mut precisions = Vec::new();
        let mut rng = self.rng.fork(0xbe5);
        for (layer, name) in names.iter().enumerate() {
            let kernels = self.params.kernels_of(name);
            let input = &acts[layer]; // (1, C, H, W)
            let (c, h, w) = (input.shape()[1], input.shape()[2], input.shape()[3]);
            // u8-quantize the whole activation map once (per-layer scale)
            let (q, _scale) = quant::quantize_activations_u8(input.data());
            let mut alloc = RowAllocator::for_chip(chip);
            let mut ok = 0usize;
            let mut total = 0usize;
            for _ in 0..samples {
                let k_idx = rng.below(kernels.len());
                if !self.sched.live_mask(layer)[k_idx] {
                    continue;
                }
                let (bits, _alpha) = quant::binarize_kernel(&kernels[k_idx]);
                // random interior output position (stride 1, pad 1)
                let oy = 1 + rng.below(h.saturating_sub(2).max(1));
                let ox = 1 + rng.below(w.saturating_sub(2).max(1));
                // gather the 3x3xC window in kernel order (C-major, ky, kx)
                let mut window = Vec::with_capacity(c * 9);
                for cc in 0..c {
                    for ky in 0..3 {
                        for kx in 0..3 {
                            let iy = oy + ky - 1;
                            let ix = ox + kx - 1;
                            window.push(q[cc * h * w + iy * w + ix]);
                        }
                    }
                }
                let Some(span) = alloc.alloc(bits.len()) else {
                    alloc.reset();
                    continue;
                };
                if store_bits(chip, &span, &bits) > 0 {
                    continue; // unrecoverable cells: skip sample
                }
                let got = vmm::binary_dot_u8(chip, &span, &window);
                let want = layers::binary_mac_ref(&bits, &window);
                total += 1;
                if got == want {
                    ok += 1;
                }
            }
            precisions.push(if total == 0 { 1.0 } else { ok as f64 / total as f64 });
        }
        self.chip_ms += t0.elapsed().as_secs_f64() * 1e3;
        precisions
    }

    /// Conv MACs for one epoch of training (fwd + bwd ~ 3x fwd).
    fn epoch_train_macs(&self) -> u64 {
        per_image_conv_macs(&live_counts(&self.sched)) * 3 * self.cfg.train_samples as u64
    }

    /// Run the full training schedule.
    pub fn train(&mut self) -> Result<TrainingReport> {
        let steps = self.train_set.len() / TRAIN_BATCH;
        assert!(steps > 0, "train set smaller than one batch");
        let mut epochs = Vec::new();
        let mut confusion = ConfusionMatrix::new(10);
        for epoch in 0..self.cfg.epochs {
            let train_macs = self.epoch_train_macs();
            let mut order: Vec<usize> = (0..self.train_set.len()).collect();
            self.rng.shuffle(&mut order);
            let mut loss_sum = 0.0;
            let mut correct = 0usize;
            for s in 0..steps {
                let idx = &order[s * TRAIN_BATCH..(s + 1) * TRAIN_BATCH];
                let (xs, ys) = self.train_set.gather(idx);
                let (loss, corr) = self.train_step(xs, ys)?;
                loss_sum += loss;
                correct += corr;
            }
            // dynamic pruning between weight updates (paper Fig. 1a loop)
            if self.cfg.mode.prunes() && self.sched.is_prune_epoch(epoch) {
                let sims = self.similarity_matrices();
                let ev = self.sched.evaluate(epoch, &sims);
                if !ev.pruned.is_empty() {
                    log::info!(
                        "epoch {epoch}: pruned {} kernels (rate {:.1}%)",
                        ev.pruned.len(),
                        100.0 * self.sched.prune_rate()
                    );
                }
            }
            let (test_acc, conf) = self.evaluate()?;
            confusion = conf;
            let mac_precision = if self.cfg.mode == TrainMode::Hpn && self.cfg.hpn_check_macs > 0 {
                self.mac_precision()
            } else {
                Vec::new()
            };
            let rec = EpochRecord {
                epoch,
                loss: loss_sum / steps as f64,
                train_acc: correct as f64 / (steps * TRAIN_BATCH) as f64,
                test_acc,
                live_kernels: self.sched.total_live(),
                live_weights: self.sched.total_live_weights(),
                train_macs,
                mac_precision,
            };
            log::info!(
                "[{}] epoch {epoch}: loss {:.4} train {:.3} test {:.3} live {}",
                self.cfg.mode.name(),
                rec.loss,
                rec.train_acc,
                rec.test_acc,
                rec.live_kernels
            );
            epochs.push(rec);
        }
        Ok(TrainingReport {
            mode: self.cfg.mode.name().into(),
            epochs,
            confusion,
            final_prune_rate: self.sched.prune_rate(),
            macs_pruned: per_image_conv_macs(&live_counts(&self.sched)),
            macs_unpruned: per_image_conv_macs(&CHANNELS),
            artifact_ms: self.artifact_ms,
            chip_ms: self.chip_ms,
        })
    }
}

fn live_counts(sched: &PruningScheduler) -> [usize; 3] {
    [sched.live_count(0), sched.live_count(1), sched.live_count(2)]
}

/// Per-image *inference* conv MACs given live kernel counts. Pruned
/// output channels also shrink the next layer's input channels.
pub fn per_image_conv_macs(live: &[usize]) -> u64 {
    let l1 = layers::conv_macs(live[0], 1, 3, 3, 28, 28, 1);
    let l2 = layers::conv_macs(live[1], live[0], 3, 3, 14, 14, 1);
    let l3 = layers::conv_macs(live[2], live[1], 3, 3, 7, 7, 1);
    l1 + l2 + l3
}

fn init_params(rng: &mut Rng) -> ParamSet {
    let mut p = ParamSet::default();
    let (c1, c2, c3) = (CHANNELS[0], CHANNELS[1], CHANNELS[2]);
    p.push(Param::he("w1", vec![c1, 1, 3, 3], 9, rng));
    p.push(Param::zeros("b1", vec![c1]));
    p.push(Param::he("w2", vec![c2, c1, 3, 3], c1 * 9, rng));
    p.push(Param::zeros("b2", vec![c2]));
    p.push(Param::he("w3", vec![c3, c2, 3, 3], c2 * 9, rng));
    p.push(Param::zeros("b3", vec![c3]));
    p.push(Param::he("wf", vec![FC_IN, 10], FC_IN, rng));
    p.push(Param::zeros("bf", vec![10]));
    p
}

/// Reference forward activations per conv layer input: [input, act1, act2]
/// using binarized+scaled, masked weights (mirrors model.mnist_forward).
fn forward_activations(params: &ParamSet, sched: &PruningScheduler, image: &Tensor) -> Vec<Tensor> {
    let mut acts = vec![image.clone()];
    let names = ["w1", "w2", "w3"];
    let biases = ["b1", "b2", "b3"];
    let mut x = image.clone();
    for layer in 0..2 {
        // only the inputs of conv2 and conv3 are needed beyond the image
        let w = params.get(names[layer]);
        let b = &params.get(biases[layer]).data;
        let mask = sched.mask_f32(layer);
        let wb = binarized_weight(w, &mask);
        let mut y = layers::conv2d(&x, &wb, Some(&mask), 1);
        for (i, v) in y.data_mut().iter_mut().enumerate() {
            let ch = (i / (x.shape()[2] * x.shape()[3])) % wb.shape()[0];
            *v = (*v + b[ch]).max(0.0);
        }
        let pooled = layers::maxpool2(&y);
        acts.push(pooled.clone());
        x = pooled;
    }
    acts
}

fn binarized_weight(w: &Param, mask: &[f32]) -> Tensor {
    let oc = w.dims[0];
    let per = w.data.len() / oc;
    let mut out = Vec::with_capacity(w.data.len());
    for o in 0..oc {
        let k = &w.data[o * per..(o + 1) * per];
        let (bits, alpha) = quant::binarize_kernel(k);
        for &bit in &bits {
            out.push(if bit { alpha } else { -alpha } * mask[o]);
        }
    }
    Tensor::new(w.dims.clone(), out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_ready() -> bool {
        cfg!(feature = "pjrt")
            && std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
                .join("artifacts/manifest.txt")
                .exists()
    }

    #[test]
    fn per_image_macs_shrink_with_pruning() {
        let full = per_image_conv_macs(&[32, 64, 32]);
        let pruned = per_image_conv_macs(&[22, 45, 22]);
        assert!(pruned < full);
        assert_eq!(full, 32 * 9 * 784 + 64 * 32 * 9 * 196 + 32 * 64 * 9 * 49);
    }

    #[test]
    fn one_epoch_spn_smoke() {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let engine = Engine::open_default().unwrap();
        let cfg = MnistConfig {
            epochs: 2,
            train_samples: 128,
            test_samples: 64,
            prune: PruneConfig { warmup_epochs: 1, prune_interval: 1, ..PruneConfig::default() },
            ..MnistConfig::default()
        };
        let mut tr = MnistTrainer::new(cfg, engine);
        let report = tr.train().unwrap();
        assert_eq!(report.epochs.len(), 2);
        // loss must be finite and accuracy within [0,1]
        assert!(report.epochs.iter().all(|e| e.loss.is_finite()));
        assert!(report.final_test_acc() >= 0.0 && report.final_test_acc() <= 1.0);
        assert!(report.epochs[1].loss < report.epochs[0].loss * 1.5);
    }
}

//! Layer-3 coordinator: the training orchestrator that drives the AOT
//! train/eval artifacts, the chip simulator (similarity search +
//! chip-in-the-loop convolution checks), and the pruning scheduler —
//! the role the ZCU102 FPGA + host plays in the paper's system.

pub mod experiment;
pub mod mnist;
pub mod params;
pub mod pointnet;

pub use experiment::TrainingReport;

/// Which of the paper's three training configurations to run (Fig. 4k /
/// Fig. 5g): software-unpruned, software-pruned, hardware-pruned.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainMode {
    /// SUN: no pruning at all.
    Sun,
    /// SPN: dynamic pruning with the bit-packed software similarity.
    Spn,
    /// HPN: dynamic pruning with the *chip's* search-in-memory similarity
    /// plus chip-in-the-loop MAC-precision checks.
    Hpn,
}

impl TrainMode {
    pub fn name(self) -> &'static str {
        match self {
            TrainMode::Sun => "SUN",
            TrainMode::Spn => "SPN",
            TrainMode::Hpn => "HPN",
        }
    }

    pub fn prunes(self) -> bool {
        !matches!(self, TrainMode::Sun)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_properties() {
        assert!(!TrainMode::Sun.prunes());
        assert!(TrainMode::Spn.prunes());
        assert!(TrainMode::Hpn.prunes());
        assert_eq!(TrainMode::Hpn.name(), "HPN");
    }
}

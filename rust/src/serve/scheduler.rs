//! The serving engine: one worker thread per pool chip plus a
//! coordinator thread that owns the batcher and the layer pipeline.
//!
//! Shards are **weight-stationary** — a filter's dots can only be
//! computed by the chip holding its rows — so conv work pins to its
//! chip's queue and load balance comes from the placer spreading filters
//! evenly. The coordinator fans a batch's packed activation windows out
//! to every worker with shards in the current layer (`Arc`-shared, built
//! once per batch per layer), collects the integer dot maps, applies
//! scale/bias/ReLU/pool on the host, and replies with per-request logits
//! and latency.
//!
//! Numeric contract: a request's logits equal
//! [`ModelBundle::reference_logits`] bit for bit, for any pool size,
//! batch size, or thread interleaving — chip dots are integer-exact and
//! every f32 step is shared with the reference implementation.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::chip::Chip;
use crate::cim::mapping::{segment_widths, RowSpan};
use crate::cim::vmm::{self, PackedWindows};
use crate::nn::quant;

use super::batcher::{Batcher, BatcherConfig, Request, Response};
use super::model::{fc_logits, im2col_u8, maxpool2_flat, scale_mac, ModelBundle};
use super::placement::{self, Placement};
use super::pool::{ChipPool, PoolConfig};
use super::stats::{ServeReport, ServeStats};

/// Server construction knobs.
#[derive(Clone, Debug, Default)]
pub struct ServerConfig {
    pub pool: PoolConfig,
    pub batcher: BatcherConfig,
}

/// A layer's worth of work for one chip: compute dots of its shards
/// against the shared packed windows.
struct Job {
    layer: usize,
    windows: Arc<PackedWindows>,
}

/// Integer dot maps of one worker for one layer.
struct JobResult {
    /// (filter index, dots per window) for every shard the chip holds.
    dots: Vec<(usize, Vec<i64>)>,
}

fn worker_loop(
    mut chip: Chip,
    shards_by_layer: Vec<Vec<(usize, RowSpan)>>,
    jobs: Receiver<Job>,
    results: Sender<JobResult>,
) -> Chip {
    while let Ok(job) = jobs.recv() {
        let mut dots = Vec::with_capacity(shards_by_layer[job.layer].len());
        for (filter, span) in &shards_by_layer[job.layer] {
            dots.push((*filter, vmm::binary_dots_batched(&mut chip, span, &job.windows)));
        }
        if results.send(JobResult { dots }).is_err() {
            break; // coordinator gone: shut down
        }
    }
    chip
}

/// A running inference server. Submit images, then [`Server::shutdown`]
/// to drain the queue and collect the [`ServeReport`].
pub struct Server {
    submit_tx: Option<SyncSender<Request>>,
    next_id: AtomicU64,
    /// Expected request image length (`input_hw^2`), checked at
    /// admission so a malformed request cannot kill the pipeline.
    image_len: usize,
    coordinator: Option<JoinHandle<ServeReport>>,
}

impl Server {
    /// Fabricate the pool, place (program) the model wear-aware, reset
    /// the energy ledgers so serving measurements exclude programming,
    /// and spawn the worker + coordinator threads.
    pub fn start(model: ModelBundle, cfg: &ServerConfig) -> Result<Self> {
        let mut pool = ChipPool::new(&cfg.pool);
        let placement = placement::place(&model, &mut pool)?;
        pool.reset_energy();
        let data_cols = pool
            .chips()
            .first()
            .ok_or_else(|| anyhow!("empty pool"))?
            .cfg()
            .data_cols();
        let (tx, batcher) = Batcher::channel(cfg.batcher.clone());
        let chips = pool.into_chips();
        let image_len = model.input_hw * model.input_hw;
        let coordinator = std::thread::spawn(move || {
            coordinator_loop(model, placement, batcher, chips, data_cols)
        });
        Ok(Server {
            submit_tx: Some(tx),
            next_id: AtomicU64::new(0),
            image_len,
            coordinator: Some(coordinator),
        })
    }

    /// Submit one image, blocking while the admission queue is full
    /// (lossless backpressure). The returned receiver yields the
    /// [`Response`] when the batch containing this request completes.
    ///
    /// Panics (in the caller, never the pipeline) if `image` is not
    /// `input_hw^2` floats.
    pub fn submit(&self, image: Vec<f32>) -> Receiver<Response> {
        assert_eq!(
            image.len(),
            self.image_len,
            "request image length vs model input ({} expected)",
            self.image_len
        );
        let (reply, rx) = channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            image,
            submitted: Instant::now(),
            reply,
        };
        self.submit_tx
            .as_ref()
            .expect("server already shut down")
            .send(req)
            .expect("serving pipeline hung up");
        rx
    }

    /// Non-blocking submit: on a full queue the image is handed back so
    /// the caller can shed or retry (explicit backpressure signal).
    ///
    /// Panics (in the caller, never the pipeline) if `image` is not
    /// `input_hw^2` floats.
    pub fn try_submit(&self, image: Vec<f32>) -> std::result::Result<Receiver<Response>, Vec<f32>> {
        assert_eq!(
            image.len(),
            self.image_len,
            "request image length vs model input ({} expected)",
            self.image_len
        );
        let (reply, rx) = channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            image,
            submitted: Instant::now(),
            reply,
        };
        match self.submit_tx.as_ref().expect("server already shut down").try_send(req) {
            Ok(()) => Ok(rx),
            Err(TrySendError::Full(r)) | Err(TrySendError::Disconnected(r)) => Err(r.image),
        }
    }

    /// Stop admitting, drain every queued request, join all threads, and
    /// report. Every request submitted before this call is served.
    pub fn shutdown(mut self) -> ServeReport {
        self.submit_tx.take(); // hang up: the batcher drains, then stops
        self.coordinator
            .take()
            .expect("server already shut down")
            .join()
            .expect("serving coordinator panicked")
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.submit_tx.take();
        if let Some(h) = self.coordinator.take() {
            let _ = h.join();
        }
    }
}

fn coordinator_loop(
    model: ModelBundle,
    placement: Placement,
    batcher: Batcher,
    chips: Vec<Chip>,
    data_cols: usize,
) -> ServeReport {
    let n_chips = chips.len();
    let n_layers = model.conv.len();
    // group shards per chip per layer
    let mut per_chip: Vec<Vec<Vec<(usize, RowSpan)>>> =
        vec![vec![Vec::new(); n_layers]; n_chips];
    for (l, layer_shards) in placement.shards.iter().enumerate() {
        for (f, shard) in layer_shards.iter().enumerate() {
            if let Some(loc) = shard {
                per_chip[loc.chip][l].push((f, loc.span.clone()));
            }
        }
    }
    let shard_counts: Vec<Vec<usize>> = per_chip
        .iter()
        .map(|layers| layers.iter().map(|v| v.len()).collect())
        .collect();

    // spawn one worker per chip
    let (res_tx, res_rx) = channel::<JobResult>();
    let mut job_txs: Vec<Sender<Job>> = Vec::with_capacity(n_chips);
    let mut handles: Vec<JoinHandle<Chip>> = Vec::with_capacity(n_chips);
    for (i, chip) in chips.into_iter().enumerate() {
        let (jtx, jrx) = channel::<Job>();
        let shards = std::mem::take(&mut per_chip[i]);
        let rtx = res_tx.clone();
        handles.push(std::thread::spawn(move || worker_loop(chip, shards, jrx, rtx)));
        job_txs.push(jtx);
    }
    drop(res_tx);

    let mut stats = ServeStats::default();
    let t_start = Instant::now();

    while let Some(batch) = batcher.next_batch() {
        let b = batch.len();
        // per-image activation maps, channel-major; layer 0 input = image
        let mut maps: Vec<Vec<f32>> = batch.iter().map(|r| r.image.clone()).collect();
        let mut c = 1usize;
        let mut hw = model.input_hw;
        for (l, layer) in model.conv.iter().enumerate() {
            debug_assert_eq!(layer.in_c, c);
            let cells = layer.kernel_cells();
            // quantize each image, im2col, and pack all windows together
            // (one shared packing serves every filter of the layer; the
            // im2col buffers concatenate directly into window-major order)
            let mut scales = Vec::with_capacity(b);
            let mut flat_windows: Vec<u8> = Vec::with_capacity(b * hw * hw * cells);
            let (mut oh, mut ow) = (hw, hw);
            for m in &maps {
                let (q, s) = quant::quantize_activations_u8(m);
                scales.push(s);
                let (flat, oh2, ow2) = im2col_u8(&q, c, hw, hw, layer.ksize, 1);
                oh = oh2;
                ow = ow2;
                flat_windows.extend_from_slice(&flat);
            }
            let n_pos = oh * ow;
            let widths = segment_widths(cells, data_cols);
            let pw = Arc::new(vmm::pack_windows(&flat_windows, &widths));
            // fan out to every chip holding shards of this layer
            let mut expected = 0usize;
            for (ci, jtx) in job_txs.iter().enumerate() {
                if shard_counts[ci][l] == 0 {
                    continue;
                }
                jtx.send(Job { layer: l, windows: Arc::clone(&pw) })
                    .expect("worker hung up");
                expected += 1;
            }
            // fan in: integer dots -> scaled activations
            let mut y = vec![0.0f32; b * layer.out_c * n_pos];
            for _ in 0..expected {
                let r = res_rx.recv().expect("worker died mid-batch");
                for (f, dvec) in r.dots {
                    debug_assert_eq!(dvec.len(), b * n_pos);
                    for (bi, &scale) in scales.iter().enumerate() {
                        let src = &dvec[bi * n_pos..(bi + 1) * n_pos];
                        let dst_base = bi * layer.out_c * n_pos + f * n_pos;
                        for (p, &dot) in src.iter().enumerate() {
                            y[dst_base + p] =
                                scale_mac(layer.alpha[f], scale, dot, layer.bias[f]).max(0.0);
                        }
                    }
                }
            }
            // pool + advance to the next layer's input maps
            maps = (0..b)
                .map(|bi| {
                    let m = &y[bi * layer.out_c * n_pos..(bi + 1) * layer.out_c * n_pos];
                    if layer.pool {
                        maxpool2_flat(m, layer.out_c, oh, ow)
                    } else {
                        m.to_vec()
                    }
                })
                .collect();
            hw = if layer.pool { oh / 2 } else { oh };
            c = layer.out_c;
        }
        // FC head + replies
        for (req, m) in batch.iter().zip(&maps) {
            debug_assert_eq!(m.len(), model.fc_in);
            let logits = fc_logits(m, &model.fc_w, &model.fc_b, model.fc_in, model.n_classes);
            let latency = req.submitted.elapsed();
            stats.record_latency(latency);
            // a dropped reply receiver is the client's choice, not an error
            let _ = req.reply.send(Response { id: req.id, logits, latency });
        }
        stats.n_requests += b as u64;
        stats.n_batches += 1;
    }

    // all submitters hung up and the queue is drained: stop the workers
    drop(job_txs);
    let chips: Vec<Chip> = handles
        .into_iter()
        .map(|h| h.join().expect("serve worker panicked"))
        .collect();
    stats.wall_s = t_start.elapsed().as_secs_f64();
    stats.energy_pj = chips.iter().map(|c| c.energy_breakdown().total_pj()).sum();
    ServeReport {
        stats,
        wear: chips.iter().map(|c| c.wear.clone()).collect(),
        rows_used: placement.rows_used.clone(),
        stuck_retries: placement.stuck_retries,
        dropped: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chip::ChipConfig;
    use crate::nn::data::mnist;
    use std::time::Duration;

    fn small_server(model: ModelBundle, chips: usize, seed: u64) -> Server {
        let cfg = ServerConfig {
            pool: PoolConfig { chips, chip: ChipConfig::small_test(), seed },
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                queue_depth: 16,
            },
        };
        Server::start(model, &cfg).unwrap()
    }

    #[test]
    fn zero_request_lifecycle() {
        let model = ModelBundle::synthetic_mnist([2, 2, 2], 0.0, 31);
        let server = small_server(model, 2, 32);
        let report = server.shutdown();
        assert_eq!(report.stats.n_requests, 0);
        assert_eq!(report.dropped, 0);
        assert_eq!(report.wear.len(), 2);
    }

    #[test]
    fn serving_matches_reference_logits_exactly() {
        let model = ModelBundle::synthetic_mnist([3, 4, 3], 0.3, 33);
        let ds = mnist::generate(5, 34);
        let server = small_server(model.clone(), 2, 35);
        let pending: Vec<_> = (0..5).map(|i| server.submit(ds.sample(i).to_vec())).collect();
        for (i, rx) in pending.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            assert_eq!(
                resp.logits,
                model.reference_logits(ds.sample(i)),
                "image {i} diverged from the software reference"
            );
            assert!(resp.latency > Duration::ZERO);
        }
        let report = server.shutdown();
        assert_eq!(report.stats.n_requests, 5);
        assert!(report.stats.energy_pj > 0.0, "serving must spend chip energy");
        assert!(report.stats.p99_ms() >= report.stats.p50_ms());
    }

    #[test]
    #[should_panic(expected = "request image length")]
    fn malformed_request_is_rejected_at_admission() {
        let model = ModelBundle::synthetic_mnist([2, 2, 2], 0.0, 39);
        let server = small_server(model, 1, 40);
        // wrong-sized image must fail in the caller, not kill the pipeline
        let _ = server.submit(vec![0.0; 10]);
    }

    #[test]
    fn wear_accrues_from_placement_not_serving() {
        let model = ModelBundle::synthetic_mnist([2, 2, 2], 0.0, 36);
        let ds = mnist::generate(1, 37);
        let server = small_server(model, 1, 38);
        let rx = server.submit(ds.sample(0).to_vec());
        rx.recv().unwrap();
        let report = server.shutdown();
        // serving reads rows (WL activations) but never programs cells
        assert!(report.wear[0].wl_activations > 0);
        assert!(report.wear[0].programmed_cells > 0, "placement programmed the shards");
    }
}

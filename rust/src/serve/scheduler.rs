//! The single-model serving engine: a blocking admission queue feeding
//! a coordinator thread that owns the batcher and the layer pipeline,
//! dispatching chip work through the public transport seam
//! ([`crate::serve::transport`]) — a one-member [`ShardRouter`] over a
//! [`LocalBackend`] wrapping this server's pool.
//!
//! Shards are **weight-stationary** — a filter's dots can only be
//! computed by a chip holding its rows — so conv work pins to its
//! chip and load balance comes from the placer spreading filters
//! evenly. Per layer, the coordinator packs the batch's activation
//! windows once (`Arc`-shared), sends one [`DispatchRequest`] naming
//! the layer's shards, and folds the reply's integer dot maps through
//! the host stages (scale/bias/ReLU/pool, and on the PointNet path the
//! set-abstraction pool/concat seams).
//!
//! Both [`ModelBundle`] paths run through the same machinery; a request
//! carries either binary u8 planes ([`vmm::PackedWindows`] →
//! [`vmm::binary_dots_batched`]) or offset-encoded i8 planes
//! ([`vmm::PackedWindowsI8`] → [`vmm::int8_dots_batched`]).
//!
//! Numeric contract: a request's logits equal
//! [`ModelBundle::reference_logits`] bit for bit, for any pool size,
//! batch size, or thread interleaving — chip dots are integer-exact and
//! every f32 step is shared with the reference implementation.
//!
//! The layer pipeline itself lives in the tenant-agnostic executor
//! (`serve::engine::exec`), shared with the multi-tenant
//! [`crate::serve::engine::Engine`]; this module contributes the
//! single-model front end: the blocking admission queue, the
//! replica-aware shedding path ([`Server::try_submit_spill`]), and the
//! legacy `Server` API.
//!
//! [`DispatchRequest`]: crate::serve::transport::DispatchRequest
//! [`vmm::PackedWindows`]: crate::cim::vmm::PackedWindows
//! [`vmm::binary_dots_batched`]: crate::cim::vmm::binary_dots_batched
//! [`vmm::PackedWindowsI8`]: crate::cim::vmm::PackedWindowsI8
//! [`vmm::int8_dots_batched`]: crate::cim::vmm::int8_dots_batched

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::cim::mapping::RowAllocator;

use super::batcher::{Batcher, BatcherConfig, Request, Response};
use super::engine::exec::run_batch;
use super::model::ModelBundle;
use super::placement::{self, Placement};
use super::pool::{ChipPool, PoolConfig};
use super::stats::{ServeReport, ServeStats};
use super::transport::{LocalBackend, ShardRouter, TenantRoute};

/// Server construction knobs.
#[derive(Clone, Debug, Default)]
pub struct ServerConfig {
    pub pool: PoolConfig,
    pub batcher: BatcherConfig,
}

/// A running inference server. Submit inputs, then [`Server::shutdown`]
/// to drain the queue and collect the [`ServeReport`].
pub struct Server {
    submit_tx: Option<SyncSender<Request>>,
    next_id: AtomicU64,
    /// Expected request input length ([`ModelBundle::input_len`]),
    /// checked at admission so a malformed request cannot kill the
    /// pipeline.
    input_len: usize,
    /// Requests shed by [`Server::try_submit`] (and terminal rejections
    /// of [`Server::try_submit_spill`]) on a full queue, folded into
    /// [`ServeStats::dropped`] at shutdown.
    dropped: Arc<AtomicU64>,
    coordinator: Option<JoinHandle<ServeReport>>,
}

impl Server {
    /// Fabricate the pool, place (program) the model wear-aware, reset
    /// the energy ledgers so serving measurements exclude programming,
    /// wrap the placed chips as a [`LocalBackend`] behind a one-member
    /// [`ShardRouter`], and spawn the coordinator thread.
    pub fn start(model: ModelBundle, cfg: &ServerConfig) -> Result<Self> {
        model.validate()?;
        let mut pool = ChipPool::new(&cfg.pool);
        if pool.is_empty() {
            return Err(anyhow!("empty pool"));
        }
        // the allocators that place the model travel into the backend:
        // fresh ones would double-book the rows placement just consumed
        let mut allocs: Vec<RowAllocator> =
            pool.chips().iter().map(RowAllocator::for_chip).collect();
        let placement = placement::place_with(&model, &mut pool, &mut allocs, None)?;
        pool.reset_energy();
        let data_cols = pool.chips()[0].cfg().data_cols();
        let (tx, batcher) = Batcher::channel(cfg.batcher.clone());
        let backend = LocalBackend::from_parts(pool.into_chips(), allocs)?;
        let router = ShardRouter::single(Box::new(backend))?;
        let input_len = model.input_len();
        let dropped = Arc::new(AtomicU64::new(0));
        let dropped_in_coord = Arc::clone(&dropped);
        let coordinator = std::thread::spawn(move || {
            coordinator_loop(model, placement, batcher, router, data_cols, dropped_in_coord)
        });
        Ok(Server {
            submit_tx: Some(tx),
            next_id: AtomicU64::new(0),
            input_len,
            dropped,
            coordinator: Some(coordinator),
        })
    }

    /// Submit one input (image or cloud), blocking while the admission
    /// queue is full (lossless backpressure). The returned receiver
    /// yields the [`Response`] when the batch containing this request
    /// completes.
    ///
    /// Panics (in the caller, never the pipeline) if `input` is not
    /// [`ModelBundle::input_len`] floats.
    pub fn submit(&self, input: Vec<f32>) -> Receiver<Response> {
        assert_eq!(
            input.len(),
            self.input_len,
            "request input length vs model input ({} expected)",
            self.input_len
        );
        // one-shot reply: capacity 1 buffers the single send without a
        // blocked receiver, keeping the serve plane free of unbounded
        // queues (the bounded-channel invariant)
        let (reply, rx) = sync_channel(1);
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            input,
            submitted: Instant::now(),
            reply,
        };
        self.submit_tx
            .as_ref()
            .expect("server already shut down")
            .send(req)
            .expect("serving pipeline hung up");
        rx
    }

    /// Admission without accounting: hand the input back on a full (or
    /// closing) queue and let the caller decide what the rejection
    /// means — retry, spill to a replica, or shed. The spillover path
    /// needs this separation: a request that three replicas each turned
    /// away was still *one* client request, and must be counted as one
    /// drop, not three.
    fn try_admit(&self, input: Vec<f32>) -> std::result::Result<Receiver<Response>, Vec<f32>> {
        assert_eq!(
            input.len(),
            self.input_len,
            "request input length vs model input ({} expected)",
            self.input_len
        );
        let (reply, rx) = sync_channel(1);
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            input,
            submitted: Instant::now(),
            reply,
        };
        match self.submit_tx.as_ref().expect("server already shut down").try_send(req) {
            Ok(()) => Ok(rx),
            Err(TrySendError::Full(r)) | Err(TrySendError::Disconnected(r)) => Err(r.input),
        }
    }

    /// Non-blocking submit: on a full queue the input is handed back so
    /// the caller can shed or retry (explicit backpressure signal), and
    /// the shed request is counted in [`ServeStats::dropped`]. A dropped
    /// request is never admitted, so it can never also be answered.
    ///
    /// Panics (in the caller, never the pipeline) if `input` is not
    /// [`ModelBundle::input_len`] floats.
    pub fn try_submit(&self, input: Vec<f32>) -> std::result::Result<Receiver<Response>, Vec<f32>> {
        match self.try_admit(input) {
            Ok(rx) => Ok(rx),
            Err(input) => {
                self.dropped.fetch_add(1, Ordering::SeqCst);
                Err(input)
            }
        }
    }

    /// Admission-plane spillover: admit into this server's queue, or —
    /// if it is full — into the first replica with space, returning
    /// which server (0 = self, `i + 1` = `replicas[i]`) took the
    /// request. A request every queue rejects is handed back and
    /// counted **exactly once**, in *this* server's
    /// [`ServeStats::dropped`] — the seed-era shape (count on every
    /// rejection) would have double-counted a spilled-then-dropped
    /// request once per queue it bounced off, breaking the
    /// `attempts == answered + dropped` partition the fleet's
    /// accounting rests on (property-tested in
    /// `tests/integration_stack.rs`).
    ///
    /// The replicas must serve the same model (asserted via input
    /// length); latency accounting starts at each server's own
    /// admission, exactly like a direct submit.
    pub fn try_submit_spill(
        &self,
        replicas: &[&Server],
        input: Vec<f32>,
    ) -> std::result::Result<(usize, Receiver<Response>), Vec<f32>> {
        let mut input = match self.try_admit(input) {
            Ok(rx) => return Ok((0, rx)),
            Err(input) => input,
        };
        for (i, replica) in replicas.iter().enumerate() {
            match replica.try_admit(input) {
                Ok(rx) => return Ok((i + 1, rx)),
                Err(back) => input = back,
            }
        }
        self.dropped.fetch_add(1, Ordering::SeqCst);
        Err(input)
    }

    /// Stop admitting, drain every queued request, join all threads, and
    /// report. Every request submitted before this call is served.
    pub fn shutdown(mut self) -> ServeReport {
        self.submit_tx.take(); // hang up: the batcher drains, then stops
        self.coordinator
            .take()
            .expect("server already shut down")
            .join()
            .expect("serving coordinator panicked")
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.submit_tx.take();
        if let Some(h) = self.coordinator.take() {
            let _ = h.join();
        }
    }
}

fn coordinator_loop(
    model: ModelBundle,
    placement: Placement,
    batcher: Batcher,
    mut router: ShardRouter,
    data_cols: usize,
    dropped: Arc<AtomicU64>,
) -> ServeReport {
    let route = TenantRoute::single_member(&placement);
    let n_layers = model.n_layers();
    let mut stats = ServeStats::default();
    let t_start = Instant::now();

    while let Some(batch) = batcher.next_batch() {
        let b = batch.len();
        let inputs: Vec<&[f32]> = batch.iter().map(|r| r.input.as_slice()).collect();
        let mut layer_windows = vec![0u64; n_layers];
        // begin_trace returns the null context while no obs plane is
        // attached — the legacy server stays untraced at zero cost
        let trace = router.begin_trace();
        let logits =
            run_batch(&model, &inputs, data_cols, &mut router, &route, &mut layer_windows, trace)
                .expect("serving transport failed mid-batch");
        // replies, in admission order (per-client FIFO)
        for (req, lg) in batch.iter().zip(logits) {
            let latency = req.submitted.elapsed();
            stats.record_latency(latency);
            // a dropped reply receiver is the client's choice, not an error
            let _ = req.reply.send(Response { id: req.id, logits: lg, latency });
        }
        stats.n_requests += b as u64;
        stats.n_batches += 1;
    }

    // all submitters hung up and the queue is drained: stop the backend
    let finishes = router.finish().expect("serving transport failed at shutdown");
    stats.wall_s = t_start.elapsed().as_secs_f64();
    stats.energy_pj = finishes.iter().map(|f| f.energy_pj).sum();
    stats.dropped = dropped.load(Ordering::SeqCst);
    ServeReport {
        stats,
        wear: finishes.into_iter().flat_map(|f| f.wear).collect(),
        rows_used: placement.rows_used.clone(),
        stuck_retries: placement.stuck_retries,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chip::ChipConfig;
    use crate::nn::data::{mnist, modelnet};
    use crate::nn::pointnet::GroupingConfig;
    use crate::serve::pointnet_model::PointNetBundle;
    use std::time::Duration;

    fn small_server(model: ModelBundle, chips: usize, seed: u64) -> Server {
        let cfg = ServerConfig {
            pool: PoolConfig { chips, chip: ChipConfig::small_test(), seed },
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                queue_depth: 16,
            },
        };
        Server::start(model, &cfg).unwrap()
    }

    fn tiny_pointnet(prune: f64, seed: u64) -> PointNetBundle {
        PointNetBundle::synthetic(
            [2, 2, 3, 2, 2, 3, 2, 4],
            3,
            prune,
            GroupingConfig { s1: 8, k1: 4, r1: 0.3, s2: 4, k2: 2, r2: 0.6 },
            seed,
        )
    }

    #[test]
    fn zero_request_lifecycle() {
        let model = ModelBundle::synthetic_mnist([2, 2, 2], 0.0, 31);
        let server = small_server(model, 2, 32);
        let report = server.shutdown();
        assert_eq!(report.stats.n_requests, 0);
        assert_eq!(report.stats.dropped, 0);
        assert_eq!(report.wear.len(), 2);
    }

    #[test]
    fn serving_matches_reference_logits_exactly() {
        let model = ModelBundle::synthetic_mnist([3, 4, 3], 0.3, 33);
        let ds = mnist::generate(5, 34);
        let server = small_server(model.clone(), 2, 35);
        let pending: Vec<_> = (0..5).map(|i| server.submit(ds.sample(i).to_vec())).collect();
        for (i, rx) in pending.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            assert_eq!(
                resp.logits,
                model.reference_logits(ds.sample(i)),
                "image {i} diverged from the software reference"
            );
            assert!(resp.latency > Duration::ZERO);
        }
        let report = server.shutdown();
        assert_eq!(report.stats.n_requests, 5);
        assert!(report.stats.energy_pj > 0.0, "serving must spend chip energy");
        assert!(report.stats.p99_ms() >= report.stats.p50_ms());
    }

    #[test]
    fn pointnet_serving_matches_reference_logits_exactly() {
        let model: ModelBundle = tiny_pointnet(0.3, 41).into();
        let ds = modelnet::generate(4, 42);
        let server = small_server(model.clone(), 2, 43);
        let pending: Vec<_> = (0..4).map(|i| server.submit(ds.sample(i).to_vec())).collect();
        for (i, rx) in pending.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            assert_eq!(
                resp.logits,
                model.reference_logits(ds.sample(i)),
                "cloud {i} diverged from the software reference"
            );
        }
        let report = server.shutdown();
        assert_eq!(report.stats.n_requests, 4);
        assert!(report.stats.energy_pj > 0.0, "serving must spend chip energy");
    }

    #[test]
    #[should_panic(expected = "request input length")]
    fn malformed_request_is_rejected_at_admission() {
        let model = ModelBundle::synthetic_mnist([2, 2, 2], 0.0, 39);
        let server = small_server(model, 1, 40);
        // wrong-sized input must fail in the caller, not kill the pipeline
        let _ = server.submit(vec![0.0; 10]);
    }

    #[test]
    fn invalid_bundle_fails_at_start_not_in_a_worker() {
        let mut pn = tiny_pointnet(0.0, 44);
        pn.grouping.s1 = pn.cloud_points + 1; // infeasible grouping
        let cfg = ServerConfig {
            pool: PoolConfig { chips: 1, chip: ChipConfig::small_test(), seed: 45 },
            batcher: BatcherConfig::default(),
        };
        assert!(Server::start(pn.into(), &cfg).is_err());
    }

    #[test]
    fn wear_accrues_from_placement_not_serving() {
        let model = ModelBundle::synthetic_mnist([2, 2, 2], 0.0, 36);
        let ds = mnist::generate(1, 37);
        let server = small_server(model, 1, 38);
        let rx = server.submit(ds.sample(0).to_vec());
        rx.recv().unwrap();
        let report = server.shutdown();
        // serving reads rows (WL activations) but never programs cells
        assert!(report.wear[0].wl_activations > 0);
        assert!(report.wear[0].programmed_cells > 0, "placement programmed the shards");
    }

    #[test]
    fn try_submit_drops_are_counted_and_never_answered() {
        let model = ModelBundle::synthetic_mnist([2, 2, 2], 0.0, 51);
        let cfg = ServerConfig {
            pool: PoolConfig { chips: 1, chip: ChipConfig::small_test(), seed: 52 },
            batcher: BatcherConfig {
                // serve one request at a time behind a depth-1 queue: a
                // tight submit loop outpaces inference and must shed
                max_batch: 1,
                max_wait: Duration::from_millis(1),
                queue_depth: 1,
            },
        };
        let server = Server::start(model, &cfg).unwrap();
        let ds = mnist::generate(1, 53);
        let mut attempts = 0u64;
        let mut receivers = Vec::new();
        let mut shed = 0u64;
        while attempts < 10_000 && (shed < 3 || attempts < 8) {
            attempts += 1;
            match server.try_submit(ds.sample(0).to_vec()) {
                Ok(rx) => receivers.push(rx),
                Err(input) => {
                    assert_eq!(input.len(), 28 * 28, "shed input returned intact");
                    shed += 1;
                }
            }
        }
        assert!(shed > 0, "depth-1 queue under a tight burst must shed");
        // every admitted request is answered exactly once, in id order
        let mut ids = Vec::new();
        for rx in receivers {
            let resp = rx.recv().expect("admitted request must be answered");
            ids.push(resp.id);
        }
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), ids.len(), "duplicate replies");
        assert_eq!(ids, sorted, "single-client replies arrive in FIFO order");
        let report = server.shutdown();
        assert_eq!(report.stats.dropped, shed, "stats vs observed sheds");
        assert_eq!(
            report.stats.n_requests + shed,
            attempts,
            "dropped + answered must partition the attempts"
        );
    }

    #[test]
    fn spillover_counts_a_twice_rejected_request_once() {
        // primary and replica both serve one-at-a-time behind depth-1
        // queues: a tight spillover loop must overflow both, and every
        // terminal rejection lands once in the PRIMARY's dropped
        let model = ModelBundle::synthetic_mnist([2, 2, 2], 0.0, 54);
        let cfg = |seed| ServerConfig {
            pool: PoolConfig { chips: 1, chip: ChipConfig::small_test(), seed },
            batcher: BatcherConfig {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
                queue_depth: 1,
            },
        };
        let primary = Server::start(model.clone(), &cfg(55)).unwrap();
        let replica = Server::start(model, &cfg(56)).unwrap();
        let ds = mnist::generate(1, 57);
        let mut attempts = 0u64;
        let mut shed = 0u64;
        let mut spilled = 0u64;
        let mut primary_rx = Vec::new();
        let mut replica_rx = Vec::new();
        while attempts < 10_000 && (shed < 3 || spilled < 3 || attempts < 8) {
            attempts += 1;
            match primary.try_submit_spill(&[&replica], ds.sample(0).to_vec()) {
                Ok((0, rx)) => primary_rx.push(rx),
                Ok((_, rx)) => {
                    spilled += 1;
                    replica_rx.push(rx);
                }
                Err(input) => {
                    assert_eq!(input.len(), 28 * 28, "rejected input returned intact");
                    shed += 1;
                }
            }
        }
        assert!(spilled > 0, "a full primary must spill to its replica");
        assert!(shed > 0, "two full queues must eventually shed");
        let answered_primary = primary_rx.len() as u64;
        for rx in primary_rx {
            rx.recv().expect("admitted request must be answered");
        }
        let answered_replica = replica_rx.len() as u64;
        for rx in replica_rx {
            rx.recv().expect("spilled request must be answered");
        }
        let pr = primary.shutdown();
        let rr = replica.shutdown();
        assert_eq!(pr.stats.dropped, shed, "terminal rejections count once, on the primary");
        assert_eq!(rr.stats.dropped, 0, "a spill target never books the client's drop");
        assert_eq!(pr.stats.n_requests, answered_primary);
        assert_eq!(rr.stats.n_requests, answered_replica);
        assert_eq!(
            answered_primary + answered_replica + shed,
            attempts,
            "attempts == answered (anywhere) + dropped (once)"
        );
    }
}

//! The serving engine: one worker thread per pool chip plus a
//! coordinator thread that owns the batcher and the layer pipeline.
//!
//! Shards are **weight-stationary** — a filter's dots can only be
//! computed by the chip holding its rows — so conv work pins to its
//! chip's queue and load balance comes from the placer spreading filters
//! evenly. The coordinator fans a batch's packed activation windows out
//! to every worker with shards in the current layer (`Arc`-shared, built
//! once per batch per layer), collects the integer dot maps, applies
//! scale/bias/ReLU/pool (and, on the PointNet path, the set-abstraction
//! pool/concat seams) on the host, and replies with per-request logits
//! and latency.
//!
//! Both [`ModelBundle`] paths run through the same fan-out/fan-in
//! machinery; a job carries either binary u8 planes
//! ([`vmm::PackedWindows`] → [`vmm::binary_dots_batched`]) or
//! offset-encoded i8 planes ([`vmm::PackedWindowsI8`] →
//! [`vmm::int8_dots_batched`]).
//!
//! Numeric contract: a request's logits equal
//! [`ModelBundle::reference_logits`] bit for bit, for any pool size,
//! batch size, or thread interleaving — chip dots are integer-exact and
//! every f32 step is shared with the reference implementation.
//!
//! The layer pipeline itself lives in the tenant-agnostic executor
//! (`serve::engine::exec`), shared with the multi-tenant
//! [`crate::serve::engine::Engine`]; this module contributes the
//! single-model front end: the blocking admission queue, the static
//! worker-per-chip fan-out, and the legacy `Server` API.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::chip::Chip;
use crate::cim::mapping::RowSpan;
use crate::cim::vmm;

use super::batcher::{Batcher, BatcherConfig, Request, Response};
use super::engine::exec::{run_batch, Dispatch, LayerWindows};
use super::model::ModelBundle;
use super::placement::{self, Placement};
use super::pool::{ChipPool, PoolConfig};
use super::stats::{ServeReport, ServeStats};

/// Server construction knobs.
#[derive(Clone, Debug, Default)]
pub struct ServerConfig {
    pub pool: PoolConfig,
    pub batcher: BatcherConfig,
}

/// A layer's worth of work for one chip: compute dots of its shards
/// against the shared packed windows.
struct Job {
    layer: usize,
    windows: LayerWindows,
}

/// Integer dot maps of one worker for one layer.
struct JobResult {
    /// (filter index, dots per window) for every shard the chip holds.
    dots: Vec<(usize, Vec<i64>)>,
}

fn worker_loop(
    mut chip: Chip,
    shards_by_layer: Vec<Vec<(usize, RowSpan)>>,
    jobs: Receiver<Job>,
    results: Sender<JobResult>,
) -> Chip {
    while let Ok(job) = jobs.recv() {
        let mut dots = Vec::with_capacity(shards_by_layer[job.layer].len());
        for (filter, span) in &shards_by_layer[job.layer] {
            let d = match &job.windows {
                LayerWindows::Binary(pw) => vmm::binary_dots_batched(&mut chip, span, pw),
                LayerWindows::Int8(pw) => vmm::int8_dots_batched(&mut chip, span, pw),
            };
            dots.push((*filter, d));
        }
        if results.send(JobResult { dots }).is_err() {
            break; // coordinator gone: shut down
        }
    }
    chip
}

/// A running inference server. Submit inputs, then [`Server::shutdown`]
/// to drain the queue and collect the [`ServeReport`].
pub struct Server {
    submit_tx: Option<SyncSender<Request>>,
    next_id: AtomicU64,
    /// Expected request input length ([`ModelBundle::input_len`]),
    /// checked at admission so a malformed request cannot kill the
    /// pipeline.
    input_len: usize,
    /// Requests shed by [`Server::try_submit`] on a full queue, folded
    /// into [`ServeStats::dropped`] at shutdown.
    dropped: Arc<AtomicU64>,
    coordinator: Option<JoinHandle<ServeReport>>,
}

impl Server {
    /// Fabricate the pool, place (program) the model wear-aware, reset
    /// the energy ledgers so serving measurements exclude programming,
    /// and spawn the worker + coordinator threads.
    pub fn start(model: ModelBundle, cfg: &ServerConfig) -> Result<Self> {
        model.validate()?;
        let mut pool = ChipPool::new(&cfg.pool);
        let placement = placement::place(&model, &mut pool)?;
        pool.reset_energy();
        let data_cols = pool
            .chips()
            .first()
            .ok_or_else(|| anyhow!("empty pool"))?
            .cfg()
            .data_cols();
        let (tx, batcher) = Batcher::channel(cfg.batcher.clone());
        let chips = pool.into_chips();
        let input_len = model.input_len();
        let dropped = Arc::new(AtomicU64::new(0));
        let dropped_in_coord = Arc::clone(&dropped);
        let coordinator = std::thread::spawn(move || {
            coordinator_loop(model, placement, batcher, chips, data_cols, dropped_in_coord)
        });
        Ok(Server {
            submit_tx: Some(tx),
            next_id: AtomicU64::new(0),
            input_len,
            dropped,
            coordinator: Some(coordinator),
        })
    }

    /// Submit one input (image or cloud), blocking while the admission
    /// queue is full (lossless backpressure). The returned receiver
    /// yields the [`Response`] when the batch containing this request
    /// completes.
    ///
    /// Panics (in the caller, never the pipeline) if `input` is not
    /// [`ModelBundle::input_len`] floats.
    pub fn submit(&self, input: Vec<f32>) -> Receiver<Response> {
        assert_eq!(
            input.len(),
            self.input_len,
            "request input length vs model input ({} expected)",
            self.input_len
        );
        let (reply, rx) = channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            input,
            submitted: Instant::now(),
            reply,
        };
        self.submit_tx
            .as_ref()
            .expect("server already shut down")
            .send(req)
            .expect("serving pipeline hung up");
        rx
    }

    /// Non-blocking submit: on a full queue the input is handed back so
    /// the caller can shed or retry (explicit backpressure signal), and
    /// the shed request is counted in [`ServeStats::dropped`]. A dropped
    /// request is never admitted, so it can never also be answered.
    ///
    /// Panics (in the caller, never the pipeline) if `input` is not
    /// [`ModelBundle::input_len`] floats.
    pub fn try_submit(&self, input: Vec<f32>) -> std::result::Result<Receiver<Response>, Vec<f32>> {
        assert_eq!(
            input.len(),
            self.input_len,
            "request input length vs model input ({} expected)",
            self.input_len
        );
        let (reply, rx) = channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            input,
            submitted: Instant::now(),
            reply,
        };
        match self.submit_tx.as_ref().expect("server already shut down").try_send(req) {
            Ok(()) => Ok(rx),
            Err(TrySendError::Full(r)) => {
                self.dropped.fetch_add(1, Ordering::SeqCst);
                Err(r.input)
            }
            Err(TrySendError::Disconnected(r)) => Err(r.input),
        }
    }

    /// Stop admitting, drain every queued request, join all threads, and
    /// report. Every request submitted before this call is served.
    pub fn shutdown(mut self) -> ServeReport {
        self.submit_tx.take(); // hang up: the batcher drains, then stops
        self.coordinator
            .take()
            .expect("server already shut down")
            .join()
            .expect("serving coordinator panicked")
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.submit_tx.take();
        if let Some(h) = self.coordinator.take() {
            let _ = h.join();
        }
    }
}

/// The [`Server`]'s chip fan-out: deliver a layer's packed windows to
/// every worker whose static shard table has filters in that layer and
/// fold each (filter, dots) pair into the executor's output buffer as
/// it arrives — no worker's result is buffered beyond its own
/// [`JobResult`], so peak transient memory stays independent of pool
/// size.
struct WorkerFanout<'a> {
    job_txs: &'a [Sender<Job>],
    shard_counts: &'a [Vec<usize>],
    res_rx: &'a Receiver<JobResult>,
}

impl Dispatch for WorkerFanout<'_> {
    fn dispatch(
        &mut self,
        layer: usize,
        windows: LayerWindows,
        on_dots: &mut dyn FnMut(usize, Vec<i64>),
    ) {
        let mut expected = 0usize;
        for (ci, jtx) in self.job_txs.iter().enumerate() {
            if self.shard_counts[ci][layer] == 0 {
                continue;
            }
            jtx.send(Job { layer, windows: windows.clone() }).expect("worker hung up");
            expected += 1;
        }
        for _ in 0..expected {
            for (f, dots) in self.res_rx.recv().expect("worker died mid-batch").dots {
                on_dots(f, dots);
            }
        }
    }
}

fn coordinator_loop(
    model: ModelBundle,
    placement: Placement,
    batcher: Batcher,
    chips: Vec<Chip>,
    data_cols: usize,
    dropped: Arc<AtomicU64>,
) -> ServeReport {
    let n_chips = chips.len();
    let n_layers = model.n_layers();
    // group shards per chip per layer
    let mut per_chip: Vec<Vec<Vec<(usize, RowSpan)>>> =
        vec![vec![Vec::new(); n_layers]; n_chips];
    for (l, layer_shards) in placement.shards.iter().enumerate() {
        for (f, shard) in layer_shards.iter().enumerate() {
            if let Some(loc) = shard {
                per_chip[loc.chip][l].push((f, loc.span.clone()));
            }
        }
    }
    let shard_counts: Vec<Vec<usize>> = per_chip
        .iter()
        .map(|layers| layers.iter().map(|v| v.len()).collect())
        .collect();

    // spawn one worker per chip
    let (res_tx, res_rx) = channel::<JobResult>();
    let mut job_txs: Vec<Sender<Job>> = Vec::with_capacity(n_chips);
    let mut handles: Vec<JoinHandle<Chip>> = Vec::with_capacity(n_chips);
    for (i, chip) in chips.into_iter().enumerate() {
        let (jtx, jrx) = channel::<Job>();
        let shards = std::mem::take(&mut per_chip[i]);
        let rtx = res_tx.clone();
        handles.push(std::thread::spawn(move || worker_loop(chip, shards, jrx, rtx)));
        job_txs.push(jtx);
    }
    drop(res_tx);

    let mut stats = ServeStats::default();
    let t_start = Instant::now();

    while let Some(batch) = batcher.next_batch() {
        let b = batch.len();
        let inputs: Vec<&[f32]> = batch.iter().map(|r| r.input.as_slice()).collect();
        let mut fanout =
            WorkerFanout { job_txs: &job_txs, shard_counts: &shard_counts, res_rx: &res_rx };
        let logits = run_batch(&model, &inputs, data_cols, &mut fanout);
        // replies, in admission order (per-client FIFO)
        for (req, lg) in batch.iter().zip(logits) {
            let latency = req.submitted.elapsed();
            stats.record_latency(latency);
            // a dropped reply receiver is the client's choice, not an error
            let _ = req.reply.send(Response { id: req.id, logits: lg, latency });
        }
        stats.n_requests += b as u64;
        stats.n_batches += 1;
    }

    // all submitters hung up and the queue is drained: stop the workers
    drop(job_txs);
    let chips: Vec<Chip> = handles
        .into_iter()
        .map(|h| h.join().expect("serve worker panicked"))
        .collect();
    stats.wall_s = t_start.elapsed().as_secs_f64();
    stats.energy_pj = chips.iter().map(|c| c.energy_breakdown().total_pj()).sum();
    stats.dropped = dropped.load(Ordering::SeqCst);
    ServeReport {
        stats,
        wear: chips.iter().map(|c| c.wear.clone()).collect(),
        rows_used: placement.rows_used.clone(),
        stuck_retries: placement.stuck_retries,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chip::ChipConfig;
    use crate::nn::data::{mnist, modelnet};
    use crate::nn::pointnet::GroupingConfig;
    use crate::serve::pointnet_model::PointNetBundle;
    use std::time::Duration;

    fn small_server(model: ModelBundle, chips: usize, seed: u64) -> Server {
        let cfg = ServerConfig {
            pool: PoolConfig { chips, chip: ChipConfig::small_test(), seed },
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                queue_depth: 16,
            },
        };
        Server::start(model, &cfg).unwrap()
    }

    fn tiny_pointnet(prune: f64, seed: u64) -> PointNetBundle {
        PointNetBundle::synthetic(
            [2, 2, 3, 2, 2, 3, 2, 4],
            3,
            prune,
            GroupingConfig { s1: 8, k1: 4, r1: 0.3, s2: 4, k2: 2, r2: 0.6 },
            seed,
        )
    }

    #[test]
    fn zero_request_lifecycle() {
        let model = ModelBundle::synthetic_mnist([2, 2, 2], 0.0, 31);
        let server = small_server(model, 2, 32);
        let report = server.shutdown();
        assert_eq!(report.stats.n_requests, 0);
        assert_eq!(report.stats.dropped, 0);
        assert_eq!(report.wear.len(), 2);
    }

    #[test]
    fn serving_matches_reference_logits_exactly() {
        let model = ModelBundle::synthetic_mnist([3, 4, 3], 0.3, 33);
        let ds = mnist::generate(5, 34);
        let server = small_server(model.clone(), 2, 35);
        let pending: Vec<_> = (0..5).map(|i| server.submit(ds.sample(i).to_vec())).collect();
        for (i, rx) in pending.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            assert_eq!(
                resp.logits,
                model.reference_logits(ds.sample(i)),
                "image {i} diverged from the software reference"
            );
            assert!(resp.latency > Duration::ZERO);
        }
        let report = server.shutdown();
        assert_eq!(report.stats.n_requests, 5);
        assert!(report.stats.energy_pj > 0.0, "serving must spend chip energy");
        assert!(report.stats.p99_ms() >= report.stats.p50_ms());
    }

    #[test]
    fn pointnet_serving_matches_reference_logits_exactly() {
        let model: ModelBundle = tiny_pointnet(0.3, 41).into();
        let ds = modelnet::generate(4, 42);
        let server = small_server(model.clone(), 2, 43);
        let pending: Vec<_> = (0..4).map(|i| server.submit(ds.sample(i).to_vec())).collect();
        for (i, rx) in pending.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            assert_eq!(
                resp.logits,
                model.reference_logits(ds.sample(i)),
                "cloud {i} diverged from the software reference"
            );
        }
        let report = server.shutdown();
        assert_eq!(report.stats.n_requests, 4);
        assert!(report.stats.energy_pj > 0.0, "serving must spend chip energy");
    }

    #[test]
    #[should_panic(expected = "request input length")]
    fn malformed_request_is_rejected_at_admission() {
        let model = ModelBundle::synthetic_mnist([2, 2, 2], 0.0, 39);
        let server = small_server(model, 1, 40);
        // wrong-sized input must fail in the caller, not kill the pipeline
        let _ = server.submit(vec![0.0; 10]);
    }

    #[test]
    fn invalid_bundle_fails_at_start_not_in_a_worker() {
        let mut pn = tiny_pointnet(0.0, 44);
        pn.grouping.s1 = pn.cloud_points + 1; // infeasible grouping
        let cfg = ServerConfig {
            pool: PoolConfig { chips: 1, chip: ChipConfig::small_test(), seed: 45 },
            batcher: BatcherConfig::default(),
        };
        assert!(Server::start(pn.into(), &cfg).is_err());
    }

    #[test]
    fn wear_accrues_from_placement_not_serving() {
        let model = ModelBundle::synthetic_mnist([2, 2, 2], 0.0, 36);
        let ds = mnist::generate(1, 37);
        let server = small_server(model, 1, 38);
        let rx = server.submit(ds.sample(0).to_vec());
        rx.recv().unwrap();
        let report = server.shutdown();
        // serving reads rows (WL activations) but never programs cells
        assert!(report.wear[0].wl_activations > 0);
        assert!(report.wear[0].programmed_cells > 0, "placement programmed the shards");
    }

    #[test]
    fn try_submit_drops_are_counted_and_never_answered() {
        let model = ModelBundle::synthetic_mnist([2, 2, 2], 0.0, 51);
        let cfg = ServerConfig {
            pool: PoolConfig { chips: 1, chip: ChipConfig::small_test(), seed: 52 },
            batcher: BatcherConfig {
                // serve one request at a time behind a depth-1 queue: a
                // tight submit loop outpaces inference and must shed
                max_batch: 1,
                max_wait: Duration::from_millis(1),
                queue_depth: 1,
            },
        };
        let server = Server::start(model, &cfg).unwrap();
        let ds = mnist::generate(1, 53);
        let mut attempts = 0u64;
        let mut receivers = Vec::new();
        let mut shed = 0u64;
        while attempts < 10_000 && (shed < 3 || attempts < 8) {
            attempts += 1;
            match server.try_submit(ds.sample(0).to_vec()) {
                Ok(rx) => receivers.push(rx),
                Err(input) => {
                    assert_eq!(input.len(), 28 * 28, "shed input returned intact");
                    shed += 1;
                }
            }
        }
        assert!(shed > 0, "depth-1 queue under a tight burst must shed");
        // every admitted request is answered exactly once, in id order
        let mut ids = Vec::new();
        for rx in receivers {
            let resp = rx.recv().expect("admitted request must be answered");
            ids.push(resp.id);
        }
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), ids.len(), "duplicate replies");
        assert_eq!(ids, sorted, "single-client replies arrive in FIFO order");
        let report = server.shutdown();
        assert_eq!(report.stats.dropped, shed, "stats vs observed sheds");
        assert_eq!(
            report.stats.n_requests + shed,
            attempts,
            "dropped + answered must partition the attempts"
        );
    }
}

//! The worker daemon of the multi-host story: a [`Host`] binds a TCP
//! listener, fabricates **its own** chip pool, and serves the
//! [`Backend`](super::Backend) protocol — decode a request frame,
//! execute it on an in-process [`LocalBackend`], reply. A remote worker
//! really is just a transport change: the host reuses the exact
//! execution core the local path uses.
//!
//! The pool outlives any single connection: if a client hangs up (or
//! its connection drops) without sending `Finish`, the daemon keeps the
//! pool — with every programmed shard intact — and waits for the next
//! connection, which is what lets a [`super::remote::RemoteBackend`]
//! reconnect after a network blip and keep serving the same shards.
//! Only a served `Finish` (or [`Host::shutdown`]) ends the daemon: the
//! pool's terminal report has been issued and there is nothing left to
//! serve. One connection owns the pool at a time (the protocol is
//! strictly request/reply per session). That stays true under the
//! executor's dispatch pipeline (DESIGN.md §11): pipelining lives in
//! the [`super::router::ShardRouter`]'s member worker queues *above*
//! this seam, so a host never sees a second request frame before it
//! replied to the first — depth-bounded overlap needs no protocol
//! change.
//!
//! A *restarted* host is a different story: [`Host::spawn`] fabricates
//! a fresh pool with a fresh incarnation
//! ([`super::BackendInfo::incarnation`]), so a client reconnecting to a
//! bounced host can tell its shards are gone and quarantine itself
//! until re-programmed (DESIGN.md §9). [`Host::spawn_at`] exists so an
//! operator (or a test) can bring a replacement host up on the exact
//! address the old one served.
//!
//! A malformed frame gets an `Err` reply and the connection lives on —
//! a bad client request must never take the silicon down.

use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use super::frame::{self, WireReply, WireRequest};
use super::local::LocalBackend;
use crate::util::sync::lock_unpoisoned;
use super::{Backend, TransportError};
use crate::serve::pool::PoolConfig;

/// Host daemon construction knobs.
#[derive(Clone, Debug, Default)]
pub struct HostConfig {
    /// The pool this host fabricates and owns.
    pub pool: PoolConfig,
}

/// A running worker daemon. [`Host::spawn`] binds an OS-assigned
/// loopback port ([`Host::spawn_at`] binds a caller-chosen address);
/// connect a [`super::remote::RemoteBackend`] to [`Host::addr`]. The
/// daemon serves client sessions until one sends `Finish` — a dropped
/// connection keeps the pool and awaits a reconnect. [`Host::join`]
/// reaps a daemon that finished; [`Host::shutdown`] force-stops one
/// that has not (simulating a host crash: the pool dies with it).
pub struct Host {
    addr: SocketAddr,
    handle: Option<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    /// The live session's stream, kept so `shutdown` can sever a
    /// connection the daemon is blocked reading from.
    live: Arc<Mutex<Option<TcpStream>>>,
}

impl Host {
    /// Bind `127.0.0.1:0` and serve `cfg`'s pool from a daemon thread.
    ///
    /// # Errors
    ///
    /// I/O errors from binding the listener.
    pub fn spawn(cfg: HostConfig) -> std::io::Result<Host> {
        Host::spawn_at("127.0.0.1:0", cfg)
    }

    /// Bind a specific address — how a replacement host takes over the
    /// address of a crashed one, so clients holding that address can
    /// reconnect (and discover the fresh incarnation).
    ///
    /// # Errors
    ///
    /// I/O errors from binding the listener (e.g. the old host still
    /// holds the port).
    pub fn spawn_at(addr: impl ToSocketAddrs, cfg: HostConfig) -> std::io::Result<Host> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let live = Arc::new(Mutex::new(None));
        let handle = {
            let stop = Arc::clone(&stop);
            let live = Arc::clone(&live);
            std::thread::spawn(move || host_loop(listener, cfg, &stop, &live))
        };
        Ok(Host { addr, handle: Some(handle), stop, live })
    }

    /// The address clients connect to.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Wait for the daemon to exit (after a client served `Finish`).
    pub fn join(mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }

    /// Kill the daemon *now*, abandoning the pool and any live session
    /// — the in-tree stand-in for a host crash. The listener closes
    /// (the port becomes free for a replacement [`Host::spawn_at`]) and
    /// any connected client sees its next read fail mid-stream.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(stream) = lock_unpoisoned(&self.live).take() {
            let _ = stream.shutdown(Shutdown::Both);
        }
        // unblock a daemon parked in accept(); the dummy connection is
        // dropped immediately by the stop check
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Host {
    fn drop(&mut self) {
        // best effort: wake the daemon so an abandoned host does not
        // leave a thread parked in accept() forever. No join — drops
        // must not block.
        if self.handle.is_some() {
            self.stop.store(true, Ordering::SeqCst);
            if let Some(stream) = lock_unpoisoned(&self.live).take() {
                let _ = stream.shutdown(Shutdown::Both);
            }
            let _ = TcpStream::connect(self.addr);
        }
    }
}

fn host_loop(
    listener: TcpListener,
    cfg: HostConfig,
    stop: &AtomicBool,
    live: &Mutex<Option<TcpStream>>,
) {
    let mut backend = match LocalBackend::from_pool_config(&cfg.pool) {
        Ok(b) => b,
        Err(e) => {
            // a host that cannot build its pool still answers: every
            // request of the first session gets the error relayed
            let msg = format!("host pool construction failed: {e}");
            if let Ok((mut stream, _)) = listener.accept() {
                while frame::read_frame(&mut stream).is_ok() {
                    let rep = frame::encode_reply(&WireReply::Err(msg.clone()));
                    if frame::write_frame(&mut stream, &rep).is_err() {
                        break;
                    }
                }
            }
            return;
        }
    };
    // session loop: the pool persists across client connections until a
    // Finish is served or the host is shut down
    loop {
        let Ok((stream, _)) = listener.accept() else { return };
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let _ = stream.set_nodelay(true);
        *lock_unpoisoned(live) = stream.try_clone().ok();
        // re-check after publishing the session: a shutdown that fired
        // between accept and the publish severed nothing, so it relies
        // on this check to stop the daemon from parking in a read
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let finished = serve_client(stream, &mut backend);
        *lock_unpoisoned(live) = None;
        if finished || stop.load(Ordering::SeqCst) {
            let _ = backend.finish();
            return;
        }
    }
}

/// Serve one client session. Returns `true` after `Finish` has been
/// answered (the daemon must exit), `false` when the client hung up
/// without finishing (the pool lives on for a reconnect).
fn serve_client(mut stream: TcpStream, backend: &mut LocalBackend) -> bool {
    loop {
        let payload = match frame::read_frame(&mut stream) {
            Ok(p) => p,
            Err(_) => return false, // client gone (clean or not): await reconnect
        };
        let (reply, done) = match frame::decode_request(&payload) {
            Err(e) => (WireReply::Err(format!("bad request frame: {e}")), false),
            Ok(req) => execute(backend, req),
        };
        let buf = frame::encode_reply(&reply);
        if frame::write_frame(&mut stream, &buf).is_err() {
            return false;
        }
        if done {
            return true;
        }
    }
}

/// Run one decoded request against the backend; the bool says whether
/// this was the session-ending `Finish`.
fn execute(backend: &mut LocalBackend, req: WireRequest) -> (WireReply, bool) {
    fn relay<T>(r: super::Result<T>, ok: impl FnOnce(T) -> WireReply) -> WireReply {
        match r {
            Ok(v) => ok(v),
            Err(TransportError::Closed) => WireReply::Err("host backend closed".into()),
            Err(e) => WireReply::Err(e.to_string()),
        }
    }
    match req {
        WireRequest::Describe => (relay(backend.describe(), WireReply::Describe), false),
        WireRequest::Dispatch(r) => {
            // re-stamp `host_ns` at the daemon boundary so the client's
            // `round_trip − host_ns` isolates pure transport: the local
            // backend's own stamp misses this function's dispatch
            // bookkeeping
            let started = std::time::Instant::now();
            let rep = backend.dispatch(r).map(|mut rep| {
                rep.host_ns = started.elapsed().as_nanos() as u64;
                rep
            });
            (relay(rep, WireReply::Dispatch), false)
        }
        WireRequest::Program(r) => (relay(backend.program(r), WireReply::Program), false),
        WireRequest::Release(r) => (relay(backend.release(r), WireReply::Release), false),
        WireRequest::Wear => (relay(backend.wear(), WireReply::Wear), false),
        WireRequest::ResetEnergy => {
            (relay(backend.reset_energy(), |()| WireReply::ResetEnergy), false)
        }
        WireRequest::Finish => (relay(backend.finish(), WireReply::Finish), true),
    }
}

//! The worker daemon of the multi-host story: a [`Host`] binds a TCP
//! listener, fabricates **its own** chip pool, and serves the
//! [`Backend`](super::Backend) protocol to one client connection —
//! decode a request frame, execute it on an in-process
//! [`LocalBackend`], reply. A remote worker really is just a transport
//! change: the host reuses the exact execution core the local path uses.
//!
//! The daemon is **single-session**: the first connection owns the pool
//! until it sends `Finish` or hangs up, and then the daemon exits (the
//! pool's terminal report has been issued — there is nothing left to
//! serve; the in-tree usage pairs one host with one engine for the
//! host's lifetime). A malformed frame gets an `Err` reply and the
//! connection lives on — a bad client request must never take the
//! silicon down.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::thread::JoinHandle;

use super::frame::{self, WireReply, WireRequest};
use super::local::LocalBackend;
use super::{Backend, TransportError};
use crate::serve::pool::PoolConfig;

/// Host daemon construction knobs.
#[derive(Clone, Debug, Default)]
pub struct HostConfig {
    /// The pool this host fabricates and owns.
    pub pool: PoolConfig,
}

/// A running worker daemon. [`Host::spawn`] binds an OS-assigned
/// loopback port; connect a [`super::remote::RemoteBackend`] to
/// [`Host::addr`]. The daemon thread exits once a client finishes (or
/// abandons) its session; [`Host::join`] reaps it.
pub struct Host {
    addr: SocketAddr,
    handle: Option<JoinHandle<()>>,
}

impl Host {
    /// Bind `127.0.0.1:0` and serve `cfg`'s pool from a daemon thread.
    pub fn spawn(cfg: HostConfig) -> std::io::Result<Host> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let handle = std::thread::spawn(move || host_loop(listener, cfg));
        Ok(Host { addr, handle: Some(handle) })
    }

    /// The address clients connect to.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Wait for the daemon to exit (after its client finished).
    pub fn join(mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn host_loop(listener: TcpListener, cfg: HostConfig) {
    let Ok((stream, _)) = listener.accept() else { return };
    let _ = stream.set_nodelay(true);
    match LocalBackend::from_pool_config(&cfg.pool) {
        Ok(mut backend) => {
            serve_client(stream, &mut backend);
            let _ = backend.finish();
        }
        Err(e) => {
            // a host that cannot build its pool still answers: every
            // request gets the construction error relayed
            let msg = format!("host pool construction failed: {e}");
            let mut stream = stream;
            while frame::read_frame(&mut stream).is_ok() {
                let rep = frame::encode_reply(&WireReply::Err(msg.clone()));
                if frame::write_frame(&mut stream, &rep).is_err() {
                    break;
                }
            }
        }
    }
}

/// Serve one client connection to completion. Returns after `Finish`
/// has been answered or the client hung up.
fn serve_client(mut stream: TcpStream, backend: &mut LocalBackend) {
    loop {
        let payload = match frame::read_frame(&mut stream) {
            Ok(p) => p,
            Err(_) => return, // client gone (clean or not): session over
        };
        let (reply, done) = match frame::decode_request(&payload) {
            Err(e) => (WireReply::Err(format!("bad request frame: {e}")), false),
            Ok(req) => execute(backend, req),
        };
        let buf = frame::encode_reply(&reply);
        if frame::write_frame(&mut stream, &buf).is_err() {
            return;
        }
        if done {
            return;
        }
    }
}

/// Run one decoded request against the backend; the bool says whether
/// this was the session-ending `Finish`.
fn execute(backend: &mut LocalBackend, req: WireRequest) -> (WireReply, bool) {
    fn relay<T>(r: super::Result<T>, ok: impl FnOnce(T) -> WireReply) -> WireReply {
        match r {
            Ok(v) => ok(v),
            Err(TransportError::Closed) => WireReply::Err("host backend closed".into()),
            Err(e) => WireReply::Err(e.to_string()),
        }
    }
    match req {
        WireRequest::Describe => (relay(backend.describe(), WireReply::Describe), false),
        WireRequest::Dispatch(r) => (relay(backend.dispatch(r), WireReply::Dispatch), false),
        WireRequest::Program(r) => (relay(backend.program(r), WireReply::Program), false),
        WireRequest::Wear => (relay(backend.wear(), WireReply::Wear), false),
        WireRequest::ResetEnergy => {
            (relay(backend.reset_energy(), |()| WireReply::ResetEnergy), false)
        }
        WireRequest::Finish => (relay(backend.finish(), WireReply::Finish), true),
    }
}

//! [`LocalBackend`]: the worker-per-chip pool of the seed serving stack,
//! refactored onto the transport types. One OS thread per [`Chip`]
//! computes dot maps, programs migrated shards, and reports wear; the
//! backend front end fans a [`DispatchRequest`]'s shard list out by chip
//! and merges the dot vectors back into one [`DispatchReply`].
//!
//! This is both halves of the wire: the in-process backend the engine
//! uses directly, and the execution core a [`super::host::Host`] daemon
//! wraps to serve [`super::remote::RemoteBackend`] clients.
//!
//! Workers are stateless with respect to routing — every dots job names
//! the shards it wants — so the coordinator can re-shard between batches
//! without touching a worker. Each worker *does* own its chip's
//! [`RowAllocator`] (append-only, rows retired on stuck tiles), because
//! allocation must live wherever the chip lives: on a remote host, the
//! client cannot reach into the host's arrays.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::anyhow;

use crate::chip::{Chip, WearLedger};
use crate::cim::mapping::{store_bits, store_int8, RowAllocator, RowSpan};
use crate::cim::vmm;
use crate::serve::pool::{ChipPool, PoolConfig};

use super::{
    Backend, BackendInfo, DispatchReply, DispatchRequest, FinishReply, OwnedPayload, ProgramReply,
    ProgramRequest, ReleaseReply, ReleaseRequest, Result, ShardRef, TraceContext, TransportError,
    WearReply, WireWindows,
};

/// Process-wide incarnation counter: every fabricated pool gets a fresh
/// identity, so a restarted [`super::host::Host`] (which fabricates a
/// new pool) is distinguishable from a surviving one whose TCP
/// connection merely dropped.
static NEXT_INCARNATION: AtomicU64 = AtomicU64::new(1);

/// One instruction to a chip worker.
enum ChipJob {
    /// Compute dots of this chip's subset of the named shards.
    Dots { shards: Arc<Vec<ShardRef>>, windows: WireWindows },
    /// Allocate a fresh span and program the payload into it.
    Program { payload: OwnedPayload },
    /// Return a span's rows to the chip's allocator (the migration
    /// protocol's drained **free** step).
    Release { span: RowSpan },
    /// Report lifetime wear + free rows.
    Wear,
    /// Zero the energy/timing ledgers (wear persists).
    ResetEnergy,
}

/// A chip worker's answer, tagged with its chip index by the send loop.
enum ChipReply {
    Dots(Vec<(u32, Vec<i64>)>),
    Programmed { span: Option<RowSpan>, failures: u64 },
    Released { accepted: bool, rows_free: u64 },
    Wear { wear: WearLedger, rows_free: u64 },
    EnergyReset,
}

fn chip_worker(
    idx: usize,
    mut chip: Chip,
    mut alloc: RowAllocator,
    jobs: Receiver<ChipJob>,
    results: SyncSender<(usize, ChipReply)>,
) -> Chip {
    while let Ok(job) = jobs.recv() {
        let reply = match job {
            ChipJob::Dots { shards, windows } => {
                let mut dots = Vec::new();
                for s in shards.iter().filter(|s| s.chip as usize == idx) {
                    let d = match &windows {
                        WireWindows::Binary(pw) => {
                            vmm::binary_dots_batched(&mut chip, &s.span, pw)
                        }
                        WireWindows::Int8(pw) => vmm::int8_dots_batched(&mut chip, &s.span, pw),
                    };
                    dots.push((s.filter, d));
                }
                ChipReply::Dots(dots)
            }
            ChipJob::Program { payload } => match alloc.alloc(payload.cells()) {
                None => ChipReply::Programmed { span: None, failures: 0 },
                Some(span) => {
                    let failures = match &payload {
                        OwnedPayload::Binary(bits) => store_bits(&mut chip, &span, bits),
                        OwnedPayload::Int8(ws) => store_int8(&mut chip, &span, ws),
                    };
                    // a failed store retires the span (append-only
                    // allocator): the rows stay consumed either way
                    ChipReply::Programmed { span: Some(span), failures: failures as u64 }
                }
            },
            ChipJob::Release { span } => {
                let accepted = alloc.release(&span);
                ChipReply::Released { accepted, rows_free: alloc.rows_free() as u64 }
            }
            ChipJob::Wear => ChipReply::Wear {
                wear: chip.wear.clone(),
                rows_free: alloc.rows_free() as u64,
            },
            ChipJob::ResetEnergy => {
                chip.reset_ledgers();
                ChipReply::EnergyReset
            }
        };
        if results.send((idx, reply)).is_err() {
            break; // backend gone: shut down
        }
    }
    chip
}

/// An in-process [`Backend`] over a pool of chips, one worker thread
/// per chip. Dots jobs run in parallel across the involved chips; the
/// control operations (program / wear / reset / finish) are sequential.
pub struct LocalBackend {
    job_txs: Vec<SyncSender<ChipJob>>,
    res_rx: Receiver<(usize, ChipReply)>,
    handles: Vec<JoinHandle<Chip>>,
    data_cols: usize,
    /// Array geometry (uniform across the pool), used to reject
    /// semantically bogus shard addresses before they reach a worker.
    blocks: usize,
    logical_rows: usize,
    incarnation: u64,
    finished: Option<FinishReply>,
}

impl LocalBackend {
    /// Fabricate and form a fresh pool per `cfg` and spawn its workers.
    pub fn from_pool_config(cfg: &PoolConfig) -> anyhow::Result<LocalBackend> {
        let pool = ChipPool::new(cfg);
        if pool.is_empty() {
            return Err(anyhow!("engine needs a non-empty pool"));
        }
        let allocs: Vec<RowAllocator> = pool.chips().iter().map(RowAllocator::for_chip).collect();
        LocalBackend::from_parts(pool.into_chips(), allocs)
    }

    /// Wrap already-built (possibly already-placed) chips with the row
    /// allocators that placed them — the allocators must be the ones
    /// used for any prior programming, or fresh allocations would
    /// double-book occupied rows.
    // lint: allow(panic-freedom) — worker setup indexes 0..n_chips over vectors it just built at that length
    pub fn from_parts(chips: Vec<Chip>, allocs: Vec<RowAllocator>) -> anyhow::Result<LocalBackend> {
        if chips.is_empty() {
            return Err(anyhow!("engine needs a non-empty pool"));
        }
        if chips.len() != allocs.len() {
            return Err(anyhow!("one row allocator per chip"));
        }
        let data_cols = chips[0].cfg().data_cols();
        let blocks = chips[0].cfg().blocks;
        let logical_rows = chips[0].cfg().logical_rows();
        // bounded worker plumbing: dispatch is sequential (&mut self)
        // and fully drains each chip's replies before the next job, so
        // at most one job per chip and one reply per chip are ever in
        // flight — the capacities below can never block the senders
        let n_chips = chips.len();
        let (res_tx, res_rx) = sync_channel::<(usize, ChipReply)>(n_chips);
        let mut job_txs = Vec::with_capacity(n_chips);
        let mut handles = Vec::with_capacity(n_chips);
        for (i, (chip, alloc)) in chips.into_iter().zip(allocs).enumerate() {
            let (jtx, jrx) = sync_channel::<ChipJob>(2);
            let rtx = res_tx.clone();
            handles.push(std::thread::spawn(move || chip_worker(i, chip, alloc, jrx, rtx)));
            job_txs.push(jtx);
        }
        Ok(LocalBackend {
            job_txs,
            res_rx,
            handles,
            data_cols,
            blocks,
            logical_rows,
            incarnation: NEXT_INCARNATION.fetch_add(1, Ordering::Relaxed),
            finished: None,
        })
    }

    /// Reject a shard address the arrays cannot hold. The frame codec
    /// guarantees well-formed *bytes*; this guards well-formed *content*
    /// — a forged span must come back as a clean `Remote` error, never
    /// panic a chip worker (which would hang the whole backend).
    fn check_shard(&self, s: &ShardRef) -> Result<()> {
        let n = self.job_txs.len();
        if s.chip as usize >= n {
            return Err(TransportError::Remote(format!(
                "shard names chip {} of a {n}-chip backend",
                s.chip
            )));
        }
        let span = &s.span;
        if span.slots.is_empty()
            || span.tail_width == 0
            || span.tail_width > self.data_cols
            || span.len != (span.slots.len() - 1) * self.data_cols + span.tail_width
        {
            return Err(TransportError::Remote(format!(
                "shard span geometry is inconsistent ({} slots, tail {}, len {})",
                span.slots.len(),
                span.tail_width,
                span.len
            )));
        }
        if let Some(&(b, r)) = span
            .slots
            .iter()
            .find(|&&(b, r)| b >= self.blocks || r >= self.logical_rows)
        {
            return Err(TransportError::Remote(format!(
                "shard slot ({b}, {r}) outside the {}x{} array geometry",
                self.blocks, self.logical_rows
            )));
        }
        Ok(())
    }

    fn live(&self) -> Result<()> {
        if self.finished.is_some() {
            return Err(TransportError::Closed);
        }
        Ok(())
    }

    // lint: allow(panic-freedom) — job_txs is sized to n_chips and chip ids were validated at dispatch entry
    fn send(&self, chip: usize, job: ChipJob) -> Result<()> {
        self.job_txs[chip].send(job).map_err(|_| TransportError::Closed)
    }

    fn recv(&self) -> Result<(usize, ChipReply)> {
        self.res_rx.recv().map_err(|_| TransportError::Closed)
    }
}

impl Backend for LocalBackend {
    fn describe(&mut self) -> Result<BackendInfo> {
        self.live()?;
        Ok(BackendInfo {
            chips: self.job_txs.len() as u32,
            data_cols: self.data_cols as u32,
            incarnation: self.incarnation,
        })
    }

    // lint: allow(panic-freedom) — reply indices were produced by workers that only ever hold valid chip ids
    fn dispatch(&mut self, req: DispatchRequest) -> Result<DispatchReply> {
        let started = std::time::Instant::now();
        self.live()?;
        // content validation (same spirit as `check_shard`): the dots
        // kernels index planes/sums by window and assert span-vs-window
        // geometry, so a forged shape must be rejected here, not let
        // panic a worker
        let (n_windows, seg_widths, planes, sums) = match &req.windows {
            WireWindows::Binary(pw) => {
                (pw.n_windows, &pw.seg_widths, pw.planes.len(), pw.sum_x.len())
            }
            WireWindows::Int8(pw) => {
                (pw.n_windows, &pw.seg_widths, pw.planes.len(), pw.sum_ux.len())
            }
        };
        let n_seg = seg_widths.len();
        if planes != n_windows * 8 * n_seg || sums != n_windows {
            return Err(TransportError::Remote(format!(
                "packed windows shape is inconsistent ({n_windows} windows, {n_seg} segments, \
                 {planes} plane words, {sums} sums)"
            )));
        }
        // `pack_windows`/`pack_windows_i8` refuse to build these, but a
        // wire peer can forge one — a zero-width (fully pruned) or
        // over-wide segment must bounce here, never panic a worker
        if seg_widths.iter().any(|&w| w == 0 || w > 64) {
            return Err(TransportError::Remote(format!(
                "packed windows carry a degenerate segment width (widths {seg_widths:?})"
            )));
        }
        let n = self.job_txs.len();
        let mut involved = vec![false; n];
        for s in req.shards.iter() {
            self.check_shard(s)?;
            if s.span.slots.len() != n_seg {
                return Err(TransportError::Remote(format!(
                    "shard span has {} row segments but the windows pack {n_seg}",
                    s.span.slots.len()
                )));
            }
            involved[s.chip as usize] = true;
        }
        let mut expected = 0usize;
        for (c, on) in involved.iter().enumerate() {
            if *on {
                self.send(
                    c,
                    ChipJob::Dots { shards: Arc::clone(&req.shards), windows: req.windows.clone() },
                )?;
                expected += 1;
            }
        }
        let mut dots = Vec::with_capacity(req.shards.len());
        for _ in 0..expected {
            match self.recv()? {
                (_, ChipReply::Dots(d)) => dots.extend(d),
                _ => unreachable!("only dots jobs are in flight during a dispatch"),
            }
        }
        Ok(DispatchReply {
            request_id: req.request_id,
            shard_epoch: req.shard_epoch,
            layer: req.layer,
            dots,
            trace: req.trace,
            host_ns: started.elapsed().as_nanos() as u64,
        })
    }

    fn program(&mut self, req: ProgramRequest) -> Result<ProgramReply> {
        self.live()?;
        let c = req.chip as usize;
        if c >= self.job_txs.len() {
            return Err(TransportError::Remote(format!(
                "program names chip {c} of a {}-chip backend",
                self.job_txs.len()
            )));
        }
        self.send(c, ChipJob::Program { payload: req.payload })?;
        match self.recv()? {
            (_, ChipReply::Programmed { span, failures }) => Ok(ProgramReply { span, failures }),
            _ => unreachable!("only the program job is in flight"),
        }
    }

    fn release(&mut self, req: ReleaseRequest) -> Result<ReleaseReply> {
        self.live()?;
        let c = req.chip as usize;
        if c >= self.job_txs.len() {
            return Err(TransportError::Remote(format!(
                "release names chip {c} of a {}-chip backend",
                self.job_txs.len()
            )));
        }
        // geometry is validated here; *ownership* (the span was handed
        // out by this chip's allocator and not yet freed) is validated
        // by the allocator itself, so a stale span from a dead pool
        // incarnation — or a double release — is refused instead of
        // double-booking rows
        if let Some(&(b, r)) = req
            .span
            .slots
            .iter()
            .find(|&&(b, r)| b >= self.blocks || r >= self.logical_rows)
        {
            return Err(TransportError::Remote(format!(
                "release slot ({b}, {r}) outside the {}x{} array geometry",
                self.blocks, self.logical_rows
            )));
        }
        self.send(c, ChipJob::Release { span: req.span })?;
        match self.recv()? {
            (_, ChipReply::Released { accepted: true, rows_free }) => {
                Ok(ReleaseReply { rows_free })
            }
            (_, ChipReply::Released { accepted: false, .. }) => Err(TransportError::Remote(
                "release names rows this allocator does not currently own".into(),
            )),
            _ => unreachable!("only the release job is in flight"),
        }
    }

    // lint: allow(panic-freedom) — per-chip ledger vectors are sized to n_chips; the expect documents that workers outlive the backend
    fn wear(&mut self) -> Result<WearReply> {
        self.live()?;
        let n = self.job_txs.len();
        for c in 0..n {
            self.send(c, ChipJob::Wear)?;
        }
        let mut wear: Vec<Option<WearLedger>> = vec![None; n];
        let mut rows_free = vec![0u64; n];
        for _ in 0..n {
            match self.recv()? {
                (c, ChipReply::Wear { wear: w, rows_free: r }) => {
                    wear[c] = Some(w);
                    rows_free[c] = r;
                }
                _ => unreachable!("only wear probes are in flight"),
            }
        }
        Ok(WearReply {
            wear: wear.into_iter().map(|w| w.expect("every chip reports wear")).collect(),
            rows_free,
        })
    }

    fn reset_energy(&mut self) -> Result<()> {
        self.live()?;
        let n = self.job_txs.len();
        for c in 0..n {
            self.send(c, ChipJob::ResetEnergy)?;
        }
        for _ in 0..n {
            match self.recv()? {
                (_, ChipReply::EnergyReset) => {}
                _ => unreachable!("only energy resets are in flight"),
            }
        }
        Ok(())
    }

    // lint: allow(panic-freedom) — join handles are present until finish() takes them exactly once
    fn finish(&mut self) -> Result<FinishReply> {
        if let Some(rep) = &self.finished {
            return Ok(rep.clone());
        }
        self.job_txs.clear(); // hang up: workers drain and return chips
        let chips: Vec<Chip> = std::mem::take(&mut self.handles)
            .into_iter()
            .map(|h| h.join().expect("chip worker panicked"))
            .collect();
        let rep = FinishReply {
            energy_pj: chips.iter().map(|c| c.energy_breakdown().total_pj()).sum(),
            wear: chips.iter().map(|c| c.wear.clone()).collect(),
        };
        self.finished = Some(rep.clone());
        Ok(rep)
    }
}

impl Drop for LocalBackend {
    fn drop(&mut self) {
        let _ = self.finish();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chip::ChipConfig;
    use crate::cim::mapping::segment_widths;

    fn backend(chips: usize, seed: u64) -> LocalBackend {
        LocalBackend::from_pool_config(&PoolConfig {
            chips,
            chip: ChipConfig::small_test(),
            seed,
        })
        .unwrap()
    }

    #[test]
    fn program_then_dispatch_is_bit_exact_vs_reference() {
        let mut b = backend(2, 0x10ca1);
        let info = b.describe().unwrap();
        assert_eq!(info.chips, 2);
        let bits: Vec<bool> = (0..17).map(|i| i % 3 == 0).collect();
        let rep = b
            .program(ProgramRequest { chip: 1, payload: OwnedPayload::Binary(bits.clone()) })
            .unwrap();
        assert_eq!(rep.failures, 0, "ideal chip stores cleanly");
        let span = rep.span.expect("fresh chip has rows");
        // two windows of u8 activations against the stored sign bits
        let widths = segment_widths(bits.len(), info.data_cols as usize);
        let flat: Vec<u8> = (0..2 * bits.len()).map(|i| (i * 7 % 256) as u8).collect();
        let pw = Arc::new(vmm::pack_windows(&flat, &widths).unwrap());
        let reply = b
            .dispatch(DispatchRequest {
                request_id: 42,
                shard_epoch: 7,
                layer: 0,
                trace: TraceContext { trace_id: 9, parent_span: 1, span_id: 2 },
                shards: Arc::new(vec![ShardRef { chip: 1, filter: 5, span }]),
                windows: WireWindows::Binary(pw),
            })
            .unwrap();
        assert_eq!((reply.request_id, reply.shard_epoch, reply.layer), (42, 7, 0));
        assert_eq!(reply.trace.trace_id, 9, "reply echoes the request's trace context");
        assert_eq!(reply.dots.len(), 1);
        let (f, dots) = &reply.dots[0];
        assert_eq!(*f, 5);
        let want: Vec<i64> = flat
            .chunks(bits.len())
            .map(|w| vmm::binary_dot_ref(&bits, w))
            .collect();
        assert_eq!(dots, &want, "backend dots diverge from the integer reference");
    }

    #[test]
    fn wear_and_finish_report_per_chip_state() {
        let mut b = backend(3, 0x10ca2);
        let w = b.wear().unwrap();
        assert_eq!(w.wear.len(), 3);
        assert_eq!(w.rows_free.len(), 3);
        assert!(w.wear.iter().all(|l| l.write_pulses > 0), "forming wear on the ledgers");
        assert!(w.rows_free.iter().all(|&r| r > 0));
        b.reset_energy().unwrap();
        let fin = b.finish().unwrap();
        assert_eq!(fin.wear.len(), 3);
        assert_eq!(fin.energy_pj, 0.0, "energy ledgers were just reset");
        // after finish every op is a clean Closed error
        assert!(matches!(b.describe(), Err(TransportError::Closed)));
        assert!(matches!(b.wear(), Err(TransportError::Closed)));
        // finish is idempotent
        assert_eq!(b.finish().unwrap().wear.len(), 3);
    }

    #[test]
    fn released_rows_are_reprogrammable_and_stay_bit_exact() {
        let mut b = backend(1, 0x10ca5);
        let info = b.describe().unwrap();
        assert!(info.incarnation > 0, "every pool carries a nonzero incarnation");
        let per_row = info.data_cols as usize;
        let before = b.wear().unwrap().rows_free[0];
        let bits: Vec<bool> = (0..3 * per_row).map(|i| i % 2 == 0).collect();
        let span = b
            .program(ProgramRequest { chip: 0, payload: OwnedPayload::Binary(bits.clone()) })
            .unwrap()
            .span
            .expect("fresh chip has rows");
        assert_eq!(b.wear().unwrap().rows_free[0], before - 3);
        // free the span: capacity is restored exactly
        let rep = b.release(ReleaseRequest { chip: 0, span: span.clone() }).unwrap();
        assert_eq!(rep.rows_free, before);
        // a fresh program recycles the released rows; dots computed over
        // the overwritten cells match the new payload, not the old one
        let flipped: Vec<bool> = bits.iter().map(|&x| !x).collect();
        let rep = b
            .program(ProgramRequest { chip: 0, payload: OwnedPayload::Binary(flipped.clone()) })
            .unwrap();
        assert_eq!(rep.failures, 0, "ideal chip stores cleanly");
        let span2 = rep.span.unwrap();
        for slot in &span2.slots {
            assert!(span.slots.contains(slot), "recycled program must reuse released rows");
        }
        let widths = segment_widths(flipped.len(), per_row);
        let flat: Vec<u8> = (0..flipped.len()).map(|i| (i * 11 % 256) as u8).collect();
        let pw = Arc::new(vmm::pack_windows(&flat, &widths).unwrap());
        let reply = b
            .dispatch(DispatchRequest {
                request_id: 1,
                shard_epoch: 1,
                layer: 0,
                trace: TraceContext::none(),
                shards: Arc::new(vec![ShardRef { chip: 0, filter: 0, span: span2 }]),
                windows: WireWindows::Binary(pw),
            })
            .unwrap();
        assert_eq!(reply.dots[0].1, vec![vmm::binary_dot_ref(&flipped, &flat)]);
        // a forged release is a clean Remote error, never a poisoned pool
        let bogus = RowSpan { slots: vec![(99, 99_999)], tail_width: 1, len: 1 };
        assert!(matches!(
            b.release(ReleaseRequest { chip: 0, span: bogus }),
            Err(TransportError::Remote(_))
        ));
        assert!(matches!(
            b.release(ReleaseRequest { chip: 7, span: span }),
            Err(TransportError::Remote(_))
        ));
    }

    #[test]
    fn bad_chip_index_is_a_clean_remote_error() {
        let mut b = backend(1, 0x10ca3);
        let err = b
            .program(ProgramRequest { chip: 9, payload: OwnedPayload::Binary(vec![true]) })
            .unwrap_err();
        assert!(matches!(err, TransportError::Remote(_)));
    }

    #[test]
    fn forged_shard_content_is_rejected_not_panicked() {
        // a wire-decodable request can still be semantically bogus; the
        // backend must answer with a Remote error, never panic a worker
        // (which would hang every later dispatch)
        let mut b = backend(1, 0x10ca4);
        let info = b.describe().unwrap();
        let windows = WireWindows::Binary(Arc::new(vmm::PackedWindows {
            n_windows: 1,
            seg_widths: vec![4],
            planes: vec![0; 8],
            sum_x: vec![0],
        }));
        let dispatch = |b: &mut LocalBackend, span: RowSpan| {
            b.dispatch(DispatchRequest {
                request_id: 1,
                shard_epoch: 1,
                layer: 0,
                trace: TraceContext::none(),
                shards: Arc::new(vec![ShardRef { chip: 0, filter: 0, span }]),
                windows: windows.clone(),
            })
        };
        // out-of-range row
        let bogus = RowSpan { slots: vec![(0, 99_999)], tail_width: 4, len: 4 };
        assert!(matches!(dispatch(&mut b, bogus), Err(TransportError::Remote(_))));
        // inconsistent span geometry
        let bogus = RowSpan { slots: vec![(0, 0)], tail_width: 4, len: 4000 };
        assert!(matches!(dispatch(&mut b, bogus), Err(TransportError::Remote(_))));
        // span segments disagree with the packed windows
        let bogus = RowSpan { slots: vec![(0, 0), (0, 1)], tail_width: 4, len: info.data_cols as usize + 4 };
        assert!(matches!(dispatch(&mut b, bogus), Err(TransportError::Remote(_))));
        // the backend is still alive and serving
        assert_eq!(b.describe().unwrap().chips, 1);
    }

    #[test]
    fn degenerate_window_geometry_is_rejected_at_the_seam() {
        // `pack_windows` refuses to build a zero-width (fully pruned)
        // segment, but a wire peer can forge one; the backend must
        // bounce it with a clean Remote error before a kernel indexes
        // by it — the regression behind this was a worker panic
        let mut b = backend(1, 0x10ca5);
        let windows = WireWindows::Binary(Arc::new(vmm::PackedWindows {
            n_windows: 1,
            seg_widths: vec![0],
            planes: vec![0; 8],
            sum_x: vec![0],
        }));
        let err = b
            .dispatch(DispatchRequest {
                request_id: 1,
                shard_epoch: 1,
                layer: 0,
                trace: TraceContext::none(),
                shards: Arc::new(vec![]),
                windows,
            })
            .unwrap_err();
        match err {
            TransportError::Remote(msg) => {
                assert!(msg.contains("degenerate segment width"), "{msg}")
            }
            other => panic!("expected a Remote error, got {other:?}"),
        }
        // the backend is still alive and serving
        assert_eq!(b.describe().unwrap().chips, 1);
    }
}

//! [`RemoteBackend`]: the [`Backend`](super::Backend) trait spoken over
//! a TCP connection as length-prefixed frames ([`super::frame`]) — the
//! client half of the multi-host story. The server half is a
//! [`super::host::Host`] daemon serving its own pool.
//!
//! The protocol is strictly synchronous per connection (one request in
//! flight at a time); the [`super::router::ShardRouter`] gets
//! concurrency by driving each backend from its own thread, which is
//! what makes hedging a straggling host possible without an async
//! runtime.

use std::net::{TcpStream, ToSocketAddrs};

use super::frame::{self, WireReply, WireRequest};
use super::{
    Backend, BackendInfo, DispatchReply, DispatchRequest, FinishReply, ProgramReply,
    ProgramRequest, Result, TransportError, WearReply,
};

/// A backend living behind a TCP connection (loopback in the in-tree
/// tests and examples; the framing is address-agnostic).
pub struct RemoteBackend {
    stream: Option<TcpStream>,
}

impl RemoteBackend {
    /// Connect to a [`super::host::Host`] daemon.
    pub fn connect(addr: impl ToSocketAddrs) -> std::io::Result<RemoteBackend> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(RemoteBackend { stream: Some(stream) })
    }

    fn call(&mut self, req: &WireRequest) -> Result<WireReply> {
        let stream = self.stream.as_mut().ok_or(TransportError::Closed)?;
        frame::write_frame(stream, &frame::encode_request(req))?;
        let payload = frame::read_frame(stream)?;
        match frame::decode_reply(&payload)? {
            WireReply::Err(msg) => Err(TransportError::Remote(msg)),
            rep => Ok(rep),
        }
    }
}

impl Backend for RemoteBackend {
    fn describe(&mut self) -> Result<BackendInfo> {
        match self.call(&WireRequest::Describe)? {
            WireReply::Describe(info) => Ok(info),
            rep => Err(TransportError::Frame(format!("unexpected reply {rep:?} to Describe"))),
        }
    }

    fn dispatch(&mut self, req: DispatchRequest) -> Result<DispatchReply> {
        match self.call(&WireRequest::Dispatch(req))? {
            WireReply::Dispatch(rep) => Ok(rep),
            rep => Err(TransportError::Frame(format!("unexpected reply {rep:?} to Dispatch"))),
        }
    }

    fn program(&mut self, req: ProgramRequest) -> Result<ProgramReply> {
        match self.call(&WireRequest::Program(req))? {
            WireReply::Program(rep) => Ok(rep),
            rep => Err(TransportError::Frame(format!("unexpected reply {rep:?} to Program"))),
        }
    }

    fn wear(&mut self) -> Result<WearReply> {
        match self.call(&WireRequest::Wear)? {
            WireReply::Wear(rep) => Ok(rep),
            rep => Err(TransportError::Frame(format!("unexpected reply {rep:?} to Wear"))),
        }
    }

    fn reset_energy(&mut self) -> Result<()> {
        match self.call(&WireRequest::ResetEnergy)? {
            WireReply::ResetEnergy => Ok(()),
            rep => Err(TransportError::Frame(format!("unexpected reply {rep:?} to ResetEnergy"))),
        }
    }

    fn finish(&mut self) -> Result<FinishReply> {
        let rep = self.call(&WireRequest::Finish)?;
        // the host closes its side after Finish; drop ours too so a
        // late call is a clean Closed, not a broken pipe
        self.stream = None;
        match rep {
            WireReply::Finish(rep) => Ok(rep),
            rep => Err(TransportError::Frame(format!("unexpected reply {rep:?} to Finish"))),
        }
    }
}

//! [`RemoteBackend`]: the [`Backend`](super::Backend) trait spoken over
//! a TCP connection as length-prefixed frames ([`super::frame`]) — the
//! client half of the multi-host story. The server half is a
//! [`super::host::Host`] daemon serving its own pool.
//!
//! The protocol is strictly synchronous per connection (one request in
//! flight at a time); the [`super::router::ShardRouter`] gets
//! concurrency by driving each backend from its own thread, which is
//! what makes hedging a straggling host possible without an async
//! runtime.
//!
//! # Reconnect lifecycle
//!
//! A dropped connection is not a dead pool. On an I/O failure the
//! backend re-dials its address with bounded exponential backoff
//! ([`ReconnectPolicy`]) and re-runs the incarnation handshake (a
//! `Describe` on the fresh connection):
//!
//! * **same incarnation** — the host survived; its pool still holds
//!   every programmed shard. The in-flight request is replayed iff it
//!   is idempotent (dispatch, describe, wear, finish are; `program` and
//!   `release` are not — their row-allocator effects may or may not
//!   have landed, so the error is surfaced and only a wear probe
//!   resyncs the truth).
//! * **new incarnation** — the host *bounced*: a replacement daemon
//!   fabricated a fresh pool and every shard this client programmed is
//!   gone. The backend **quarantines itself**: every `dispatch` fails
//!   fast (computing dots against unprogrammed arrays would return
//!   well-formed garbage, which no epoch check could catch), while
//!   `program`/`wear`/`describe` still pass so the owner can re-program
//!   the current placement at the current epoch and then lift the
//!   quarantine with [`Backend::rejoin`](super::Backend::rejoin).
//!
//! The owning [`super::router::ShardRouter`] observes the bounce via
//! [`Backend::health`](super::Backend::health) and drives the
//! re-program + rejoin sequence (DESIGN.md §9).

use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::Duration;

use super::frame::{self, WireReply, WireRequest};
use super::{
    Backend, BackendInfo, DispatchReply, DispatchRequest, FinishReply, HealthReply, ProgramReply,
    ProgramRequest, ReleaseReply, ReleaseRequest, Result, TransportError, WearReply,
};

/// Bounded-backoff reconnect knobs for a [`RemoteBackend`].
#[derive(Clone, Debug)]
pub struct ReconnectPolicy {
    /// Re-dial attempts per failure before the error is surfaced;
    /// 0 disables reconnecting entirely.
    pub max_attempts: u32,
    /// Backoff before the second attempt (the first re-dial is
    /// immediate); doubles per attempt.
    pub base: Duration,
    /// Backoff clamp.
    pub cap: Duration,
}

impl Default for ReconnectPolicy {
    fn default() -> Self {
        ReconnectPolicy {
            max_attempts: 6,
            base: Duration::from_millis(20),
            cap: Duration::from_millis(400),
        }
    }
}

impl ReconnectPolicy {
    /// No reconnecting: every connection failure is surfaced at once.
    pub fn disabled() -> Self {
        ReconnectPolicy { max_attempts: 0, ..ReconnectPolicy::default() }
    }

    /// Backoff before re-dial `attempt` (0-based; attempt 0 re-dials
    /// immediately): `base << (attempt - 1)`, clamped to `cap`. Every
    /// step saturates — a pathological policy (`base` or `cap` near
    /// [`Duration::MAX`]) degrades to the clamp where `Duration`'s
    /// plain `Mul` would abort the worker thread on overflow.
    pub fn backoff_delay(&self, attempt: u32) -> Duration {
        if attempt == 0 {
            return Duration::ZERO;
        }
        let factor = 1u32 << (attempt - 1).min(16);
        self.base.saturating_mul(factor).min(self.cap)
    }

    /// Per-attempt bound on the reconnect dial and handshake I/O, so a
    /// half-open host (accepting into the backlog while parked on a
    /// dead session) cannot wedge a nominally bounded retry loop.
    /// `4 * cap` with a one-second floor, saturating like
    /// [`ReconnectPolicy::backoff_delay`].
    pub fn handshake_timeout(&self) -> Duration {
        self.cap.saturating_mul(4).max(Duration::from_secs(1))
    }
}

/// A backend living behind a TCP connection (loopback in the in-tree
/// tests and examples; the framing and reconnect logic are
/// address-agnostic).
pub struct RemoteBackend {
    addr: SocketAddr,
    policy: ReconnectPolicy,
    stream: Option<TcpStream>,
    /// Incarnation of the pool our shards were programmed into, from
    /// the connect-time handshake.
    incarnation: Option<u64>,
    reconnects: u64,
    bounced: bool,
    /// `finish` was served: every further call is a clean `Closed`.
    finished: bool,
}

impl RemoteBackend {
    /// Connect to a [`super::host::Host`] daemon with the default
    /// [`ReconnectPolicy`] and run the incarnation handshake.
    ///
    /// # Errors
    ///
    /// [`TransportError::Io`] when the address does not resolve or the
    /// dial fails; handshake failures as their transport error.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<RemoteBackend> {
        RemoteBackend::connect_with(addr, ReconnectPolicy::default())
    }

    /// [`RemoteBackend::connect`] with explicit reconnect knobs.
    ///
    /// # Errors
    ///
    /// See [`RemoteBackend::connect`].
    pub fn connect_with(
        addr: impl ToSocketAddrs,
        policy: ReconnectPolicy,
    ) -> Result<RemoteBackend> {
        let addr = addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| {
                TransportError::Io(std::io::Error::new(
                    std::io::ErrorKind::AddrNotAvailable,
                    "address resolved to nothing",
                ))
            })?;
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let mut backend = RemoteBackend {
            addr,
            policy,
            stream: Some(stream),
            incarnation: None,
            reconnects: 0,
            bounced: false,
            finished: false,
        };
        backend.handshake()?;
        Ok(backend)
    }

    /// Connections re-established so far.
    pub fn reconnects(&self) -> u64 {
        self.reconnects
    }

    /// Is this backend quarantined after reconnecting to a fresh pool?
    pub fn is_bounced(&self) -> bool {
        self.bounced
    }

    /// One `Describe` round-trip recording (or checking) the pool
    /// incarnation; flips the bounce quarantine on when the pool
    /// changed identity under us.
    fn handshake(&mut self) -> Result<()> {
        let info = match self.call_raw(&WireRequest::Describe)? {
            WireReply::Describe(info) => info,
            rep => {
                return Err(TransportError::Frame(format!(
                    "unexpected reply {rep:?} to the handshake Describe"
                )))
            }
        };
        match self.incarnation {
            None => self.incarnation = Some(info.incarnation),
            Some(inc) if inc != info.incarnation => {
                self.incarnation = Some(info.incarnation);
                self.bounced = true;
            }
            Some(_) => {}
        }
        Ok(())
    }

    /// Bounded-backoff re-dial + handshake. `true` once a connection is
    /// live again (possibly to a bounced pool — see `self.bounced`).
    fn try_reconnect(&mut self) -> bool {
        self.stream = None;
        let timeout = self.policy.handshake_timeout();
        for attempt in 0..self.policy.max_attempts {
            let delay = self.policy.backoff_delay(attempt);
            if !delay.is_zero() {
                std::thread::sleep(delay);
            }
            let Ok(stream) = TcpStream::connect_timeout(&self.addr, timeout) else { continue };
            if stream.set_nodelay(true).is_err()
                || stream.set_read_timeout(Some(timeout)).is_err()
                || stream.set_write_timeout(Some(timeout)).is_err()
            {
                continue;
            }
            self.stream = Some(stream);
            let handshook = self.handshake().is_ok();
            // lift the timeouts for normal operation: a dispatch may
            // legitimately compute for longer than any handshake bound
            let lifted = self
                .stream
                .as_ref()
                .map(|s| {
                    s.set_read_timeout(None).is_ok() && s.set_write_timeout(None).is_ok()
                })
                .unwrap_or(false);
            if handshook && lifted {
                self.reconnects += 1;
                return true;
            }
            self.stream = None;
        }
        false
    }

    /// One request/reply on the live stream — no reconnect logic.
    fn call_raw(&mut self, req: &WireRequest) -> Result<WireReply> {
        let stream = self.stream.as_mut().ok_or_else(|| {
            TransportError::Io(std::io::Error::new(
                std::io::ErrorKind::NotConnected,
                "connection is down",
            ))
        })?;
        frame::write_frame(stream, &frame::encode_request(req))?;
        let payload = frame::read_frame(stream)?;
        match frame::decode_reply(&payload)? {
            WireReply::Err(msg) => Err(TransportError::Remote(msg)),
            rep => Ok(rep),
        }
    }

    /// One request/reply with the reconnect lifecycle wrapped around
    /// it. `idempotent` requests are replayed once after a successful
    /// same-incarnation reconnect; everything else surfaces the
    /// original error (the connection is still re-established for the
    /// next caller).
    fn call(&mut self, req: &WireRequest, idempotent: bool) -> Result<WireReply> {
        if self.finished {
            return Err(TransportError::Closed);
        }
        if self.bounced && matches!(req, WireRequest::Dispatch(_)) {
            return Err(TransportError::Remote(
                "host bounced: shards lost, awaiting re-program + rejoin".into(),
            ));
        }
        if self.stream.is_none() && !self.try_reconnect() {
            return Err(TransportError::Io(std::io::Error::new(
                std::io::ErrorKind::NotConnected,
                "host unreachable after bounded reconnect attempts",
            )));
        }
        // the reconnect handshake may have just flipped the quarantine
        if self.bounced && matches!(req, WireRequest::Dispatch(_)) {
            return Err(TransportError::Remote(
                "host bounced: shards lost, awaiting re-program + rejoin".into(),
            ));
        }
        match self.call_raw(req) {
            Ok(rep) => Ok(rep),
            Err(e @ (TransportError::Io(_) | TransportError::Closed)) => {
                // the connection died mid-call: re-establish it for the
                // next caller…
                if !self.try_reconnect() {
                    return Err(e);
                }
                // …and replay only what is safe: idempotent requests,
                // except a dispatch against a pool that bounced out
                // from under it (its shards are gone; recomputing would
                // return well-formed garbage no epoch check can catch)
                let dispatch_on_bounced =
                    self.bounced && matches!(req, WireRequest::Dispatch(_));
                if idempotent && !dispatch_on_bounced {
                    self.call_raw(req)
                } else {
                    Err(e)
                }
            }
            Err(e) => Err(e),
        }
    }
}

impl Backend for RemoteBackend {
    fn describe(&mut self) -> Result<BackendInfo> {
        match self.call(&WireRequest::Describe, true)? {
            WireReply::Describe(info) => Ok(info),
            rep => Err(TransportError::Frame(format!("unexpected reply {rep:?} to Describe"))),
        }
    }

    fn dispatch(&mut self, req: DispatchRequest) -> Result<DispatchReply> {
        match self.call(&WireRequest::Dispatch(req), true)? {
            WireReply::Dispatch(rep) => Ok(rep),
            rep => Err(TransportError::Frame(format!("unexpected reply {rep:?} to Dispatch"))),
        }
    }

    fn program(&mut self, req: ProgramRequest) -> Result<ProgramReply> {
        match self.call(&WireRequest::Program(req), false)? {
            WireReply::Program(rep) => Ok(rep),
            rep => Err(TransportError::Frame(format!("unexpected reply {rep:?} to Program"))),
        }
    }

    fn release(&mut self, req: ReleaseRequest) -> Result<ReleaseReply> {
        match self.call(&WireRequest::Release(req), false)? {
            WireReply::Release(rep) => Ok(rep),
            rep => Err(TransportError::Frame(format!("unexpected reply {rep:?} to Release"))),
        }
    }

    fn wear(&mut self) -> Result<WearReply> {
        match self.call(&WireRequest::Wear, true)? {
            WireReply::Wear(rep) => Ok(rep),
            rep => Err(TransportError::Frame(format!("unexpected reply {rep:?} to Wear"))),
        }
    }

    fn health(&mut self) -> Result<HealthReply> {
        let info = self.describe()?;
        Ok(HealthReply { info, reconnects: self.reconnects, bounced: self.bounced })
    }

    fn rejoin(&mut self) -> Result<()> {
        if self.finished {
            return Err(TransportError::Closed);
        }
        self.bounced = false;
        Ok(())
    }

    fn reset_energy(&mut self) -> Result<()> {
        match self.call(&WireRequest::ResetEnergy, false)? {
            WireReply::ResetEnergy => Ok(()),
            rep => Err(TransportError::Frame(format!("unexpected reply {rep:?} to ResetEnergy"))),
        }
    }

    fn finish(&mut self) -> Result<FinishReply> {
        let rep = self.call(&WireRequest::Finish, true)?;
        // the host exits after Finish; drop our side too so a late call
        // is a clean Closed, not a broken pipe
        self.stream = None;
        self.finished = true;
        match rep {
            WireReply::Finish(rep) => Ok(rep),
            rep => Err(TransportError::Frame(format!("unexpected reply {rep:?} to Finish"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_delays_double_and_clamp_at_the_cap() {
        let p = ReconnectPolicy::default(); // base 20ms, cap 400ms
        assert_eq!(p.backoff_delay(0), Duration::ZERO, "first re-dial is immediate");
        assert_eq!(p.backoff_delay(1), Duration::from_millis(20));
        assert_eq!(p.backoff_delay(2), Duration::from_millis(40));
        assert_eq!(p.backoff_delay(5), Duration::from_millis(320));
        assert_eq!(p.backoff_delay(6), Duration::from_millis(400), "clamped at cap");
        assert_eq!(p.backoff_delay(u32::MAX), Duration::from_millis(400));
    }

    #[test]
    fn pathological_policy_saturates_instead_of_overflowing() {
        // `Duration::MAX * 4` via Duration's plain `Mul` aborts the
        // process; the policy helpers must degrade to the clamp
        let p = ReconnectPolicy {
            max_attempts: u32::MAX,
            base: Duration::MAX,
            cap: Duration::MAX,
        };
        assert_eq!(p.backoff_delay(1), Duration::MAX);
        assert_eq!(p.backoff_delay(40), Duration::MAX);
        assert_eq!(p.handshake_timeout(), Duration::MAX);
        // a tiny cap still floors the per-attempt handshake bound
        let p = ReconnectPolicy { cap: Duration::from_nanos(1), ..ReconnectPolicy::default() };
        assert_eq!(p.handshake_timeout(), Duration::from_secs(1));
    }
}

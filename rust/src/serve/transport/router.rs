//! [`ShardRouter`]: the fleet-shaped composite over N [`Backend`]s —
//! one tenant's layers split across backends ("groups"), each group
//! optionally a **replica set** holding byte-identical shard payloads,
//! with request **hedging** for tail latency and dispatch-plane
//! **spillover** off a full member queue.
//!
//! # Topology
//!
//! ```text
//!   ShardRouter
//!     ├─ group 0: layers 0..k     [ member A ─ replica A' ]   (hedged pair)
//!     └─ group 1: layers k..N     [ member B ]                (solo)
//! ```
//!
//! Each member backend is driven from its own thread, so a synchronous
//! `Backend` (a TCP host, a local pool) becomes concurrently
//! dispatchable without an async runtime. The router itself is used
//! from one coordinator thread; its concurrency is *across members*.
//!
//! # Hedging invariant
//!
//! A dispatch goes to one member of the owning group (round-robin). If
//! no reply lands within the hedge deadline — derived from the group's
//! dispatch [`LatencyHistogram`] (`quantile(q) × factor`, clamped), or
//! fixed via [`HedgeConfig::after`] — the *same* request (same request
//! id, same shard epoch, the replica's own shard spans) is duplicated
//! to the next replica. Replies are bit-exact across replicas (digital
//! chips, byte-identical payloads), so **the first reply wins** and the
//! loser is discarded by `(request id, shard epoch)` identity when it
//! eventually arrives. A hedged duplicate can therefore never produce a
//! second answer to the caller: `dispatch_layer` returns exactly once
//! per request id, and stale replies only increment a counter.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::anyhow;

use crate::serve::model::ModelBundle;
use crate::serve::placement::Placement;
use crate::serve::stats::LatencyHistogram;

use super::{
    Backend, BackendInfo, DispatchReply, DispatchRequest, FinishReply, OwnedPayload, ProgramReply,
    ProgramRequest, Result, ShardRef, TransportError, WearReply, WireWindows,
};

/// When to duplicate a straggling dispatch to a replica.
#[derive(Clone, Debug)]
pub struct HedgeConfig {
    /// Master switch (hedging also needs a group with ≥ 2 members).
    pub enabled: bool,
    /// Fixed deadline override. `Some(Duration::ZERO)` hedges every
    /// dispatch — the determinism knob the duplicate-discard tests use.
    /// `None` derives the deadline from the latency histogram.
    pub after: Option<Duration>,
    /// Histogram quantile the deadline is derived from (0..=1).
    pub quantile: f64,
    /// Multiplier on the quantile estimate.
    pub factor: f64,
    /// Below this many recorded dispatches the deadline stays at
    /// `ceiling` (no meaningful tail estimate yet).
    pub min_samples: u64,
    /// Deadline clamp, low side.
    pub floor: Duration,
    /// Deadline clamp, high side (also the cold-start deadline).
    pub ceiling: Duration,
}

impl Default for HedgeConfig {
    fn default() -> Self {
        HedgeConfig {
            enabled: true,
            after: None,
            quantile: 0.99,
            factor: 4.0,
            min_samples: 64,
            floor: Duration::from_micros(200),
            ceiling: Duration::from_millis(250),
        }
    }
}

/// Router construction knobs.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    pub hedge: HedgeConfig,
    /// Bound on queued-but-unstarted jobs per member; a full primary
    /// queue spills the dispatch to its replica.
    pub inflight: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig { hedge: HedgeConfig::default(), inflight: 32 }
    }
}

/// Fleet-level dispatch counters (surfaced in
/// [`crate::serve::EngineReport::transport`]).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RouterStats {
    /// Layer dispatches issued (hedged duplicates not double-counted).
    pub dispatches: u64,
    /// Duplicates sent to a replica after the hedge deadline (or after
    /// the only outstanding attempt failed).
    pub hedges_fired: u64,
    /// Hedged dispatches whose *duplicate* replied first.
    pub hedge_wins: u64,
    /// Replies discarded by request-id/epoch identity (the losing half
    /// of a hedge, arriving after its request was already answered).
    pub stale_discarded: u64,
    /// Dispatches rerouted to a replica because the chosen member's
    /// bounded queue was full (dispatch-plane admission spillover).
    pub spills: u64,
}

enum MemberJob {
    Dispatch(DispatchRequest),
    Program(ProgramRequest),
    Wear,
    Describe,
    ResetEnergy,
    Finish,
}

enum MemberReply {
    Dispatch { request_id: u64, result: Result<DispatchReply> },
    Program(Result<ProgramReply>),
    Wear(Result<WearReply>),
    Describe(Result<BackendInfo>),
    ResetEnergy(Result<()>),
    Finish(Result<FinishReply>),
}

fn member_worker(
    idx: usize,
    mut backend: Box<dyn Backend>,
    jobs: Receiver<MemberJob>,
    results: Sender<(usize, MemberReply)>,
) {
    while let Ok(job) = jobs.recv() {
        let (reply, done) = match job {
            MemberJob::Dispatch(req) => {
                let request_id = req.request_id;
                (MemberReply::Dispatch { request_id, result: backend.dispatch(req) }, false)
            }
            MemberJob::Program(req) => (MemberReply::Program(backend.program(req)), false),
            MemberJob::Wear => (MemberReply::Wear(backend.wear()), false),
            MemberJob::Describe => (MemberReply::Describe(backend.describe()), false),
            MemberJob::ResetEnergy => (MemberReply::ResetEnergy(backend.reset_energy()), false),
            MemberJob::Finish => (MemberReply::Finish(backend.finish()), true),
        };
        if results.send((idx, reply)).is_err() {
            break; // router gone: shut down
        }
        if done {
            break;
        }
    }
}

struct Member {
    job_tx: Option<SyncSender<MemberJob>>,
    handle: Option<JoinHandle<()>>,
    group: usize,
    local: usize,
    info: BackendInfo,
    /// Client-side mirror of per-chip free rows (kept exact by every
    /// program reply; resynced from every wear probe).
    rows_free: Vec<usize>,
    /// Placement-ranking wear estimate per chip (resynced likewise).
    est_pulses: Vec<u64>,
    /// Rows consumed per chip over this router's lifetime (placement,
    /// stuck retries, migrations — retired rows included).
    rows_used: Vec<usize>,
}

struct Group {
    members: Vec<usize>,
    lat: LatencyHistogram,
    rr: usize,
}

/// One tenant's layer → group/shard routing, built from a
/// [`RouterPlacement`] and carried into every batch. Rebuilt (with a
/// bumped epoch) whenever a migration lands; in-flight requests keep
/// the old `Arc`s alive until their replies are folded or discarded.
#[derive(Clone, Debug)]
pub struct TenantRoute {
    /// Placement generation — stamped into every request, echoed in
    /// every reply, checked before a reply is accepted.
    pub epoch: u64,
    pub layers: Vec<LayerRoute>,
}

/// One layer's route: the owning group and, per group member, the
/// member-local shard list (each replica holds its own spans).
#[derive(Clone, Debug)]
pub struct LayerRoute {
    pub group: usize,
    pub shards: Vec<Arc<Vec<ShardRef>>>,
}

impl TenantRoute {
    /// Build the per-batch routing view of a [`RouterPlacement`].
    pub fn from_placement(p: &RouterPlacement, epoch: u64) -> TenantRoute {
        TenantRoute {
            epoch,
            layers: p
                .layers
                .iter()
                .map(|pl| LayerRoute {
                    group: pl.group,
                    shards: pl
                        .shards
                        .iter()
                        .map(|ms| Arc::new(ms.iter().flatten().cloned().collect::<Vec<_>>()))
                        .collect(),
                })
                .collect(),
        }
    }

    /// Adapt a legacy single-pool [`Placement`] (chips addressed
    /// directly, no replicas) onto a single-member group-0 route — how
    /// the legacy [`crate::serve::Server`] rides the transport seam.
    pub fn single_member(p: &Placement) -> TenantRoute {
        TenantRoute {
            epoch: 0,
            layers: p
                .shards
                .iter()
                .map(|layer| LayerRoute {
                    group: 0,
                    shards: vec![Arc::new(
                        layer
                            .iter()
                            .enumerate()
                            .filter_map(|(f, loc)| {
                                loc.as_ref().map(|loc| ShardRef {
                                    chip: loc.chip as u32,
                                    filter: f as u32,
                                    span: loc.span.clone(),
                                })
                            })
                            .collect::<Vec<_>>(),
                    )],
                })
                .collect(),
        }
    }
}

/// One model's placement across the router's fleet: per layer, the
/// owning group and — per group member — where every live filter's
/// payload was programmed. Replicas hold the same *payloads* in their
/// own *spans*.
#[derive(Clone, Debug)]
pub struct RouterPlacement {
    pub layers: Vec<PlacedLayer>,
    /// Store attempts abandoned to stuck tiles across all members.
    pub stuck_retries: usize,
}

/// See [`RouterPlacement`]; `shards[member_local][filter]`.
#[derive(Clone, Debug)]
pub struct PlacedLayer {
    pub group: usize,
    pub shards: Vec<Vec<Option<ShardRef>>>,
}

impl RouterPlacement {
    /// Rows currently occupied by live shards on one member of one
    /// group — what per-member tenant row quotas are enforced against.
    pub fn rows_live_on(&self, group: usize, member_local: usize) -> usize {
        self.layers
            .iter()
            .filter(|pl| pl.group == group)
            .flat_map(|pl| pl.shards[member_local].iter().flatten())
            .map(|s| s.span.slots.len())
            .sum()
    }

    /// Placed (live) shards, counted once per logical shard (replicas
    /// do not multiply the count).
    pub fn live_shards(&self) -> usize {
        self.layers
            .iter()
            .map(|pl| pl.shards[0].iter().filter(|s| s.is_some()).count())
            .sum()
    }
}

enum PlaceOutcome {
    Placed { chip: usize, span: crate::cim::mapping::RowSpan, retries: usize },
    NoRoom { retries: usize },
}

/// The composite front end over the fleet. See the module docs for the
/// topology and the hedging invariant.
pub struct ShardRouter {
    cfg: RouterConfig,
    members: Vec<Member>,
    groups: Vec<Group>,
    res_rx: Receiver<(usize, MemberReply)>,
    next_request: u64,
    stats: RouterStats,
}

impl ShardRouter {
    /// Build a router over `groups` of replica backends: `groups[g]`
    /// all hold the same shards once a model is placed; distinct groups
    /// own distinct layer ranges. Fails if any group is empty or the
    /// backends disagree on data-column geometry.
    pub fn new(groups: Vec<Vec<Box<dyn Backend>>>, cfg: RouterConfig) -> anyhow::Result<ShardRouter> {
        if groups.is_empty() || groups.iter().any(|g| g.is_empty()) {
            return Err(anyhow!("router needs at least one backend per group"));
        }
        if cfg.inflight == 0 {
            return Err(anyhow!("router inflight bound must be positive"));
        }
        let (res_tx, res_rx) = channel::<(usize, MemberReply)>();
        let mut members: Vec<Member> = Vec::new();
        let mut group_meta: Vec<Group> = Vec::new();
        for (gi, group) in groups.into_iter().enumerate() {
            let mut ids = Vec::with_capacity(group.len());
            for (li, backend) in group.into_iter().enumerate() {
                let idx = members.len();
                let (jtx, jrx) = std::sync::mpsc::sync_channel::<MemberJob>(cfg.inflight);
                let rtx = res_tx.clone();
                let handle = std::thread::spawn(move || member_worker(idx, backend, jrx, rtx));
                members.push(Member {
                    job_tx: Some(jtx),
                    handle: Some(handle),
                    group: gi,
                    local: li,
                    info: BackendInfo { chips: 0, data_cols: 0 },
                    rows_free: Vec::new(),
                    est_pulses: Vec::new(),
                    rows_used: Vec::new(),
                });
                ids.push(idx);
            }
            group_meta.push(Group { members: ids, lat: LatencyHistogram::default(), rr: 0 });
        }
        drop(res_tx);
        let mut router = ShardRouter {
            cfg,
            members,
            groups: group_meta,
            res_rx,
            next_request: 0,
            stats: RouterStats::default(),
        };
        for m in 0..router.members.len() {
            let info = match router.call(m, MemberJob::Describe)? {
                MemberReply::Describe(r) => r?,
                _ => unreachable!("describe answers describe"),
            };
            if info.chips == 0 {
                return Err(anyhow!("backend {m} has no chips"));
            }
            router.members[m].info = info;
            router.members[m].rows_used = vec![0; router.members[m].info.chips as usize];
            router.wear_member(m)?;
        }
        let dc = router.members[0].info.data_cols;
        if router.members.iter().any(|m| m.info.data_cols != dc) {
            return Err(anyhow!("backends disagree on data-column geometry"));
        }
        Ok(router)
    }

    /// A trivial fleet: one group, one member — the drop-in shape for
    /// single-pool serving (local or remote alike).
    pub fn single(backend: Box<dyn Backend>) -> anyhow::Result<ShardRouter> {
        ShardRouter::new(vec![vec![backend]], RouterConfig::default())
    }

    /// One hedged replica group over all `backends`.
    pub fn replicated(
        backends: Vec<Box<dyn Backend>>,
        cfg: RouterConfig,
    ) -> anyhow::Result<ShardRouter> {
        ShardRouter::new(vec![backends], cfg)
    }

    // -- plumbing ----------------------------------------------------------

    fn job_tx(&self, member: usize) -> Result<&SyncSender<MemberJob>> {
        self.members[member].job_tx.as_ref().ok_or(TransportError::Closed)
    }

    fn send_blocking(&self, member: usize, job: MemberJob) -> Result<()> {
        self.job_tx(member)?.send(job).map_err(|_| TransportError::Closed)
    }

    /// `Ok(false)` = the member's bounded queue is full right now.
    fn try_send(&self, member: usize, job: MemberJob) -> Result<bool> {
        match self.job_tx(member)?.try_send(job) {
            Ok(()) => Ok(true),
            Err(TrySendError::Full(_)) => Ok(false),
            Err(TrySendError::Disconnected(_)) => Err(TransportError::Closed),
        }
    }

    /// Serialized control call: send one job, return its (non-dispatch)
    /// reply. Stale dispatch replies draining in are discarded by
    /// identity — they belong to hedges that already lost.
    fn call(&mut self, member: usize, job: MemberJob) -> Result<MemberReply> {
        self.send_blocking(member, job)?;
        loop {
            let (m, reply) = self.res_rx.recv().map_err(|_| TransportError::Closed)?;
            match reply {
                MemberReply::Dispatch { .. } => self.stats.stale_discarded += 1,
                other => {
                    debug_assert_eq!(m, member, "control replies are strictly serialized");
                    return Ok(other);
                }
            }
        }
    }

    // -- accessors ---------------------------------------------------------

    /// Data columns per array row, uniform across the fleet.
    pub fn data_cols(&self) -> usize {
        self.members[0].info.data_cols as usize
    }

    pub fn n_members(&self) -> usize {
        self.members.len()
    }

    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    /// `(group, member-local index)` of a global member id.
    pub fn member_group(&self, member: usize) -> (usize, usize) {
        (self.members[member].group, self.members[member].local)
    }

    /// Chips behind one member backend.
    pub fn member_chips(&self, member: usize) -> usize {
        self.members[member].info.chips as usize
    }

    /// Rows consumed so far, flattened member-major (the fleet-level
    /// `rows_used` the engine reports).
    pub fn rows_used_flat(&self) -> Vec<usize> {
        self.members.iter().flat_map(|m| m.rows_used.iter().copied()).collect()
    }

    /// Fleet dispatch counters so far.
    pub fn stats(&self) -> RouterStats {
        self.stats.clone()
    }

    // -- control plane -----------------------------------------------------

    /// Program one payload onto `chip` of `member`, keeping the
    /// client-side row/wear mirrors exact. See [`ProgramReply`].
    pub fn program(
        &mut self,
        member: usize,
        chip: usize,
        payload: OwnedPayload,
    ) -> Result<ProgramReply> {
        let need = payload.cells().div_ceil(self.members[member].info.data_cols as usize);
        let rep = match self.call(
            member,
            MemberJob::Program(ProgramRequest { chip: chip as u32, payload }),
        )? {
            MemberReply::Program(r) => r?,
            _ => unreachable!("program answers program"),
        };
        let mm = &mut self.members[member];
        match &rep.span {
            Some(span) => {
                let used = span.slots.len();
                mm.rows_free[chip] = mm.rows_free[chip].saturating_sub(used);
                mm.rows_used[chip] += used;
                mm.est_pulses[chip] += span.len as u64;
            }
            None => {
                // the backend had fewer free rows than our mirror
                // thought: resync conservatively
                mm.rows_free[chip] = mm.rows_free[chip].min(need.saturating_sub(1));
            }
        }
        Ok(rep)
    }

    fn wear_member(&mut self, member: usize) -> Result<WearReply> {
        let rep = match self.call(member, MemberJob::Wear)? {
            MemberReply::Wear(r) => r?,
            _ => unreachable!("wear answers wear"),
        };
        let mm = &mut self.members[member];
        mm.rows_free = rep.rows_free.iter().map(|&r| r as usize).collect();
        mm.est_pulses = rep.wear.iter().map(|w| w.write_pulses).collect();
        Ok(rep)
    }

    /// Per-member wear + free rows (the rebalancer's input), refreshing
    /// the client-side mirrors along the way.
    pub fn wear_all(&mut self) -> Result<Vec<WearReply>> {
        (0..self.members.len()).map(|m| self.wear_member(m)).collect()
    }

    /// Zero every member's energy ledgers (post-placement baseline).
    pub fn reset_energy_all(&mut self) -> Result<()> {
        for m in 0..self.members.len() {
            match self.call(m, MemberJob::ResetEnergy)? {
                MemberReply::ResetEnergy(r) => r?,
                _ => unreachable!("reset answers reset"),
            }
        }
        Ok(())
    }

    /// Finish every member (workers join; remote hosts close) and
    /// collect their terminal reports, member-major.
    pub fn finish(&mut self) -> Result<Vec<FinishReply>> {
        let mut out = Vec::with_capacity(self.members.len());
        for m in 0..self.members.len() {
            let rep = match self.call(m, MemberJob::Finish)? {
                MemberReply::Finish(r) => r?,
                _ => unreachable!("finish answers finish"),
            };
            self.members[m].job_tx = None;
            if let Some(h) = self.members[m].handle.take() {
                let _ = h.join();
            }
            out.push(rep);
        }
        Ok(out)
    }

    // -- placement ---------------------------------------------------------

    /// Which group owns layer `l` of an `n_layers` model: a contiguous
    /// split, balanced by layer count.
    pub fn group_of_layer(&self, l: usize, n_layers: usize) -> usize {
        l * self.groups.len() / n_layers.max(1)
    }

    /// Place (and program) every live filter of `model` across the
    /// fleet: layers are split across groups, and **every member** of
    /// the owning group receives a byte-identical copy of each shard
    /// (that is what makes its replies interchangeable under hedging).
    /// `row_quota`, when set, bounds the rows the model may occupy *per
    /// member*; chip choice within a member is least-estimated-wear
    /// first with stuck-tile retry, mirroring the single-pool placer.
    pub fn place(
        &mut self,
        model: &ModelBundle,
        row_quota: Option<usize>,
    ) -> anyhow::Result<RouterPlacement> {
        let per_row = self.data_cols();
        let n_layers = model.n_layers();
        let pls = model.placement_layers();
        // pre-checks: each member must fit — and have quota for — its
        // own group's layers. The quota is per member (a replica spends
        // it again on its own pool), so a multi-group split is checked
        // against each group's share, not the whole model.
        for (gi, group) in self.groups.iter().enumerate() {
            let need: usize = pls
                .iter()
                .enumerate()
                .filter(|(l, _)| self.group_of_layer(*l, n_layers) == gi)
                .map(|(_, pl)| {
                    pl.shards.iter().flatten().count() * pl.cells.div_ceil(per_row)
                })
                .sum();
            if let Some(quota) = row_quota {
                if need > quota {
                    return Err(anyhow!(
                        "model needs {need} rows on each member of group {gi} \
                         but its tenant row quota is {quota}"
                    ));
                }
            }
            for &m in &group.members {
                let free: usize = self.members[m].rows_free.iter().sum();
                if need > free {
                    return Err(anyhow!(
                        "model needs {need} rows on backend {m} but it has {free} free; \
                         prune harder, grow the pool, or evict a tenant"
                    ));
                }
            }
        }
        let mut layers = Vec::with_capacity(n_layers);
        let mut stuck_retries = 0usize;
        let mut quota_rows = vec![0usize; self.members.len()];
        for (l, pl) in pls.iter().enumerate() {
            let g = self.group_of_layer(l, n_layers);
            let group_members = self.groups[g].members.clone();
            let need = pl.cells.div_ceil(per_row);
            let mut member_shards: Vec<Vec<Option<ShardRef>>> =
                Vec::with_capacity(group_members.len());
            for &m in &group_members {
                let mut shards: Vec<Option<ShardRef>> = Vec::with_capacity(pl.shards.len());
                for (f, payload) in pl.shards.iter().enumerate() {
                    let Some(payload) = payload else {
                        shards.push(None);
                        continue;
                    };
                    if let Some(quota) = row_quota {
                        if quota_rows[m] + need > quota {
                            return Err(anyhow!(
                                "tenant row quota {quota} exhausted at layer {} filter {f} \
                                 ({} rows already live)",
                                pl.name,
                                quota_rows[m]
                            ));
                        }
                    }
                    let owned: OwnedPayload = (*payload).into();
                    match self
                        .place_filter(m, need, &owned)
                        .map_err(|e| anyhow!("transport failed during placement: {e}"))?
                    {
                        PlaceOutcome::Placed { chip, span, retries } => {
                            stuck_retries += retries;
                            quota_rows[m] += span.slots.len();
                            shards.push(Some(ShardRef {
                                chip: chip as u32,
                                filter: f as u32,
                                span,
                            }));
                        }
                        PlaceOutcome::NoRoom { retries } => {
                            stuck_retries += retries;
                            return Err(anyhow!(
                                "placement failed: layer {} filter {f} ({} cells) fits no chip \
                                 of backend {m} ({stuck_retries} stuck-tile retries so far)",
                                pl.name,
                                pl.cells
                            ));
                        }
                    }
                }
                member_shards.push(shards);
            }
            layers.push(PlacedLayer { group: g, shards: member_shards });
        }
        Ok(RouterPlacement { layers, stuck_retries })
    }

    /// One filter onto one member: chips in least-estimated-wear order
    /// (ties toward more free rows), retrying past stuck tiles.
    fn place_filter(
        &mut self,
        member: usize,
        need: usize,
        payload: &OwnedPayload,
    ) -> Result<PlaceOutcome> {
        let n_chips = self.members[member].info.chips as usize;
        let mut order: Vec<usize> = (0..n_chips).collect();
        {
            let mm = &self.members[member];
            order.sort_by_key(|&c| (mm.est_pulses[c], usize::MAX - mm.rows_free[c], c));
        }
        let mut retries = 0usize;
        for &c in &order {
            if self.members[member].rows_free[c] < need {
                continue;
            }
            let rep = self.program(member, c, payload.clone())?;
            match rep.span {
                None => continue, // mirror already resynced by program()
                Some(span) => {
                    if rep.failures > 0 {
                        retries += 1; // stuck tile: rows retired, next chip
                        continue;
                    }
                    return Ok(PlaceOutcome::Placed { chip: c, span, retries });
                }
            }
        }
        Ok(PlaceOutcome::NoRoom { retries })
    }

    // -- data plane --------------------------------------------------------

    fn hedge_deadline(&self, group: usize) -> Duration {
        if let Some(d) = self.cfg.hedge.after {
            return d;
        }
        let lat = &self.groups[group].lat;
        if lat.count() < self.cfg.hedge.min_samples {
            return self.cfg.hedge.ceiling;
        }
        let q = lat.quantile(self.cfg.hedge.quantile);
        Duration::from_secs_f64(q.as_secs_f64() * self.cfg.hedge.factor)
            .clamp(self.cfg.hedge.floor, self.cfg.hedge.ceiling)
    }

    /// Dispatch one layer's windows to the owning group and return the
    /// `(filter, dots)` pairs of the first matching reply. Spills off a
    /// full member queue, hedges past the group's deadline, and
    /// discards duplicate replies by `(request id, shard epoch)` — the
    /// caller sees exactly one answer per call.
    pub fn dispatch_layer(
        &mut self,
        route: &TenantRoute,
        layer: usize,
        windows: WireWindows,
    ) -> Result<Vec<(u32, Vec<i64>)>> {
        let lr = &route.layers[layer];
        let g = lr.group;
        let members = self.groups[g].members.clone();
        let n = members.len();
        debug_assert_eq!(lr.shards.len(), n, "route member count vs group");
        self.stats.dispatches += 1;
        let req_id = self.next_request;
        self.next_request += 1;
        let start = self.groups[g].rr % n;
        self.groups[g].rr = self.groups[g].rr.wrapping_add(1);
        let request = |local: usize| DispatchRequest {
            request_id: req_id,
            shard_epoch: route.epoch,
            layer: layer as u32,
            shards: Arc::clone(&lr.shards[local]),
            windows: windows.clone(),
        };
        // pick the primary round-robin; a full queue spills to the next
        // replica, and only if every queue is full do we block (compute
        // is never shed here — shedding belongs to the admission plane)
        let mut primary_local = None;
        for k in 0..n {
            let local = (start + k) % n;
            if self.try_send(members[local], MemberJob::Dispatch(request(local)))? {
                if k > 0 {
                    self.stats.spills += 1;
                }
                primary_local = Some(local);
                break;
            }
        }
        let primary_local = match primary_local {
            Some(local) => local,
            None => {
                self.send_blocking(members[start], MemberJob::Dispatch(request(start)))?;
                start
            }
        };
        let t0 = Instant::now();
        let hedge_after =
            if n > 1 && self.cfg.hedge.enabled { Some(self.hedge_deadline(g)) } else { None };
        let mut timer_armed = hedge_after.is_some();
        let mut hedge_member: Option<usize> = None;
        let mut in_flight = 1usize;
        loop {
            let received = if timer_armed && hedge_member.is_none() {
                let after = hedge_after.expect("armed timer has a deadline");
                let elapsed = t0.elapsed();
                if elapsed >= after {
                    Err(RecvTimeoutError::Timeout)
                } else {
                    self.res_rx.recv_timeout(after - elapsed)
                }
            } else {
                self.res_rx.recv().map_err(|_| RecvTimeoutError::Disconnected)
            };
            match received {
                Ok((m, MemberReply::Dispatch { request_id, result })) => {
                    if request_id != req_id {
                        self.stats.stale_discarded += 1; // a hedge that already lost
                        continue;
                    }
                    let failed = match result {
                        Ok(rep) if rep.shard_epoch == route.epoch => {
                            self.groups[g].lat.record(t0.elapsed());
                            if hedge_member == Some(m) {
                                self.stats.hedge_wins += 1;
                            }
                            return Ok(rep.dots);
                        }
                        Ok(_) => {
                            self.stats.stale_discarded += 1;
                            TransportError::Remote("reply carries a stale shard epoch".into())
                        }
                        Err(e) => e,
                    };
                    in_flight -= 1;
                    if in_flight == 0 {
                        if n > 1 && hedge_member.is_none() {
                            // the only attempt died: fail over to the
                            // replica instead of surfacing the error
                            let alt = (primary_local + 1) % n;
                            self.send_blocking(members[alt], MemberJob::Dispatch(request(alt)))?;
                            self.stats.hedges_fired += 1;
                            hedge_member = Some(members[alt]);
                            in_flight = 1;
                        } else {
                            return Err(failed);
                        }
                    }
                }
                Ok((_, _)) => {
                    unreachable!("control replies cannot be in flight during a dispatch")
                }
                Err(RecvTimeoutError::Timeout) => {
                    let alt = (primary_local + 1) % n;
                    if self.try_send(members[alt], MemberJob::Dispatch(request(alt)))? {
                        self.stats.hedges_fired += 1;
                        hedge_member = Some(members[alt]);
                        in_flight += 1;
                    } else {
                        // replica saturated: stop hedging this request
                        timer_armed = false;
                    }
                }
                Err(RecvTimeoutError::Disconnected) => return Err(TransportError::Closed),
            }
        }
    }
}

impl Drop for ShardRouter {
    fn drop(&mut self) {
        for m in &mut self.members {
            m.job_tx = None; // hang up: workers drain and exit
        }
        for m in &mut self.members {
            if let Some(h) = m.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chip::WearLedger;
    use std::sync::atomic::{AtomicU64, Ordering};

    /// A scriptable backend: fixed dots, optional per-dispatch delay,
    /// optional scripted failures — enough to pin down hedging,
    /// failover, and duplicate-discard behavior without silicon.
    struct MockBackend {
        delay: Duration,
        fail_dispatches: u64,
        served: Arc<AtomicU64>,
        dot: i64,
    }

    impl MockBackend {
        fn boxed(delay: Duration, fail_dispatches: u64, served: Arc<AtomicU64>, dot: i64) -> Box<dyn Backend> {
            Box::new(MockBackend { delay, fail_dispatches, served, dot })
        }
    }

    impl Backend for MockBackend {
        fn describe(&mut self) -> Result<BackendInfo> {
            Ok(BackendInfo { chips: 1, data_cols: 30 })
        }

        fn dispatch(&mut self, req: DispatchRequest) -> Result<DispatchReply> {
            if self.fail_dispatches > 0 {
                self.fail_dispatches -= 1;
                return Err(TransportError::Remote("scripted failure".into()));
            }
            if !self.delay.is_zero() {
                std::thread::sleep(self.delay);
            }
            self.served.fetch_add(1, Ordering::SeqCst);
            Ok(DispatchReply {
                request_id: req.request_id,
                shard_epoch: req.shard_epoch,
                layer: req.layer,
                dots: req.shards.iter().map(|s| (s.filter, vec![self.dot])).collect(),
            })
        }

        fn program(&mut self, _req: ProgramRequest) -> Result<ProgramReply> {
            Ok(ProgramReply {
                span: Some(crate::cim::mapping::RowSpan {
                    slots: vec![(0, 0)],
                    tail_width: 1,
                    len: 1,
                }),
                failures: 0,
            })
        }

        fn wear(&mut self) -> Result<WearReply> {
            Ok(WearReply { wear: vec![WearLedger::default()], rows_free: vec![64] })
        }

        fn reset_energy(&mut self) -> Result<()> {
            Ok(())
        }

        fn finish(&mut self) -> Result<FinishReply> {
            Ok(FinishReply { energy_pj: 0.0, wear: vec![WearLedger::default()] })
        }
    }

    fn route_one_layer(n_members: usize) -> TenantRoute {
        TenantRoute {
            epoch: 1,
            layers: vec![LayerRoute {
                group: 0,
                shards: (0..n_members)
                    .map(|_| {
                        Arc::new(vec![ShardRef {
                            chip: 0,
                            filter: 0,
                            span: crate::cim::mapping::RowSpan {
                                slots: vec![(0, 0)],
                                tail_width: 1,
                                len: 1,
                            },
                        }])
                    })
                    .collect(),
            }],
        }
    }

    fn empty_windows() -> WireWindows {
        WireWindows::Binary(Arc::new(crate::cim::vmm::PackedWindows {
            n_windows: 0,
            seg_widths: vec![1],
            planes: vec![],
            sum_x: vec![],
        }))
    }

    #[test]
    fn hedge_fires_on_a_straggler_and_the_replica_wins() {
        let slow_served = Arc::new(AtomicU64::new(0));
        let fast_served = Arc::new(AtomicU64::new(0));
        let cfg = RouterConfig {
            hedge: HedgeConfig {
                after: Some(Duration::from_millis(5)),
                ..HedgeConfig::default()
            },
            ..RouterConfig::default()
        };
        let mut router = ShardRouter::replicated(
            vec![
                MockBackend::boxed(Duration::from_millis(250), 0, Arc::clone(&slow_served), 7),
                MockBackend::boxed(Duration::ZERO, 0, Arc::clone(&fast_served), 7),
            ],
            cfg,
        )
        .unwrap();
        let route = route_one_layer(2);
        // round-robin starts at the slow member; the 5ms deadline fires
        // and the instant replica answers first
        let dots = router.dispatch_layer(&route, 0, empty_windows()).unwrap();
        assert_eq!(dots, vec![(0, vec![7])]);
        let stats = router.stats();
        assert_eq!(stats.dispatches, 1);
        assert_eq!(stats.hedges_fired, 1);
        assert_eq!(stats.hedge_wins, 1, "the duplicate must have won");
        assert_eq!(fast_served.load(Ordering::SeqCst), 1);
        // the straggler's late reply is discarded by request id — drain
        // it via a control call and check the counter
        std::thread::sleep(Duration::from_millis(300));
        let _ = router.wear_all().unwrap();
        assert_eq!(router.stats().stale_discarded, 1, "losing reply discarded, not re-answered");
        router.finish().unwrap();
        assert_eq!(slow_served.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn failed_primary_fails_over_to_the_replica() {
        let served = Arc::new(AtomicU64::new(0));
        let cfg = RouterConfig {
            hedge: HedgeConfig { after: Some(Duration::from_secs(5)), ..HedgeConfig::default() },
            ..RouterConfig::default()
        };
        let mut router = ShardRouter::replicated(
            vec![
                MockBackend::boxed(Duration::ZERO, 1, Arc::clone(&served), 3),
                MockBackend::boxed(Duration::ZERO, 0, Arc::clone(&served), 3),
            ],
            cfg,
        )
        .unwrap();
        let route = route_one_layer(2);
        let dots = router.dispatch_layer(&route, 0, empty_windows()).unwrap();
        assert_eq!(dots, vec![(0, vec![3])]);
        assert_eq!(router.stats().hedges_fired, 1, "failover counts as a hedge");
        router.finish().unwrap();
    }

    #[test]
    fn solo_member_surfaces_its_error() {
        let served = Arc::new(AtomicU64::new(0));
        let mut router = ShardRouter::single(MockBackend::boxed(
            Duration::ZERO,
            1,
            Arc::clone(&served),
            0,
        ))
        .unwrap();
        let route = route_one_layer(1);
        let err = router.dispatch_layer(&route, 0, empty_windows()).unwrap_err();
        assert!(matches!(err, TransportError::Remote(_)));
        // the next dispatch works again
        assert_eq!(
            router.dispatch_layer(&route, 0, empty_windows()).unwrap(),
            vec![(0, vec![0])]
        );
        router.finish().unwrap();
    }

    #[test]
    fn construction_rejects_empty_and_mismatched_fleets() {
        assert!(ShardRouter::new(vec![], RouterConfig::default()).is_err());
        assert!(ShardRouter::new(vec![vec![]], RouterConfig::default()).is_err());
    }
}

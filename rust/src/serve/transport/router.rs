//! [`ShardRouter`]: the fleet-shaped composite over N [`Backend`]s —
//! one tenant's layers split across backends ("groups"), each group
//! optionally a **replica set** holding byte-identical shard payloads,
//! with request **hedging** for tail latency and dispatch-plane
//! **spillover** off a full member queue.
//!
//! # Topology
//!
//! ```text
//!   ShardRouter
//!     ├─ group 0: layers 0..k     [ member A ─ replica A' ]   (hedged pair)
//!     └─ group 1: layers k..N     [ member B ]                (solo)
//! ```
//!
//! Each member backend is driven from its own thread, so a synchronous
//! `Backend` (a TCP host, a local pool) becomes concurrently
//! dispatchable without an async runtime. The router itself is used
//! from one coordinator thread; its concurrency is *across members*.
//!
//! # Hedging invariant
//!
//! A dispatch goes to one member of the owning group (round-robin over
//! the members not currently quarantined). If no reply lands within the
//! hedge deadline — derived from the group's dispatch
//! [`LatencyHistogram`] (`quantile(q) × factor`, clamped), or fixed via
//! [`HedgeConfig::after`] — the *same* request (same request id, same
//! shard epoch, the replica's own shard spans) is duplicated to the
//! next replica. Replies are bit-exact across replicas (digital chips,
//! byte-identical payloads), so **the first reply wins** and the loser
//! is discarded by `(request id, shard epoch)` identity when it
//! eventually arrives. A hedged duplicate can therefore never produce a
//! second answer to the caller: `dispatch_layer` returns exactly once
//! per request id, and stale replies only increment a counter.
//!
//! # Cross-group migration (epoch-fenced cutover)
//!
//! [`ShardRouter::migrate_layer`] moves a whole layer **between**
//! groups — the capacity/wear mobility the single-backend rebalancer
//! cannot provide — through a four-state fence machine (DESIGN.md §9):
//!
//! ```text
//!   PROGRAM ──ok──▶ FENCE ──▶ DRAIN ──▶ FREE   (migration completed)
//!      │
//!      └─any failure─▶ ABORT (partial destination spans released;
//!                             the source stays authoritative)
//! ```
//!
//! * **program** — every member of the destination group receives a
//!   byte-identical copy of every live shard payload over the wire
//!   (least-worn chip first, stuck-tile retry — the placement policy).
//!   The source keeps serving; nothing observable has changed.
//! * **fence** — the tenant's epoch advances (epochs are router-issued
//!   and globally monotone) and the old epoch is recorded as fenced.
//!   From here the destination copies are authoritative.
//! * **drain** — the router blocks until every in-flight
//!   [`DispatchRequest`] has been answered. Because the coordinator
//!   serializes batches, the only possible stragglers are hedge losers
//!   of already-answered requests; each drained reply is discarded by
//!   identity and counted exactly once
//!   ([`RouterStats::epoch_discards`] when it carries a fenced epoch).
//! * **free** — only now are the source spans released
//!   ([`super::Backend::release`]), so no request that could still
//!   address those rows exists anywhere in the fleet. A backend
//!   without release support retires the rows instead (append-only
//!   fallback); the migration still completes.
//!
//! # Reconnect / rejoin
//!
//! A [`super::remote::RemoteBackend`] reconnects on its own (bounded
//! backoff) and quarantines itself when the host it re-reached is a
//! fresh incarnation — its shards are gone. The router observes this
//! via [`ShardRouter::probe_members`], skips quarantined members in
//! the dispatch rotation, and — after the owner re-programs the
//! member's shards at the current epoch — lifts the quarantine with
//! [`ShardRouter::rejoin_member`], returning the member to its replica
//! group (and to hedging duty).

use std::collections::BTreeSet;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::anyhow;

use crate::serve::model::ModelBundle;
use crate::serve::obs::{stage, Histogram, Obs, ObsEvent, SpanRecord, Stage, TraceContext};
use crate::serve::placement::Placement;
use crate::serve::stats::LatencyHistogram;

use super::{
    Backend, BackendInfo, DispatchReply, DispatchRequest, FinishReply, HealthReply, OwnedPayload,
    ProgramReply, ProgramRequest, ReleaseReply, ReleaseRequest, Result, ShardRef, TransportError,
    WearReply, WireWindows,
};

/// The router's slice of the observability plane: the shared [`Obs`]
/// plus stage-histogram handles cached at wiring time (one registry
/// lookup per [`ShardRouter::set_obs`], not per dispatch).
struct RouterObs {
    plane: Arc<Obs>,
    stage_dispatch: Histogram,
    stage_execute: Histogram,
    stage_transport: Histogram,
}

impl RouterObs {
    fn new(plane: Arc<Obs>) -> RouterObs {
        RouterObs {
            stage_dispatch: plane.metrics.histogram(stage::DISPATCH),
            stage_execute: plane.metrics.histogram(stage::EXECUTE),
            stage_transport: plane.metrics.histogram(stage::TRANSPORT),
            plane,
        }
    }
}

/// When to duplicate a straggling dispatch to a replica.
#[derive(Clone, Debug)]
pub struct HedgeConfig {
    /// Master switch (hedging also needs a group with ≥ 2 members).
    pub enabled: bool,
    /// Fixed deadline override. `Some(Duration::ZERO)` hedges every
    /// dispatch — the determinism knob the duplicate-discard tests use.
    /// `None` derives the deadline from the latency histogram.
    pub after: Option<Duration>,
    /// Histogram quantile the deadline is derived from (0..=1).
    pub quantile: f64,
    /// Multiplier on the quantile estimate.
    pub factor: f64,
    /// Below this many recorded dispatches the deadline stays at
    /// `ceiling` (no meaningful tail estimate yet).
    pub min_samples: u64,
    /// Deadline clamp, low side.
    pub floor: Duration,
    /// Deadline clamp, high side (also the cold-start deadline).
    pub ceiling: Duration,
}

impl Default for HedgeConfig {
    fn default() -> Self {
        HedgeConfig {
            enabled: true,
            after: None,
            quantile: 0.99,
            factor: 4.0,
            min_samples: 64,
            floor: Duration::from_micros(200),
            ceiling: Duration::from_millis(250),
        }
    }
}

/// Depth bound on the executor's pack/dispatch overlap pipeline.
///
/// The executor splits each batch into up to `depth` micro-batches and
/// keeps that many dispatches in flight per group: while layer `l`'s
/// windows stream through the chips, layer `l`'s *next* micro-batch is
/// already being quantized and packed on the host
/// ([`ShardRouter::submit_layer`] / [`ShardRouter::collect`]).
/// `depth == 1` is exactly the pre-pipeline serial behavior — one
/// dispatch submitted, packed, and folded at a time.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Maximum uncollected [`PendingDispatch`]es a single executor may
    /// hold ([`ShardRouter::submit_layer`] rejects the `depth + 1`th).
    pub depth: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig { depth: 2 }
    }
}

/// Router construction knobs.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    pub hedge: HedgeConfig,
    /// Bound on queued-but-unstarted jobs per member; a full primary
    /// queue spills the dispatch to its replica.
    pub inflight: usize,
    /// Executor pipeline depth bound (see [`PipelineConfig`]).
    pub pipeline: PipelineConfig,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            hedge: HedgeConfig::default(),
            inflight: 32,
            pipeline: PipelineConfig::default(),
        }
    }
}

/// Fleet-level dispatch counters (surfaced in
/// [`crate::serve::EngineReport::transport`]).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RouterStats {
    /// Layer dispatches issued (hedged duplicates not double-counted).
    pub dispatches: u64,
    /// Duplicates sent to a replica after the hedge deadline (or after
    /// the only outstanding attempt failed).
    pub hedges_fired: u64,
    /// Hedged dispatches whose *duplicate* replied first.
    pub hedge_wins: u64,
    /// Replies discarded by request-id identity (the losing half of a
    /// hedge, arriving after its request was already answered, with an
    /// epoch that was never fenced).
    pub stale_discarded: u64,
    /// Replies discarded because they carry a **fenced** (pre-cutover)
    /// shard epoch. Each such reply is counted here exactly once and
    /// never also in `stale_discarded`.
    pub epoch_discards: u64,
    /// Dispatches rerouted to a replica because the chosen member's
    /// bounded queue was full (dispatch-plane admission spillover).
    pub spills: u64,
    /// Cross-group layer migrations entered (the `program` state).
    pub migrations_started: u64,
    /// Migrations that reached the `fence` state (destination copies
    /// verified; epoch advanced).
    pub migrations_fenced: u64,
    /// Migrations that completed (`drain` + `free` done; source rows
    /// released or retired).
    pub migrations_completed: u64,
    /// Migrations abandoned in the `program` state (capacity, stuck
    /// tiles, or transport failure); partial destination spans were
    /// released and the source never stopped being authoritative.
    pub migrations_aborted: u64,
    /// Connections re-established by member backends (bounded-backoff
    /// reconnects), as of the last [`ShardRouter::probe_members`].
    pub reconnects: u64,
    /// High-water mark of simultaneously outstanding dispatch attempts
    /// (pipelined submissions plus hedged duplicates) — the pipeline
    /// depth bound is verifiable against this.
    pub peak_inflight: u64,
}

enum MemberJob {
    Dispatch(DispatchRequest),
    Program(ProgramRequest),
    Release(ReleaseRequest),
    Wear,
    Describe,
    Health,
    Rejoin,
    ResetEnergy,
    Finish,
}

enum MemberReply {
    Dispatch { request_id: u64, result: Result<DispatchReply> },
    Program(Result<ProgramReply>),
    Release(Result<ReleaseReply>),
    Wear(Result<WearReply>),
    Describe(Result<BackendInfo>),
    Health(Result<HealthReply>),
    Rejoin(Result<()>),
    ResetEnergy(Result<()>),
    Finish(Result<FinishReply>),
}

fn member_worker(
    idx: usize,
    mut backend: Box<dyn Backend>,
    jobs: Receiver<MemberJob>,
    results: SyncSender<(usize, MemberReply)>,
) {
    while let Ok(job) = jobs.recv() {
        let (reply, done) = match job {
            MemberJob::Dispatch(req) => {
                let request_id = req.request_id;
                (MemberReply::Dispatch { request_id, result: backend.dispatch(req) }, false)
            }
            MemberJob::Program(req) => (MemberReply::Program(backend.program(req)), false),
            MemberJob::Release(req) => (MemberReply::Release(backend.release(req)), false),
            MemberJob::Wear => (MemberReply::Wear(backend.wear()), false),
            MemberJob::Describe => (MemberReply::Describe(backend.describe()), false),
            MemberJob::Health => (MemberReply::Health(backend.health()), false),
            MemberJob::Rejoin => (MemberReply::Rejoin(backend.rejoin()), false),
            MemberJob::ResetEnergy => (MemberReply::ResetEnergy(backend.reset_energy()), false),
            MemberJob::Finish => (MemberReply::Finish(backend.finish()), true),
        };
        if results.send((idx, reply)).is_err() {
            break; // router gone: shut down
        }
        if done {
            break;
        }
    }
}

struct Member {
    job_tx: Option<SyncSender<MemberJob>>,
    handle: Option<JoinHandle<()>>,
    group: usize,
    local: usize,
    info: BackendInfo,
    /// Client-side mirror of per-chip free rows (kept exact by every
    /// program/release reply; resynced from every wear probe).
    rows_free: Vec<usize>,
    /// Placement-ranking wear estimate per chip (resynced likewise).
    est_pulses: Vec<u64>,
    /// Net rows consumed per chip of the member's **current pool
    /// incarnation** (placement, stuck retries, migrations — retired
    /// rows included; rows freed by a fenced migration leave the count
    /// again, and a bounce resets it with the pool).
    rows_used: Vec<usize>,
    /// Reconnects this member's backend reported at the last probe.
    reconnects: u64,
    /// Quarantined members are skipped by the dispatch rotation until
    /// re-programmed and rejoined (see the module docs).
    quarantined: bool,
}

struct Group {
    members: Vec<usize>,
    lat: LatencyHistogram,
    rr: usize,
}

/// One tenant's layer → group/shard routing, built from a
/// [`RouterPlacement`] and carried into every batch. Rebuilt (with a
/// bumped epoch) whenever a migration lands; in-flight requests keep
/// the old `Arc`s alive until their replies are folded or discarded.
#[derive(Clone, Debug)]
pub struct TenantRoute {
    /// Placement generation — stamped into every request, echoed in
    /// every reply, checked before a reply is accepted.
    pub epoch: u64,
    pub layers: Vec<LayerRoute>,
}

/// One layer's route: the owning group and, per group member, the
/// member-local shard list (each replica holds its own spans).
#[derive(Clone, Debug)]
pub struct LayerRoute {
    pub group: usize,
    pub shards: Vec<Arc<Vec<ShardRef>>>,
}

impl TenantRoute {
    /// Build the per-batch routing view of a [`RouterPlacement`].
    pub fn from_placement(p: &RouterPlacement, epoch: u64) -> TenantRoute {
        TenantRoute {
            epoch,
            layers: p
                .layers
                .iter()
                .map(|pl| LayerRoute {
                    group: pl.group,
                    shards: pl
                        .shards
                        .iter()
                        .map(|ms| Arc::new(ms.iter().flatten().cloned().collect::<Vec<_>>()))
                        .collect(),
                })
                .collect(),
        }
    }

    /// Adapt a legacy single-pool [`Placement`] (chips addressed
    /// directly, no replicas) onto a single-member group-0 route — how
    /// the legacy [`crate::serve::Server`] rides the transport seam.
    // lint: allow(epoch-discipline) — legacy single-pool adapter: epoch 0 is the documented pre-epoch sentinel, bumped by the router on first re-shard
    pub fn single_member(p: &Placement) -> TenantRoute {
        TenantRoute {
            epoch: 0,
            layers: p
                .shards
                .iter()
                .map(|layer| LayerRoute {
                    group: 0,
                    shards: vec![Arc::new(
                        layer
                            .iter()
                            .enumerate()
                            .filter_map(|(f, loc)| {
                                loc.as_ref().map(|loc| ShardRef {
                                    chip: loc.chip as u32,
                                    filter: f as u32,
                                    span: loc.span.clone(),
                                })
                            })
                            .collect::<Vec<_>>(),
                    )],
                })
                .collect(),
        }
    }
}

/// One model's placement across the router's fleet: per layer, the
/// owning group and — per group member — where every live filter's
/// payload was programmed. Replicas hold the same *payloads* in their
/// own *spans*.
#[derive(Clone, Debug)]
pub struct RouterPlacement {
    pub layers: Vec<PlacedLayer>,
    /// Store attempts abandoned to stuck tiles across all members.
    pub stuck_retries: usize,
}

/// See [`RouterPlacement`]; `shards[member_local][filter]`.
#[derive(Clone, Debug)]
pub struct PlacedLayer {
    pub group: usize,
    pub shards: Vec<Vec<Option<ShardRef>>>,
}

impl RouterPlacement {
    /// Rows currently occupied by live shards on one member of one
    /// group — what per-member tenant row quotas are enforced against.
    // lint: allow(panic-freedom) — shard lists index the route table they were built from
    pub fn rows_live_on(&self, group: usize, member_local: usize) -> usize {
        self.layers
            .iter()
            .filter(|pl| pl.group == group)
            .flat_map(|pl| pl.shards[member_local].iter().flatten())
            .map(|s| s.span.slots.len())
            .sum()
    }

    /// Placed (live) shards, counted once per logical shard (replicas
    /// do not multiply the count).
    // lint: allow(panic-freedom) — shard lists index the route table they were built from
    pub fn live_shards(&self) -> usize {
        self.layers
            .iter()
            .map(|pl| pl.shards[0].iter().filter(|s| s.is_some()).count())
            .sum()
    }
}

pub(crate) enum PlaceOutcome {
    Placed { chip: usize, span: crate::cim::mapping::RowSpan, retries: usize },
    NoRoom { retries: usize },
}

/// The verdict of one [`ShardRouter::migrate_layer`] call.
#[derive(Clone, Debug)]
pub enum MigrationOutcome {
    /// Every destination member holds a verified byte-identical copy,
    /// the old epoch is fenced and drained, and the source rows are
    /// released (or retired where the backend lacks release support).
    Completed {
        /// `shards[member_local][filter]` on the destination group.
        shards: Vec<Vec<Option<ShardRef>>>,
        /// The tenant's new (router-issued, globally monotone) epoch.
        epoch: u64,
        /// Store attempts abandoned to stuck tiles while programming.
        stuck_retries: usize,
    },
    /// Programming the destination failed (capacity, stuck tiles, or
    /// transport); every partially programmed destination span was
    /// released again and the source never stopped being authoritative.
    /// Nothing was fenced.
    Aborted {
        /// Store attempts abandoned to stuck tiles before the abort.
        stuck_retries: usize,
    },
}

/// One member's verdict from [`ShardRouter::probe_members`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MemberProbe {
    /// Global member id.
    pub member: usize,
    pub state: MemberState,
    /// Reconnects the member's backend has accumulated.
    pub reconnects: u64,
}

/// A probed member's health (see [`ShardRouter::probe_members`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemberState {
    /// Reachable, same pool incarnation: serving normally.
    Healthy,
    /// Reconnected to a **fresh pool incarnation** — its shards are
    /// gone. Quarantined until re-programmed and rejoined.
    Bounced,
    /// Unreachable even after bounded reconnect attempts. Quarantined;
    /// probed again at the next heal — the engine heals on every
    /// rebalance pass and after any member dispatch failure, so a host
    /// that comes back (same incarnation) is re-admitted there.
    Unreachable,
}

/// A dispatch issued by [`ShardRouter::submit_layer`] whose reply has
/// not been collected yet. Opaque to callers: hand it back to
/// [`ShardRouter::collect`]. Executors collect in FIFO submission
/// order, but any order is correct — a reply that arrives for a
/// different pending dispatch is stashed for its own `collect`, never
/// dropped.
pub struct PendingDispatch {
    req_id: u64,
    group: usize,
    layer: usize,
    epoch: u64,
    /// Global member ids of the owning group.
    members: Vec<usize>,
    /// Rotation order (member-local indices) fixed at submit time.
    order: Vec<usize>,
    /// Position in `order` that accepted the primary attempt.
    primary_pos: usize,
    /// Per-member-local shard lists, retained so a hedge or failover
    /// can rebuild the request after the route moved on.
    shards: Vec<Arc<Vec<ShardRef>>>,
    windows: WireWindows,
    parent: TraceContext,
    primary_ctx: TraceContext,
    t0: Instant,
    hedge_after: Option<Duration>,
}

impl PendingDispatch {
    /// The request id stamped into every attempt of this dispatch.
    pub fn request_id(&self) -> u64 {
        self.req_id
    }
}

/// The composite front end over the fleet. See the module docs for the
/// topology, the hedging invariant, and the migration fence machine.
pub struct ShardRouter {
    cfg: RouterConfig,
    members: Vec<Member>,
    groups: Vec<Group>,
    res_rx: Receiver<(usize, MemberReply)>,
    next_request: u64,
    /// Dispatch jobs sent but not yet answered (every reply — folded,
    /// discarded, or failed — decrements). The drain step of the fence
    /// machine waits for this to hit zero.
    outstanding: usize,
    /// Epochs retired by a fenced cutover (one entry per migration);
    /// replies carrying one are counted as
    /// [`RouterStats::epoch_discards`] — an exact set, so another
    /// tenant's ordinary hedge losers are never misclassified.
    fenced: BTreeSet<u64>,
    /// Router-issued epoch source ([`ShardRouter::next_epoch`]).
    epoch_counter: u64,
    /// A member dispatch failed since the last probe: the owner should
    /// run [`ShardRouter::probe_members`] at the next batch boundary.
    suspect: bool,
    /// Request ids submitted ([`ShardRouter::submit_layer`]) and not
    /// yet collected. A fence drain clears this set, so collecting an
    /// invalidated [`PendingDispatch`] fails cleanly instead of
    /// blocking on a reply that was already discarded.
    pending: BTreeSet<u64>,
    /// Replies that arrived for a *pending* request while another
    /// request was being collected, in arrival order. Consumed by the
    /// matching [`ShardRouter::collect`]; discarded (and counted) by a
    /// fence drain.
    stash: Vec<(u64, usize, Result<DispatchReply>)>,
    stats: RouterStats,
    obs: RouterObs,
}

impl ShardRouter {
    /// Build a router over `groups` of replica backends: `groups[g]`
    /// all hold the same shards once a model is placed; distinct groups
    /// own distinct layer ranges. Fails if any group is empty or the
    /// backends disagree on data-column geometry.
    // lint: allow(panic-freedom) — setup indexes the member and group vectors it is building at the same length
    pub fn new(groups: Vec<Vec<Box<dyn Backend>>>, cfg: RouterConfig) -> anyhow::Result<ShardRouter> {
        if groups.is_empty() || groups.iter().any(|g| g.is_empty()) {
            return Err(anyhow!("router needs at least one backend per group"));
        }
        if cfg.inflight == 0 {
            return Err(anyhow!("router inflight bound must be positive"));
        }
        if cfg.pipeline.depth == 0 {
            return Err(anyhow!("pipeline depth must be positive (1 == serial dispatch)"));
        }
        if !(0.0..=1.0).contains(&cfg.hedge.quantile) {
            return Err(anyhow!(
                "hedge quantile {} is outside 0..=1 (this knob is a fraction, \
                 not a percentile rank)",
                cfg.hedge.quantile
            ));
        }
        // Bounded reply path: every member holds at most `inflight` queued
        // jobs plus one in hand, and each job produces exactly one reply, so
        // this capacity is a hard ceiling on outstanding replies — sends
        // never block and the serve plane stays free of unbounded queues
        // (the bounded-channel invariant).
        let n_members: usize = groups.iter().map(|g| g.len()).sum();
        let (res_tx, res_rx) = sync_channel::<(usize, MemberReply)>(n_members * (cfg.inflight + 1));
        let mut members: Vec<Member> = Vec::new();
        let mut group_meta: Vec<Group> = Vec::new();
        for (gi, group) in groups.into_iter().enumerate() {
            let mut ids = Vec::with_capacity(group.len());
            for (li, backend) in group.into_iter().enumerate() {
                let idx = members.len();
                let (jtx, jrx) = std::sync::mpsc::sync_channel::<MemberJob>(cfg.inflight);
                let rtx = res_tx.clone();
                let handle = std::thread::spawn(move || member_worker(idx, backend, jrx, rtx));
                members.push(Member {
                    job_tx: Some(jtx),
                    handle: Some(handle),
                    group: gi,
                    local: li,
                    info: BackendInfo { chips: 0, data_cols: 0, incarnation: 0 },
                    rows_free: Vec::new(),
                    est_pulses: Vec::new(),
                    rows_used: Vec::new(),
                    reconnects: 0,
                    quarantined: false,
                });
                ids.push(idx);
            }
            group_meta.push(Group { members: ids, lat: LatencyHistogram::default(), rr: 0 });
        }
        drop(res_tx);
        let mut router = ShardRouter {
            cfg,
            members,
            groups: group_meta,
            res_rx,
            next_request: 0,
            outstanding: 0,
            fenced: BTreeSet::new(),
            epoch_counter: 0,
            suspect: false,
            pending: BTreeSet::new(),
            stash: Vec::new(),
            stats: RouterStats::default(),
            obs: RouterObs::new(Arc::new(Obs::disabled())),
        };
        for m in 0..router.members.len() {
            let info = match router.call(m, MemberJob::Describe)? {
                MemberReply::Describe(r) => r?,
                _ => unreachable!("describe answers describe"),
            };
            if info.chips == 0 {
                return Err(anyhow!("backend {m} has no chips"));
            }
            router.members[m].info = info;
            router.members[m].rows_used = vec![0; router.members[m].info.chips as usize];
            router.wear_member(m)?;
        }
        let dc = router.members[0].info.data_cols;
        if router.members.iter().any(|m| m.info.data_cols != dc) {
            return Err(anyhow!("backends disagree on data-column geometry"));
        }
        Ok(router)
    }

    /// A trivial fleet: one group, one member — the drop-in shape for
    /// single-pool serving (local or remote alike).
    pub fn single(backend: Box<dyn Backend>) -> anyhow::Result<ShardRouter> {
        ShardRouter::new(vec![vec![backend]], RouterConfig::default())
    }

    /// One hedged replica group over all `backends`.
    pub fn replicated(
        backends: Vec<Box<dyn Backend>>,
        cfg: RouterConfig,
    ) -> anyhow::Result<ShardRouter> {
        ShardRouter::new(vec![backends], cfg)
    }

    // -- plumbing ----------------------------------------------------------

    // lint: allow(panic-freedom) — indexing follows the explicit member/group bounds check at the top of the accessor
    fn job_tx(&self, member: usize) -> Result<&SyncSender<MemberJob>> {
        self.members[member].job_tx.as_ref().ok_or(TransportError::Closed)
    }

    fn send_blocking(&self, member: usize, job: MemberJob) -> Result<()> {
        self.job_tx(member)?.send(job).map_err(|_| TransportError::Closed)
    }

    /// `Ok(false)` = the member's bounded queue is full right now.
    fn try_send(&self, member: usize, job: MemberJob) -> Result<bool> {
        match self.job_tx(member)?.try_send(job) {
            Ok(()) => Ok(true),
            Err(TrySendError::Full(_)) => Ok(false),
            Err(TrySendError::Disconnected(_)) => Err(TransportError::Closed),
        }
    }

    /// Count one dispatch attempt handed to a member worker and keep
    /// the in-flight high-water mark ([`RouterStats::peak_inflight`])
    /// honest — the pipeline depth bound is asserted against it.
    fn note_attempt_sent(&mut self) {
        self.outstanding += 1;
        self.stats.peak_inflight = self.stats.peak_inflight.max(self.outstanding as u64);
    }

    /// Classify and count one dispatch reply that was **not** folded
    /// into an answer: a reply carrying a fenced epoch is a pre-cutover
    /// straggler ([`RouterStats::epoch_discards`]); any other unclaimed
    /// reply is a plain hedge loser ([`RouterStats::stale_discarded`]).
    /// Exactly one counter increments per discarded reply.
    fn note_unclaimed_dispatch(&mut self, result: &Result<DispatchReply>) {
        match result {
            Ok(rep) if self.fenced.contains(&rep.shard_epoch) => {
                self.stats.epoch_discards += 1
            }
            _ => self.stats.stale_discarded += 1,
        }
    }

    /// Serialized control call: send one job, return its (non-dispatch)
    /// reply. Stale dispatch replies draining in are discarded by
    /// identity — they belong to hedges that already lost — while a
    /// reply for a still-pending pipelined dispatch is stashed for its
    /// eventual [`ShardRouter::collect`].
    fn call(&mut self, member: usize, job: MemberJob) -> Result<MemberReply> {
        self.send_blocking(member, job)?;
        loop {
            let (m, reply) = self.res_rx.recv().map_err(|_| TransportError::Closed)?;
            match reply {
                MemberReply::Dispatch { request_id, result } => {
                    self.outstanding = self.outstanding.saturating_sub(1);
                    if self.pending.contains(&request_id) {
                        self.stash.push((request_id, m, result));
                    } else {
                        self.note_unclaimed_dispatch(&result);
                    }
                }
                other => {
                    debug_assert_eq!(m, member, "control replies are strictly serialized");
                    return Ok(other);
                }
            }
        }
    }

    // -- accessors ---------------------------------------------------------

    /// Data columns per array row, uniform across the fleet.
    // lint: allow(panic-freedom) — geometry agreement across members is validated in new(), so member 0 always exists
    pub fn data_cols(&self) -> usize {
        self.members[0].info.data_cols as usize
    }

    pub fn n_members(&self) -> usize {
        self.members.len()
    }

    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    /// Members of one group (grouping is fixed at construction).
    // lint: allow(panic-freedom) — indexing follows the explicit member/group bounds check at the top of the accessor
    pub fn group_size(&self, group: usize) -> usize {
        self.groups[group].members.len()
    }

    /// Global member ids of one group, in member-local order — the
    /// order [`PlacedLayer::shards`] is indexed in, so a cutover can
    /// pair each member-local shard row with the member that holds it.
    // lint: allow(panic-freedom) — indexing follows the explicit member/group bounds check at the top of the accessor
    pub fn group_members(&self, group: usize) -> Vec<usize> {
        self.groups[group].members.clone()
    }

    /// `(group, member-local index)` of a global member id.
    // lint: allow(panic-freedom) — indexing follows the explicit member/group bounds check at the top of the accessor
    pub fn member_group(&self, member: usize) -> (usize, usize) {
        (self.members[member].group, self.members[member].local)
    }

    /// Chips behind one member backend.
    // lint: allow(panic-freedom) — indexing follows the explicit member/group bounds check at the top of the accessor
    pub fn member_chips(&self, member: usize) -> usize {
        self.members[member].info.chips as usize
    }

    /// Rows consumed so far, flattened member-major (the fleet-level
    /// `rows_used` the engine reports).
    pub fn rows_used_flat(&self) -> Vec<usize> {
        self.members.iter().flat_map(|m| m.rows_used.iter().copied()).collect()
    }

    /// Total free rows on one member, from the client-side mirrors
    /// (exact after every program/release reply and wear probe) — the
    /// capacity-pressure planner's input.
    // lint: allow(panic-freedom) — indexing follows the explicit member/group bounds check at the top of the accessor
    pub fn member_rows_free(&self, member: usize) -> usize {
        self.members[member].rows_free.iter().sum()
    }

    /// Fleet dispatch counters so far.
    pub fn stats(&self) -> RouterStats {
        self.stats.clone()
    }

    /// The configured executor pipeline depth bound
    /// ([`PipelineConfig::depth`]; 1 == serial dispatch).
    pub fn pipeline_depth(&self) -> usize {
        self.cfg.pipeline.depth
    }

    /// Dispatches submitted through [`ShardRouter::submit_layer`] and
    /// not yet collected.
    pub fn pending_dispatches(&self) -> usize {
        self.pending.len()
    }

    /// Attach an observability plane. The router starts with a disabled
    /// plane; the engine injects its shared one before serving
    /// (`Engine` and `Server` both do), and tests/benches may inject an
    /// enabled or disabled plane to observe or to measure overhead.
    pub fn set_obs(&mut self, plane: Arc<Obs>) {
        self.obs = RouterObs::new(plane);
    }

    /// The attached observability plane.
    pub fn obs(&self) -> &Arc<Obs> {
        &self.obs.plane
    }

    /// A fresh root trace context (the null context when the plane is
    /// disabled) — what a caller threads into
    /// [`ShardRouter::dispatch_layer`] to get the batch traced.
    pub fn begin_trace(&self) -> TraceContext {
        self.obs.plane.trace.new_trace()
    }

    /// Issue the next globally monotone shard epoch. Every
    /// [`TenantRoute`] built against this router should carry a
    /// router-issued epoch, so that "epoch `e` is fenced" is
    /// unambiguous fleet-wide (no two tenants ever share an epoch).
    pub fn next_epoch(&mut self) -> u64 {
        self.epoch_counter += 1;
        self.epoch_counter
    }

    /// Did a member dispatch fail since the last
    /// [`ShardRouter::probe_members`]? The owner should probe (and heal
    /// bounced members) at the next batch boundary.
    pub fn has_suspects(&self) -> bool {
        self.suspect
    }

    /// Is `member` currently quarantined (bounced or unreachable,
    /// awaiting re-program + [`ShardRouter::rejoin_member`])?
    // lint: allow(panic-freedom) — indexing follows the explicit member/group bounds check at the top of the accessor
    pub fn is_quarantined(&self, member: usize) -> bool {
        self.members[member].quarantined
    }

    // -- control plane -----------------------------------------------------

    /// Program one payload onto `chip` of `member`, keeping the
    /// client-side row/wear mirrors exact. See [`ProgramReply`].
    // lint: allow(panic-freedom) — member ids come from the router membership tables, validated at entry
    pub fn program(
        &mut self,
        member: usize,
        chip: usize,
        payload: OwnedPayload,
    ) -> Result<ProgramReply> {
        let need = payload.cells().div_ceil(self.members[member].info.data_cols as usize);
        let rep = match self.call(
            member,
            MemberJob::Program(ProgramRequest { chip: chip as u32, payload }),
        )? {
            MemberReply::Program(r) => r?,
            _ => unreachable!("program answers program"),
        };
        let mm = &mut self.members[member];
        match &rep.span {
            Some(span) => {
                let used = span.slots.len();
                mm.rows_free[chip] = mm.rows_free[chip].saturating_sub(used);
                mm.rows_used[chip] += used;
                mm.est_pulses[chip] += span.len as u64;
            }
            None => {
                // the backend had fewer free rows than our mirror
                // thought: resync conservatively
                mm.rows_free[chip] = mm.rows_free[chip].min(need.saturating_sub(1));
            }
        }
        Ok(rep)
    }

    /// Release a previously programmed span on `chip` of `member` —
    /// the **free** step of the fence machine. Must only be called for
    /// spans no in-flight request can still address (i.e. after
    /// [`ShardRouter::fence_and_drain`]). Resyncs the client-side row
    /// mirrors from the reply.
    ///
    /// # Errors
    ///
    /// The backend's [`super::Backend::release`] failure modes; a
    /// backend without release support answers
    /// [`TransportError::Remote`] and the rows simply stay retired.
    // lint: allow(panic-freedom) — member ids come from the router membership tables, validated at entry
    pub fn release(
        &mut self,
        member: usize,
        chip: usize,
        span: crate::cim::mapping::RowSpan,
    ) -> Result<ReleaseReply> {
        let freed = span.slots.len();
        let rep = match self.call(
            member,
            MemberJob::Release(ReleaseRequest { chip: chip as u32, span }),
        )? {
            MemberReply::Release(r) => r?,
            _ => unreachable!("release answers release"),
        };
        let mm = &mut self.members[member];
        mm.rows_free[chip] = rep.rows_free as usize;
        mm.rows_used[chip] = mm.rows_used[chip].saturating_sub(freed);
        Ok(rep)
    }

    /// Probe every member's health: reachability, reconnect count, and
    /// pool incarnation. Bounced and unreachable members are
    /// quarantined (skipped by the dispatch rotation) until
    /// re-programmed and [rejoined](ShardRouter::rejoin_member); a
    /// bounced member's row/wear mirrors are resynced from its fresh
    /// pool. Clears the suspect flag and refreshes
    /// [`RouterStats::reconnects`].
    // lint: allow(panic-freedom) — probe replies index the member table the probes were fanned out over
    pub fn probe_members(&mut self) -> Vec<MemberProbe> {
        self.suspect = false;
        let mut out = Vec::with_capacity(self.members.len());
        for m in 0..self.members.len() {
            let was_quarantined = self.members[m].quarantined;
            let prev_reconnects = self.members[m].reconnects;
            let state = match self.call(m, MemberJob::Health) {
                Ok(MemberReply::Health(Ok(h))) => {
                    self.members[m].reconnects = h.reconnects;
                    if h.bounced {
                        // fresh pool: the old mirrors describe arrays
                        // that no longer exist
                        let compatible = h.info.data_cols == self.members[m].info.data_cols
                            && h.info.chips > 0;
                        self.members[m].quarantined = true;
                        if !compatible {
                            // a replacement pool with different geometry
                            // can never serve this fleet's packings
                            MemberState::Unreachable
                        } else {
                            self.members[m].info = h.info;
                            let chips = self.members[m].info.chips as usize;
                            // consumption restarts with the fresh pool:
                            // the dead pool's rows are gone, not in use
                            self.members[m].rows_used = vec![0; chips];
                            match self.wear_member(m) {
                                Ok(_) => MemberState::Bounced,
                                Err(_) => MemberState::Unreachable,
                            }
                        }
                    } else {
                        // a member is only ever quarantined by a bounce
                        // or unreachability, both of which its backend
                        // still reports until rejoined — so a healthy
                        // verdict here means any stale quarantine from
                        // a transient outage can be lifted
                        self.members[m].quarantined = false;
                        MemberState::Healthy
                    }
                }
                Ok(MemberReply::Health(Err(_))) | Err(_) => {
                    self.members[m].quarantined = true;
                    MemberState::Unreachable
                }
                Ok(_) => unreachable!("health answers health"),
            };
            // transitions, not observations: a member probed as
            // quarantined N times emits one Quarantine (exactly-once —
            // the bus contract)
            let now = &self.members[m];
            if now.reconnects > prev_reconnects {
                self.obs
                    .plane
                    .bus
                    .emit(ObsEvent::Reconnect { member: m, reconnects: now.reconnects });
            }
            if now.quarantined && !was_quarantined {
                self.obs.plane.bus.emit(ObsEvent::Quarantine { member: m });
            } else if !now.quarantined && was_quarantined {
                // a transient outage healed by the probe itself lifts
                // the quarantine without a rejoin_member call
                self.obs.plane.bus.emit(ObsEvent::Rejoin { member: m });
            }
            out.push(MemberProbe { member: m, state, reconnects: self.members[m].reconnects });
        }
        self.stats.reconnects = self.members.iter().map(|m| m.reconnects).sum();
        out
    }

    /// Lift a member's quarantine after its shards were re-programmed
    /// at the current epoch — the member returns to its replica group's
    /// dispatch rotation (and to hedging duty).
    ///
    /// # Errors
    ///
    /// The backend's [`super::Backend::rejoin`] failure modes.
    // lint: allow(panic-freedom) — member id is validated at entry before indexing
    pub fn rejoin_member(&mut self, member: usize) -> Result<()> {
        match self.call(member, MemberJob::Rejoin)? {
            MemberReply::Rejoin(r) => r?,
            _ => unreachable!("rejoin answers rejoin"),
        }
        if self.members[member].quarantined {
            self.members[member].quarantined = false;
            self.obs.plane.bus.emit(ObsEvent::Rejoin { member });
        }
        Ok(())
    }

    // lint: allow(panic-freedom) — member id is validated at entry before indexing
    fn wear_member(&mut self, member: usize) -> Result<WearReply> {
        let rep = match self.call(member, MemberJob::Wear)? {
            MemberReply::Wear(r) => r?,
            _ => unreachable!("wear answers wear"),
        };
        let mm = &mut self.members[member];
        mm.rows_free = rep.rows_free.iter().map(|&r| r as usize).collect();
        mm.est_pulses = rep.wear.iter().map(|w| w.write_pulses).collect();
        Ok(rep)
    }

    /// Per-member wear + free rows (the rebalancer's input), refreshing
    /// the client-side mirrors along the way.
    pub fn wear_all(&mut self) -> Result<Vec<WearReply>> {
        (0..self.members.len()).map(|m| self.wear_member(m)).collect()
    }

    /// Zero every member's energy ledgers (post-placement baseline).
    pub fn reset_energy_all(&mut self) -> Result<()> {
        for m in 0..self.members.len() {
            match self.call(m, MemberJob::ResetEnergy)? {
                MemberReply::ResetEnergy(r) => r?,
                _ => unreachable!("reset answers reset"),
            }
        }
        Ok(())
    }

    /// Finish every member (workers join; remote hosts close) and
    /// collect their terminal reports, member-major.
    // lint: allow(panic-freedom) — join handles are present until finish() takes them exactly once
    pub fn finish(&mut self) -> Result<Vec<FinishReply>> {
        let mut out = Vec::with_capacity(self.members.len());
        for m in 0..self.members.len() {
            let rep = match self.call(m, MemberJob::Finish)? {
                MemberReply::Finish(r) => r?,
                _ => unreachable!("finish answers finish"),
            };
            self.members[m].job_tx = None;
            if let Some(h) = self.members[m].handle.take() {
                let _ = h.join();
            }
            out.push(rep);
        }
        Ok(out)
    }

    // -- placement ---------------------------------------------------------

    /// Which group owns layer `l` of an `n_layers` model: a contiguous
    /// split, balanced by layer count.
    pub fn group_of_layer(&self, l: usize, n_layers: usize) -> usize {
        l * self.groups.len() / n_layers.max(1)
    }

    /// Place (and program) every live filter of `model` across the
    /// fleet: layers are split across groups, and **every member** of
    /// the owning group receives a byte-identical copy of each shard
    /// (that is what makes its replies interchangeable under hedging).
    /// `row_quota`, when set, bounds the rows the model may occupy *per
    /// member*; chip choice within a member is least-estimated-wear
    /// first with stuck-tile retry, mirroring the single-pool placer.
    // lint: allow(panic-freedom) — placement indexes the member tables the capacity plan was derived from
    pub fn place(
        &mut self,
        model: &ModelBundle,
        row_quota: Option<usize>,
    ) -> anyhow::Result<RouterPlacement> {
        let per_row = self.data_cols();
        let n_layers = model.n_layers();
        let pls = model.placement_layers();
        // pre-checks: each member must fit — and have quota for — its
        // own group's layers. The quota is per member (a replica spends
        // it again on its own pool), so a multi-group split is checked
        // against each group's share, not the whole model.
        for (gi, group) in self.groups.iter().enumerate() {
            let need: usize = pls
                .iter()
                .enumerate()
                .filter(|(l, _)| self.group_of_layer(*l, n_layers) == gi)
                .map(|(_, pl)| {
                    pl.shards.iter().flatten().count() * pl.cells.div_ceil(per_row)
                })
                .sum();
            if let Some(quota) = row_quota {
                if need > quota {
                    return Err(anyhow!(
                        "model needs {need} rows on each member of group {gi} \
                         but its tenant row quota is {quota}"
                    ));
                }
            }
            for &m in &group.members {
                let free: usize = self.members[m].rows_free.iter().sum();
                if need > free {
                    return Err(anyhow!(
                        "model needs {need} rows on backend {m} but it has {free} free; \
                         prune harder, grow the pool, or evict a tenant"
                    ));
                }
            }
        }
        let mut layers = Vec::with_capacity(n_layers);
        let mut stuck_retries = 0usize;
        let mut quota_rows = vec![0usize; self.members.len()];
        for (l, pl) in pls.iter().enumerate() {
            let g = self.group_of_layer(l, n_layers);
            let group_members = self.groups[g].members.clone();
            let need = pl.cells.div_ceil(per_row);
            let mut member_shards: Vec<Vec<Option<ShardRef>>> =
                Vec::with_capacity(group_members.len());
            for &m in &group_members {
                let mut shards: Vec<Option<ShardRef>> = Vec::with_capacity(pl.shards.len());
                for (f, payload) in pl.shards.iter().enumerate() {
                    let Some(payload) = payload else {
                        shards.push(None);
                        continue;
                    };
                    if let Some(quota) = row_quota {
                        if quota_rows[m] + need > quota {
                            return Err(anyhow!(
                                "tenant row quota {quota} exhausted at layer {} filter {f} \
                                 ({} rows already live)",
                                pl.name,
                                quota_rows[m]
                            ));
                        }
                    }
                    let owned: OwnedPayload = (*payload).into();
                    match self
                        .place_filter(m, need, &owned)
                        .map_err(|e| anyhow!("transport failed during placement: {e}"))?
                    {
                        PlaceOutcome::Placed { chip, span, retries } => {
                            stuck_retries += retries;
                            quota_rows[m] += span.slots.len();
                            shards.push(Some(ShardRef {
                                chip: chip as u32,
                                filter: f as u32,
                                span,
                            }));
                        }
                        PlaceOutcome::NoRoom { retries } => {
                            stuck_retries += retries;
                            return Err(anyhow!(
                                "placement failed: layer {} filter {f} ({} cells) fits no chip \
                                 of backend {m} ({stuck_retries} stuck-tile retries so far)",
                                pl.name,
                                pl.cells
                            ));
                        }
                    }
                }
                member_shards.push(shards);
            }
            layers.push(PlacedLayer { group: g, shards: member_shards });
        }
        Ok(RouterPlacement { layers, stuck_retries })
    }

    /// One shard payload onto one member, chip chosen by the placement
    /// policy — how cross-group migration and post-bounce re-programming
    /// store copies (the engine's heal path calls this directly).
    // lint: allow(panic-freedom) — row cursor was bounds-checked against rows_free by the caller
    pub(crate) fn place_shard(
        &mut self,
        member: usize,
        payload: &OwnedPayload,
    ) -> Result<PlaceOutcome> {
        let need = payload.cells().div_ceil(self.members[member].info.data_cols as usize);
        self.place_filter(member, need, payload)
    }

    /// One filter onto one member: chips in least-estimated-wear order
    /// (ties toward more free rows), retrying past stuck tiles.
    // lint: allow(panic-freedom) — candidate members were filtered against rows_free before indexing
    fn place_filter(
        &mut self,
        member: usize,
        need: usize,
        payload: &OwnedPayload,
    ) -> Result<PlaceOutcome> {
        let n_chips = self.members[member].info.chips as usize;
        let mut order: Vec<usize> = (0..n_chips).collect();
        {
            let mm = &self.members[member];
            order.sort_by_key(|&c| (mm.est_pulses[c], usize::MAX - mm.rows_free[c], c));
        }
        let mut retries = 0usize;
        for &c in &order {
            if self.members[member].rows_free[c] < need {
                continue;
            }
            let rep = self.program(member, c, payload.clone())?;
            match rep.span {
                None => continue, // mirror already resynced by program()
                Some(span) => {
                    if rep.failures > 0 {
                        retries += 1; // stuck tile: rows retired, next chip
                        continue;
                    }
                    return Ok(PlaceOutcome::Placed { chip: c, span, retries });
                }
            }
        }
        Ok(PlaceOutcome::NoRoom { retries })
    }

    // -- data plane --------------------------------------------------------

    // lint: allow(panic-freedom) — quantile index is clamped to the histogram length
    fn hedge_deadline(&self, group: usize) -> Duration {
        if let Some(d) = self.cfg.hedge.after {
            return d;
        }
        let lat = &self.groups[group].lat;
        if lat.count() < self.cfg.hedge.min_samples {
            return self.cfg.hedge.ceiling;
        }
        let q = lat.quantile(self.cfg.hedge.quantile);
        Duration::from_secs_f64(q.as_secs_f64() * self.cfg.hedge.factor)
            .clamp(self.cfg.hedge.floor, self.cfg.hedge.ceiling)
    }

    /// Dispatch one layer's windows to the owning group and return the
    /// `(filter, dots)` pairs of the first matching reply. Spills off a
    /// full member queue, hedges past the group's deadline, skips
    /// quarantined members, and discards duplicate replies by
    /// `(request id, shard epoch)` — the caller sees exactly one answer
    /// per call.
    ///
    /// `parent` is the caller's trace context (a batch-level span from
    /// [`ShardRouter::begin_trace`], or [`TraceContext::none`] to opt
    /// out): each attempt rides the wire as a child span — a hedged
    /// duplicate shares the trace but gets its own span id — and the
    /// winning reply's echoed context stitches the host-boundary
    /// execute time into the tree. Stage histograms
    /// ([`stage::DISPATCH`], [`stage::EXECUTE`], [`stage::TRANSPORT`])
    /// are fed regardless of tracing.
    ///
    /// # Errors
    ///
    /// [`TransportError::Remote`] when every member of the owning group
    /// is quarantined, or when the last reachable member rejected the
    /// request; [`TransportError::Closed`] when the router's workers
    /// are gone.
    pub fn dispatch_layer(
        &mut self,
        route: &TenantRoute,
        layer: usize,
        windows: WireWindows,
        parent: TraceContext,
    ) -> Result<Vec<(u32, Vec<i64>)>> {
        let pending = self.submit_layer(route, layer, windows, parent)?;
        self.collect(pending)
    }

    /// First half of [`ShardRouter::dispatch_layer`]: pick a member
    /// (round-robin, spilling off a full queue) and send the request
    /// without waiting for the reply. Up to [`PipelineConfig::depth`]
    /// dispatches may be pending at once — the executor overlaps the
    /// next micro-batch's quantize/pack work with these in-flight chip
    /// dots and folds each reply via [`ShardRouter::collect`].
    ///
    /// # Errors
    ///
    /// [`TransportError::Remote`] when every member of the owning group
    /// is quarantined or the pipeline depth bound is already consumed;
    /// [`TransportError::Closed`] when the router's workers are gone.
    // lint: allow(panic-freedom) — layer routes index tables built by place() for this very router
    pub fn submit_layer(
        &mut self,
        route: &TenantRoute,
        layer: usize,
        windows: WireWindows,
        parent: TraceContext,
    ) -> Result<PendingDispatch> {
        if self.pending.len() >= self.cfg.pipeline.depth {
            return Err(TransportError::Remote(format!(
                "pipeline depth {} exhausted: collect a pending dispatch first",
                self.cfg.pipeline.depth
            )));
        }
        let lr = &route.layers[layer];
        let g = lr.group;
        let members = self.groups[g].members.clone();
        debug_assert_eq!(lr.shards.len(), members.len(), "route member count vs group");
        // rotation order over the members currently allowed to serve
        let live: Vec<usize> = (0..members.len())
            .filter(|&l| !self.members[members[l]].quarantined)
            .collect();
        let n = live.len();
        if n == 0 {
            return Err(TransportError::Remote(format!(
                "every member of group {g} is quarantined awaiting re-program"
            )));
        }
        self.stats.dispatches += 1;
        let req_id = self.next_request;
        self.next_request += 1;
        let start = self.groups[g].rr % n;
        self.groups[g].rr = self.groups[g].rr.wrapping_add(1);
        // positions rotate through `order`; each entry is a member-local
        // index of the owning group
        let order: Vec<usize> = (0..n).map(|k| live[(start + k) % n]).collect();
        let primary_ctx = if parent.is_traced() {
            parent.child(self.obs.plane.trace.next_span())
        } else {
            TraceContext::none()
        };
        let request = |local: usize, ctx: TraceContext| DispatchRequest {
            request_id: req_id,
            shard_epoch: route.epoch,
            layer: layer as u32,
            trace: ctx,
            shards: Arc::clone(&lr.shards[local]),
            windows: windows.clone(),
        };
        // pick the primary round-robin; a full queue spills to the next
        // replica, and only if every queue is full do we block (compute
        // is never shed here — shedding belongs to the admission plane)
        let mut primary_pos = None;
        for (k, &local) in order.iter().enumerate() {
            if self.try_send(members[local], MemberJob::Dispatch(request(local, primary_ctx)))? {
                if k > 0 {
                    self.stats.spills += 1;
                    self.obs
                        .plane
                        .bus
                        .emit(ObsEvent::SpillOver { group: g, member: members[local] });
                }
                self.note_attempt_sent();
                primary_pos = Some(k);
                break;
            }
        }
        let primary_pos = match primary_pos {
            Some(pos) => pos,
            None => {
                self.send_blocking(
                    members[order[0]],
                    MemberJob::Dispatch(request(order[0], primary_ctx)),
                )?;
                self.note_attempt_sent();
                0
            }
        };
        let t0 = Instant::now();
        let hedge_after =
            if n > 1 && self.cfg.hedge.enabled { Some(self.hedge_deadline(g)) } else { None };
        self.pending.insert(req_id);
        Ok(PendingDispatch {
            req_id,
            group: g,
            layer,
            epoch: route.epoch,
            members,
            order,
            primary_pos,
            shards: lr.shards.clone(),
            windows,
            parent,
            primary_ctx,
            t0,
            hedge_after,
        })
    }

    /// Second half of [`ShardRouter::dispatch_layer`]: wait for
    /// `pending`'s reply, hedging past the group deadline and failing
    /// over off a dead member exactly as the serial path does. A reply
    /// for a *different* pending dispatch that arrives meanwhile is
    /// stashed for that dispatch's own `collect` — never dropped. A
    /// hedge for a pending dispatch fires only while it is the one
    /// being collected, so at depth 1 this is exactly the old serial
    /// timing.
    ///
    /// # Errors
    ///
    /// [`TransportError::Remote`] when the last reachable member
    /// rejected the request, or when `pending` was invalidated by a
    /// fence drain ([`ShardRouter::fence_and_drain`] retires the whole
    /// pipeline, not just the dispatch being collected);
    /// [`TransportError::Closed`] when the router's workers are gone.
    // lint: allow(panic-freedom) — reply bookkeeping indexes the outstanding-request tables the submits populated; the expect documents that a pending id is always stashed
    pub fn collect(&mut self, pending: PendingDispatch) -> Result<Vec<(u32, Vec<i64>)>> {
        if !self.pending.remove(&pending.req_id) {
            return Err(TransportError::Remote(
                "pending dispatch was invalidated by a fence drain".into(),
            ));
        }
        let p = pending;
        let n = p.order.len();
        let g = p.group;
        let request = |local: usize, ctx: TraceContext| DispatchRequest {
            request_id: p.req_id,
            shard_epoch: p.epoch,
            layer: p.layer as u32,
            trace: ctx,
            shards: Arc::clone(&p.shards[local]),
            windows: p.windows.clone(),
        };
        let mut timer_armed = p.hedge_after.is_some();
        let mut hedge_member: Option<usize> = None;
        let mut hedge_span: Option<(TraceContext, Instant, usize)> = None;
        let mut in_flight = 1usize;
        loop {
            // a reply stashed while another dispatch was collected is
            // consumed before the channel is touched (its `outstanding`
            // decrement already happened on receipt)
            let next = if let Some(i) = self.stash.iter().position(|(id, _, _)| *id == p.req_id) {
                let (id, m, result) = self.stash.remove(i);
                Ok((m, id, result))
            } else {
                let recv = if timer_armed && hedge_member.is_none() {
                    let after = p.hedge_after.expect("armed timer has a deadline");
                    let elapsed = p.t0.elapsed();
                    if elapsed >= after {
                        Err(RecvTimeoutError::Timeout)
                    } else {
                        self.res_rx.recv_timeout(after - elapsed)
                    }
                } else {
                    self.res_rx.recv().map_err(|_| RecvTimeoutError::Disconnected)
                };
                match recv {
                    Ok((m, MemberReply::Dispatch { request_id, result })) => {
                        self.outstanding = self.outstanding.saturating_sub(1);
                        Ok((m, request_id, result))
                    }
                    Ok((_, _)) => {
                        unreachable!("control replies cannot be in flight during a dispatch")
                    }
                    Err(e) => Err(e),
                }
            };
            match next {
                Ok((m, request_id, result)) => {
                    if request_id != p.req_id {
                        if self.pending.contains(&request_id) {
                            // another pipelined dispatch's reply: hold
                            // it for that dispatch's own collect
                            self.stash.push((request_id, m, result));
                        } else {
                            // a hedge that already lost (or a
                            // pre-cutover straggler) — count it in
                            // exactly one bucket
                            self.note_unclaimed_dispatch(&result);
                        }
                        continue;
                    }
                    let failed = match result {
                        Ok(rep) if rep.shard_epoch == p.epoch => {
                            let rtt = p.t0.elapsed();
                            self.groups[g].lat.record(rtt);
                            let hedge_won = hedge_member == Some(m);
                            if hedge_won {
                                self.stats.hedge_wins += 1;
                            }
                            self.record_dispatch_spans(
                                &rep, g, p.layer, m, p.t0, rtt, p.primary_ctx, hedge_span,
                                hedge_won,
                            );
                            return Ok(rep.dots);
                        }
                        Ok(rep) => {
                            self.note_unclaimed_dispatch(&Ok(rep));
                            TransportError::Remote("reply carries a stale shard epoch".into())
                        }
                        Err(e) => {
                            // a member failed a live dispatch: have the
                            // owner probe the fleet at the next boundary
                            self.suspect = true;
                            e
                        }
                    };
                    in_flight -= 1;
                    if in_flight == 0 {
                        if n > 1 && hedge_member.is_none() {
                            // the only attempt died: fail over to the
                            // replica instead of surfacing the error
                            let alt = p.order[(p.primary_pos + 1) % n];
                            let hctx = if p.parent.is_traced() {
                                p.parent.child(self.obs.plane.trace.next_span())
                            } else {
                                TraceContext::none()
                            };
                            self.send_blocking(
                                p.members[alt],
                                MemberJob::Dispatch(request(alt, hctx)),
                            )?;
                            self.note_attempt_sent();
                            self.stats.hedges_fired += 1;
                            hedge_member = Some(p.members[alt]);
                            hedge_span = Some((hctx, Instant::now(), p.members[alt]));
                            in_flight = 1;
                        } else {
                            return Err(failed);
                        }
                    }
                }
                Err(RecvTimeoutError::Timeout) => {
                    let alt = p.order[(p.primary_pos + 1) % n];
                    let hctx = if p.parent.is_traced() {
                        p.parent.child(self.obs.plane.trace.next_span())
                    } else {
                        TraceContext::none()
                    };
                    if self.try_send(p.members[alt], MemberJob::Dispatch(request(alt, hctx)))? {
                        self.note_attempt_sent();
                        self.stats.hedges_fired += 1;
                        hedge_member = Some(p.members[alt]);
                        hedge_span = Some((hctx, Instant::now(), p.members[alt]));
                        in_flight += 1;
                    } else {
                        // replica saturated: stop hedging this request
                        timer_armed = false;
                    }
                }
                Err(RecvTimeoutError::Disconnected) => return Err(TransportError::Closed),
            }
        }
    }

    /// Feed the stage histograms and (for traced requests) record the
    /// dispatch/hedge/execute spans of one answered dispatch. The
    /// execute span hangs under the *winning attempt's* echoed context —
    /// over TCP that context crossed the wire twice, which is exactly
    /// the multi-host stitch.
    #[allow(clippy::too_many_arguments)]
    fn record_dispatch_spans(
        &self,
        rep: &DispatchReply,
        group: usize,
        layer: usize,
        winner: usize,
        t0: Instant,
        rtt: Duration,
        primary_ctx: TraceContext,
        hedge_span: Option<(TraceContext, Instant, usize)>,
        hedge_won: bool,
    ) {
        // host_ns is the serving side's own clock; clamp to the observed
        // round trip so `transport = rtt − execute` can never underflow
        let host = Duration::from_nanos(rep.host_ns).min(rtt);
        self.obs.stage_dispatch.record(rtt);
        self.obs.stage_execute.record(host);
        self.obs.stage_transport.record(rtt - host);
        if !primary_ctx.is_traced() {
            return;
        }
        let log = &self.obs.plane.trace;
        log.record(SpanRecord {
            ctx: primary_ctx,
            stage: Stage::Dispatch,
            note: format!(
                "layer={layer} group={group} member={winner}{}",
                if hedge_won { " hedge-won" } else { "" }
            ),
            start: t0,
            dur: rtt,
        });
        if let Some((hctx, ht, hm)) = hedge_span {
            log.record(SpanRecord {
                ctx: hctx,
                stage: Stage::Hedge,
                note: format!(
                    "duplicate member={hm}{}",
                    if hedge_won { " won" } else { " discarded" }
                ),
                start: ht,
                dur: ht.elapsed(),
            });
        }
        log.record(SpanRecord {
            ctx: rep.trace.child(log.next_span()),
            stage: Stage::Execute,
            note: format!("member={winner} host_ns={}", rep.host_ns),
            start: t0 + (rtt - host),
            dur: host,
        });
    }

    // -- migration (the fence machine; see the module docs) ----------------

    /// **Fence + drain**: retire `old_epoch` and block until every
    /// in-flight dispatch has been answered. Afterwards no request that
    /// was built against the pre-cutover placement exists anywhere in
    /// the fleet, so its rows may be freed. Each drained reply is
    /// discarded by identity and counted exactly once
    /// ([`RouterStats::epoch_discards`] when its epoch is fenced,
    /// [`RouterStats::stale_discarded`] otherwise).
    ///
    /// # Errors
    ///
    /// [`TransportError::Closed`] when the router's workers are gone
    /// (an in-flight reply can then never arrive).
    pub fn fence_and_drain(&mut self, old_epoch: u64) -> Result<()> {
        self.fenced.insert(old_epoch);
        self.drain_inflight()
    }

    /// Wait for every outstanding dispatch reply and discard it. Member
    /// workers are strictly serial, so every sent job is answered and
    /// this terminates. The executor pipeline is retired wholesale:
    /// uncollected [`PendingDispatch`]es are invalidated (their
    /// `collect` fails cleanly instead of blocking on a discarded
    /// reply) and already-stashed replies are discarded and counted
    /// like any other drained straggler.
    fn drain_inflight(&mut self) -> Result<()> {
        self.pending.clear();
        let stashed = std::mem::take(&mut self.stash);
        for (_, _, result) in &stashed {
            self.note_unclaimed_dispatch(result);
        }
        while self.outstanding > 0 {
            let (_, reply) = self.res_rx.recv().map_err(|_| TransportError::Closed)?;
            match reply {
                MemberReply::Dispatch { result, .. } => {
                    self.outstanding -= 1;
                    self.note_unclaimed_dispatch(&result);
                }
                _ => unreachable!("no control call is in flight during a drain"),
            }
        }
        Ok(())
    }

    /// Migrate one whole layer **between groups**: program byte-identical
    /// copies of every live shard payload onto every member of
    /// `to_group`, fence `old_epoch`, drain the fleet, then free the
    /// source spans. The caller (the engine coordinator) must be the
    /// only dispatcher — the drain guarantee assumes no new dispatches
    /// are issued mid-migration — and applies the returned shard table
    /// and epoch to its placement/route before dispatching again.
    ///
    /// `old_shards[member_local][filter]` are the source copies on
    /// `from_group` (released in the free step); `payloads[filter]` is
    /// `None` for pruned filters and must match the source's liveness.
    ///
    /// On any programming failure the migration aborts: partial
    /// destination spans are released again, nothing is fenced, and the
    /// source placement remains authoritative —
    /// [`MigrationOutcome::Aborted`] tells the caller to keep serving
    /// from where it was (bit-exactness is never at risk, because the
    /// cutover happens only after every copy verified clean).
    ///
    /// # Errors
    ///
    /// [`TransportError::Closed`] when the router's workers are gone.
    /// Transport failures against individual members abort the
    /// migration instead of erroring (the fleet may heal later).
    // lint: allow(panic-freedom) — migration indexes the placement snapshot captured under the fence
    pub fn migrate_layer(
        &mut self,
        layer: usize,
        old_epoch: u64,
        from_group: usize,
        old_shards: &[Vec<Option<ShardRef>>],
        to_group: usize,
        payloads: &[Option<OwnedPayload>],
    ) -> Result<MigrationOutcome> {
        assert_ne!(from_group, to_group, "cross-group migration needs distinct groups");
        debug_assert_eq!(
            old_shards.len(),
            self.groups[from_group].members.len(),
            "old shard table shape vs source group"
        );
        self.stats.migrations_started += 1;
        self.obs.plane.bus.emit(ObsEvent::MigrationStarted { layer, from_group, to_group });
        let dst_members = self.groups[to_group].members.clone();
        let mut stuck_retries = 0usize;
        // -- program: every destination member gets every live payload
        let mut new_shards: Vec<Vec<Option<ShardRef>>> = Vec::with_capacity(dst_members.len());
        for &m in &dst_members {
            let mut member_shards: Vec<Option<ShardRef>> = Vec::with_capacity(payloads.len());
            let mut failed = false;
            for (f, payload) in payloads.iter().enumerate() {
                let Some(payload) = payload else {
                    member_shards.push(None);
                    continue;
                };
                match self.place_shard(m, payload) {
                    Ok(PlaceOutcome::Placed { chip, span, retries }) => {
                        stuck_retries += retries;
                        member_shards.push(Some(ShardRef {
                            chip: chip as u32,
                            filter: f as u32,
                            span,
                        }));
                    }
                    Ok(PlaceOutcome::NoRoom { retries }) => {
                        stuck_retries += retries;
                        failed = true;
                        break;
                    }
                    Err(TransportError::Closed) => return Err(TransportError::Closed),
                    Err(_) => {
                        // member unreachable mid-program: abort, heal later
                        self.suspect = true;
                        failed = true;
                        break;
                    }
                }
            }
            new_shards.push(member_shards);
            if failed {
                self.rollback_partial(&dst_members, &new_shards);
                self.stats.migrations_aborted += 1;
                self.obs.plane.bus.emit(ObsEvent::MigrationAborted { layer });
                return Ok(MigrationOutcome::Aborted { stuck_retries });
            }
        }
        // -- fence: the destination copies are now authoritative
        let epoch = self.next_epoch();
        self.stats.migrations_fenced += 1;
        self.obs.plane.bus.emit(ObsEvent::MigrationFenced { layer, epoch: old_epoch });
        // -- drain: no pre-cutover request survives this call
        self.fence_and_drain(old_epoch)?;
        // -- free: the source rows can no longer be addressed by anyone
        let src_members = self.groups[from_group].members.clone();
        for (local, &m) in src_members.iter().enumerate() {
            for shard in old_shards[local].iter().flatten() {
                // best effort: a backend without release support (or an
                // unreachable one) just retires these rows
                let _ = self.release(m, shard.chip as usize, shard.span.clone());
            }
        }
        self.stats.migrations_completed += 1;
        self.obs.plane.bus.emit(ObsEvent::MigrationCompleted { layer, epoch });
        Ok(MigrationOutcome::Completed { shards: new_shards, epoch, stuck_retries })
    }

    /// Undo the program phase of an aborted migration: release every
    /// span already stored on the destination members.
    // lint: allow(panic-freedom) — rollback walks exactly the members the partial migration touched
    fn rollback_partial(&mut self, dst_members: &[usize], partial: &[Vec<Option<ShardRef>>]) {
        for (mi, shards) in partial.iter().enumerate() {
            for shard in shards.iter().flatten() {
                let _ = self.release(dst_members[mi], shard.chip as usize, shard.span.clone());
            }
        }
    }
}

impl Drop for ShardRouter {
    fn drop(&mut self) {
        for m in &mut self.members {
            m.job_tx = None; // hang up: workers drain and exit
        }
        for m in &mut self.members {
            if let Some(h) = m.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chip::WearLedger;
    use crate::util::sync::lock_unpoisoned;
    use std::sync::atomic::{AtomicU64, Ordering};

    /// A scriptable backend: fixed dots, optional per-dispatch delay,
    /// optional scripted failures, a toy row allocator with release
    /// accounting — enough to pin down hedging, failover,
    /// duplicate-discard, and the migration fence machine without
    /// silicon.
    #[derive(Default)]
    struct MockBackend {
        delay: Duration,
        fail_dispatches: u64,
        /// Scripted `span: None` program replies (capacity refusal).
        fail_programs: u64,
        /// Scripted `Err` program replies (the member dying mid-program
        /// — the migration fence machine's transport-failure edge).
        error_programs: u64,
        served: Arc<AtomicU64>,
        /// Rows released onto this backend (the free/rollback steps).
        released: Arc<AtomicU64>,
        /// Trace contexts of every dispatch this backend received, in
        /// arrival order — what the hedge-trace test inspects.
        traces: Arc<std::sync::Mutex<Vec<TraceContext>>>,
        next_row: usize,
        dot: i64,
    }

    impl MockBackend {
        fn boxed(delay: Duration, fail_dispatches: u64, served: Arc<AtomicU64>, dot: i64) -> Box<dyn Backend> {
            Box::new(MockBackend { delay, fail_dispatches, served, dot, ..MockBackend::default() })
        }
    }

    impl Backend for MockBackend {
        fn describe(&mut self) -> Result<BackendInfo> {
            Ok(BackendInfo { chips: 1, data_cols: 30, incarnation: 1 })
        }

        fn dispatch(&mut self, req: DispatchRequest) -> Result<DispatchReply> {
            if self.fail_dispatches > 0 {
                self.fail_dispatches -= 1;
                return Err(TransportError::Remote("scripted failure".into()));
            }
            if !self.delay.is_zero() {
                std::thread::sleep(self.delay);
            }
            self.served.fetch_add(1, Ordering::SeqCst);
            lock_unpoisoned(&self.traces).push(req.trace);
            Ok(DispatchReply {
                request_id: req.request_id,
                shard_epoch: req.shard_epoch,
                layer: req.layer,
                dots: req.shards.iter().map(|s| (s.filter, vec![self.dot])).collect(),
                trace: req.trace,
                host_ns: 1,
            })
        }

        fn program(&mut self, req: ProgramRequest) -> Result<ProgramReply> {
            if self.error_programs > 0 {
                self.error_programs -= 1;
                return Err(TransportError::Remote("scripted program failure".into()));
            }
            if self.fail_programs > 0 {
                self.fail_programs -= 1;
                return Ok(ProgramReply { span: None, failures: 0 });
            }
            let per_row = 30usize;
            let cells = req.payload.cells();
            let need = cells.div_ceil(per_row);
            let slots: Vec<(usize, usize)> =
                (0..need).map(|i| (0, self.next_row + i)).collect();
            self.next_row += need;
            Ok(ProgramReply {
                span: Some(crate::cim::mapping::RowSpan {
                    slots,
                    tail_width: cells - (need - 1) * per_row,
                    len: cells,
                }),
                failures: 0,
            })
        }

        fn release(&mut self, req: super::ReleaseRequest) -> Result<super::ReleaseReply> {
            self.released.fetch_add(req.span.slots.len() as u64, Ordering::SeqCst);
            Ok(super::ReleaseReply { rows_free: 64 })
        }

        fn wear(&mut self) -> Result<WearReply> {
            Ok(WearReply { wear: vec![WearLedger::default()], rows_free: vec![64] })
        }

        fn reset_energy(&mut self) -> Result<()> {
            Ok(())
        }

        fn finish(&mut self) -> Result<FinishReply> {
            Ok(FinishReply { energy_pj: 0.0, wear: vec![WearLedger::default()] })
        }
    }

    fn route_one_layer(n_members: usize) -> TenantRoute {
        TenantRoute {
            epoch: 1,
            layers: vec![LayerRoute {
                group: 0,
                shards: (0..n_members)
                    .map(|_| {
                        Arc::new(vec![ShardRef {
                            chip: 0,
                            filter: 0,
                            span: crate::cim::mapping::RowSpan {
                                slots: vec![(0, 0)],
                                tail_width: 1,
                                len: 1,
                            },
                        }])
                    })
                    .collect(),
            }],
        }
    }

    fn empty_windows() -> WireWindows {
        WireWindows::Binary(Arc::new(crate::cim::vmm::PackedWindows {
            n_windows: 0,
            seg_widths: vec![1],
            planes: vec![],
            sum_x: vec![],
        }))
    }

    #[test]
    fn hedge_fires_on_a_straggler_and_the_replica_wins() {
        let slow_served = Arc::new(AtomicU64::new(0));
        let fast_served = Arc::new(AtomicU64::new(0));
        let cfg = RouterConfig {
            hedge: HedgeConfig {
                after: Some(Duration::from_millis(5)),
                ..HedgeConfig::default()
            },
            ..RouterConfig::default()
        };
        let mut router = ShardRouter::replicated(
            vec![
                MockBackend::boxed(Duration::from_millis(250), 0, Arc::clone(&slow_served), 7),
                MockBackend::boxed(Duration::ZERO, 0, Arc::clone(&fast_served), 7),
            ],
            cfg,
        )
        .unwrap();
        let route = route_one_layer(2);
        // round-robin starts at the slow member; the 5ms deadline fires
        // and the instant replica answers first
        let dots = router.dispatch_layer(&route, 0, empty_windows(), TraceContext::none()).unwrap();
        assert_eq!(dots, vec![(0, vec![7])]);
        let stats = router.stats();
        assert_eq!(stats.dispatches, 1);
        assert_eq!(stats.hedges_fired, 1);
        assert_eq!(stats.hedge_wins, 1, "the duplicate must have won");
        assert_eq!(fast_served.load(Ordering::SeqCst), 1);
        // the straggler's late reply is discarded by request id — drain
        // it via a control call and check the counter
        std::thread::sleep(Duration::from_millis(300));
        let _ = router.wear_all().unwrap();
        assert_eq!(router.stats().stale_discarded, 1, "losing reply discarded, not re-answered");
        router.finish().unwrap();
        assert_eq!(slow_served.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn failed_primary_fails_over_to_the_replica() {
        let served = Arc::new(AtomicU64::new(0));
        let cfg = RouterConfig {
            hedge: HedgeConfig { after: Some(Duration::from_secs(5)), ..HedgeConfig::default() },
            ..RouterConfig::default()
        };
        let mut router = ShardRouter::replicated(
            vec![
                MockBackend::boxed(Duration::ZERO, 1, Arc::clone(&served), 3),
                MockBackend::boxed(Duration::ZERO, 0, Arc::clone(&served), 3),
            ],
            cfg,
        )
        .unwrap();
        let route = route_one_layer(2);
        let dots = router.dispatch_layer(&route, 0, empty_windows(), TraceContext::none()).unwrap();
        assert_eq!(dots, vec![(0, vec![3])]);
        assert_eq!(router.stats().hedges_fired, 1, "failover counts as a hedge");
        router.finish().unwrap();
    }

    #[test]
    fn solo_member_surfaces_its_error() {
        let served = Arc::new(AtomicU64::new(0));
        let mut router = ShardRouter::single(MockBackend::boxed(
            Duration::ZERO,
            1,
            Arc::clone(&served),
            0,
        ))
        .unwrap();
        let route = route_one_layer(1);
        let err = router.dispatch_layer(&route, 0, empty_windows(), TraceContext::none()).unwrap_err();
        assert!(matches!(err, TransportError::Remote(_)));
        // the next dispatch works again
        assert_eq!(
            router.dispatch_layer(&route, 0, empty_windows(), TraceContext::none()).unwrap(),
            vec![(0, vec![0])]
        );
        router.finish().unwrap();
    }

    #[test]
    fn construction_rejects_empty_and_mismatched_fleets() {
        assert!(ShardRouter::new(vec![], RouterConfig::default()).is_err());
        assert!(ShardRouter::new(vec![vec![]], RouterConfig::default()).is_err());
    }

    #[test]
    fn stale_epoch_reply_after_cutover_is_discarded_and_counted_once() {
        // hedge on every dispatch: the loser's reply is still in flight
        // when the cutover fences its epoch; the drain must discard it
        // and bump epoch_discards exactly once (never stale_discarded)
        let served = Arc::new(AtomicU64::new(0));
        let cfg = RouterConfig {
            hedge: HedgeConfig { after: Some(Duration::ZERO), ..HedgeConfig::default() },
            ..RouterConfig::default()
        };
        let mut router = ShardRouter::replicated(
            vec![
                MockBackend::boxed(Duration::ZERO, 0, Arc::clone(&served), 9),
                MockBackend::boxed(Duration::ZERO, 0, Arc::clone(&served), 9),
            ],
            cfg,
        )
        .unwrap();
        let mut route = route_one_layer(2);
        route.epoch = router.next_epoch();
        let dots = router.dispatch_layer(&route, 0, empty_windows(), TraceContext::none()).unwrap();
        assert_eq!(dots, vec![(0, vec![9])]);
        // exactly one attempt is still unanswered (the hedge loser)
        router.fence_and_drain(route.epoch).unwrap();
        let s = router.stats();
        assert_eq!(s.epoch_discards, 1, "the fenced straggler is counted once");
        assert_eq!(s.stale_discarded, 0, "…and never double-counted as a plain stale");
        // nothing else is in flight: later control traffic sees nothing
        let _ = router.wear_all().unwrap();
        assert_eq!(router.stats().epoch_discards, 1);
        assert_eq!(router.stats().stale_discarded, 0);
        router.finish().unwrap();
        assert_eq!(served.load(Ordering::SeqCst), 2, "both replicas computed the hedge");
    }

    #[test]
    fn migrate_layer_programs_fences_drains_and_frees() {
        let src_released = Arc::new(AtomicU64::new(0));
        let dst_released = Arc::new(AtomicU64::new(0));
        let src = Box::new(MockBackend {
            released: Arc::clone(&src_released),
            ..MockBackend::default()
        });
        let dst = Box::new(MockBackend {
            released: Arc::clone(&dst_released),
            ..MockBackend::default()
        });
        let mut router =
            ShardRouter::new(vec![vec![src], vec![dst]], RouterConfig::default()).unwrap();
        let old_epoch = router.next_epoch();
        let old_shards = vec![vec![
            Some(ShardRef {
                chip: 0,
                filter: 0,
                span: crate::cim::mapping::RowSpan {
                    slots: vec![(0, 0), (0, 1)],
                    tail_width: 5,
                    len: 35,
                },
            }),
            None, // a pruned filter stays pruned through the move
        ]];
        let payloads = vec![Some(OwnedPayload::Binary(vec![true; 35])), None];
        match router.migrate_layer(0, old_epoch, 0, &old_shards, 1, &payloads).unwrap() {
            MigrationOutcome::Completed { shards, epoch, stuck_retries } => {
                assert!(epoch > old_epoch, "the cutover must advance the epoch");
                assert_eq!(stuck_retries, 0);
                assert_eq!(shards.len(), 1, "one destination member");
                let new = shards[0][0].as_ref().expect("live filter placed");
                assert_eq!(new.span.len, 35, "byte-identical payload, same cell count");
                assert!(shards[0][1].is_none(), "pruned filter still pruned");
            }
            MigrationOutcome::Aborted { .. } => panic!("ideal fleet must complete"),
        }
        let s = router.stats();
        assert_eq!(s.migrations_started, 1);
        assert_eq!(s.migrations_fenced, 1);
        assert_eq!(s.migrations_completed, 1);
        assert_eq!(s.migrations_aborted, 0);
        assert_eq!(src_released.load(Ordering::SeqCst), 2, "both source rows freed");
        assert_eq!(dst_released.load(Ordering::SeqCst), 0, "nothing rolled back");
        router.finish().unwrap();
    }

    #[test]
    fn aborted_migration_releases_partials_and_never_fences() {
        // destination is a replica pair; the second member refuses the
        // program (capacity), so the whole migration must unwind
        let a_released = Arc::new(AtomicU64::new(0));
        let b_released = Arc::new(AtomicU64::new(0));
        let src = Box::new(MockBackend::default());
        let dst_a = Box::new(MockBackend {
            released: Arc::clone(&a_released),
            ..MockBackend::default()
        });
        let dst_b = Box::new(MockBackend {
            fail_programs: 64, // every candidate chip refuses
            released: Arc::clone(&b_released),
            ..MockBackend::default()
        });
        let mut router =
            ShardRouter::new(vec![vec![src], vec![dst_a, dst_b]], RouterConfig::default())
                .unwrap();
        let old_epoch = router.next_epoch();
        let span = crate::cim::mapping::RowSpan { slots: vec![(0, 0)], tail_width: 7, len: 7 };
        let old_shards = vec![vec![Some(ShardRef { chip: 0, filter: 0, span: span.clone() })]];
        let payloads = vec![Some(OwnedPayload::Binary(vec![true; 7]))];
        match router.migrate_layer(0, old_epoch, 0, &old_shards, 1, &payloads).unwrap() {
            MigrationOutcome::Aborted { .. } => {}
            MigrationOutcome::Completed { .. } => {
                panic!("a destination refusal must abort the migration")
            }
        }
        let s = router.stats();
        assert_eq!(s.migrations_started, 1);
        assert_eq!(s.migrations_aborted, 1);
        assert_eq!(s.migrations_fenced, 0, "an aborted migration never reaches the fence");
        assert_eq!(s.migrations_completed, 0);
        assert_eq!(a_released.load(Ordering::SeqCst), 1, "partial copy on A rolled back");
        assert_eq!(b_released.load(Ordering::SeqCst), 0);
        // the epoch counter never advanced past the caller's epoch
        assert_eq!(router.next_epoch(), old_epoch + 1);
        router.finish().unwrap();
    }

    #[test]
    fn member_dying_mid_program_aborts_and_flags_the_fleet_suspect() {
        // the transport-failure edge of the program state: member A of
        // the destination pair takes its copies, then member B errors
        // (unreachable) — the migration must unwind A's spans, never
        // fence, and leave the source authoritative + the fleet suspect
        let a_released = Arc::new(AtomicU64::new(0));
        let src = Box::new(MockBackend::default());
        let dst_a = Box::new(MockBackend {
            released: Arc::clone(&a_released),
            ..MockBackend::default()
        });
        let dst_b = Box::new(MockBackend { error_programs: 8, ..MockBackend::default() });
        let mut router =
            ShardRouter::new(vec![vec![src], vec![dst_a, dst_b]], RouterConfig::default())
                .unwrap();
        let old_epoch = router.next_epoch();
        let span = crate::cim::mapping::RowSpan { slots: vec![(0, 0)], tail_width: 3, len: 3 };
        let old_shards = vec![vec![Some(ShardRef { chip: 0, filter: 0, span })]];
        let payloads = vec![Some(OwnedPayload::Binary(vec![true; 3]))];
        assert!(!router.has_suspects());
        match router.migrate_layer(0, old_epoch, 0, &old_shards, 1, &payloads).unwrap() {
            MigrationOutcome::Aborted { .. } => {}
            MigrationOutcome::Completed { .. } => {
                panic!("a dying destination member must abort the migration")
            }
        }
        let s = router.stats();
        assert_eq!((s.migrations_started, s.migrations_aborted), (1, 1));
        assert_eq!(s.migrations_fenced, 0, "the fence is never crossed");
        assert_eq!(s.migrations_completed, 0);
        assert_eq!(a_released.load(Ordering::SeqCst), 1, "A's partial copy rolled back");
        assert!(router.has_suspects(), "a program failure must schedule a health probe");
        router.finish().unwrap();
    }

    #[test]
    fn quarantined_members_are_skipped_until_rejoined() {
        struct BouncedBackend {
            served: Arc<AtomicU64>,
        }
        impl Backend for BouncedBackend {
            fn describe(&mut self) -> Result<BackendInfo> {
                Ok(BackendInfo { chips: 1, data_cols: 30, incarnation: 2 })
            }
            fn dispatch(&mut self, req: DispatchRequest) -> Result<DispatchReply> {
                self.served.fetch_add(1, Ordering::SeqCst);
                Ok(DispatchReply {
                    request_id: req.request_id,
                    shard_epoch: req.shard_epoch,
                    layer: req.layer,
                    dots: req.shards.iter().map(|s| (s.filter, vec![5])).collect(),
                    trace: req.trace,
                    host_ns: 1,
                })
            }
            fn program(&mut self, _req: ProgramRequest) -> Result<ProgramReply> {
                Ok(ProgramReply {
                    span: Some(crate::cim::mapping::RowSpan {
                        slots: vec![(0, 0)],
                        tail_width: 1,
                        len: 1,
                    }),
                    failures: 0,
                })
            }
            fn wear(&mut self) -> Result<WearReply> {
                Ok(WearReply { wear: vec![WearLedger::default()], rows_free: vec![64] })
            }
            fn health(&mut self) -> Result<HealthReply> {
                Ok(HealthReply { info: self.describe()?, reconnects: 3, bounced: true })
            }
            fn reset_energy(&mut self) -> Result<()> {
                Ok(())
            }
            fn finish(&mut self) -> Result<FinishReply> {
                Ok(FinishReply { energy_pj: 0.0, wear: vec![WearLedger::default()] })
            }
        }
        let bounced_served = Arc::new(AtomicU64::new(0));
        let healthy_served = Arc::new(AtomicU64::new(0));
        let cfg = RouterConfig {
            hedge: HedgeConfig { after: Some(Duration::from_secs(5)), ..HedgeConfig::default() },
            ..RouterConfig::default()
        };
        let mut router = ShardRouter::replicated(
            vec![
                Box::new(BouncedBackend { served: Arc::clone(&bounced_served) }),
                MockBackend::boxed(Duration::ZERO, 0, Arc::clone(&healthy_served), 5),
            ],
            cfg,
        )
        .unwrap();
        router.set_obs(Arc::new(Obs::new()));
        let sub = router.obs().bus.subscribe();
        let probes = router.probe_members();
        assert_eq!(probes[0].state, MemberState::Bounced);
        assert_eq!(probes[0].reconnects, 3);
        assert_eq!(probes[1].state, MemberState::Healthy);
        assert!(router.is_quarantined(0));
        assert_eq!(router.stats().reconnects, 3);
        let events = sub.drain();
        let kinds: Vec<&str> = events.iter().map(|r| r.event.kind()).collect();
        assert_eq!(
            kinds,
            vec!["reconnect", "quarantine"],
            "a bounce surfaces the reconnect, then the quarantine"
        );
        assert_eq!(events[0].event, ObsEvent::Reconnect { member: 0, reconnects: 3 });
        assert_eq!(events[1].event, ObsEvent::Quarantine { member: 0 });
        // probing again is an observation, not a transition
        let _ = router.probe_members();
        assert!(sub.drain().is_empty(), "repeat probes emit nothing (exactly-once)");
        // every dispatch lands on the healthy replica while member 0 is out
        let route = route_one_layer(2);
        for _ in 0..4 {
            assert_eq!(router.dispatch_layer(&route, 0, empty_windows(), TraceContext::none()).unwrap().len(), 1);
        }
        assert_eq!(bounced_served.load(Ordering::SeqCst), 0, "quarantined member never serves");
        assert_eq!(healthy_served.load(Ordering::SeqCst), 4);
        // after (re-programming and) rejoining, the rotation includes it again
        router.rejoin_member(0).unwrap();
        assert!(!router.is_quarantined(0));
        let events = sub.drain();
        let kinds: Vec<&str> = events.iter().map(|r| r.event.kind()).collect();
        assert_eq!(kinds, vec!["rejoin"], "quarantine always precedes rejoin");
        assert_eq!(events[0].event, ObsEvent::Rejoin { member: 0 });
        for _ in 0..4 {
            assert_eq!(router.dispatch_layer(&route, 0, empty_windows(), TraceContext::none()).unwrap().len(), 1);
        }
        assert!(bounced_served.load(Ordering::SeqCst) > 0, "rejoined member serves again");
        router.finish().unwrap();
    }

    #[test]
    fn hedged_duplicates_share_trace_with_distinct_span_ids() {
        let slow_traces = Arc::new(std::sync::Mutex::new(Vec::new()));
        let fast_traces = Arc::new(std::sync::Mutex::new(Vec::new()));
        let cfg = RouterConfig {
            hedge: HedgeConfig {
                after: Some(Duration::from_millis(5)),
                ..HedgeConfig::default()
            },
            ..RouterConfig::default()
        };
        let slow = Box::new(MockBackend {
            delay: Duration::from_millis(100),
            traces: Arc::clone(&slow_traces),
            dot: 7,
            ..MockBackend::default()
        });
        let fast = Box::new(MockBackend {
            traces: Arc::clone(&fast_traces),
            dot: 7,
            ..MockBackend::default()
        });
        let mut router = ShardRouter::replicated(vec![slow, fast], cfg).unwrap();
        router.set_obs(Arc::new(Obs::new()));
        let parent = router.begin_trace();
        assert!(parent.is_traced());
        let route = route_one_layer(2);
        let dots = router.dispatch_layer(&route, 0, empty_windows(), parent).unwrap();
        assert_eq!(dots, vec![(0, vec![7])]);
        // wait out the straggler, then inspect what each member saw
        std::thread::sleep(Duration::from_millis(150));
        let a = lock_unpoisoned(&slow_traces).clone();
        let b = lock_unpoisoned(&fast_traces).clone();
        assert_eq!((a.len(), b.len()), (1, 1), "one attempt per member");
        assert_eq!(a[0].trace_id, parent.trace_id, "primary shares the trace");
        assert_eq!(b[0].trace_id, parent.trace_id, "duplicate shares the trace");
        assert_eq!(a[0].parent_span, parent.span_id);
        assert_eq!(b[0].parent_span, parent.span_id);
        assert_ne!(a[0].span_id, b[0].span_id, "each attempt is its own span");
        // the trace log retains the dispatch, hedge, and execute spans
        let spans = router.obs().trace.trace(parent.trace_id);
        let stages: Vec<&str> = spans.iter().map(|s| s.stage.label()).collect();
        assert!(stages.contains(&"dispatch"), "{stages:?}");
        assert!(stages.contains(&"hedge"), "{stages:?}");
        assert!(stages.contains(&"execute"), "{stages:?}");
        // and the stage histograms saw the round trip
        let snap = router.obs().snapshot().render();
        assert!(snap.contains("stage.dispatch"), "{snap}");
        router.finish().unwrap();
    }

    #[test]
    fn aborted_migration_emits_started_then_aborted_never_completed() {
        let src = Box::new(MockBackend::default());
        let dst = Box::new(MockBackend { fail_programs: 64, ..MockBackend::default() });
        let mut router =
            ShardRouter::new(vec![vec![src], vec![dst]], RouterConfig::default()).unwrap();
        router.set_obs(Arc::new(Obs::new()));
        let sub = router.obs().bus.subscribe();
        let old_epoch = router.next_epoch();
        let span = crate::cim::mapping::RowSpan { slots: vec![(0, 0)], tail_width: 7, len: 7 };
        let old_shards = vec![vec![Some(ShardRef { chip: 0, filter: 0, span })]];
        let payloads = vec![Some(OwnedPayload::Binary(vec![true; 7]))];
        match router.migrate_layer(3, old_epoch, 0, &old_shards, 1, &payloads).unwrap() {
            MigrationOutcome::Aborted { .. } => {}
            MigrationOutcome::Completed { .. } => panic!("scripted refusal must abort"),
        }
        let events = sub.drain();
        let kinds: Vec<&str> = events.iter().map(|r| r.event.kind()).collect();
        assert_eq!(
            kinds,
            vec!["migration_started", "migration_aborted"],
            "an aborted migration emits Started then Aborted and never Completed/Fenced"
        );
        assert_eq!(
            events[0].event,
            ObsEvent::MigrationStarted { layer: 3, from_group: 0, to_group: 1 }
        );
        assert_eq!(events[1].event, ObsEvent::MigrationAborted { layer: 3 });
        for (i, r) in events.iter().enumerate() {
            assert_eq!(r.seq, i as u64, "per-subscriber seq is gapless");
        }
        router.finish().unwrap();
    }

    #[test]
    fn completed_migration_emits_the_full_fence_sequence() {
        let src = Box::new(MockBackend::default());
        let dst = Box::new(MockBackend::default());
        let mut router =
            ShardRouter::new(vec![vec![src], vec![dst]], RouterConfig::default()).unwrap();
        router.set_obs(Arc::new(Obs::new()));
        let sub = router.obs().bus.subscribe();
        let old_epoch = router.next_epoch();
        let span = crate::cim::mapping::RowSpan { slots: vec![(0, 0)], tail_width: 5, len: 5 };
        let old_shards = vec![vec![Some(ShardRef { chip: 0, filter: 0, span })]];
        let payloads = vec![Some(OwnedPayload::Binary(vec![true; 5]))];
        let epoch = match router.migrate_layer(1, old_epoch, 0, &old_shards, 1, &payloads).unwrap()
        {
            MigrationOutcome::Completed { epoch, .. } => epoch,
            MigrationOutcome::Aborted { .. } => panic!("ideal fleet must complete"),
        };
        let events = sub.drain();
        let kinds: Vec<&str> = events.iter().map(|r| r.event.kind()).collect();
        assert_eq!(kinds, vec!["migration_started", "migration_fenced", "migration_completed"]);
        assert_eq!(
            events[1].event,
            ObsEvent::MigrationFenced { layer: 1, epoch: old_epoch },
            "the fence names the epoch it retired"
        );
        assert_eq!(events[2].event, ObsEvent::MigrationCompleted { layer: 1, epoch });
        router.finish().unwrap();
    }

    #[test]
    fn pipeline_depth_bounds_submissions_and_inflight() {
        let served = Arc::new(AtomicU64::new(0));
        let cfg = RouterConfig {
            pipeline: PipelineConfig { depth: 2 },
            ..RouterConfig::default()
        };
        let mut router = ShardRouter::replicated(
            vec![MockBackend::boxed(Duration::from_millis(20), 0, Arc::clone(&served), 4)],
            cfg,
        )
        .unwrap();
        let route = route_one_layer(1);
        let a = router.submit_layer(&route, 0, empty_windows(), TraceContext::none()).unwrap();
        let b = router.submit_layer(&route, 0, empty_windows(), TraceContext::none()).unwrap();
        assert_eq!(router.pending_dispatches(), 2);
        let over = router.submit_layer(&route, 0, empty_windows(), TraceContext::none());
        assert!(
            matches!(over, Err(TransportError::Remote(_))),
            "depth 2 must reject a third uncollected submission"
        );
        assert_eq!(router.collect(a).unwrap(), vec![(0, vec![4])]);
        assert_eq!(router.collect(b).unwrap(), vec![(0, vec![4])]);
        let stats = router.stats();
        assert_eq!(stats.dispatches, 2);
        assert_eq!(
            stats.peak_inflight, 2,
            "both submissions overlapped, and the depth bound was never exceeded"
        );
        router.finish().unwrap();
        assert_eq!(served.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn out_of_order_collect_stashes_the_other_pendings_reply() {
        // collect b before a: a's reply (the member worker is serial,
        // so it arrives first) must be stashed for a's own collect
        let served = Arc::new(AtomicU64::new(0));
        let cfg = RouterConfig {
            pipeline: PipelineConfig { depth: 2 },
            ..RouterConfig::default()
        };
        let mut router = ShardRouter::replicated(
            vec![MockBackend::boxed(Duration::ZERO, 0, Arc::clone(&served), 6)],
            cfg,
        )
        .unwrap();
        let route = route_one_layer(1);
        let a = router.submit_layer(&route, 0, empty_windows(), TraceContext::none()).unwrap();
        let b = router.submit_layer(&route, 0, empty_windows(), TraceContext::none()).unwrap();
        assert_eq!(router.collect(b).unwrap(), vec![(0, vec![6])]);
        assert_eq!(router.collect(a).unwrap(), vec![(0, vec![6])]);
        let s = router.stats();
        assert_eq!(s.stale_discarded + s.epoch_discards, 0, "no reply was dropped");
        router.finish().unwrap();
        assert_eq!(served.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn fence_drain_retires_the_whole_pipeline_and_collect_fails_cleanly() {
        let served = Arc::new(AtomicU64::new(0));
        let cfg = RouterConfig {
            pipeline: PipelineConfig { depth: 4 },
            ..RouterConfig::default()
        };
        let mut router = ShardRouter::replicated(
            vec![MockBackend::boxed(Duration::ZERO, 0, Arc::clone(&served), 2)],
            cfg,
        )
        .unwrap();
        let mut route = route_one_layer(1);
        route.epoch = router.next_epoch();
        let a = router.submit_layer(&route, 0, empty_windows(), TraceContext::none()).unwrap();
        let b = router.submit_layer(&route, 0, empty_windows(), TraceContext::none()).unwrap();
        // cutover mid-pipeline: the fence must drain *every* pending
        // dispatch, not just the one being collected
        router.fence_and_drain(route.epoch).unwrap();
        assert_eq!(router.pending_dispatches(), 0, "the fence retired every pending dispatch");
        assert_eq!(router.stats().epoch_discards, 2, "both pipelined replies drained + counted");
        for p in [a, b] {
            let err = router.collect(p).unwrap_err();
            assert!(matches!(err, TransportError::Remote(_)), "post-fence collect errors cleanly");
        }
        // the router serves again at the new epoch
        route.epoch = router.next_epoch();
        assert_eq!(
            router.dispatch_layer(&route, 0, empty_windows(), TraceContext::none()).unwrap(),
            vec![(0, vec![2])]
        );
        router.finish().unwrap();
    }
}

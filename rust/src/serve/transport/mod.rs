//! The transport-agnostic execution seam of the serving stack: a
//! [`Backend`] is "somewhere that holds programmed shards and computes
//! integer dot maps", reachable through owned, `Send`, wire-serializable
//! request/reply types — so a remote worker is a *transport* change, not
//! a *protocol* change.
//!
//! This module replaces the seed-era `pub(crate) trait Dispatch` (a
//! callback-based, borrow-heavy, in-process-only contract): the batch
//! executor now builds a [`DispatchRequest`] per layer (request id,
//! shard epoch, shard list, packed activation windows) and folds the
//! [`DispatchReply`]'s integer dots, whoever computed them.
//!
//! # Pieces
//!
//! | type | role |
//! |---|---|
//! | [`Backend`] | the RPC-shaped seam (dispatch / program / release / wear / health / finish) |
//! | [`local::LocalBackend`] | worker-per-chip pool in this process |
//! | [`remote::RemoteBackend`] | length-prefixed frames over TCP ([`frame`]), reconnect with bounded backoff |
//! | [`host::Host`] | worker daemon serving its own pool across client sessions |
//! | [`router::ShardRouter`] | layer sharding, replica groups, hedging, spillover, epoch-fenced cross-group migration |
//!
//! # Numeric contract
//!
//! Chip dots are integer-exact and the payload programmed into every
//! replica is byte-identical, so any backend combination — local pool,
//! TCP-loopback host, a hedged replica group — returns bit-identical
//! [`DispatchReply::dots`] for the same request. That is what makes
//! hedging safe: the first reply to arrive *is* the answer, and a late
//! duplicate (matched by request id + shard epoch) can be discarded
//! without reconciliation. An analogue CIM fleet could not make this
//! guarantee — per-chip drift would make replica replies disagree.

pub mod frame;
pub mod host;
pub mod local;
pub mod remote;
pub mod router;

use std::sync::Arc;

use crate::chip::WearLedger;
use crate::cim::mapping::RowSpan;
use crate::cim::vmm::{PackedWindows, PackedWindowsI8};
use crate::serve::model::ShardPayload;
use crate::serve::obs::TraceContext;

pub use host::{Host, HostConfig};
pub use local::LocalBackend;
pub use remote::{ReconnectPolicy, RemoteBackend};
pub use router::{
    HedgeConfig, LayerRoute, MemberProbe, MemberState, MigrationOutcome, PendingDispatch,
    PipelineConfig, PlacedLayer, RouterConfig, RouterPlacement, RouterStats, ShardRouter,
    TenantRoute,
};

/// Transport-layer failure: the connection, the frame, or the far side.
#[derive(Debug)]
pub enum TransportError {
    /// Socket-level failure (connect, read, write).
    Io(std::io::Error),
    /// A frame that cannot be decoded (truncated, oversized, bad tag,
    /// trailing garbage) — the protocol equivalent of memory corruption,
    /// always surfaced, never guessed around.
    Frame(String),
    /// The far side executed the request and reported an error.
    Remote(String),
    /// The backend has already finished (or its worker is gone).
    Closed,
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Io(e) => write!(f, "transport i/o: {e}"),
            TransportError::Frame(m) => write!(f, "bad frame: {m}"),
            TransportError::Remote(m) => write!(f, "remote error: {m}"),
            TransportError::Closed => write!(f, "backend closed"),
        }
    }
}

impl std::error::Error for TransportError {}

impl From<std::io::Error> for TransportError {
    fn from(e: std::io::Error) -> Self {
        TransportError::Io(e)
    }
}

/// Transport-layer result.
pub type Result<T> = std::result::Result<T, TransportError>;

/// One batch's packed activation windows for one layer, shared by every
/// shard of that layer. `Arc`-wrapped so an in-process send costs one
/// refcount bump; the wire codec serializes through the `Arc`.
#[derive(Clone, Debug)]
pub enum WireWindows {
    /// Binary path: u8 activations as 8 bit planes ([`PackedWindows`]).
    Binary(Arc<PackedWindows>),
    /// INT8 path: offset-encoded i8 activations ([`PackedWindowsI8`]).
    Int8(Arc<PackedWindowsI8>),
}

impl WireWindows {
    /// Activation windows carried (0 for an empty batch).
    pub fn n_windows(&self) -> usize {
        match self {
            WireWindows::Binary(pw) => pw.n_windows,
            WireWindows::Int8(pw) => pw.n_windows,
        }
    }
}

impl PartialEq for WireWindows {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (WireWindows::Binary(a), WireWindows::Binary(b)) => {
                a.n_windows == b.n_windows
                    && a.seg_widths == b.seg_widths
                    && a.planes == b.planes
                    && a.sum_x == b.sum_x
            }
            (WireWindows::Int8(a), WireWindows::Int8(b)) => {
                a.n_windows == b.n_windows
                    && a.seg_widths == b.seg_widths
                    && a.planes == b.planes
                    && a.sum_ux == b.sum_ux
            }
            _ => false,
        }
    }
}

/// One shard's address inside a backend: which chip, which filter the
/// dots belong to, and the row span the payload was programmed into.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardRef {
    /// Chip index within the backend's own pool.
    pub chip: u32,
    /// Filter (output channel) index within the layer.
    pub filter: u32,
    /// Rows holding the shard's cells on that chip.
    pub span: RowSpan,
}

/// One layer's dots RPC: compute the integer dot vector of every named
/// shard against the shared packed windows. Owned and `Send`; the shard
/// list rides along with every request, so backends hold no routing
/// state and the coordinator can re-shard between batches.
#[derive(Clone, Debug)]
pub struct DispatchRequest {
    /// Unique per logical dispatch; a hedged duplicate reuses the id so
    /// the router can accept the first reply and discard the second.
    pub request_id: u64,
    /// The placement generation these shard addresses belong to; bumped
    /// by every migration. A reply carrying a stale epoch is discarded.
    pub shard_epoch: u64,
    /// Model layer index (for tracing; routing is by the shard list).
    pub layer: u32,
    /// The shards to compute, addressed within the receiving backend.
    pub shards: Arc<Vec<ShardRef>>,
    /// The batch's packed activation windows, shared by every shard.
    pub windows: WireWindows,
    /// Wire-carried trace identity (DESIGN.md §10): hedged duplicates
    /// share `trace_id` but carry distinct `span_id`s, so a multi-host
    /// trace stitches the race back together. The null context
    /// ([`TraceContext::none`]) marks an untraced request.
    pub trace: TraceContext,
}

impl PartialEq for DispatchRequest {
    fn eq(&self, other: &Self) -> bool {
        self.request_id == other.request_id
            && self.shard_epoch == other.shard_epoch
            && self.layer == other.layer
            && *self.shards == *other.shards
            && self.windows == other.windows
            && self.trace == other.trace
    }
}

/// The dots answer to one [`DispatchRequest`], echoing the request id
/// and shard epoch so duplicates (hedges, stale placements) are
/// discarded by identity, never by guesswork.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DispatchReply {
    pub request_id: u64,
    pub shard_epoch: u64,
    pub layer: u32,
    /// `(filter, dots per window)` for every requested shard, in
    /// whatever order the backend's chips finished.
    pub dots: Vec<(u32, Vec<i64>)>,
    /// Echo of the request's trace context, so the client stitches the
    /// serving side's span into its own trace by identity.
    pub trace: TraceContext,
    /// Wall-clock the serving side spent executing this request,
    /// nanoseconds — stamped at the host boundary for a remote backend,
    /// so the client's `round_trip − host_ns` is the pure
    /// transport/queueing share of the dispatch.
    pub host_ns: u64,
}

/// An owned shard payload as the wire carries it — byte-identical to
/// what initial placement stored, so a re-programmed replica computes
/// bit-identical dots.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum OwnedPayload {
    /// Binary sign bits, 1 RRAM cell per weight.
    Binary(Vec<bool>),
    /// INT8 weights, offset-encoded into 4 cells per weight.
    Int8(Vec<i8>),
}

impl OwnedPayload {
    /// RRAM cells this payload occupies when programmed.
    pub fn cells(&self) -> usize {
        match self {
            OwnedPayload::Binary(bits) => bits.len(),
            OwnedPayload::Int8(ws) => 4 * ws.len(),
        }
    }
}

impl From<ShardPayload<'_>> for OwnedPayload {
    fn from(p: ShardPayload<'_>) -> Self {
        match p {
            ShardPayload::Binary(bits) => OwnedPayload::Binary(bits.to_vec()),
            ShardPayload::Int8(ws) => OwnedPayload::Int8(ws.to_vec()),
        }
    }
}

/// Program one shard's payload into a fresh row span on the named chip
/// of the receiving backend (placement and migration both speak this).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ProgramRequest {
    /// Chip index within the backend's pool.
    pub chip: u32,
    pub payload: OwnedPayload,
}

/// The outcome of a [`ProgramRequest`]. `span: None` means the chip had
/// too few free rows; `failures > 0` means stuck cells defeated the ECC
/// and the span was retired (the rows stay consumed, mirroring the
/// placement policy) — the caller must not route dots at it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ProgramReply {
    pub span: Option<RowSpan>,
    pub failures: u64,
}

/// Per-chip lifetime wear + free rows of one backend — the rebalancer's
/// input, fetched at batch boundaries.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WearReply {
    pub wear: Vec<WearLedger>,
    pub rows_free: Vec<u64>,
}

/// Return a span's rows to the backend's allocator — the **free** step
/// of the cross-group migration protocol (DESIGN.md §9), issued only
/// after the epoch fence has drained every request that could still
/// address those rows.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ReleaseRequest {
    /// Chip index within the backend's pool.
    pub chip: u32,
    /// The span to free (must have been handed out by a prior
    /// [`ProgramRequest`] on the same chip, and released at most once).
    pub span: RowSpan,
}

/// The outcome of a [`ReleaseRequest`]: the chip's authoritative free
/// row count after the release, so client-side mirrors resync exactly.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ReleaseReply {
    pub rows_free: u64,
}

/// Static facts about a backend, fetched at connection time and
/// re-checked by health probes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BackendInfo {
    /// Chips in the backend's pool.
    pub chips: u32,
    /// Data columns per array row (must match across a fleet — the
    /// window packing geometry depends on it).
    pub data_cols: u32,
    /// Identity of this *pool fabrication*. A restarted host fabricates
    /// a fresh pool and therefore reports a new incarnation — the
    /// signal that every shard it held is gone and the member must be
    /// re-programmed before it may serve dispatches again.
    pub incarnation: u64,
}

/// A liveness/identity probe answer (see [`Backend::health`]): the
/// backend's current facts plus the client-side reconnect history a
/// [`remote::RemoteBackend`] accumulates.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HealthReply {
    pub info: BackendInfo,
    /// Connections re-established so far (bounded-backoff retries that
    /// succeeded), cumulative over the backend's lifetime.
    pub reconnects: u64,
    /// The backend reconnected to a *different pool incarnation* and is
    /// quarantined: dispatches fail fast until the owner re-programs
    /// its shards and calls [`Backend::rejoin`].
    pub bounced: bool,
}

/// The backend's terminal report: serving energy spent and final wear.
/// After `finish` a backend accepts no further requests.
#[derive(Clone, Debug, PartialEq)]
pub struct FinishReply {
    pub energy_pj: f64,
    pub wear: Vec<WearLedger>,
}

/// The serving stack's execution seam: anything that holds programmed
/// shards and can compute integer dot maps against packed activation
/// windows. All methods are synchronous request/reply — concurrency
/// (fan-out across backends, hedging) is the [`router::ShardRouter`]'s
/// job, which drives each backend from its own thread.
///
/// Implementations ship in-tree for both sides of the wire:
/// [`local::LocalBackend`] (worker-per-chip pool in this process, also
/// the execution engine inside a [`host::Host`] daemon) and
/// [`remote::RemoteBackend`] (frames over TCP). The bit-exactness
/// property harness passes identically over either — see
/// `tests/transport_remote.rs`.
pub trait Backend: Send {
    /// Pool shape facts (chip count, data-column geometry, pool
    /// incarnation).
    ///
    /// # Errors
    ///
    /// [`TransportError::Closed`] after [`Backend::finish`];
    /// [`TransportError::Io`]/[`TransportError::Frame`] when the
    /// transport to a remote pool fails.
    fn describe(&mut self) -> Result<BackendInfo>;

    /// Compute the integer dots of every shard named in `req` against
    /// its packed windows. The reply echoes `request_id`/`shard_epoch`.
    ///
    /// # Errors
    ///
    /// [`TransportError::Remote`] for a request the backend rejects
    /// (forged shard addresses, inconsistent window shapes, or a
    /// bounced remote pool awaiting re-programming);
    /// [`TransportError::Io`] when the connection dies and bounded
    /// reconnect/retry cannot restore it; [`TransportError::Closed`]
    /// after [`Backend::finish`].
    fn dispatch(&mut self, req: DispatchRequest) -> Result<DispatchReply>;

    /// Program a shard payload into a fresh span on one of this
    /// backend's chips (see [`ProgramReply`] for the partial-failure
    /// contract). Not idempotent: a transport failure mid-call is
    /// surfaced, never blindly retried — the rows may or may not have
    /// been consumed, and only a wear probe resyncs the truth.
    ///
    /// # Errors
    ///
    /// [`TransportError::Remote`] for an invalid chip index;
    /// [`TransportError::Io`] on connection loss (the call is *not*
    /// replayed); [`TransportError::Closed`] after [`Backend::finish`].
    fn program(&mut self, req: ProgramRequest) -> Result<ProgramReply>;

    /// Return a previously programmed span's rows to the chip's
    /// allocator — the **free** step of cross-group migration. The
    /// caller must have drained every in-flight request that could
    /// still address the span (DESIGN.md §9).
    ///
    /// The default implementation refuses: a backend that does not
    /// opt in keeps its append-only row discipline, and callers treat
    /// the refusal as "rows retired instead of freed".
    ///
    /// # Errors
    ///
    /// [`TransportError::Remote`] when unsupported or the request names
    /// an invalid chip/span; [`TransportError::Io`] on connection loss;
    /// [`TransportError::Closed`] after [`Backend::finish`].
    fn release(&mut self, req: ReleaseRequest) -> Result<ReleaseReply> {
        let _ = req;
        Err(TransportError::Remote("backend does not support releasing rows".into()))
    }

    /// Lifetime wear + free rows per chip.
    ///
    /// # Errors
    ///
    /// [`TransportError::Io`]/[`TransportError::Frame`] on transport
    /// failure; [`TransportError::Closed`] after [`Backend::finish`].
    fn wear(&mut self) -> Result<WearReply>;

    /// Liveness/identity probe: current [`BackendInfo`] plus reconnect
    /// history. The default derives it from [`Backend::describe`] with
    /// no reconnect state (an in-process backend cannot bounce).
    ///
    /// # Errors
    ///
    /// Same failure modes as [`Backend::describe`]; for a remote
    /// backend an `Err` means the host is unreachable even after
    /// bounded reconnect attempts.
    fn health(&mut self) -> Result<HealthReply> {
        Ok(HealthReply { info: self.describe()?, reconnects: 0, bounced: false })
    }

    /// Lift the bounce quarantine after the owner has re-programmed
    /// this backend's shards to the current epoch — the final step of
    /// the reconnect lifecycle (DESIGN.md §9). A no-op for backends
    /// that never bounce.
    ///
    /// # Errors
    ///
    /// [`TransportError::Closed`] after [`Backend::finish`] (the
    /// default implementation never fails).
    fn rejoin(&mut self) -> Result<()> {
        Ok(())
    }

    /// Zero the energy/timing ledgers (wear persists) — called once
    /// after placement so serving measurements exclude programming.
    ///
    /// # Errors
    ///
    /// [`TransportError::Io`]/[`TransportError::Frame`] on transport
    /// failure; [`TransportError::Closed`] after [`Backend::finish`].
    fn reset_energy(&mut self) -> Result<()>;

    /// Stop the backend's workers and collect the terminal report.
    /// Every call after this returns [`TransportError::Closed`].
    ///
    /// Availability over telemetry purity at shutdown: a remote backend
    /// replays `finish` across a reconnect even onto a bounced pool, so
    /// the fleet always terminates cleanly — but the terminal report
    /// then describes the *replacement* pool (near-zero energy/wear),
    /// not the crashed one's lifetime.
    ///
    /// # Errors
    ///
    /// [`TransportError::Io`]/[`TransportError::Frame`] when the
    /// terminal handshake with a remote host fails.
    fn finish(&mut self) -> Result<FinishReply>;
}

//! The wire codec: length-prefixed frames carrying a hand-rolled binary
//! encoding of every [`Backend`](super::Backend) request and reply.
//!
//! # Frame layout
//!
//! ```text
//! [len: u32 LE] [payload: len bytes]
//! payload = [tag: u8] [tag-specific body]
//! ```
//!
//! Body primitives are little-endian (`u32`/`u64`/`i64`/`f64`); vectors
//! are a `u64` length followed by items; booleans are one byte each.
//! Decoding is strict: a truncated body, an unknown tag, an absurd
//! length, or trailing bytes all return [`TransportError::Frame`] —
//! never a panic, never a silently misparsed value (property-tested
//! below: every request/reply survives encode→decode bit-exactly, and
//! every strict prefix of an encoding is rejected).

use std::io::{Read, Write};
use std::sync::Arc;

use crate::chip::WearLedger;
use crate::cim::mapping::RowSpan;
use crate::cim::vmm::{PackedWindows, PackedWindowsI8};
use crate::serve::obs::TraceContext;

use super::{
    BackendInfo, DispatchReply, DispatchRequest, FinishReply, OwnedPayload, ProgramReply,
    ProgramRequest, ReleaseReply, ReleaseRequest, Result, ShardRef, TransportError, WearReply,
    WireWindows,
};

/// Hard bound on one frame's payload (256 MiB): a corrupt length prefix
/// fails fast instead of attempting a absurd allocation.
pub const MAX_FRAME: usize = 256 << 20;

/// Every request a backend understands, as the wire sees it.
#[derive(Clone, Debug, PartialEq)]
pub enum WireRequest {
    Dispatch(DispatchRequest),
    Program(ProgramRequest),
    Release(ReleaseRequest),
    Wear,
    Describe,
    ResetEnergy,
    Finish,
}

/// Every reply a backend produces. `Err` relays a host-side failure to
/// the client, which surfaces it as [`TransportError::Remote`].
#[derive(Clone, Debug, PartialEq)]
pub enum WireReply {
    Dispatch(DispatchReply),
    Program(ProgramReply),
    Release(ReleaseReply),
    Wear(WearReply),
    Describe(BackendInfo),
    ResetEnergy,
    Finish(FinishReply),
    Err(String),
}

// ---------------------------------------------------------------------------
// framing
// ---------------------------------------------------------------------------

/// Write one `[u32 LE length][payload]` frame.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME {
        return Err(TransportError::Frame(format!(
            "frame of {} bytes exceeds MAX_FRAME ({MAX_FRAME})",
            payload.len()
        )));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame's payload. A clean EOF before any length byte is
/// [`TransportError::Closed`] (the peer hung up between frames); EOF
/// mid-frame is a truncation error.
pub fn read_frame(r: &mut impl Read) -> Result<Vec<u8>> {
    let mut len = [0u8; 4];
    match r.read_exact(&mut len) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
            return Err(TransportError::Closed)
        }
        Err(e) => return Err(TransportError::Io(e)),
    }
    let len = u32::from_le_bytes(len) as usize;
    if len > MAX_FRAME {
        return Err(TransportError::Frame(format!(
            "frame length {len} exceeds MAX_FRAME ({MAX_FRAME})"
        )));
    }
    let mut payload = vec![0u8; len];
    match r.read_exact(&mut payload) {
        Ok(()) => Ok(payload),
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
            Err(TransportError::Frame("truncated frame body".into()))
        }
        Err(e) => Err(TransportError::Io(e)),
    }
}

// ---------------------------------------------------------------------------
// encoding
// ---------------------------------------------------------------------------

const REQ_DISPATCH: u8 = 1;
const REQ_PROGRAM: u8 = 2;
const REQ_WEAR: u8 = 3;
const REQ_DESCRIBE: u8 = 4;
const REQ_RESET_ENERGY: u8 = 5;
const REQ_FINISH: u8 = 6;
const REQ_RELEASE: u8 = 7;

const REP_DISPATCH: u8 = 129;
const REP_PROGRAM: u8 = 130;
const REP_WEAR: u8 = 131;
const REP_DESCRIBE: u8 = 132;
const REP_RESET_ENERGY: u8 = 133;
const REP_FINISH: u8 = 134;
const REP_RELEASE: u8 = 135;
const REP_ERR: u8 = 255;

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_i64(buf: &mut Vec<u8>, v: i64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_usize(buf: &mut Vec<u8>, v: usize) {
    put_u64(buf, v as u64);
}

fn put_u64s(buf: &mut Vec<u8>, vs: &[u64]) {
    put_usize(buf, vs.len());
    for &v in vs {
        put_u64(buf, v);
    }
}

fn put_i64s(buf: &mut Vec<u8>, vs: &[i64]) {
    put_usize(buf, vs.len());
    for &v in vs {
        put_i64(buf, v);
    }
}

fn put_usizes(buf: &mut Vec<u8>, vs: &[usize]) {
    put_usize(buf, vs.len());
    for &v in vs {
        put_usize(buf, v);
    }
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_usize(buf, s.len());
    buf.extend_from_slice(s.as_bytes());
}

fn put_span(buf: &mut Vec<u8>, span: &RowSpan) {
    put_usize(buf, span.slots.len());
    for &(b, r) in &span.slots {
        put_usize(buf, b);
        put_usize(buf, r);
    }
    put_usize(buf, span.tail_width);
    put_usize(buf, span.len);
}

fn put_wear(buf: &mut Vec<u8>, w: &WearLedger) {
    put_u64(buf, w.write_pulses);
    put_u64(buf, w.programmed_cells);
    put_u64(buf, w.wl_activations);
}

fn put_windows(buf: &mut Vec<u8>, w: &WireWindows) {
    match w {
        WireWindows::Binary(pw) => {
            buf.push(0);
            put_usize(buf, pw.n_windows);
            put_usizes(buf, &pw.seg_widths);
            put_u64s(buf, &pw.planes);
            put_i64s(buf, &pw.sum_x);
        }
        WireWindows::Int8(pw) => {
            buf.push(1);
            put_usize(buf, pw.n_windows);
            put_usizes(buf, &pw.seg_widths);
            put_u64s(buf, &pw.planes);
            put_i64s(buf, &pw.sum_ux);
        }
    }
}

fn put_payload(buf: &mut Vec<u8>, p: &OwnedPayload) {
    match p {
        OwnedPayload::Binary(bits) => {
            buf.push(0);
            put_usize(buf, bits.len());
            buf.extend(bits.iter().map(|&b| b as u8));
        }
        OwnedPayload::Int8(ws) => {
            buf.push(1);
            put_usize(buf, ws.len());
            buf.extend(ws.iter().map(|&w| w as u8));
        }
    }
}

fn put_trace(buf: &mut Vec<u8>, t: &TraceContext) {
    put_u64(buf, t.trace_id);
    put_u64(buf, t.parent_span);
    put_u64(buf, t.span_id);
}

fn put_dispatch_request(buf: &mut Vec<u8>, req: &DispatchRequest) {
    put_u64(buf, req.request_id);
    put_u64(buf, req.shard_epoch);
    put_u32(buf, req.layer);
    put_trace(buf, &req.trace);
    put_usize(buf, req.shards.len());
    for s in req.shards.iter() {
        put_u32(buf, s.chip);
        put_u32(buf, s.filter);
        put_span(buf, &s.span);
    }
    put_windows(buf, &req.windows);
}

fn put_dispatch_reply(buf: &mut Vec<u8>, rep: &DispatchReply) {
    put_u64(buf, rep.request_id);
    put_u64(buf, rep.shard_epoch);
    put_u32(buf, rep.layer);
    put_trace(buf, &rep.trace);
    put_u64(buf, rep.host_ns);
    put_usize(buf, rep.dots.len());
    for (f, dots) in &rep.dots {
        put_u32(buf, *f);
        put_i64s(buf, dots);
    }
}

/// Encode one request payload (framing is [`write_frame`]'s job).
pub fn encode_request(req: &WireRequest) -> Vec<u8> {
    let mut buf = Vec::new();
    match req {
        WireRequest::Dispatch(r) => {
            buf.push(REQ_DISPATCH);
            put_dispatch_request(&mut buf, r);
        }
        WireRequest::Program(r) => {
            buf.push(REQ_PROGRAM);
            put_u32(&mut buf, r.chip);
            put_payload(&mut buf, &r.payload);
        }
        WireRequest::Release(r) => {
            buf.push(REQ_RELEASE);
            put_u32(&mut buf, r.chip);
            put_span(&mut buf, &r.span);
        }
        WireRequest::Wear => buf.push(REQ_WEAR),
        WireRequest::Describe => buf.push(REQ_DESCRIBE),
        WireRequest::ResetEnergy => buf.push(REQ_RESET_ENERGY),
        WireRequest::Finish => buf.push(REQ_FINISH),
    }
    buf
}

/// Encode one reply payload.
pub fn encode_reply(rep: &WireReply) -> Vec<u8> {
    let mut buf = Vec::new();
    match rep {
        WireReply::Dispatch(r) => {
            buf.push(REP_DISPATCH);
            put_dispatch_reply(&mut buf, r);
        }
        WireReply::Program(r) => {
            buf.push(REP_PROGRAM);
            match &r.span {
                None => buf.push(0),
                Some(span) => {
                    buf.push(1);
                    put_span(&mut buf, span);
                }
            }
            put_u64(&mut buf, r.failures);
        }
        WireReply::Wear(r) => {
            buf.push(REP_WEAR);
            put_usize(&mut buf, r.wear.len());
            for w in &r.wear {
                put_wear(&mut buf, w);
            }
            put_u64s(&mut buf, &r.rows_free);
        }
        WireReply::Release(r) => {
            buf.push(REP_RELEASE);
            put_u64(&mut buf, r.rows_free);
        }
        WireReply::Describe(info) => {
            buf.push(REP_DESCRIBE);
            put_u32(&mut buf, info.chips);
            put_u32(&mut buf, info.data_cols);
            put_u64(&mut buf, info.incarnation);
        }
        WireReply::ResetEnergy => buf.push(REP_RESET_ENERGY),
        WireReply::Finish(r) => {
            buf.push(REP_FINISH);
            put_f64(&mut buf, r.energy_pj);
            put_usize(&mut buf, r.wear.len());
            for w in &r.wear {
                put_wear(&mut buf, w);
            }
        }
        WireReply::Err(msg) => {
            buf.push(REP_ERR);
            put_str(&mut buf, msg);
        }
    }
    buf
}

// ---------------------------------------------------------------------------
// decoding
// ---------------------------------------------------------------------------

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    // lint: allow(panic-freedom) — slice read is guarded by the explicit length check above
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            return Err(TransportError::Frame(format!(
                "truncated: wanted {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            )));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    // lint: allow(panic-freedom) — take(1) guarantees one byte
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    // lint: allow(panic-freedom) — take() guarantees the exact byte width, so the fixed-size conversion is infallible
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    // lint: allow(panic-freedom) — take() guarantees the exact byte width, so the fixed-size conversion is infallible
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    // lint: allow(panic-freedom) — take() guarantees the exact byte width, so the fixed-size conversion is infallible
    fn i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    // lint: allow(panic-freedom) — take() guarantees the exact byte width, so the fixed-size conversion is infallible
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn usize(&mut self) -> Result<usize> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| TransportError::Frame(format!("length {v} overflows")))
    }

    /// A vector length, sanity-bounded by what the remaining bytes could
    /// possibly hold (`min_item_bytes` per item) so a corrupt length
    /// fails here instead of in an absurd allocation.
    fn len(&mut self, min_item_bytes: usize) -> Result<usize> {
        let n = self.usize()?;
        let room = self.buf.len() - self.pos;
        if n > room / min_item_bytes.max(1) + 1 {
            return Err(TransportError::Frame(format!(
                "length {n} impossible with {room} bytes left"
            )));
        }
        Ok(n)
    }

    fn u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.len(8)?;
        (0..n).map(|_| self.u64()).collect()
    }

    fn i64s(&mut self) -> Result<Vec<i64>> {
        let n = self.len(8)?;
        (0..n).map(|_| self.i64()).collect()
    }

    fn usizes(&mut self) -> Result<Vec<usize>> {
        let n = self.len(8)?;
        (0..n).map(|_| self.usize()).collect()
    }

    fn str(&mut self) -> Result<String> {
        let n = self.len(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| TransportError::Frame("non-utf8 string".into()))
    }

    fn span(&mut self) -> Result<RowSpan> {
        let n = self.len(16)?;
        let mut slots = Vec::with_capacity(n);
        for _ in 0..n {
            let b = self.usize()?;
            let r = self.usize()?;
            slots.push((b, r));
        }
        let tail_width = self.usize()?;
        let len = self.usize()?;
        Ok(RowSpan { slots, tail_width, len })
    }

    fn wear(&mut self) -> Result<WearLedger> {
        Ok(WearLedger {
            write_pulses: self.u64()?,
            programmed_cells: self.u64()?,
            wl_activations: self.u64()?,
        })
    }

    fn windows(&mut self) -> Result<WireWindows> {
        let tag = self.u8()?;
        let n_windows = self.usize()?;
        let seg_widths = self.usizes()?;
        let planes = self.u64s()?;
        match tag {
            0 => {
                let sum_x = self.i64s()?;
                Ok(WireWindows::Binary(Arc::new(PackedWindows {
                    n_windows,
                    seg_widths,
                    planes,
                    sum_x,
                })))
            }
            1 => {
                let sum_ux = self.i64s()?;
                Ok(WireWindows::Int8(Arc::new(PackedWindowsI8 {
                    n_windows,
                    seg_widths,
                    planes,
                    sum_ux,
                })))
            }
            t => Err(TransportError::Frame(format!("unknown windows tag {t}"))),
        }
    }

    fn payload(&mut self) -> Result<OwnedPayload> {
        let tag = self.u8()?;
        let n = self.len(1)?;
        let bytes = self.take(n)?;
        match tag {
            0 => Ok(OwnedPayload::Binary(bytes.iter().map(|&b| b != 0).collect())),
            1 => Ok(OwnedPayload::Int8(bytes.iter().map(|&b| b as i8).collect())),
            t => Err(TransportError::Frame(format!("unknown payload tag {t}"))),
        }
    }

    fn trace(&mut self) -> Result<TraceContext> {
        Ok(TraceContext {
            trace_id: self.u64()?,
            parent_span: self.u64()?,
            span_id: self.u64()?,
        })
    }

    fn dispatch_request(&mut self) -> Result<DispatchRequest> {
        let request_id = self.u64()?;
        let shard_epoch = self.u64()?;
        let layer = self.u32()?;
        let trace = self.trace()?;
        let n = self.len(8)?;
        let mut shards = Vec::with_capacity(n);
        for _ in 0..n {
            let chip = self.u32()?;
            let filter = self.u32()?;
            let span = self.span()?;
            shards.push(ShardRef { chip, filter, span });
        }
        let windows = self.windows()?;
        Ok(DispatchRequest {
            request_id,
            shard_epoch,
            layer,
            shards: Arc::new(shards),
            windows,
            trace,
        })
    }

    fn dispatch_reply(&mut self) -> Result<DispatchReply> {
        let request_id = self.u64()?;
        let shard_epoch = self.u64()?;
        let layer = self.u32()?;
        let trace = self.trace()?;
        let host_ns = self.u64()?;
        let n = self.len(8)?;
        let mut dots = Vec::with_capacity(n);
        for _ in 0..n {
            let f = self.u32()?;
            let d = self.i64s()?;
            dots.push((f, d));
        }
        Ok(DispatchReply { request_id, shard_epoch, layer, dots, trace, host_ns })
    }

    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(TransportError::Frame(format!(
                "{} trailing bytes after a complete message",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

/// Decode one request payload (strict: trailing bytes are an error).
pub fn decode_request(buf: &[u8]) -> Result<WireRequest> {
    let mut r = Reader::new(buf);
    let req = match r.u8()? {
        REQ_DISPATCH => WireRequest::Dispatch(r.dispatch_request()?),
        REQ_PROGRAM => {
            let chip = r.u32()?;
            let payload = r.payload()?;
            WireRequest::Program(ProgramRequest { chip, payload })
        }
        REQ_RELEASE => {
            let chip = r.u32()?;
            let span = r.span()?;
            WireRequest::Release(ReleaseRequest { chip, span })
        }
        REQ_WEAR => WireRequest::Wear,
        REQ_DESCRIBE => WireRequest::Describe,
        REQ_RESET_ENERGY => WireRequest::ResetEnergy,
        REQ_FINISH => WireRequest::Finish,
        t => return Err(TransportError::Frame(format!("unknown request tag {t}"))),
    };
    r.done()?;
    Ok(req)
}

/// Decode one reply payload (strict: trailing bytes are an error).
pub fn decode_reply(buf: &[u8]) -> Result<WireReply> {
    let mut r = Reader::new(buf);
    let rep = match r.u8()? {
        REP_DISPATCH => WireReply::Dispatch(r.dispatch_reply()?),
        REP_PROGRAM => {
            let span = match r.u8()? {
                0 => None,
                1 => Some(r.span()?),
                t => return Err(TransportError::Frame(format!("unknown span flag {t}"))),
            };
            let failures = r.u64()?;
            WireReply::Program(ProgramReply { span, failures })
        }
        REP_WEAR => {
            let n = r.len(24)?;
            let wear = (0..n).map(|_| r.wear()).collect::<Result<Vec<_>>>()?;
            let rows_free = r.u64s()?;
            WireReply::Wear(WearReply { wear, rows_free })
        }
        REP_RELEASE => WireReply::Release(ReleaseReply { rows_free: r.u64()? }),
        REP_DESCRIBE => {
            let chips = r.u32()?;
            let data_cols = r.u32()?;
            let incarnation = r.u64()?;
            WireReply::Describe(BackendInfo { chips, data_cols, incarnation })
        }
        REP_RESET_ENERGY => WireReply::ResetEnergy,
        REP_FINISH => {
            let energy_pj = r.f64()?;
            let n = r.len(24)?;
            let wear = (0..n).map(|_| r.wear()).collect::<Result<Vec<_>>>()?;
            WireReply::Finish(FinishReply { energy_pj, wear })
        }
        REP_ERR => WireReply::Err(r.str()?),
        t => return Err(TransportError::Frame(format!("unknown reply tag {t}"))),
    };
    r.done()?;
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::forall;
    use crate::util::rng::Rng;

    fn rand_span(rng: &mut Rng) -> RowSpan {
        let rows = 1 + rng.below(4);
        let per_row = 1 + rng.below(30);
        let tail = 1 + rng.below(per_row);
        RowSpan {
            slots: (0..rows).map(|_| (rng.below(4), rng.below(512))).collect(),
            tail_width: tail,
            len: (rows - 1) * per_row + tail,
        }
    }

    fn rand_windows(rng: &mut Rng) -> WireWindows {
        // empty windows (n_windows == 0) are a required round-trip case
        let n_windows = rng.below(4);
        let n_seg = 1 + rng.below(3);
        let seg_widths: Vec<usize> = (0..n_seg).map(|_| 1 + rng.below(30)).collect();
        let planes: Vec<u64> = (0..n_windows * 8 * n_seg).map(|_| rng.next_u64()).collect();
        if rng.chance(0.5) {
            WireWindows::Binary(Arc::new(PackedWindows {
                n_windows,
                seg_widths,
                planes,
                sum_x: (0..n_windows).map(|_| rng.below(1 << 20) as i64).collect(),
            }))
        } else {
            WireWindows::Int8(Arc::new(PackedWindowsI8 {
                n_windows,
                seg_widths,
                planes,
                sum_ux: (0..n_windows).map(|_| rng.below(1 << 20) as i64).collect(),
            }))
        }
    }

    fn rand_trace(rng: &mut Rng) -> TraceContext {
        if rng.chance(0.3) {
            TraceContext::none()
        } else {
            TraceContext {
                trace_id: rng.next_u64(),
                parent_span: rng.next_u64(),
                span_id: rng.next_u64(),
            }
        }
    }

    fn rand_dispatch_request(rng: &mut Rng) -> DispatchRequest {
        let n_shards = rng.below(5);
        DispatchRequest {
            request_id: rng.next_u64(),
            shard_epoch: rng.next_u64(),
            layer: rng.below(8) as u32,
            trace: rand_trace(rng),
            shards: Arc::new(
                (0..n_shards)
                    .map(|f| ShardRef {
                        chip: rng.below(8) as u32,
                        filter: f as u32,
                        span: rand_span(rng),
                    })
                    .collect(),
            ),
            windows: rand_windows(rng),
        }
    }

    fn rand_dispatch_reply(rng: &mut Rng) -> DispatchReply {
        let n = rng.below(5);
        DispatchReply {
            request_id: rng.next_u64(),
            shard_epoch: rng.next_u64(),
            layer: rng.below(8) as u32,
            trace: rand_trace(rng),
            host_ns: rng.next_u64(),
            dots: (0..n)
                .map(|f| {
                    let extremes = rng.chance(0.3);
                    let dots = (0..rng.below(6))
                        .map(|_| {
                            if extremes {
                                if rng.chance(0.5) {
                                    i64::MAX
                                } else {
                                    i64::MIN
                                }
                            } else {
                                rng.next_u64() as i64
                            }
                        })
                        .collect();
                    (f as u32, dots)
                })
                .collect(),
        }
    }

    #[test]
    fn prop_dispatch_round_trips_bit_exactly() {
        forall(
            "frame codec: DispatchRequest/DispatchReply encode→decode identity",
            0xf4a3e,
            40,
            |rng| (rand_dispatch_request(rng), rand_dispatch_reply(rng)),
            |(req, rep)| {
                let got = decode_request(&encode_request(&WireRequest::Dispatch(req.clone())))
                    .map_err(|e| e.to_string())?;
                if got != WireRequest::Dispatch(req.clone()) {
                    return Err(format!("request mangled: {got:?}"));
                }
                let got = decode_reply(&encode_reply(&WireReply::Dispatch(rep.clone())))
                    .map_err(|e| e.to_string())?;
                if got != WireReply::Dispatch(rep.clone()) {
                    return Err(format!("reply mangled: {got:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_every_strict_prefix_of_a_dispatch_frame_is_rejected() {
        forall(
            "frame codec: truncated frames error, never panic or misparse",
            0x7c47e,
            12,
            rand_dispatch_request,
            |req| {
                let buf = encode_request(&WireRequest::Dispatch(req.clone()));
                for cut in 0..buf.len() {
                    match decode_request(&buf[..cut]) {
                        Err(TransportError::Frame(_)) => {}
                        Err(e) => return Err(format!("cut {cut}: wrong error kind {e}")),
                        Ok(_) => return Err(format!("cut {cut}: truncation decoded cleanly")),
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn max_width_int8_payload_round_trips() {
        // ±127 extremes — the INT8 path's full dynamic range
        let payload = OwnedPayload::Int8(vec![127, -127, 0, -1, 1, 127, -127]);
        let req = WireRequest::Program(ProgramRequest { chip: 3, payload });
        assert_eq!(decode_request(&encode_request(&req)).unwrap(), req);
        let bits = OwnedPayload::Binary(vec![true, false, true, true]);
        let req = WireRequest::Program(ProgramRequest { chip: 0, payload: bits });
        assert_eq!(decode_request(&encode_request(&req)).unwrap(), req);
    }

    #[test]
    fn control_messages_round_trip() {
        for req in [
            WireRequest::Wear,
            WireRequest::Describe,
            WireRequest::ResetEnergy,
            WireRequest::Finish,
            WireRequest::Release(ReleaseRequest {
                chip: 2,
                span: RowSpan { slots: vec![(1, 7), (0, 3)], tail_width: 5, len: 35 },
            }),
        ] {
            assert_eq!(decode_request(&encode_request(&req)).unwrap(), req);
        }
        let wear = WearLedger { write_pulses: 7, programmed_cells: 9, wl_activations: 11 };
        for rep in [
            WireReply::Program(ProgramReply { span: None, failures: 2 }),
            WireReply::Program(ProgramReply {
                span: Some(RowSpan { slots: vec![(0, 1), (1, 2)], tail_width: 3, len: 33 }),
                failures: 0,
            }),
            WireReply::Release(ReleaseReply { rows_free: 17 }),
            WireReply::Wear(WearReply { wear: vec![wear.clone()], rows_free: vec![12] }),
            WireReply::Describe(BackendInfo { chips: 4, data_cols: 30, incarnation: 0xf1ee7 }),
            WireReply::ResetEnergy,
            WireReply::Finish(FinishReply { energy_pj: 123.5, wear: vec![wear] }),
            WireReply::Err("stuck tile".into()),
        ] {
            assert_eq!(decode_reply(&encode_reply(&rep)).unwrap(), rep);
        }
    }

    #[test]
    fn trailing_bytes_and_bad_tags_are_rejected() {
        let mut buf = encode_request(&WireRequest::Wear);
        buf.push(0);
        assert!(matches!(decode_request(&buf), Err(TransportError::Frame(_))));
        assert!(matches!(decode_request(&[0x7f]), Err(TransportError::Frame(_))));
        assert!(matches!(decode_reply(&[0x01]), Err(TransportError::Frame(_))));
        assert!(matches!(decode_request(&[]), Err(TransportError::Frame(_))));
    }

    #[test]
    fn framing_round_trips_and_detects_truncation() {
        let payload = encode_request(&WireRequest::Describe);
        let mut wire = Vec::new();
        write_frame(&mut wire, &payload).unwrap();
        let mut r = &wire[..];
        assert_eq!(read_frame(&mut r).unwrap(), payload);
        // a second read on the drained stream is a clean close
        assert!(matches!(read_frame(&mut r), Err(TransportError::Closed)));
        // truncated body
        let mut cut = &wire[..wire.len() - 1];
        assert!(matches!(read_frame(&mut cut), Err(TransportError::Frame(_))));
        // absurd length prefix fails fast
        let mut bogus = &[0xff, 0xff, 0xff, 0xff][..];
        assert!(matches!(read_frame(&mut bogus), Err(TransportError::Frame(_))));
    }
}

//! Wear-aware shard placement: map every live (unpruned) filter of a
//! [`ModelBundle`] — binary sign bits (MNIST path, 1 cell per weight) or
//! offset-encoded INT8 slices (PointNet path, 4 cells per weight) — onto
//! RRAM rows of exactly one pool chip.
//!
//! Policy, per filter in layer/filter order:
//! 1. rank candidate chips by lifetime [`crate::chip::WearLedger`]
//!    `write_pulses` ascending (least-worn first), ties broken toward
//!    more free rows — on a fresh pool this degenerates to row-balanced
//!    round-robin, on a warm pool it steers programming away from tired
//!    chips;
//! 2. allocate a [`RowSpan`] on the best candidate and program the
//!    payload through the ECC plan;
//! 3. if the store hits cells the ECC spare/backup budget cannot absorb
//!    (a *stuck tile*), retire that span and retry on the next candidate.
//!
//! Pruning is what makes dense models feasible at all on small pools: a
//! dense 32-64-32 MNIST model needs more rows than one 2x512x32 chip
//! offers, and the INT8 PointNet stack is 4x hungrier per weight — the
//! serving-throughput win measured by `benches/serve_throughput.rs`.
//!
//! This module places onto a pool it can touch directly (the legacy
//! [`crate::serve::Server`] path and the placement tests). The
//! multi-host engine places through the transport seam instead —
//! [`crate::serve::transport::ShardRouter::place`] speaks
//! `ProgramRequest`s to backends it cannot reach into — but applies
//! the same policy: least-worn chip first, ties toward free rows,
//! stuck-tile spans retired and retried on the next candidate.
//!
//! Rows retired by stuck tiles are never reused, and rows vacated by an
//! intra-backend wear move stay retired too. The one sanctioned way
//! rows come back is the **free** step of an epoch-fenced cross-group
//! migration ([`crate::serve::transport::ShardRouter::migrate_layer`]),
//! which releases them only after the fence has drained every request
//! that could still address them — see DESIGN.md §9.

use anyhow::{anyhow, Result};

use crate::cim::mapping::{store_bits, store_int8, RowAllocator, RowSpan};

use super::model::{ModelBundle, ShardPayload};
use super::pool::ChipPool;

/// Where one live filter's cells physically live.
#[derive(Clone, Debug)]
pub struct ShardLoc {
    pub chip: usize,
    pub span: RowSpan,
}

/// The full model-to-pool mapping.
#[derive(Clone, Debug)]
pub struct Placement {
    /// `shards[layer][filter]` — `None` for pruned filters.
    pub shards: Vec<Vec<Option<ShardLoc>>>,
    /// Rows consumed per chip (including rows retired by stuck-tile
    /// retries).
    pub rows_used: Vec<usize>,
    /// Store attempts abandoned because stuck cells defeated the ECC.
    pub stuck_retries: usize,
}

impl Placement {
    /// Number of placed (live) shards.
    pub fn live_shards(&self) -> usize {
        self.shards
            .iter()
            .map(|l| l.iter().filter(|s| s.is_some()).count())
            .sum()
    }

    /// Array rows currently occupied by live shards (excludes rows
    /// retired by stuck-tile retries or vacated by migration) — the
    /// quantity tenant row quotas are enforced against.
    pub fn rows_live(&self) -> usize {
        self.shards
            .iter()
            .flat_map(|layer| layer.iter().flatten())
            .map(|loc| loc.span.slots.len())
            .sum()
    }

    /// Chips hosting at least one shard.
    pub fn chips_touched(&self) -> usize {
        let mut used: Vec<bool> = vec![false; self.rows_used.len()];
        for layer in &self.shards {
            for loc in layer.iter().flatten() {
                used[loc.chip] = true;
            }
        }
        used.iter().filter(|&&b| b).count()
    }
}

/// Place (and program) every live filter of `model` onto `pool`.
/// Fails if some filter fits on no chip (capacity or unrecoverable
/// faults); on success every live filter is on exactly one chip.
pub fn place(model: &ModelBundle, pool: &mut ChipPool) -> Result<Placement> {
    let mut allocs: Vec<RowAllocator> =
        pool.chips().iter().map(RowAllocator::for_chip).collect();
    place_with(model, pool, &mut allocs, None)
}

/// Multi-tenant placement: place `model` onto `pool` through a set of
/// **shared** row allocators (one per chip), so several models can be
/// placed onto one pool in sequence — each sees only the rows its
/// predecessors left free. `row_quota`, when set, bounds the rows this
/// model's live shards may occupy across the whole pool (enforced here
/// at placement time and again by the rebalancer at migration time).
///
/// The single-model [`place`] is this with fresh allocators and no quota.
pub fn place_with(
    model: &ModelBundle,
    pool: &mut ChipPool,
    allocs: &mut [RowAllocator],
    row_quota: Option<usize>,
) -> Result<Placement> {
    let n = pool.len();
    if n == 0 || allocs.len() != n {
        return Err(anyhow!("placement needs a non-empty pool with one allocator per chip"));
    }
    let per_row = allocs[0].data_cols;
    let free: usize = allocs.iter().map(|a| a.rows_free()).sum();
    let required = model.rows_required(per_row);
    if required > free {
        return Err(anyhow!(
            "model needs {required} rows but the {n}-chip pool has {free} free; \
             prune harder, grow the pool, or evict a tenant"
        ));
    }
    if let Some(quota) = row_quota {
        if required > quota {
            return Err(anyhow!(
                "model needs {required} rows but its tenant row quota is {quota}"
            ));
        }
    }
    let mut shards = Vec::with_capacity(model.n_layers());
    let mut stuck_retries = 0usize;
    let mut rows_used = vec![0usize; n];
    let mut quota_rows = 0usize;
    for layer in model.placement_layers() {
        let cells = layer.cells;
        let need = cells.div_ceil(per_row);
        let mut layer_shards: Vec<Option<ShardLoc>> = Vec::with_capacity(layer.shards.len());
        for (f, payload) in layer.shards.iter().enumerate() {
            let Some(payload) = payload else {
                layer_shards.push(None);
                continue;
            };
            if let Some(quota) = row_quota {
                if quota_rows + need > quota {
                    return Err(anyhow!(
                        "tenant row quota {quota} exhausted at layer {} filter {f} \
                         ({quota_rows} rows already live)",
                        layer.name
                    ));
                }
            }
            // wear-aware candidate order (recomputed per filter: wear
            // accrued by this very placement run feeds back immediately)
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by_key(|&c| {
                (
                    pool.chips()[c].wear.write_pulses,
                    usize::MAX - allocs[c].rows_free(),
                    c,
                )
            });
            let mut placed = None;
            for &c in &order {
                let Some(span) = allocs[c].alloc(cells) else {
                    continue; // chip full
                };
                rows_used[c] += span.slots.len();
                let chip = &mut pool.chips_mut()[c];
                let failures = match *payload {
                    ShardPayload::Binary(bits) => store_bits(chip, &span, bits),
                    ShardPayload::Int8(weights) => store_int8(chip, &span, weights),
                };
                if failures == 0 {
                    placed = Some(ShardLoc { chip: c, span });
                    break;
                }
                // stuck tile: rows stay retired, try the next chip
                stuck_retries += 1;
            }
            let Some(loc) = placed else {
                return Err(anyhow!(
                    "placement failed: layer {} filter {f} ({cells} cells) fits no chip \
                     ({stuck_retries} stuck-tile retries so far)",
                    layer.name
                ));
            };
            quota_rows += loc.span.slots.len();
            layer_shards.push(Some(loc));
        }
        shards.push(layer_shards);
    }
    Ok(Placement { shards, rows_used, stuck_retries })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chip::ChipConfig;
    use crate::cim::mapping::{load_bits, load_int8};
    use crate::nn::pointnet::GroupingConfig;
    use crate::serve::pool::PoolConfig;
    use crate::serve::{MnistBundle, ModelBundle, PointNetBundle};

    fn small_pool(chips: usize, seed: u64) -> ChipPool {
        ChipPool::new(&PoolConfig { chips, chip: ChipConfig::small_test(), seed })
    }

    fn tiny_pointnet(prune: f64, seed: u64) -> PointNetBundle {
        PointNetBundle::synthetic(
            [2, 2, 3, 2, 2, 3, 2, 4],
            3,
            prune,
            GroupingConfig { s1: 8, k1: 4, r1: 0.3, s2: 4, k2: 2, r2: 0.6 },
            seed,
        )
    }

    #[test]
    fn roundtrip_every_live_filter_on_exactly_one_tile() {
        let mnist = MnistBundle::synthetic([4, 4, 4], 0.3, 11);
        let model: ModelBundle = mnist.clone().into();
        let mut pool = small_pool(2, 12);
        let placement = place(&model, &mut pool).unwrap();
        assert_eq!(placement.shards.len(), 3);
        for (l, layer) in mnist.conv.iter().enumerate() {
            for f in 0..layer.out_c {
                let loc = &placement.shards[l][f];
                assert_eq!(loc.is_some(), layer.live[f], "layer {l} filter {f}");
                if let Some(loc) = loc {
                    assert!(loc.chip < pool.len());
                    // bits read back through the ECC are the stored bits
                    let got = load_bits(&mut pool.chips_mut()[loc.chip], &loc.span);
                    assert_eq!(&got, &layer.bits[f], "layer {l} filter {f}");
                }
            }
        }
        assert_eq!(placement.live_shards(), model.live_filters());
    }

    #[test]
    fn pointnet_int8_shards_roundtrip() {
        let pn = tiny_pointnet(0.3, 21);
        let model: ModelBundle = pn.clone().into();
        let mut pool = small_pool(2, 22);
        let placement = place(&model, &mut pool).unwrap();
        assert_eq!(placement.shards.len(), 8);
        for (l, layer) in pn.layers.iter().enumerate() {
            for f in 0..layer.out_c {
                let loc = &placement.shards[l][f];
                assert_eq!(loc.is_some(), layer.live[f], "layer {l} channel {f}");
                if let Some(loc) = loc {
                    assert_eq!(loc.span.len, 4 * layer.in_c, "4 cells per weight");
                    let got = load_int8(&mut pool.chips_mut()[loc.chip], &loc.span);
                    assert_eq!(&got, &layer.w_q[f], "layer {l} channel {f}");
                }
            }
        }
        assert_eq!(placement.live_shards(), model.live_filters());
    }

    #[test]
    fn placement_balances_across_fresh_chips() {
        let model = ModelBundle::synthetic_mnist([4, 4, 4], 0.0, 13);
        let mut pool = small_pool(2, 14);
        let placement = place(&model, &mut pool).unwrap();
        assert_eq!(placement.chips_touched(), 2, "fresh pool must be load-balanced");
        assert!(placement.rows_used.iter().all(|&r| r > 0));
    }

    #[test]
    fn placement_prefers_less_worn_chips() {
        let model = ModelBundle::synthetic_mnist([2, 2, 2], 0.0, 15);
        let mut pool = small_pool(2, 16);
        // artificially age chip 0 far beyond anything placement adds
        pool.chips_mut()[0].wear.write_pulses += 10_000_000;
        let placement = place(&model, &mut pool).unwrap();
        for layer in &placement.shards {
            for loc in layer.iter().flatten() {
                assert_eq!(loc.chip, 1, "worn chip must be avoided");
            }
        }
    }

    #[test]
    fn stuck_tiles_are_skipped() {
        // chip 0: no ECC spares + heavy stuck faults => most rows
        // unusable once the tiny backup region is exhausted; chip 1 ideal.
        let mut bad_cfg = ChipConfig::small_test();
        bad_cfg.spares_per_row = 0;
        bad_cfg.device.stuck_fault_prob = 0.05;
        let mut rng = crate::util::rng::Rng::new(17);
        let mut bad = crate::chip::Chip::new(bad_cfg, &mut rng.fork(1));
        bad.form();
        let mut good = crate::chip::Chip::new(ChipConfig::small_test(), &mut rng.fork(2));
        good.form();
        // make the bad chip the preferred candidate
        good.wear.write_pulses = bad.wear.write_pulses + 1_000_000;
        let mut pool = ChipPool::from_chips(vec![bad, good]);
        let mnist = MnistBundle::synthetic([4, 4, 4], 0.0, 18);
        let model: ModelBundle = mnist.clone().into();
        let placement = place(&model, &mut pool).unwrap();
        assert!(placement.stuck_retries > 0, "expected stuck-tile retries");
        // every filter still landed somewhere, and reads back intact
        assert_eq!(placement.live_shards(), model.live_filters());
        for (l, layer) in mnist.conv.iter().enumerate() {
            for (f, loc) in placement.shards[l].iter().enumerate() {
                let loc = loc.as_ref().unwrap();
                let got = load_bits(&mut pool.chips_mut()[loc.chip], &loc.span);
                assert_eq!(&got, &layer.bits[f]);
            }
        }
    }

    #[test]
    fn shared_allocators_host_two_models_disjointly() {
        // two tenants placed in sequence through the same allocators:
        // every shard row is owned by exactly one tenant
        let mnist: ModelBundle = MnistBundle::synthetic([3, 4, 3], 0.0, 61).into();
        let pointnet: ModelBundle = tiny_pointnet(0.0, 62).into();
        let mut pool = small_pool(3, 63);
        let mut allocs: Vec<_> =
            pool.chips().iter().map(crate::cim::mapping::RowAllocator::for_chip).collect();
        let pa = place_with(&mnist, &mut pool, &mut allocs, None).unwrap();
        let pb = place_with(&pointnet, &mut pool, &mut allocs, None).unwrap();
        assert_eq!(pa.live_shards(), mnist.live_filters());
        assert_eq!(pb.live_shards(), pointnet.live_filters());
        // no (chip, block, row) slot is shared between the two tenants
        let slots = |p: &Placement| -> Vec<(usize, usize, usize)> {
            p.shards
                .iter()
                .flat_map(|l| l.iter().flatten())
                .flat_map(|loc| {
                    loc.span.slots.iter().map(move |&(b, r)| (loc.chip, b, r))
                })
                .collect()
        };
        let a_slots = slots(&pa);
        for s in slots(&pb) {
            assert!(!a_slots.contains(&s), "row {s:?} double-booked across tenants");
        }
        assert_eq!(pa.rows_live(), a_slots.len());
    }

    #[test]
    fn row_quota_is_enforced_at_placement() {
        let model: ModelBundle = MnistBundle::synthetic([4, 4, 4], 0.0, 64).into();
        let mut pool = small_pool(2, 65);
        let mut allocs: Vec<_> =
            pool.chips().iter().map(crate::cim::mapping::RowAllocator::for_chip).collect();
        let err = place_with(&model, &mut pool, &mut allocs, Some(3)).unwrap_err();
        assert!(err.to_string().contains("quota"), "{err}");
        // a generous quota places normally and stays within bound
        let mut pool = small_pool(2, 66);
        let mut allocs: Vec<_> =
            pool.chips().iter().map(crate::cim::mapping::RowAllocator::for_chip).collect();
        let p = place_with(&model, &mut pool, &mut allocs, Some(64)).unwrap();
        assert!(p.rows_live() <= 64);
        assert_eq!(p.live_shards(), model.live_filters());
    }

    #[test]
    fn oversized_model_fails_with_capacity_error() {
        // dense MNIST model needs ~1312 rows; one small test chip has 60
        let model = ModelBundle::synthetic_mnist([32, 64, 32], 0.0, 19);
        let mut pool = small_pool(1, 20);
        let err = place(&model, &mut pool).unwrap_err();
        assert!(err.to_string().contains("rows"), "{err}");
    }

    #[test]
    fn oversized_pointnet_fails_with_capacity_error() {
        // full-width INT8 stack needs thousands of rows
        let model: ModelBundle = PointNetBundle::synthetic(
            [32, 32, 64, 64, 64, 128, 128, 256],
            128,
            0.0,
            GroupingConfig::default(),
            23,
        )
        .into();
        let mut pool = small_pool(1, 24);
        let err = place(&model, &mut pool).unwrap_err();
        assert!(err.to_string().contains("rows"), "{err}");
    }
}

//! Admission queue + batch coalescing: single-image requests enter a
//! bounded queue and leave as batches sized to fill the arrays'
//! row-parallel width.
//!
//! * **Coalescing** — a batch closes when it reaches `max_batch` images
//!   or `max_wait` has elapsed since its first request, whichever comes
//!   first (bounded added latency for sparse traffic).
//! * **Backpressure** — the queue holds at most `queue_depth` requests.
//!   Blocking submission ([`std::sync::mpsc::SyncSender::send`]) never
//!   drops a request; `try_send` surfaces a full queue as an error for
//!   callers that prefer shedding to waiting.

use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::time::{Duration, Instant};

/// Batching knobs.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Maximum images coalesced into one batch.
    pub max_batch: usize,
    /// Maximum time a batch waits for more images after its first one.
    pub max_wait: Duration,
    /// Bound on queued (admitted but unbatched) requests.
    pub queue_depth: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            queue_depth: 256,
        }
    }
}

/// One admitted inference request.
pub struct Request {
    pub id: u64,
    /// Flat request input: `input_hw^2` grayscale floats (MNIST path)
    /// or `3 * cloud_points` interleaved xyz floats (PointNet path).
    pub input: Vec<f32>,
    pub submitted: Instant,
    /// Where the scheduler sends the result. One-shot: a bounded
    /// `sync_channel(1)` sender, so the single reply buffers without a
    /// blocked receiver and the serve plane holds no unbounded queues.
    pub reply: SyncSender<Response>,
}

/// One served inference.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    /// Submit-to-reply latency (queueing + batching + compute).
    pub latency: Duration,
}

/// The consuming half of the admission queue.
pub struct Batcher {
    rx: Receiver<Request>,
    cfg: BatcherConfig,
}

impl Batcher {
    /// Build the bounded admission channel and its batcher.
    pub fn channel(cfg: BatcherConfig) -> (SyncSender<Request>, Batcher) {
        assert!(cfg.max_batch > 0 && cfg.queue_depth > 0);
        let (tx, rx) = sync_channel(cfg.queue_depth);
        (tx, Batcher { rx, cfg })
    }

    pub fn cfg(&self) -> &BatcherConfig {
        &self.cfg
    }

    /// Block for the next coalesced batch. Returns `None` once every
    /// submitter has hung up and the queue is drained — the scheduler's
    /// shutdown signal. A batch always holds 1..=`max_batch` requests.
    pub fn next_batch(&self) -> Option<Vec<Request>> {
        let first = self.rx.recv().ok()?;
        let deadline = Instant::now() + self.cfg.max_wait;
        let mut batch = vec![first];
        while batch.len() < self.cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::TrySendError;

    fn request(id: u64) -> (Request, Receiver<Response>) {
        let (reply, rx) = sync_channel(1);
        (
            Request { id, input: vec![0.0; 4], submitted: Instant::now(), reply },
            rx,
        )
    }

    #[test]
    fn coalesces_up_to_max_batch() {
        let (tx, batcher) = Batcher::channel(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(50),
            queue_depth: 16,
        });
        let mut replies = Vec::new();
        for i in 0..10 {
            let (r, rx) = request(i);
            tx.send(r).unwrap();
            replies.push(rx);
        }
        drop(tx); // disconnect: batches flush without waiting max_wait
        let sizes: Vec<usize> = std::iter::from_fn(|| batcher.next_batch())
            .map(|b| b.len())
            .collect();
        assert_eq!(sizes, vec![4, 4, 2]);
        assert!(batcher.next_batch().is_none(), "drained queue ends the stream");
    }

    #[test]
    fn batch_order_preserves_admission_order() {
        let (tx, batcher) = Batcher::channel(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(50),
            queue_depth: 8,
        });
        for i in 0..5 {
            let (r, _rx) = request(i);
            tx.send(r).unwrap();
        }
        drop(tx);
        let ids: Vec<u64> = batcher.next_batch().unwrap().iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn fifo_order_is_preserved_across_coalescing_rounds() {
        // one client's requests must drain in admission order even when
        // they span several full coalescing rounds of a saturated pool
        let (tx, batcher) = Batcher::channel(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(50),
            queue_depth: 32,
        });
        for i in 0..11 {
            let (r, _rx) = request(i);
            tx.send(r).unwrap();
        }
        drop(tx);
        let mut next = 0u64;
        while let Some(batch) = batcher.next_batch() {
            for r in &batch {
                assert_eq!(r.id, next, "request served out of client order");
                next += 1;
            }
        }
        assert_eq!(next, 11, "every admitted request drained exactly once");
    }

    #[test]
    fn max_wait_bounds_partial_batch_latency() {
        let (tx, batcher) = Batcher::channel(BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(10),
            queue_depth: 8,
        });
        let (r, _rx) = request(0);
        tx.send(r).unwrap();
        let t0 = Instant::now();
        // sender stays alive: only max_wait can close this batch
        let batch = batcher.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(9), "closed too early: {waited:?}");
        assert!(waited < Duration::from_secs(2), "missed the deadline: {waited:?}");
        drop(tx);
    }

    #[test]
    fn queue_depth_bounds_admission() {
        let (tx, _batcher) = Batcher::channel(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_depth: 2,
        });
        let (r0, _k0) = request(0);
        let (r1, _k1) = request(1);
        let (r2, _k2) = request(2);
        assert!(tx.try_send(r0).is_ok());
        assert!(tx.try_send(r1).is_ok());
        match tx.try_send(r2) {
            Err(TrySendError::Full(r)) => assert_eq!(r.id, 2, "request returned intact"),
            other => panic!("expected backpressure, got {:?}", other.map(|_| ()).map_err(|_| ())),
        }
    }
}

//! The chip pool: N independently fabricated + formed [`Chip`] instances
//! with their per-chip energy/timing/endurance ledgers. The pool is the
//! unit the placer shards a model across and the scheduler spawns one
//! worker thread per member of.

use crate::chip::{Chip, ChipConfig, WearLedger};
use crate::util::rng::Rng;

/// Pool construction knobs.
#[derive(Clone, Debug)]
pub struct PoolConfig {
    /// Number of chips in the pool.
    pub chips: usize,
    /// Per-chip configuration (all pool members share it; their device
    /// statistics still differ through per-chip RNG forks).
    pub chip: ChipConfig,
    /// Root seed for fabrication randomness.
    pub seed: u64,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig { chips: 4, chip: ChipConfig::default(), seed: 0x5e7e }
    }
}

/// A pool of formed chips.
pub struct ChipPool {
    chips: Vec<Chip>,
}

impl ChipPool {
    /// Fabricate and form `cfg.chips` chips, each from an independent
    /// RNG fork (distinct device statistics / stuck maps per chip).
    pub fn new(cfg: &PoolConfig) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let chips = (0..cfg.chips)
            .map(|i| {
                let mut chip = Chip::new(cfg.chip.clone(), &mut rng.fork(0x9001 + i as u64));
                chip.form();
                chip
            })
            .collect();
        ChipPool { chips }
    }

    /// Wrap already-built chips (placement tests, warm pools).
    pub fn from_chips(chips: Vec<Chip>) -> Self {
        ChipPool { chips }
    }

    pub fn len(&self) -> usize {
        self.chips.len()
    }

    pub fn is_empty(&self) -> bool {
        self.chips.is_empty()
    }

    pub fn chips(&self) -> &[Chip] {
        &self.chips
    }

    pub fn chips_mut(&mut self) -> &mut [Chip] {
        &mut self.chips
    }

    /// Hand the chips to the scheduler's worker threads.
    pub fn into_chips(self) -> Vec<Chip> {
        self.chips
    }

    /// Array rows one pool member offers to the placer.
    pub fn rows_per_chip(&self) -> usize {
        self.chips
            .first()
            .map(|c| c.cfg().blocks * c.cfg().logical_rows())
            .unwrap_or(0)
    }

    /// Per-chip lifetime wear snapshot (endurance ledger).
    pub fn wear(&self) -> Vec<WearLedger> {
        self.chips.iter().map(|c| c.wear.clone()).collect()
    }

    /// Total energy currently on the pool's ledgers (pJ).
    pub fn energy_pj(&self) -> f64 {
        self.chips.iter().map(|c| c.energy_breakdown().total_pj()).sum()
    }

    /// Zero every chip's energy/timing ledgers (wear persists) — called
    /// after placement so serving measurements exclude programming cost.
    pub fn reset_energy(&mut self) {
        for c in &mut self.chips {
            c.reset_ledgers();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_members_are_formed_and_distinct() {
        let cfg = PoolConfig {
            chips: 3,
            chip: ChipConfig::small_test(),
            seed: 7,
        };
        let pool = ChipPool::new(&cfg);
        assert_eq!(pool.len(), 3);
        assert!(pool.chips().iter().all(|c| c.is_formed()));
        assert!(pool.rows_per_chip() > 0);
        // forming wear is on the ledgers
        assert!(pool.wear().iter().all(|w| w.write_pulses > 0));
    }

    #[test]
    fn reset_energy_keeps_wear() {
        let cfg = PoolConfig { chips: 1, chip: ChipConfig::small_test(), seed: 8 };
        let mut pool = ChipPool::new(&cfg);
        let wear_before = pool.wear()[0].write_pulses;
        assert!(pool.energy_pj() > 0.0, "forming energy expected");
        pool.reset_energy();
        assert_eq!(pool.energy_pj(), 0.0);
        assert_eq!(pool.wear()[0].write_pulses, wear_before);
    }
}

//! The chip pool: N independently fabricated + formed [`Chip`] instances
//! with their per-chip energy/timing/endurance ledgers. The pool is the
//! unit the placer shards a model across, the unit a
//! [`crate::serve::transport::LocalBackend`] spawns one worker thread
//! per member of, and the unit a [`crate::serve::transport::Host`]
//! daemon owns on the far side of a TCP connection.

use crate::chip::{Chip, ChipConfig, WearLedger};
use crate::util::rng::Rng;

/// Pool construction knobs.
#[derive(Clone, Debug)]
pub struct PoolConfig {
    /// Number of chips in the pool.
    pub chips: usize,
    /// Per-chip configuration (all pool members share it; their device
    /// statistics still differ through per-chip RNG forks).
    pub chip: ChipConfig,
    /// Root seed for fabrication randomness.
    pub seed: u64,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig { chips: 4, chip: ChipConfig::default(), seed: 0x5e7e }
    }
}

/// A point-in-time copy of every pool member's lifetime [`WearLedger`]
/// — the rebalancer's input ([`crate::serve::engine::rebalance`]).
/// Snapshots of the same pool are monotone non-decreasing over time
/// (wear is lifetime state, never reset), so the per-chip
/// [`WearSnapshot::delta`] against an earlier snapshot is always
/// well-defined and measures the wear accrued in between.
#[derive(Clone, Debug)]
pub struct WearSnapshot {
    /// One ledger per pool chip, in pool order.
    pub per_chip: Vec<WearLedger>,
}

impl WearSnapshot {
    /// Per-chip wear accrued since `earlier` (saturating per counter).
    pub fn delta(&self, earlier: &WearSnapshot) -> Vec<WearLedger> {
        assert_eq!(self.per_chip.len(), earlier.per_chip.len(), "snapshot pool size");
        self.per_chip
            .iter()
            .zip(&earlier.per_chip)
            .map(|(now, then)| now.delta(then))
            .collect()
    }

    /// True when no counter of any chip went backwards since `earlier`.
    pub fn is_monotone_since(&self, earlier: &WearSnapshot) -> bool {
        self.per_chip.len() == earlier.per_chip.len()
            && self
                .per_chip
                .iter()
                .zip(&earlier.per_chip)
                .all(|(now, then)| now.is_monotone_since(then))
    }
}

/// A pool of formed chips.
pub struct ChipPool {
    chips: Vec<Chip>,
}

impl ChipPool {
    /// Fabricate and form `cfg.chips` chips, each from an independent
    /// RNG fork (distinct device statistics / stuck maps per chip).
    pub fn new(cfg: &PoolConfig) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let chips = (0..cfg.chips)
            .map(|i| {
                let mut chip = Chip::new(cfg.chip.clone(), &mut rng.fork(0x9001 + i as u64));
                chip.form();
                chip
            })
            .collect();
        ChipPool { chips }
    }

    /// Wrap already-built chips (placement tests, warm pools).
    pub fn from_chips(chips: Vec<Chip>) -> Self {
        ChipPool { chips }
    }

    pub fn len(&self) -> usize {
        self.chips.len()
    }

    pub fn is_empty(&self) -> bool {
        self.chips.is_empty()
    }

    pub fn chips(&self) -> &[Chip] {
        &self.chips
    }

    pub fn chips_mut(&mut self) -> &mut [Chip] {
        &mut self.chips
    }

    /// Hand the chips to the scheduler's worker threads.
    pub fn into_chips(self) -> Vec<Chip> {
        self.chips
    }

    /// Array rows one pool member offers to the placer.
    pub fn rows_per_chip(&self) -> usize {
        self.chips
            .first()
            .map(|c| c.cfg().blocks * c.cfg().logical_rows())
            .unwrap_or(0)
    }

    /// Per-chip lifetime wear snapshot (endurance ledger).
    pub fn wear(&self) -> Vec<WearLedger> {
        self.chips.iter().map(|c| c.wear.clone()).collect()
    }

    /// Point-in-time [`WearSnapshot`] of the whole pool. Successive
    /// snapshots are monotone non-decreasing per chip, so their
    /// [`WearSnapshot::delta`] is the wear a serving window accrued —
    /// the signal the engine's rebalancer migrates shards on.
    pub fn wear_snapshot(&self) -> WearSnapshot {
        WearSnapshot { per_chip: self.wear() }
    }

    /// Total energy currently on the pool's ledgers (pJ).
    pub fn energy_pj(&self) -> f64 {
        self.chips.iter().map(|c| c.energy_breakdown().total_pj()).sum()
    }

    /// Zero every chip's energy/timing ledgers (wear persists) — called
    /// after placement so serving measurements exclude programming cost.
    pub fn reset_energy(&mut self) {
        for c in &mut self.chips {
            c.reset_ledgers();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_members_are_formed_and_distinct() {
        let cfg = PoolConfig {
            chips: 3,
            chip: ChipConfig::small_test(),
            seed: 7,
        };
        let pool = ChipPool::new(&cfg);
        assert_eq!(pool.len(), 3);
        assert!(pool.chips().iter().all(|c| c.is_formed()));
        assert!(pool.rows_per_chip() > 0);
        // forming wear is on the ledgers
        assert!(pool.wear().iter().all(|w| w.write_pulses > 0));
    }

    #[test]
    fn wear_snapshots_are_monotone_across_batches() {
        use crate::cim::mapping::{segment_widths, store_bits, RowAllocator};
        use crate::cim::vmm;

        let cfg = PoolConfig { chips: 2, chip: ChipConfig::small_test(), seed: 9 };
        let mut pool = ChipPool::new(&cfg);
        // shard a small filter onto chip 0 (placement wear)
        let mut alloc = RowAllocator::for_chip(&pool.chips()[0]);
        let bits: Vec<bool> = (0..9).map(|i| i % 2 == 0).collect();
        let span = alloc.alloc(bits.len()).unwrap();
        let mut snap = pool.wear_snapshot();
        assert_eq!(store_bits(&mut pool.chips_mut()[0], &span, &bits), 0);
        // serve a few "batches" of dot products; every batch moves the
        // snapshot forward and never backwards, on every chip
        let widths = segment_widths(bits.len(), alloc.data_cols);
        for batch in 0..4 {
            let flat: Vec<u8> = (0..2 * bits.len()).map(|i| (i % 7) as u8).collect();
            let pw = vmm::pack_windows(&flat, &widths).unwrap();
            let dots = vmm::binary_dots_batched(&mut pool.chips_mut()[0], &span, &pw);
            assert_eq!(dots.len(), 2);
            let next = pool.wear_snapshot();
            assert!(
                next.is_monotone_since(&snap),
                "batch {batch}: wear went backwards"
            );
            let delta = next.delta(&snap);
            assert!(delta[0].wl_activations > 0, "batch {batch}: chip 0 served rows");
            assert_eq!(delta[1].wl_activations, 0, "chip 1 is idle");
            snap = next;
        }
    }

    #[test]
    fn reset_energy_keeps_wear() {
        let cfg = PoolConfig { chips: 1, chip: ChipConfig::small_test(), seed: 8 };
        let mut pool = ChipPool::new(&cfg);
        let wear_before = pool.wear()[0].write_pulses;
        assert!(pool.energy_pj() > 0.0, "forming energy expected");
        pool.reset_energy();
        assert_eq!(pool.energy_pj(), 0.0);
        assert_eq!(pool.wear()[0].write_pulses, wear_before);
    }
}

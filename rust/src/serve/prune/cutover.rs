//! The prune cutover: an epoch-fenced state machine that retires a
//! [`PrunePlan`]'s filters from a *serving* placement without a single
//! wrong logit (DESIGN.md §12).
//!
//! ```text
//!  Planned ──validate──▶ Started ──fence+drain──▶ Fenced ──▶ Committed
//!     │                                                        masks │
//!     └── any check fails ──▶ Aborted                        flipped,
//!         (dense layer stays authoritative,                  route
//!          nothing was touched)                              rebuilt,
//!                                                            rows freed
//! ```
//!
//! Pruning is the degenerate in-place case of cross-group migration
//! ([`ShardRouter::migrate_layer`]): the surviving shards never move,
//! so there is no program phase and no partial-destination state — the
//! only irreversible step is the mask flip, and it happens strictly
//! after the fence has drained every request built against the dense
//! placement. Abort is therefore only possible (and only needed)
//! before the fence.
//!
//! The commit order is what keeps the bit-exactness contract intact:
//! the model's live masks flip *first* (re-basing
//! [`ModelBundle::reference_logits`] to the pruned oracle), then the
//! placement drops the shard slots and the route is rebuilt at the new
//! epoch — so every batch dispatched after the cutover computes, and
//! is checked against, the same pruned model. The dense→pruned answer
//! shift is measured on a probe input across the flip and reported in
//! [`PruneCommit::logit_delta`], never silently absorbed.

use crate::serve::model::ModelBundle;
use crate::serve::obs::{Obs, ObsEvent};
use crate::serve::transport::{
    self, RouterPlacement, ShardRef, ShardRouter, TenantRoute, TransportError,
};

use super::PrunePlan;

/// Borrowed view of everything one cutover mutates. Construct, call
/// [`PruneCutover::execute`], done — the struct enforces that a single
/// actor (the engine coordinator) holds every mutable piece for the
/// duration, which is what makes the fence's drain guarantee sound.
pub struct PruneCutover<'a> {
    pub tenant: usize,
    pub router: &'a mut ShardRouter,
    pub placement: &'a mut RouterPlacement,
    pub route: &'a mut TenantRoute,
    pub model: &'a mut ModelBundle,
    pub obs: &'a Obs,
}

/// What a cutover did.
#[derive(Clone, Debug)]
pub enum CutoverOutcome {
    Committed(PruneCommit),
    /// Validation failed pre-fence: nothing was mutated, no epoch was
    /// spent, and the dense layer remains authoritative.
    Aborted { reason: &'static str },
}

/// A committed cutover's receipt.
#[derive(Clone, Debug)]
pub struct PruneCommit {
    pub layer: usize,
    /// The route epoch the pruned placement serves under.
    pub epoch: u64,
    /// Filters retired, ascending.
    pub filters: Vec<usize>,
    /// Rows returned to backend allocators across the owning group.
    pub rows_freed: u64,
    /// Rows whose release failed (backend without release support or
    /// unreachable) — retired, not reusable.
    pub rows_retired: u64,
    /// Max |dense − pruned| logit shift on the probe input, `None`
    /// when the caller had no probe to measure with.
    pub logit_delta: Option<f64>,
}

impl PruneCutover<'_> {
    /// Run the state machine for one plan. `probe` is a recent real
    /// input of this tenant (the engine stashes one per served batch)
    /// used to measure the answer shift across the flip.
    ///
    /// # Errors
    ///
    /// [`TransportError::Closed`] when the fleet's workers are gone —
    /// the one failure the fence cannot drain through. Everything else
    /// is an [`CutoverOutcome::Aborted`] (pre-fence) or a per-row
    /// `rows_retired` count (post-fence release failures).
    // lint: allow(panic-freedom) — shard and member indices come from the cutover plan validated against the live placement before the fence
    pub fn execute(
        self,
        plan: &PrunePlan,
        probe: Option<&[f32]>,
    ) -> transport::Result<CutoverOutcome> {
        let PruneCutover { tenant, router, placement, route, model, obs } = self;
        let layer = plan.layer;
        obs.bus.emit(ObsEvent::PrunePlanned {
            tenant,
            layer,
            filters: plan.filters.clone(),
        });
        let abort = |reason: &'static str| {
            obs.bus.emit(ObsEvent::PruneAborted { tenant, layer });
            Ok(CutoverOutcome::Aborted { reason })
        };
        // -- validate: every check before any mutation ------------------
        if layer >= placement.layers.len() {
            return abort("layer out of range");
        }
        if plan.filters.is_empty() {
            return abort("empty plan");
        }
        if plan.filters.windows(2).any(|w| w[1] <= w[0]) {
            return abort("plan filters not strictly ascending");
        }
        let mask = model.live_mask(layer);
        if plan.filters.iter().any(|&f| f >= mask.len() || !mask[f]) {
            return abort("stale plan: filter already pruned");
        }
        if mask.iter().filter(|&&b| b).count() <= plan.filters.len() {
            return abort("plan would retire the layer's last live kernel");
        }
        let group = placement.layers[layer].group;
        let members = router.group_members(group);
        if members.iter().any(|&m| router.is_quarantined(m)) {
            return abort("owning group has a quarantined member");
        }
        {
            let shards = &placement.layers[layer].shards;
            debug_assert_eq!(shards.len(), members.len(), "shard table vs group size");
            if plan.filters.iter().any(|&f| shards.iter().any(|ms| ms[f].is_none())) {
                return abort("stale placement: shard slot already empty");
            }
        }
        obs.bus.emit(ObsEvent::PruneStarted { tenant, layer });
        // capture the doomed spans before the placement forgets them
        let doomed: Vec<(usize, ShardRef)> = {
            let shards = &placement.layers[layer].shards;
            members
                .iter()
                .enumerate()
                .flat_map(|(local, &m)| {
                    plan.filters
                        .iter()
                        .map(move |&f| (m, shards[local][f].clone().expect("validated live")))
                })
                .collect()
        };
        let before = probe.map(|p| model.reference_logits(p));
        // -- fence + drain: after this, no request that addressed the
        // dense placement exists anywhere in the fleet ------------------
        let old_epoch = route.epoch;
        let epoch = router.next_epoch();
        router.fence_and_drain(old_epoch)?;
        obs.bus.emit(ObsEvent::PruneFenced { tenant, layer, epoch });
        // -- commit: masks first (the reference oracle re-bases), then
        // the placement and the route at the new epoch ------------------
        for &f in &plan.filters {
            let was_live = model.prune_filter(layer, f);
            debug_assert!(was_live, "validated live above");
        }
        for member_shards in &mut placement.layers[layer].shards {
            for &f in &plan.filters {
                member_shards[f] = None;
            }
        }
        *route = TenantRoute::from_placement(placement, epoch);
        let logit_delta = match (&before, probe) {
            (Some(b), Some(p)) => {
                let after = model.reference_logits(p);
                let d = b
                    .iter()
                    .zip(&after)
                    .map(|(x, y)| (x - y).abs() as f64)
                    .fold(0.0, f64::max);
                Some(d)
            }
            _ => None,
        };
        // -- free: the drained rows go back to every member's allocator
        let (mut rows_freed, mut rows_retired) = (0u64, 0u64);
        for (m, shard) in &doomed {
            let rows = shard.span.slots.len() as u64;
            match router.release(*m, shard.chip as usize, shard.span.clone()) {
                Ok(_) => rows_freed += rows,
                Err(TransportError::Closed) => return Err(TransportError::Closed),
                // best effort: a backend without release support (or an
                // unreachable one) just retires these rows
                Err(_) => rows_retired += rows,
            }
        }
        obs.bus.emit(ObsEvent::PruneCommitted {
            tenant,
            layer,
            filters: plan.filters.clone(),
            rows_freed,
        });
        Ok(CutoverOutcome::Committed(PruneCommit {
            layer,
            epoch,
            filters: plan.filters.clone(),
            rows_freed,
            rows_retired,
            logit_delta,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chip::ChipConfig;
    use crate::serve::obs::EventSubscriber;
    use crate::serve::pool::PoolConfig;
    use crate::serve::transport::{LocalBackend, RouterConfig};

    fn pool_cfg(chips: usize, seed: u64) -> PoolConfig {
        PoolConfig { chips, chip: ChipConfig::small_test(), seed }
    }

    fn single_router(seed: u64) -> ShardRouter {
        let backend = LocalBackend::from_pool_config(&pool_cfg(3, seed)).expect("pool builds");
        ShardRouter::single(Box::new(backend)).expect("single-member fleet builds")
    }

    fn replicated_router(seed: u64) -> ShardRouter {
        let mk = |s: u64| LocalBackend::from_pool_config(&pool_cfg(2, s)).expect("pool builds");
        ShardRouter::replicated(
            vec![Box::new(mk(seed)), Box::new(mk(seed ^ 1))],
            RouterConfig::default(),
        )
        .expect("replica fleet builds")
    }

    struct Fixture {
        router: ShardRouter,
        placement: RouterPlacement,
        route: TenantRoute,
        model: ModelBundle,
        obs: Obs,
    }

    fn fixture(mut router: ShardRouter) -> Fixture {
        let model = ModelBundle::synthetic_mnist([6, 6, 6], 0.0, 5);
        let placement = router.place(&model, None).expect("placement fits");
        let epoch = router.next_epoch();
        let route = TenantRoute::from_placement(&placement, epoch);
        Fixture { router, placement, route, model, obs: Obs::new() }
    }

    fn run(
        fx: &mut Fixture,
        plan: &PrunePlan,
        probe: Option<&[f32]>,
    ) -> (CutoverOutcome, Vec<ObsEvent>) {
        let sub = fx.obs.bus.subscribe();
        let out = PruneCutover {
            tenant: plan.tenant,
            router: &mut fx.router,
            placement: &mut fx.placement,
            route: &mut fx.route,
            model: &mut fx.model,
            obs: &fx.obs,
        }
        .execute(plan, probe)
        .expect("local fleet never closes mid-test");
        let events = drain_events(&sub);
        (out, events)
    }

    fn drain_events(sub: &EventSubscriber) -> Vec<ObsEvent> {
        sub.drain().into_iter().map(|r| r.event).collect()
    }

    #[test]
    fn commit_flips_masks_rebuilds_route_and_frees_rows() {
        let mut fx = fixture(single_router(9));
        let free_before = fx.router.member_rows_free(0);
        let probe: Vec<f32> = (0..fx.model.input_len()).map(|i| (i % 7) as f32 / 7.0).collect();
        let plan = PrunePlan { tenant: 0, layer: 1, filters: vec![2, 4] };
        let (out, events) = run(&mut fx, &plan, Some(&probe));
        let CutoverOutcome::Committed(commit) = out else {
            panic!("expected a commit, got {out:?}");
        };
        // masks flipped, oracle re-based
        assert!(!fx.model.live_mask(1)[2] && !fx.model.live_mask(1)[4]);
        assert_eq!(fx.model.reference_logits(&probe).len(), 10);
        // placement slots emptied on every member, route at the new epoch
        assert!(fx.placement.layers[1].shards.iter().all(|ms| ms[2].is_none()));
        assert_eq!(fx.route.epoch, commit.epoch);
        assert_eq!(fx.route.layers[1].shards[0].len(), 4, "6 filters - 2 pruned");
        // rows went back to the allocator: headroom grew by exactly the
        // released spans and nothing was merely retired
        assert_eq!(commit.rows_retired, 0);
        assert!(commit.rows_freed > 0);
        assert_eq!(fx.router.member_rows_free(0), free_before + commit.rows_freed as usize);
        // the answer shift was measured, not silently absorbed
        assert!(commit.logit_delta.is_some());
        // event ladder: Planned -> Started -> Fenced -> Committed
        let kinds: Vec<&str> = events.iter().map(|e| e.kind()).collect();
        assert_eq!(
            kinds,
            ["prune_planned", "prune_started", "prune_fenced", "prune_committed"]
        );
        assert!(matches!(
            &events[3],
            ObsEvent::PruneCommitted { rows_freed, .. } if *rows_freed == commit.rows_freed
        ));
    }

    #[test]
    fn freed_rows_are_reallocatable() {
        let mut fx = fixture(single_router(10));
        let plan = PrunePlan { tenant: 0, layer: 0, filters: vec![0, 1, 2, 3] };
        let (out, _) = run(&mut fx, &plan, None);
        assert!(matches!(out, CutoverOutcome::Committed(_)));
        // a fresh placement of the (now smaller) model must succeed and
        // reuse the freed rows
        let again = fx.router.place(&fx.model, None);
        assert!(again.is_ok(), "freed rows must be re-allocatable: {again:?}");
    }

    #[test]
    fn replicated_groups_release_on_every_member() {
        let mut fx = fixture(replicated_router(11));
        assert_eq!(fx.router.n_members(), 2);
        let free_before: Vec<usize> =
            (0..2).map(|m| fx.router.member_rows_free(m)).collect();
        let plan = PrunePlan { tenant: 0, layer: 0, filters: vec![5] };
        let (out, _) = run(&mut fx, &plan, None);
        let CutoverOutcome::Committed(commit) = out else {
            panic!("expected a commit, got {out:?}");
        };
        assert_eq!(commit.rows_retired, 0);
        for m in 0..2 {
            assert!(
                fx.router.member_rows_free(m) > free_before[m],
                "member {m} must regain rows"
            );
        }
    }

    #[test]
    fn stale_and_malformed_plans_abort_without_mutating() {
        let mut fx = fixture(single_router(12));
        fx.model.prune_filter(2, 1);
        fx.placement.layers[2].shards[0][1] = None;
        let epoch_before = fx.route.epoch;
        let cases: Vec<(PrunePlan, &str)> = vec![
            (PrunePlan { tenant: 0, layer: 9, filters: vec![0] }, "layer out of range"),
            (PrunePlan { tenant: 0, layer: 0, filters: vec![] }, "empty plan"),
            (
                PrunePlan { tenant: 0, layer: 0, filters: vec![3, 3] },
                "plan filters not strictly ascending",
            ),
            (
                PrunePlan { tenant: 0, layer: 2, filters: vec![1] },
                "stale plan: filter already pruned",
            ),
            (
                PrunePlan { tenant: 0, layer: 0, filters: vec![0, 1, 2, 3, 4, 5] },
                "plan would retire the layer's last live kernel",
            ),
        ];
        for (plan, want) in cases {
            let (out, events) = run(&mut fx, &plan, None);
            let CutoverOutcome::Aborted { reason } = out else {
                panic!("plan {plan:?} must abort");
            };
            assert_eq!(reason, want);
            let kinds: Vec<&str> = events.iter().map(|e| e.kind()).collect();
            assert_eq!(kinds, ["prune_planned", "prune_aborted"], "no Started/Fenced");
        }
        // aborts spent no epoch and left the dense layer authoritative
        assert_eq!(fx.route.epoch, epoch_before);
        assert!(fx.model.live_mask(0).iter().all(|&b| b));
    }

    #[test]
    fn half_present_shard_aborts_the_cutover() {
        let mut fx = fixture(replicated_router(13));
        // stale placement slot: pretend member 1's copy vanished
        fx.placement.layers[0].shards[1][0] = None;
        let (out, _) = run(&mut fx, &PrunePlan { tenant: 0, layer: 0, filters: vec![0] }, None);
        let CutoverOutcome::Aborted { reason } = out else {
            panic!("must abort on a half-present shard");
        };
        assert_eq!(reason, "stale placement: shard slot already empty");
    }
}

//! The similarity monitor: re-runs the paper's prune rule over one
//! tenant's *programmed* kernels on a serving cadence and turns the
//! scheduler's decisions into per-layer [`PrunePlan`]s.
//!
//! Sign bits never change while a tenant serves (only live masks do),
//! so the monitor packs each layer's kernels **once** at construction
//! and rebuilds only the live-masked similarity matrices per pass. The
//! scheduler itself is rebuilt fresh from the model's current masks
//! each pass (state lives in the model, not the monitor) — an aborted
//! cutover therefore needs no compensation: the next pass re-derives
//! the same proposal from the unchanged masks.

use crate::cim::similarity::SimilarityMatrix;
use crate::pruning::{PackedKernels, PruningScheduler};
use crate::serve::model::ModelBundle;

use super::{LivePruneConfig, PrunePlan};

/// Per-tenant similarity monitor (see the module docs).
pub struct LivePruneMonitor {
    cfg: LivePruneConfig,
    /// Packed sign bits per layer — the exact bit patterns programmed
    /// on chip, packed once ([`ModelBundle::layer_sign_bits`]).
    packed: Vec<PackedKernels>,
    /// Weights (bit cells) per kernel of each layer, for the
    /// scheduler's parameter accounting.
    weights: Vec<usize>,
    /// Monitor passes run so far — doubles as the scheduler epoch, so
    /// the rule's warm-up/interval schedule applies to serving passes
    /// exactly as it does to training epochs.
    passes: usize,
}

impl LivePruneMonitor {
    pub fn new(cfg: LivePruneConfig, model: &ModelBundle) -> Self {
        let n_layers = model.n_layers();
        let packed: Vec<PackedKernels> = (0..n_layers)
            .map(|l| PackedKernels::from_bit_kernels(&model.layer_sign_bits(l)))
            .collect();
        let weights = packed.iter().map(|p| p.n_bits).collect();
        LivePruneMonitor { cfg, packed, weights, passes: 0 }
    }

    /// Monitor passes run so far.
    pub fn passes(&self) -> usize {
        self.passes
    }

    /// Run one monitor pass: similarity matrices over the currently
    /// live kernels, one scheduler evaluation, and the pruned pairs
    /// grouped into at most
    /// [`LivePruneConfig::max_layers_per_pass`] per-layer plans
    /// (shallowest layers first — they are re-proposed next pass if
    /// deferred). Returns no plans during the rule's warm-up or on
    /// off-interval passes.
    pub fn propose(&mut self, tenant: usize, model: &ModelBundle) -> Vec<PrunePlan> {
        self.passes += 1;
        let epoch = self.passes;
        let masks: Vec<Vec<bool>> =
            (0..self.packed.len()).map(|l| model.live_mask(l).to_vec()).collect();
        let mut sched =
            PruningScheduler::from_live_masks(self.cfg.rule.clone(), &masks, &self.weights);
        if !sched.is_prune_epoch(epoch) {
            return Vec::new();
        }
        let sims: Vec<SimilarityMatrix> = self
            .packed
            .iter()
            .zip(&masks)
            .map(|(p, live)| p.similarity_matrix(live))
            .collect();
        let event = sched.evaluate(epoch, &sims);
        let mut plans: Vec<PrunePlan> = Vec::new();
        for (layer, filter) in event.pruned {
            match plans.iter_mut().find(|p| p.layer == layer) {
                Some(p) => p.filters.push(filter),
                None => plans.push(PrunePlan { tenant, layer, filters: vec![filter] }),
            }
        }
        for p in &mut plans {
            p.filters.sort_unstable();
        }
        plans.sort_by_key(|p| p.layer);
        plans.truncate(self.cfg.max_layers_per_pass);
        plans
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::PruneConfig;
    use crate::serve::model::MnistBundle;

    /// An MNIST bundle whose filters repeat two sign prototypes per
    /// layer — similarity 1.0 within each pair class, so the rule fires
    /// deterministically.
    fn clustered_model(channels: [usize; 3]) -> ModelBundle {
        let mut m = MnistBundle::synthetic(channels, 0.0, 7);
        for layer in &mut m.conv {
            let protos: Vec<Vec<bool>> = layer.bits[..2].to_vec();
            for (f, bits) in layer.bits.iter_mut().enumerate() {
                *bits = protos[f % 2].clone();
            }
        }
        m.into()
    }

    fn aggressive_rule() -> PruneConfig {
        PruneConfig { min_live_per_layer: 1, max_prune_rate: 1.0, ..Default::default() }
    }

    #[test]
    fn warmup_passes_propose_nothing() {
        let model = clustered_model([8, 8, 8]);
        let cfg = LivePruneConfig { rule: aggressive_rule(), ..Default::default() };
        let mut mon = LivePruneMonitor::new(cfg, &model);
        // default warmup_epochs = 2: pass 1 is warm-up
        assert!(mon.propose(0, &model).is_empty());
        assert_eq!(mon.passes(), 1);
        // pass 2 is the first prune epoch and the clusters are ripe
        assert!(!mon.propose(0, &model).is_empty());
    }

    #[test]
    fn plans_respect_the_per_pass_layer_cap_and_are_sorted() {
        let model = clustered_model([8, 8, 8]);
        let cfg = LivePruneConfig {
            max_layers_per_pass: 1,
            rule: aggressive_rule(),
            ..Default::default()
        };
        let mut mon = LivePruneMonitor::new(cfg, &model);
        mon.propose(0, &model);
        let plans = mon.propose(3, &model);
        assert_eq!(plans.len(), 1, "one layer per pass");
        let p = &plans[0];
        assert_eq!(p.tenant, 3);
        assert!(p.filters.windows(2).all(|w| w[0] < w[1]), "ascending: {:?}", p.filters);
        // every proposed filter is currently live
        assert!(p.filters.iter().all(|&f| model.live_mask(p.layer)[f]));
    }

    #[test]
    fn deferred_layers_are_reproposed_and_committed_masks_converge() {
        let mut model = clustered_model([8, 8, 8]);
        let cfg = LivePruneConfig {
            max_layers_per_pass: 1,
            rule: aggressive_rule(),
            ..Default::default()
        };
        let mut mon = LivePruneMonitor::new(cfg, &model);
        // drive passes, committing each plan into the model as the
        // engine's cutover would, until the rule runs dry
        let mut idle = 0;
        let mut committed = 0usize;
        while idle < 3 {
            let plans = mon.propose(0, &model);
            if plans.is_empty() {
                idle += 1;
                continue;
            }
            idle = 0;
            for p in &plans {
                for &f in &p.filters {
                    assert!(model.prune_filter(p.layer, f), "plans never repeat a filter");
                    committed += 1;
                }
            }
        }
        // two prototypes per 8-filter layer -> 6 dups pruned per layer
        assert_eq!(committed, 3 * 6);
        for l in 0..3 {
            assert_eq!(model.live_mask(l).iter().filter(|&&b| b).count(), 2);
        }
    }

    #[test]
    fn dissimilar_kernels_never_fire() {
        // random synthetic kernels sit far below the 0.75 threshold
        let model = ModelBundle::synthetic_mnist([8, 8, 8], 0.0, 21);
        let mut mon = LivePruneMonitor::new(
            LivePruneConfig { rule: aggressive_rule(), ..Default::default() },
            &model,
        );
        for _ in 0..6 {
            assert!(mon.propose(0, &model).is_empty());
        }
    }
}

//! Live in-situ pruning: the serving-side closure of the paper's
//! similarity-driven prune loop (Fig. 4b) — monitor → cutover →
//! headroom — run against tenants that are *serving traffic*, not
//! training.
//!
//! The training-side loop ([`crate::pruning`]) evaluates kernel
//! similarity between epochs and flips live-mask bits in a model that
//! nobody is querying. This module runs the same rule over the kernels
//! a tenant has **programmed on the fleet** and re-shards the pruned
//! layer mid-serve:
//!
//! 1. **Monitor** ([`LivePruneMonitor`]): on a batch-count cadence
//!    ([`LivePruneConfig::every_batches`]), pack each layer's sign bits
//!    once ([`crate::pruning::similarity::PackedKernels`] — the same
//!    XOR+popcount primitive the chip's search-in-memory implements),
//!    rebuild the pairwise similarity matrices of the *currently live*
//!    kernels, and feed them to a fresh
//!    [`crate::pruning::PruningScheduler`] seeded from the tenant
//!    model's live masks. Whatever the scheduler would prune becomes a
//!    [`PrunePlan`] per layer.
//! 2. **Cutover** ([`cutover::PruneCutover`]): an epoch-fenced state
//!    machine (plan → fence → drain → commit masks → free rows) that
//!    retires the pruned filters' shards from the serving placement
//!    without ever producing a wrong logit — the same
//!    fence-then-free protocol as cross-group migration (DESIGN.md §9,
//!    §12). Aborts leave the dense layer authoritative.
//! 3. **Headroom**: freed rows return to every member's
//!    [`crate::cim::mapping::RowAllocator`] free list via
//!    `Backend::release`, so the quota headroom and the engine report
//!    ([`PruneReport`]) show capacity gained, and the dense→pruned
//!    logit shift is measured on a live probe input and reported —
//!    never silent.
//!
//! Every transition is observable: `ObsEvent::Prune{Planned, Started,
//! Fenced, Committed, Aborted}` on the event bus, a
//! [`crate::serve::obs::Stage::Prune`] span per pass, and
//! `prune.*` metrics.

pub mod cutover;
pub mod monitor;

pub use cutover::{CutoverOutcome, PruneCommit, PruneCutover};
pub use monitor::LivePruneMonitor;

use crate::pruning::PruneConfig;

/// Engine-level knobs for the live prune loop. Disabled by default
/// (`every_batches: 0`) — enabling it changes *which model* a tenant
/// serves over time (the pruned one), which is an operator decision,
/// not a transparent optimization.
#[derive(Clone, Debug)]
pub struct LivePruneConfig {
    /// Run a monitor pass every N batches served fleet-wide
    /// (0 = live pruning off). Same cadence convention as
    /// [`crate::serve::engine::rebalance::RebalanceConfig`].
    pub every_batches: u64,
    /// At most this many layer cutovers per tenant per pass — each
    /// cutover costs a fence + full fleet drain, so passes are kept
    /// shallow and the loop converges over several passes instead.
    pub max_layers_per_pass: usize,
    /// The similarity rule itself (threshold, frequency, floors, global
    /// rate cap) — shared verbatim with the training-side scheduler.
    pub rule: PruneConfig,
}

impl Default for LivePruneConfig {
    fn default() -> Self {
        LivePruneConfig {
            every_batches: 0,
            max_layers_per_pass: 1,
            rule: PruneConfig::default(),
        }
    }
}

impl LivePruneConfig {
    /// Is a monitor pass due at this fleet batch count?
    pub fn due(&self, batches_served: u64) -> bool {
        self.every_batches > 0 && batches_served > 0 && batches_served % self.every_batches == 0
    }
}

/// One layer's worth of proposed prunes: the filters the similarity
/// rule retired, to be committed by a single epoch-fenced cutover.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PrunePlan {
    pub tenant: usize,
    pub layer: usize,
    /// Filter indices to retire, ascending, each currently live.
    pub filters: Vec<usize>,
}

/// Fleet-level outcome of the live prune loop, embedded in
/// [`crate::serve::EngineReport`].
#[derive(Clone, Debug, Default)]
pub struct PruneReport {
    /// Cutovers committed (one per layer per firing pass).
    pub cutovers: u64,
    /// Cutovers aborted pre-fence (stale plan, quarantined member, …).
    pub aborted: u64,
    /// Filters retired across all tenants.
    pub filters_pruned: u64,
    /// Rows returned to backend allocators (re-allocatable headroom).
    pub rows_freed: u64,
    /// Rows whose release failed (backend without release support) —
    /// retired but not reusable until the member restarts.
    pub rows_retired: u64,
    /// Per-tenant detail, indexed like `EngineReport::tenants`.
    pub per_tenant: Vec<TenantPruneStats>,
}

/// Per-tenant live-pruning outcome.
#[derive(Clone, Debug, Default)]
pub struct TenantPruneStats {
    /// Filters retired from this tenant while it served.
    pub filters_pruned: u64,
    /// Rows freed back to the allocators by this tenant's cutovers.
    pub rows_freed: u64,
    /// MAC ops per input at engine start (under the masks it started
    /// serving with) and at shutdown — the paper's op-reduction claim,
    /// measured on live traffic.
    pub mac_ops_start: u64,
    pub mac_ops_end: u64,
    /// Final fraction of this tenant's kernels pruned (export-time
    /// pruning included).
    pub prune_rate: f64,
    /// Largest |dense − pruned| logit shift observed on any cutover's
    /// probe input. 0.0 when no probe was available.
    pub max_logit_delta: f64,
    /// Row-quota headroom at shutdown: quota minus rows still used.
    pub quota_headroom_rows: u64,
    /// Final live masks, one per layer — what a caller needs to rebuild
    /// the pruned reference oracle after the fact.
    pub live_masks: Vec<Vec<bool>>,
}

impl TenantPruneStats {
    /// Fraction of per-input MAC ops removed while serving
    /// (0.0 when nothing was pruned or the model had no ops).
    pub fn mac_reduction(&self) -> f64 {
        if self.mac_ops_start == 0 {
            return 0.0;
        }
        1.0 - self.mac_ops_end as f64 / self.mac_ops_start as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cadence_follows_the_rebalance_convention() {
        let off = LivePruneConfig::default();
        assert!(!off.due(0));
        assert!(!off.due(100));
        let on = LivePruneConfig { every_batches: 4, ..Default::default() };
        assert!(!on.due(0), "batch 0 never fires");
        assert!(!on.due(3));
        assert!(on.due(4));
        assert!(!on.due(5));
        assert!(on.due(8));
    }

    #[test]
    fn mac_reduction_handles_degenerate_models() {
        let zero = TenantPruneStats::default();
        assert_eq!(zero.mac_reduction(), 0.0);
        let pruned = TenantPruneStats {
            mac_ops_start: 1000,
            mac_ops_end: 600,
            ..Default::default()
        };
        assert!((pruned.mac_reduction() - 0.4).abs() < 1e-12);
    }
}

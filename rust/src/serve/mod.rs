//! Batched, multi-chip inference serving: the subsystem that answers
//! "how many inferences/sec can a fleet of these chips sustain?".
//!
//! A trained (and pruned) model is exported as a [`ModelBundle`]
//! (binarized conv filters + digital scales + live masks + host-side FC),
//! sharded filter-by-filter across a [`pool::ChipPool`] by the
//! **wear-aware placer** ([`placement`]), and driven by a worker-per-chip
//! [`scheduler::Server`] fed from a coalescing admission queue
//! ([`batcher`]).
//!
//! # Architecture
//!
//! ```text
//!  submit() ──► bounded queue ──► Batcher (max_batch / max_wait)
//!                                   │ batch of requests
//!                                   ▼
//!                         coordinator thread
//!              quantize u8 → im2col → pack bit planes (shared)
//!                   │ Job(layer, Arc<PackedWindows>)
//!         ┌─────────┼─────────────┐
//!         ▼         ▼             ▼
//!     worker 0   worker 1  ...  worker N-1     (one thread per Chip;
//!     chip dots  chip dots      chip dots       weight-stationary shards)
//!         └─────────┴───────┬─────┘
//!                           ▼
//!              scale + bias + ReLU + pool → next layer → FC → reply
//! ```
//!
//! # Model & numeric contract
//!
//! * Each conv filter's sign bits live on RRAM rows of exactly one chip
//!   (weight-stationary). Activations are u8-quantized per image per
//!   layer and streamed bit-serially (8 planes) against the stored rows —
//!   the paper's XNOR/popcount binary convolution.
//! * Chip dots are integer-exact ([`crate::cim::vmm::binary_dots_batched`]),
//!   so pool-of-N serving output equals the software reference
//!   ([`ModelBundle::reference_logits`]) bit for bit, regardless of pool
//!   size, batch size, or thread interleaving.
//! * Batching amortizes the dominant WRC row-walk energy: the word line
//!   stays selected while a whole batch streams, which is where the
//!   nJ/inference win over unbatched serving comes from (Fig. 3e).
//!
//! # Knobs
//!
//! * [`PoolConfig`] — pool size, per-chip [`crate::chip::ChipConfig`], seed.
//! * [`BatcherConfig`] — `max_batch` (coalescing width), `max_wait`
//!   (latency bound for partially filled batches), `queue_depth`
//!   (admission bound: blocking `submit` gives lossless backpressure,
//!   `try_submit` surfaces it as an error instead).
//! * Placement prefers chips with the fewest lifetime
//!   [`crate::chip::WearLedger::write_pulses`] and routes around tiles
//!   whose stuck cells defeat the ECC spare budget.

pub mod batcher;
pub mod model;
pub mod placement;
pub mod pool;
pub mod scheduler;
pub mod stats;

pub use batcher::{BatcherConfig, Request, Response};
pub use model::{ConvLayer, ModelBundle};
pub use placement::{place, Placement, ShardLoc};
pub use pool::{ChipPool, PoolConfig};
pub use scheduler::{Server, ServerConfig};
pub use stats::{ServeReport, ServeStats};

//! Batched, multi-chip inference serving: the subsystem that answers
//! "how many inferences/sec can a fleet of these chips sustain?".
//!
//! A trained (and pruned) model is exported as a [`ModelBundle`] — the
//! two-path servable format — sharded filter-by-filter across a
//! [`pool::ChipPool`] by the **wear-aware placer** ([`placement`]), and
//! driven by a worker-per-chip [`scheduler::Server`] fed from a
//! coalescing admission queue ([`batcher`]).
//!
//! # The two model paths
//!
//! | | [`MnistBundle`] (binary) | [`PointNetBundle`] (INT8) |
//! |---|---|---|
//! | workload | 28x28 grayscale images | raw xyz point clouds |
//! | weight encoding | 1 cell per weight (sign bit) | 4 x 2-bit cells per weight (offset-encoded) |
//! | activations | u8 per image per layer | i8 per cloud per layer |
//! | batched VMM | [`crate::cim::vmm::binary_dots_batched`] | [`crate::cim::vmm::int8_dots_batched`] |
//! | host stages | scale/bias/ReLU, 2x2 max-pool, FC | scale/bias/ReLU, set-abstraction pool/concat, dense head |
//! | exported by | `MnistTrainer::export_bundle` | `PointNetTrainer::export_bundle` |
//!
//! Both variants flow through the same placement, admission, fan-out,
//! and stats machinery; a [`ModelBundle`] value is all a caller needs
//! (`Server::start(bundle, &cfg)`). Construct one via
//! [`ModelBundle::synthetic_mnist`] / [`PointNetBundle::synthetic`] for
//! benches, or the trainers' `export_bundle` for trained checkpoints.
//!
//! # Architecture
//!
//! ```text
//!  submit() ──► bounded queue ──► Batcher (max_batch / max_wait)
//!                                   │ batch of requests
//!                                   ▼
//!                         coordinator thread
//!        quantize → window (im2col / grouped points) → pack planes
//!                   │ Job(layer, Arc<packed windows>)
//!         ┌─────────┼─────────────┐
//!         ▼         ▼             ▼
//!     worker 0   worker 1  ...  worker N-1     (one thread per Chip;
//!     chip dots  chip dots      chip dots       weight-stationary shards)
//!         └─────────┴───────┬─────┘
//!                           ▼
//!            scale + bias + ReLU + pool/concat → next layer → head → reply
//! ```
//!
//! # Numeric contract
//!
//! * Each filter's cells live on RRAM rows of exactly one chip
//!   (weight-stationary). Activations are quantized per request per
//!   layer and streamed bit-serially (8 planes) against the stored rows.
//! * Chip dots are integer-exact, so pool-of-N serving output equals the
//!   software reference ([`ModelBundle::reference_logits`]) bit for bit,
//!   regardless of pool size, batch size, or thread interleaving — for
//!   both paths (property-tested in `tests/integration_stack.rs`).
//! * Batching amortizes the dominant WRC row-walk energy: the word line
//!   stays selected while a whole batch streams, which is where the
//!   nJ/inference win over unbatched serving comes from (Fig. 3e). The
//!   INT8 path additionally pays one 2-bit sense burst per row segment
//!   and is charged per offset-encoded bit plane
//!   ([`crate::chip::Chip::account_batched_passes`]).
//!
//! # Knobs
//!
//! * [`PoolConfig`] — pool size, per-chip [`crate::chip::ChipConfig`], seed.
//! * [`BatcherConfig`] — `max_batch` (coalescing width), `max_wait`
//!   (latency bound for partially filled batches), `queue_depth`
//!   (admission bound: blocking `submit` gives lossless backpressure,
//!   `try_submit` sheds on a full queue and the shed is counted in
//!   [`ServeStats::dropped`]).
//! * Placement prefers chips with the fewest lifetime
//!   [`crate::chip::WearLedger::write_pulses`] and routes around tiles
//!   whose stuck cells defeat the ECC spare budget.
//!
//! # Multi-tenancy
//!
//! The diagram above is the single-model [`Server`]. The multi-tenant
//! front end is [`engine::Engine`]: one pool registers N named
//! [`ModelBundle`]s concurrently (per-tenant chip-row quotas,
//! [`TenantConfig`]), admission is an event loop of per-tenant bounded
//! queues drained deficit-round-robin ([`engine::admission`]), repeated
//! inputs replay from a bit-exact result cache ([`engine::cache`]), and
//! placement adapts to live wear deltas — every K batches the hottest
//! shards migrate to the least-worn chip with the pool drained, so
//! logits stay bit-exact mid-migration ([`engine::rebalance`]). Both
//! front ends share one batch executor and numeric contract; see the
//! [`engine`] docs for the comparison table.
//!
//! # Multi-host transport
//!
//! Every chip interaction flows through the public [`transport`] seam:
//! a [`transport::Backend`] speaks owned, wire-serializable
//! request/reply types, so "the pool" may equally be a
//! [`transport::LocalBackend`] in this process, a
//! [`transport::RemoteBackend`] talking length-prefixed frames to a
//! [`transport::Host`] daemon over TCP, or a [`transport::ShardRouter`]
//! fleet — one tenant's layers split across several hosts, replica
//! groups with request hedging for tail latency, and spillover off
//! full queues. Because the chips are fully digital, every replica's
//! reply is bit-identical, which is what makes hedging and multi-host
//! scaling drift-free (DESIGN.md §8). See `tests/transport_remote.rs`
//! for the bit-exactness harness over every backend combination and
//! `examples/multi_host.rs` for a two-host hedged deployment.
//!
//! # Observability
//!
//! The fleet's sensory system is [`obs`]: per-request trace spans whose
//! [`obs::TraceContext`] rides the dispatch frames (multi-host traces
//! stitch into one tree), an operator [`obs::EventBus`] publishing
//! control-plane transitions (migrations, quarantines, rebalances,
//! sheds — subscribe via [`engine::Engine::events`]), and a typed
//! [`obs::MetricsRegistry`] with a JSON snapshot exporter (DESIGN.md
//! §10, OPERATIONS.md "Telemetry"). Serve-side code never prints:
//! operator output flows through the [`log`] facade or the event bus
//! (enforced by the `clippy::disallowed_macros` deny below, configured
//! in `clippy.toml`).

#![deny(clippy::disallowed_macros)]
// Serve code acquires locks only through `util::sync::lock_unpoisoned`
// and the Condvar wrappers — the documented poisoning policy — never
// the raw panicking std methods (see clippy.toml `disallowed-methods`).
#![deny(clippy::disallowed_methods)]

pub mod batcher;
pub mod engine;
pub mod model;
pub mod obs;
pub mod placement;
pub mod pointnet_model;
pub mod pool;
pub mod prune;
pub mod scheduler;
pub mod stats;
pub mod transport;

pub use batcher::{BatcherConfig, Request, Response};
pub use engine::admission::AdmissionConfig;
pub use engine::cache::{CacheConfig, RequestKey, ResultCache};
pub use engine::cam::{CamConfig, CamReport, TenantCamStats, VerifyPolicy};
pub use engine::rebalance::RebalanceConfig;
pub use engine::tenant::{TenantConfig, TenantId};
pub use engine::{Engine, EngineConfig};
pub use model::{ConvLayer, MnistBundle, ModelBundle, PlacementLayer, ShardPayload};
pub use obs::{
    EventBus, EventRecord, EventSubscriber, MetricsRegistry, Obs, ObsEvent, TraceContext, TraceLog,
};
pub use placement::{place, place_with, Placement, ShardLoc};
pub use pointnet_model::{max_over_groups, PointNetBundle, PointwiseLayer, POINTWISE_LAYERS};
pub use pool::{ChipPool, PoolConfig, WearSnapshot};
pub use prune::{
    CutoverOutcome, LivePruneConfig, LivePruneMonitor, PruneCommit, PruneCutover, PrunePlan,
    PruneReport, TenantPruneStats,
};
pub use scheduler::{Server, ServerConfig};
pub use stats::{EngineReport, LatencyHistogram, ServeReport, ServeStats, TenantStats};
pub use transport::{
    Backend, HedgeConfig, Host, HostConfig, LocalBackend, MemberState, MigrationOutcome,
    PipelineConfig, ReconnectPolicy, RemoteBackend, RouterConfig, RouterStats, ShardRouter,
    TransportError,
};

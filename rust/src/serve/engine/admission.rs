//! The admission plane: per-tenant bounded queues drained by a
//! deficit-round-robin scheduler into per-tenant coalesced batches.
//!
//! Replaces the single blocking `sync_channel` front end: submission is
//! non-blocking by default ([`Admission::try_submit`] sheds on a full
//! *per-tenant* queue, counted against that tenant only), and no tenant
//! can starve another — the drain side visits tenants round-robin,
//! crediting each visited non-empty queue `quantum` requests of deficit
//! and serving at most `min(deficit, max_batch)` per turn. A bursty
//! tenant that floods its own queue therefore costs itself drops while
//! the other tenants keep their full turn share (property-tested in
//! `tests/integration_stack.rs`).
//!
//! Batches never mix tenants (each tenant's model is its own chip
//! pipeline), and requests leave in admission order per tenant — FIFO
//! is preserved across coalescing rounds exactly as in the legacy
//! batcher.
//!
//! This plane is the only place a request may be *dropped*: once a
//! batch leaves here, the transport layer below
//! ([`crate::serve::transport`]) spills a dispatch off a full backend
//! queue to its replica and hedges stragglers, but never sheds — so
//! `answered + dropped` partitions every tenant's attempts no matter
//! how many hosts or replicas serve it. (The legacy single-model
//! server's replica-set analogue is
//! [`crate::serve::Server::try_submit_spill`], which counts a request
//! every replica rejected exactly once.)

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::serve::batcher::Request;
use crate::serve::obs::{Obs, ObsEvent};
use crate::util::sync::{lock_unpoisoned, wait_timeout_unpoisoned, wait_unpoisoned};

/// Admission/drain knobs.
#[derive(Clone, Debug)]
pub struct AdmissionConfig {
    /// Maximum requests coalesced into one (single-tenant) batch.
    pub max_batch: usize,
    /// Maximum time a batch waits for more of its tenant's requests
    /// after its first one.
    pub max_wait: Duration,
    /// Deficit-round-robin quantum: requests of credit a non-empty
    /// tenant queue earns per drain visit. With `quantum == max_batch`
    /// this degenerates to plain round-robin over full batches.
    pub quantum: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            quantum: 32,
        }
    }
}

struct TenantQueue {
    q: VecDeque<Request>,
    depth: usize,
    deficit: usize,
    dropped: u64,
}

struct Shared {
    queues: Vec<TenantQueue>,
    /// Round-robin cursor: the tenant the next drain visit starts at.
    next_rr: usize,
    closed: bool,
    /// The observability plane sheds are reported to (disabled until
    /// [`Admission::attach_obs`]). Lives in the shared state so every
    /// clone of the handle reports to the same bus.
    obs: Arc<Obs>,
}

/// The admission plane handle. Cloneable: submitters and the draining
/// coordinator share one state.
#[derive(Clone)]
pub struct Admission {
    inner: Arc<(Mutex<Shared>, Condvar)>,
    cfg: AdmissionConfig,
}

impl Admission {
    /// One bounded queue per tenant, depth per `depths`.
    pub fn new(cfg: AdmissionConfig, depths: &[usize]) -> Admission {
        assert!(cfg.max_batch > 0 && cfg.quantum > 0);
        assert!(depths.iter().all(|&d| d > 0), "queue depths must be positive");
        let queues = depths
            .iter()
            .map(|&depth| TenantQueue { q: VecDeque::new(), depth, deficit: 0, dropped: 0 })
            .collect();
        let shared = Shared {
            queues,
            next_rr: 0,
            closed: false,
            obs: Arc::new(Obs::disabled()),
        };
        Admission { inner: Arc::new((Mutex::new(shared), Condvar::new())), cfg }
    }

    /// Attach the engine's observability plane: from here on every
    /// counted shed also emits [`ObsEvent::DropShed`] — the event and
    /// the `dropped` counter move in lockstep, exactly once per shed.
    pub fn attach_obs(&self, obs: Arc<Obs>) {
        lock_unpoisoned(&self.inner.0).obs = obs;
    }

    /// Blocking submit: waits while the tenant's queue is full (lossless
    /// per-tenant backpressure). Panics if the engine already shut down.
    // lint: allow(panic-freedom) — tenant slot comes from the registry lookup above; queue vectors are sized to the tenant count at construction
    pub fn submit(&self, tenant: usize, req: Request) {
        let (lock, cv) = &*self.inner;
        let mut s = lock_unpoisoned(lock);
        loop {
            assert!(!s.closed, "engine already shut down");
            if s.queues[tenant].q.len() < s.queues[tenant].depth {
                break;
            }
            s = wait_unpoisoned(cv, s);
        }
        s.queues[tenant].q.push_back(req);
        cv.notify_all();
    }

    /// Non-blocking submit: on a full tenant queue the request is handed
    /// back and counted in that tenant's `dropped` — never admitted, so
    /// never also answered. A closed plane hands the request back
    /// without counting (the caller is racing shutdown, not load).
    // lint: allow(panic-freedom) — tenant slot comes from the registry lookup above; queue vectors are sized to the tenant count at construction
    pub fn try_submit(&self, tenant: usize, req: Request) -> Result<(), Request> {
        let (lock, cv) = &*self.inner;
        let mut s = lock_unpoisoned(lock);
        if s.closed {
            return Err(req);
        }
        if s.queues[tenant].q.len() >= s.queues[tenant].depth {
            s.queues[tenant].dropped += 1;
            s.obs.bus.emit(ObsEvent::DropShed { tenant });
            return Err(req);
        }
        s.queues[tenant].q.push_back(req);
        cv.notify_all();
        Ok(())
    }

    /// Stop admitting. Queued requests still drain; `next_batch` returns
    /// `None` once every queue is empty.
    pub fn close(&self) {
        let (lock, cv) = &*self.inner;
        lock_unpoisoned(lock).closed = true;
        cv.notify_all();
    }

    /// Requests a tenant shed so far.
    // lint: allow(panic-freedom) — per-tenant stats vector is sized to the tenant count at construction
    pub fn dropped(&self, tenant: usize) -> u64 {
        lock_unpoisoned(&self.inner.0).queues[tenant].dropped
    }

    /// Queued (admitted, not yet drained) requests of one tenant.
    // lint: allow(panic-freedom) — per-tenant stats vector is sized to the tenant count at construction
    pub fn queued(&self, tenant: usize) -> usize {
        lock_unpoisoned(&self.inner.0).queues[tenant].q.len()
    }

    /// DRR visit: pick the next non-empty tenant queue (round-robin from
    /// the cursor) and credit it a quantum. Returns `None` when all
    /// queues are empty.
    // lint: allow(panic-freedom) — deficit-round-robin cursor is reduced modulo the queue count before indexing
    fn pick(s: &mut Shared, quantum: usize) -> Option<usize> {
        let n = s.queues.len();
        for i in 0..n {
            let t = (s.next_rr + i) % n;
            if !s.queues[t].q.is_empty() {
                s.queues[t].deficit += quantum;
                s.next_rr = (t + 1) % n;
                return Some(t);
            }
        }
        None
    }

    /// Block for the next coalesced single-tenant batch `(tenant,
    /// requests)`. A batch closes at `min(deficit, max_batch)` requests
    /// or when `max_wait` elapses after its first one. Returns `None`
    /// once the plane is closed and every queue has drained — the
    /// coordinator's shutdown signal.
    // lint: allow(panic-freedom) — queue indices come from pick(), which stays within the queue vector
    pub fn next_batch(&self) -> Option<(usize, Vec<Request>)> {
        let (lock, cv) = &*self.inner;
        let mut s = lock_unpoisoned(lock);
        loop {
            if let Some(t) = Self::pick(&mut s, self.cfg.quantum) {
                let limit = s.queues[t].deficit.min(self.cfg.max_batch).max(1);
                let mut batch: Vec<Request> = Vec::with_capacity(limit);
                let deadline = Instant::now() + self.cfg.max_wait;
                loop {
                    while batch.len() < limit {
                        match s.queues[t].q.pop_front() {
                            Some(r) => batch.push(r),
                            None => break,
                        }
                    }
                    if batch.len() >= limit || s.closed {
                        break;
                    }
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    let (guard, timeout) = wait_timeout_unpoisoned(cv, s, deadline - now);
                    s = guard;
                    if timeout.timed_out() {
                        // drain whatever arrived with the timeout race
                        while batch.len() < limit {
                            match s.queues[t].q.pop_front() {
                                Some(r) => batch.push(r),
                                None => break,
                            }
                        }
                        break;
                    }
                }
                debug_assert!(!batch.is_empty(), "picked tenant had a request");
                let q = &mut s.queues[t];
                q.deficit = q.deficit.saturating_sub(batch.len());
                if q.q.is_empty() {
                    q.deficit = 0; // classic DRR: empty queues keep no credit
                }
                cv.notify_all(); // space freed: wake blocked submitters
                return Some((t, batch));
            }
            if s.closed {
                return None;
            }
            s = wait_unpoisoned(cv, s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::{sync_channel, Receiver};
    use crate::serve::batcher::Response;

    fn request(id: u64) -> (Request, Receiver<Response>) {
        let (reply, rx) = sync_channel(1);
        (Request { id, input: vec![0.0; 4], submitted: Instant::now(), reply }, rx)
    }

    fn cfg(max_batch: usize, quantum: usize) -> AdmissionConfig {
        AdmissionConfig { max_batch, max_wait: Duration::from_millis(5), quantum }
    }

    #[test]
    fn try_submit_sheds_on_full_tenant_queue_only() {
        let adm = Admission::new(cfg(4, 4), &[2, 2]);
        for i in 0..2 {
            assert!(adm.try_submit(0, request(i).0).is_ok());
        }
        // tenant 0 is full: its burst sheds and is counted against it
        let (r, _rx) = request(2);
        let back = adm.try_submit(0, r).unwrap_err();
        assert_eq!(back.id, 2, "request handed back intact");
        assert_eq!(adm.dropped(0), 1);
        // tenant 1 is unaffected
        assert!(adm.try_submit(1, request(3).0).is_ok());
        assert_eq!(adm.dropped(1), 0);
        assert_eq!(adm.queued(0), 2);
        assert_eq!(adm.queued(1), 1);
    }

    #[test]
    fn drain_is_round_robin_and_fifo_per_tenant() {
        let adm = Admission::new(cfg(2, 2), &[16, 16]);
        // tenant 0 floods before tenant 1 submits anything
        for i in 0..6 {
            assert!(adm.try_submit(0, request(i).0).is_ok());
        }
        for i in 6..8 {
            assert!(adm.try_submit(1, request(i).0).is_ok());
        }
        adm.close();
        let mut turns = Vec::new();
        let mut per_tenant: [Vec<u64>; 2] = [Vec::new(), Vec::new()];
        while let Some((t, batch)) = adm.next_batch() {
            turns.push(t);
            per_tenant[t].extend(batch.iter().map(|r| r.id));
        }
        // the flood does not monopolize the drain: tenant 1 is visited
        // on the second turn despite tenant 0's backlog
        assert_eq!(turns, vec![0, 1, 0, 0], "round-robin over non-empty queues");
        assert_eq!(per_tenant[0], vec![0, 1, 2, 3, 4, 5], "FIFO per tenant");
        assert_eq!(per_tenant[1], vec![6, 7]);
    }

    #[test]
    fn deficit_carries_over_when_quantum_undersizes_batches() {
        // quantum 1 but max_batch 4: each visit earns 1 credit, so
        // batches stay at 1 while the other tenant has work (fairness
        // beats coalescing), and FIFO still holds
        let adm = Admission::new(cfg(4, 1), &[8, 8]);
        for i in 0..3 {
            assert!(adm.try_submit(0, request(i).0).is_ok());
        }
        assert!(adm.try_submit(1, request(10).0).is_ok());
        adm.close();
        let mut sizes = Vec::new();
        while let Some((_, batch)) = adm.next_batch() {
            sizes.push(batch.len());
        }
        assert_eq!(sizes, vec![1, 1, 1, 1]);
    }

    #[test]
    fn close_drains_then_ends() {
        let adm = Admission::new(cfg(8, 8), &[4]);
        assert!(adm.try_submit(0, request(0).0).is_ok());
        adm.close();
        // closed plane sheds without counting
        assert!(adm.try_submit(0, request(1).0).is_err());
        assert_eq!(adm.dropped(0), 0);
        let (t, batch) = adm.next_batch().expect("queued request drains after close");
        assert_eq!((t, batch.len()), (0, 1));
        assert!(adm.next_batch().is_none(), "drained + closed ends the stream");
    }

    #[test]
    fn blocking_submit_waits_for_space() {
        let adm = Admission::new(cfg(1, 1), &[1]);
        assert!(adm.try_submit(0, request(0).0).is_ok());
        let adm2 = adm.clone();
        let submitter = std::thread::spawn(move || {
            let (r, _rx) = request(1);
            adm2.submit(0, r); // full: blocks until the drain frees space
        });
        std::thread::sleep(Duration::from_millis(10));
        let (_, batch) = adm.next_batch().unwrap();
        assert_eq!(batch[0].id, 0);
        submitter.join().unwrap();
        assert_eq!(adm.queued(0), 1, "blocked submitter landed after the drain");
    }
}

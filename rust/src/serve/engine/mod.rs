//! The multi-tenant async serving engine: one chip fleet, N named
//! models, an event-loop admission plane, a bit-exact result cache, and
//! live wear rebalancing — with every chip interaction behind the
//! public transport seam ([`crate::serve::transport`]), so the fleet
//! may be a local pool, a TCP-loopback host daemon, several hosts with
//! a tenant's layers split across them, or hedged replica groups.
//!
//! This subsystem replaces the single-bundle blocking front end for
//! multi-workload deployments — the paper's "one reconfigurable fabric,
//! many workloads" claim made operational. One [`Engine`] serves the
//! binary MNIST path and the INT8 PointNet path *concurrently from the
//! same arrays*:
//!
//! ```text
//!  try_submit(tenant, input)      try_submit(tenant, input)
//!        │ per-tenant bounded queues (shed on full, counted per tenant)
//!        ▼
//!  [admission] deficit-round-robin drain → single-tenant coalesced batch
//!        │
//!        ▼
//!  [cache]  content-keyed logits replay (bit-exact, per tenant)
//!        │ misses only
//!        ▼
//!  [cam]    similarity front end (off by default): probe the packed
//!        │   request key against a bounded CAM of recent answers via
//!        │   XOR+popcount; exact hits replay after a byte verify, near
//!        │   hits recompute-and-compare under VerifyPolicy::Exact
//!        │   (see `cam` — exactness never depends on the CAM)
//!        ▼
//!  [exec]   per layer: split the batch into ≤ depth micro-batches,
//!        │   quantize → pack planes → submit_layer, collecting FIFO so
//!        │   packing overlaps the chips' dots (DESIGN.md §11;
//!        │   PipelineConfig — depth 1 is the old serial lockstep)
//!        │                   (ShardRouter: group split, replica choice,
//!        ▼                    hedging, spillover — Backend::dispatch)
//!  [rebalance] every K batches: diff WearLedger snapshots over the
//!              transport, migrate the hottest shards to the least-worn
//!              chip of their backend (drained fleet, epoch bump, so
//!              logits stay bit-exact mid-migration), and — under
//!              capacity pressure — migrate whole layers BETWEEN groups
//!              through the epoch-fenced program→fence→drain→free
//!              cutover (DESIGN.md §9); invalidate caches
//!  [heal]      after any member dispatch failure: probe the fleet,
//!              re-program a bounced host's shards at the current
//!              epoch, rejoin it to its replica group, retry the batch
//!  [prune]     every K batches (off by default): re-run the paper's
//!              similarity rule over each tenant's *programmed* kernels
//!              and retire redundant filters through the same
//!              epoch-fenced cutover (DESIGN.md §12) — the live masks
//!              flip before the route does, so every answer stays
//!              bit-exact against the now-pruned oracle; freed rows
//!              return to the allocators as headroom
//! ```
//!
//! # Differences from the legacy [`crate::serve::Server`]
//!
//! | | `Server` | `Engine` |
//! |---|---|---|
//! | models per pool | 1 | N, each with a row quota |
//! | admission | one blocking `sync_channel` | per-tenant bounded queues, DRR drain |
//! | backends | one local pool | any [`crate::serve::transport::Backend`] fleet |
//! | placement | fixed at start | migrates on live wear deltas |
//! | repeated inputs | recomputed | replayed from the bit-exact cache |
//!
//! Both front ends share the batch executor (the crate-private `exec`
//! submodule) and therefore the numeric contract: every answer equals
//! the tenant model's
//! [`crate::serve::ModelBundle::reference_logits`] bit for bit — cache
//! hit or miss, before or after any number of migrations, local or
//! remote, hedged or not, under stuck tile fault injection
//! (property-tested in `tests/integration_stack.rs`,
//! `tests/transport_remote.rs`, and — at every pipeline depth —
//! `tests/pipeline.rs`).

pub mod admission;
pub mod cache;
pub mod cam;
pub(crate) mod exec;
pub mod rebalance;
pub mod tenant;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::chip::WearLedger;
use crate::util::sync::lock_unpoisoned;

use super::batcher::{Request, Response};
use super::obs::{stage, Counter, EventSubscriber, Histogram, Obs, ObsEvent, SpanRecord, Stage};
use super::model::ModelBundle;
use super::prune::{CutoverOutcome, LivePruneConfig, LivePruneMonitor, PruneCutover, PruneReport};
use super::stats::{EngineReport, TenantStats};
use super::transport::router::PlaceOutcome;
use super::transport::{
    LocalBackend, MemberState, MigrationOutcome, OwnedPayload, PlacedLayer, RouterPlacement,
    ShardRef, ShardRouter, TenantRoute,
};

use admission::{Admission, AdmissionConfig};
use cache::{CacheConfig, RequestKey, ResultCache};
use cam::{CamConfig, CamFrontEnd, CamOutcome, CamReport};
use exec::run_batch;
use rebalance::{plan_group_move, plan_moves, RebalanceConfig, Rebalancer, ShardHeat};
use tenant::{TenantConfig, TenantId};

/// Transport-failure retries per batch: each attempt is preceded by a
/// fleet heal (probe, re-program bounced members, rejoin), so this
/// bounds how long the coordinator chases an unreachable fleet before
/// crashing — admitted requests must never be silently mis-answered.
const MAX_BATCH_ATTEMPTS: u32 = 5;

/// Engine construction knobs. The defaults serve: 4-chip pool, 32-deep
/// coalescing with DRR fairness, a 1024-entry cache per tenant, and
/// rebalancing off (enable via [`RebalanceConfig::every_batches`]).
/// `pool` describes the local backend [`Engine::start`] builds; it is
/// ignored by [`Engine::start_with_router`], where the fleet is handed
/// in ready-made.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub pool: super::pool::PoolConfig,
    pub admission: AdmissionConfig,
    pub cache: CacheConfig,
    pub rebalance: RebalanceConfig,
    /// Live in-situ pruning (default off): every
    /// [`LivePruneConfig::every_batches`] batches, re-run the paper's
    /// similarity rule over each prunable tenant's programmed kernels
    /// and retire redundant filters through an epoch-fenced cutover
    /// ([`crate::serve::prune`]).
    pub prune: LivePruneConfig,
    /// The CAM similarity front end (default off, capacity 0): probe
    /// each cache-missed request against a bounded per-tenant store of
    /// recently answered inputs by XOR+popcount distance over the
    /// canonical packed request key, replaying exact hits and
    /// verify-recomputing near hits ([`cam`]). Per-tenant opt-out and
    /// the trusted near-serve policy live on [`TenantConfig::cam`].
    pub cam: CamConfig,
    /// Observability plane switch (default on): request tracing, the
    /// operator event bus, and the metrics registry. Off hands the
    /// engine a [`Obs::disabled`] plane — every emit/record is a no-op
    /// branch, which is what the overhead benchmark compares against.
    pub obs: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            pool: Default::default(),
            admission: Default::default(),
            cache: Default::default(),
            rebalance: Default::default(),
            prune: Default::default(),
            cam: Default::default(),
            obs: true,
        }
    }
}

/// Cached `cam.*` counter handles — like `queue_wait`, one registry
/// lookup each at startup instead of one per batch.
struct CamMetrics {
    hits: Counter,
    near_hits: Counter,
    verify_pass: Counter,
    verify_fail: Counter,
    trusted_served: Counter,
    fallbacks: Counter,
    flushes: Counter,
}

impl CamMetrics {
    fn new(obs: &Obs) -> CamMetrics {
        CamMetrics {
            hits: obs.metrics.counter("cam.hits"),
            near_hits: obs.metrics.counter("cam.near_hits"),
            verify_pass: obs.metrics.counter("cam.verify_pass"),
            verify_fail: obs.metrics.counter("cam.verify_fail"),
            trusted_served: obs.metrics.counter("cam.trusted_served"),
            fallbacks: obs.metrics.counter("cam.fallbacks"),
            flushes: obs.metrics.counter("cam.flushes"),
        }
    }
}

/// The single thread that owns all serving state: placements, routes,
/// caches, heat counters, and the router driving the backend fleet.
/// Its single-threadedness is the drain-before-migrate invariant — a
/// rebalance can only run at a batch boundary, when no dispatch is in
/// flight anywhere (a lost hedge duplicate may still be draining, but
/// its reply is discarded by request id, never folded).
struct Coordinator {
    admission: Admission,
    models: Vec<ModelBundle>,
    quotas: Vec<Option<usize>>,
    placements: Vec<RouterPlacement>,
    /// Per-tenant routing view of the placement; rebuilt (epoch bumped)
    /// whenever a migration lands.
    routes: Vec<TenantRoute>,
    /// Per-shard dispatch heat `heat[tenant][layer][filter]` (windows
    /// computed), the rebalancer's shard-ranking signal.
    heat: Vec<ShardHeat>,
    caches: Vec<Arc<Mutex<ResultCache>>>,
    /// One CAM similarity front end per tenant (`None`: engine config
    /// disabled it, or the tenant opted out). Coordinator-owned, no
    /// lock: every probe, insert, and flush is ordered by the same
    /// single thread that orders migrations against batches.
    cams: Vec<Option<CamFrontEnd>>,
    cam_metrics: CamMetrics,
    stats: Vec<TenantStats>,
    router: ShardRouter,
    data_cols: usize,
    /// The shared observability plane (also attached to the router).
    obs: Arc<Obs>,
    /// Cached `stage.queue_wait` histogram handle (one registry lookup
    /// at startup, not one per batch).
    queue_wait: Histogram,
    rebalancer: Rebalancer,
    force_rebalance: Arc<AtomicBool>,
    /// Batches that reached the chips (cache-only batches excluded).
    chip_batches_total: u64,
    /// Last batch count a periodic pass ran at (so a quiet fleet does
    /// not re-run the pass every drained batch).
    last_pass_at: u64,
    stuck_retries: usize,
    /// The live prune loop's cadence + rule (see [`super::prune`]).
    prune_cfg: LivePruneConfig,
    /// One similarity monitor per prunable tenant (`None` when the
    /// tenant opted out or the loop is off).
    monitors: Vec<Option<LivePruneMonitor>>,
    /// Last batch count a prune pass ran at (same quiet-fleet guard as
    /// `last_pass_at`).
    last_prune_at: u64,
    /// Most recent input served per tenant — the probe a cutover uses
    /// to measure the dense→pruned answer shift.
    probes: Vec<Option<Vec<f32>>>,
    /// Prune outcome accounting, reported in [`EngineReport::prune`].
    prune: PruneReport,
}

impl Coordinator {
    fn run(mut self) -> EngineReport {
        let t_start = Instant::now();
        while let Some((t, batch)) = self.admission.next_batch() {
            if self.router.has_suspects() {
                // a member dispatch failed last batch: probe the fleet
                // and re-program any bounced member before serving on
                self.heal();
            }
            let force = self.force_rebalance.swap(false, Ordering::SeqCst);
            if force
                || (self.rebalancer.due(self.chip_batches_total)
                    && self.chip_batches_total != self.last_pass_at)
            {
                self.last_pass_at = self.chip_batches_total;
                self.rebalance_pass(force);
            }
            if self.prune_cfg.due(self.chip_batches_total)
                && self.chip_batches_total != self.last_prune_at
            {
                self.last_prune_at = self.chip_batches_total;
                self.prune_pass();
            }
            self.serve_batch(t, batch);
        }
        self.finish(t_start)
    }

    // lint: allow(panic-freedom) — shard, layer, and tenant indices all come from the placement table built at registration and re-validated on every re-shard
    fn serve_batch(&mut self, t: usize, batch: Vec<Request>) {
        let b = batch.len();
        if self.monitors[t].is_some() {
            // keep a recent real input around as the prune probe
            if let Some(req) = batch.first() {
                self.probes[t] = Some(req.input.clone());
            }
        }
        // batch-level trace root: every span of this batch (queue wait,
        // cache pass, per-layer dispatches, hedges, remote executes)
        // chains off this context — the null context when obs is off
        let trace = self.router.begin_trace();
        // queue wait = the oldest request's admission-to-drain time (the
        // batch cannot leave earlier than its first request arrived)
        let queued = batch
            .iter()
            .map(|r| r.submitted.elapsed())
            .max()
            .unwrap_or_default();
        self.queue_wait.record(queued);
        if trace.is_traced() {
            self.obs.trace.record(SpanRecord {
                ctx: trace.child(self.obs.trace.next_span()),
                stage: Stage::Queue,
                note: format!("tenant={t} batch={b}"),
                start: Instant::now() - queued,
                dur: queued,
            });
        }
        // cache pass: resolve exact-replay hits, remember the canonical
        // keys of misses. One quantize-then-pack per request — the same
        // RequestKey feeds the result-cache lookup (exact bytes) and
        // the CAM probe (packed words), so the two stores can never
        // disagree about what "the same input" means.
        let t_cache = Instant::now();
        let mut results: Vec<Option<Vec<f32>>> = vec![None; b];
        let mut keys: Vec<Option<RequestKey>> = vec![None; b];
        {
            let mut cache = lock_unpoisoned(&self.caches[t]);
            if cache.enabled() || self.cams[t].is_some() {
                for (i, req) in batch.iter().enumerate() {
                    let key = RequestKey::for_input(&self.models[t], &req.input);
                    if cache.enabled() {
                        results[i] = cache.lookup(&key.exact);
                    }
                    keys[i] = Some(key);
                }
            }
        }
        let cache_misses = (0..b).filter(|&i| results[i].is_none()).count();
        let hits = (b - cache_misses) as u64;
        if trace.is_traced() {
            self.obs.trace.record(SpanRecord {
                ctx: trace.child(self.obs.trace.next_span()),
                stage: Stage::Cache,
                note: format!("hits={hits} misses={cache_misses}"),
                start: t_cache,
                dur: t_cache.elapsed(),
            });
        }
        // CAM probe pass over the remaining misses: byte-verified exact
        // hits and trusted near serves resolve here; near hits under
        // VerifyPolicy::Exact join the compute batch (verify_slots) and
        // are compared against the recompute afterwards
        let cam_before = self.cams[t].as_ref().map(|c| c.stats.clone());
        let mut verify_slots: Vec<Option<usize>> = vec![None; b];
        if let Some(cam) = self.cams[t].as_mut() {
            let t_cam = Instant::now();
            for i in 0..b {
                if results[i].is_some() {
                    continue;
                }
                let Some(key) = keys[i].as_ref() else { continue };
                match cam.probe(key) {
                    CamOutcome::Hit(logits) | CamOutcome::Trusted(logits) => {
                        results[i] = Some(logits);
                    }
                    CamOutcome::NearVerify(slot) => verify_slots[i] = Some(slot),
                    CamOutcome::Miss => {}
                }
            }
            if trace.is_traced() {
                let (s, z) = (&cam.stats, cam_before.clone().unwrap_or_default());
                self.obs.trace.record(SpanRecord {
                    ctx: trace.child(self.obs.trace.next_span()),
                    stage: Stage::Cam,
                    note: format!(
                        "hits={} near={} fallbacks={}",
                        s.hits - z.hits,
                        s.near_hits - z.near_hits,
                        s.fallbacks - z.fallbacks
                    ),
                    start: t_cam,
                    dur: t_cam.elapsed(),
                });
            }
        }
        let miss_idx: Vec<usize> = (0..b).filter(|&i| results[i].is_none()).collect();
        if !miss_idx.is_empty() {
            let inputs: Vec<&[f32]> =
                miss_idx.iter().map(|&i| batch[i].input.as_slice()).collect();
            let mut layer_windows;
            let mut attempt = 0u32;
            // a batch survives transport failures by healing and
            // retrying against the (possibly re-programmed, epoch-
            // bumped) fleet — every retry recomputes from the inputs,
            // so the eventual answer is bit-exact no matter how many
            // attempts it took. Only a fleet that stays unreachable
            // crashes the coordinator: admitted requests must never be
            // silently mis-answered.
            let logits = loop {
                layer_windows = vec![0u64; self.models[t].n_layers()];
                match run_batch(
                    &self.models[t],
                    &inputs,
                    self.data_cols,
                    &mut self.router,
                    &self.routes[t],
                    &mut layer_windows,
                    trace,
                ) {
                    Ok(logits) => break logits,
                    Err(e) => {
                        attempt += 1;
                        assert!(
                            attempt < MAX_BATCH_ATTEMPTS,
                            "serving transport failed mid-batch after {attempt} heal \
                             attempts: {e}"
                        );
                        self.heal();
                    }
                }
            };
            let mut cache = lock_unpoisoned(&self.caches[t]);
            for (&i, lg) in miss_idx.iter().zip(&logits) {
                if let Some(key) = keys[i].take() {
                    if let Some(cam) = self.cams[t].as_mut() {
                        // verify-then-insert: the near candidate is
                        // compared against the recompute before the
                        // recompute itself becomes a CAM entry
                        if let Some(slot) = verify_slots[i] {
                            cam.verify(slot, lg);
                        }
                        cam.insert(&key, lg);
                    }
                    cache.insert(key.exact, lg.clone());
                }
                results[i] = Some(lg.clone());
            }
            drop(cache);
            // heat: every live shard of layer l served that layer's
            // windows (within a layer all live filters do equal work;
            // across layers window counts differ by orders of magnitude,
            // which is what ranks migrations meaningfully)
            for (l, pl) in self.placements[t].layers.iter().enumerate() {
                for (f, loc) in pl.shards[0].iter().enumerate() {
                    if loc.is_some() {
                        self.heat[t][l][f] += layer_windows[l];
                    }
                }
            }
            self.stats[t].chip_batches += 1;
            self.chip_batches_total += 1;
        }
        // replies, in admission order (per-tenant FIFO)
        for (req, res) in batch.iter().zip(results) {
            let logits = res.expect("every batched request is resolved");
            let latency = req.submitted.elapsed();
            self.stats[t].latency.record(latency);
            // a dropped reply receiver is the client's choice, not an error
            let _ = req.reply.send(Response { id: req.id, logits, latency });
        }
        self.stats[t].answered += b as u64;
        self.stats[t].cache_hits += hits;
        // fold this batch's CAM deltas into the cam.* counters; a
        // flushes delta here means a trusted audit breached its bound
        // mid-batch (placement flushes go through flush_tenant_caches)
        if let (Some(cam), Some(z)) = (self.cams[t].as_ref(), cam_before) {
            let s = &cam.stats;
            self.cam_metrics.hits.add(s.hits - z.hits);
            self.cam_metrics.near_hits.add(s.near_hits - z.near_hits);
            self.cam_metrics.verify_pass.add(s.verify_pass - z.verify_pass);
            self.cam_metrics.verify_fail.add(s.verify_fail - z.verify_fail);
            self.cam_metrics.trusted_served.add(s.trusted_served - z.trusted_served);
            self.cam_metrics.fallbacks.add(s.fallbacks - z.fallbacks);
            if s.flushes > z.flushes {
                self.cam_metrics.flushes.add(s.flushes - z.flushes);
                self.obs.bus.emit(ObsEvent::CamFlush {
                    tenant: t,
                    entries: s.entries_flushed - z.entries_flushed,
                });
            }
        }
    }

    /// Flush one tenant's result cache AND its CAM front end: shared
    /// invalidation. Any re-shard, heal, or committed prune cutover
    /// changes what silicon would answer, so both replay stores drop
    /// together — emitting [`ObsEvent::CacheInvalidated`] and
    /// [`ObsEvent::CamFlush`] exactly once per non-empty flush.
    fn flush_tenant_caches(&mut self, t: usize) {
        if let Some(cache) = self.caches.get(t) {
            let entries = lock_unpoisoned(cache).invalidate_all();
            if entries > 0 {
                self.obs.bus.emit(ObsEvent::CacheInvalidated { tenant: t, entries });
            }
        }
        if let Some(cam) = self.cams.get_mut(t).and_then(|c| c.as_mut()) {
            let entries = cam.flush();
            if entries > 0 {
                self.cam_metrics.flushes.inc();
                self.obs.bus.emit(ObsEvent::CamFlush { tenant: t, entries });
            }
        }
    }

    /// One rebalance pass: snapshot every backend's wear over the
    /// transport, migrate up to `max_moves` hottest shards off the
    /// hottest chip (within its backend), then consider up to
    /// `group_moves` epoch-fenced **cross-group layer migrations**
    /// under capacity pressure; invalidate every tenant's cache if
    /// anything moved. See [`rebalance`] for both protocols.
    fn rebalance_pass(&mut self, force: bool) {
        // heal first: the pass must plan against the fleet that will
        // serve it (a bounced member re-programmed and rejoined, not
        // migrated onto while its placement refs point at a dead pool).
        // This is also the periodic re-probe that re-admits a member
        // quarantined Unreachable once its host returns.
        self.heal();
        let wears = match self.router.wear_all() {
            Ok(w) => w,
            Err(_) => return, // fleet unhealthy: heal again next pass
        };
        let now: Vec<Vec<WearLedger>> = wears.iter().map(|w| w.wear.clone()).collect();
        let rows_free: Vec<Vec<usize>> = wears
            .iter()
            .map(|w| w.rows_free.iter().map(|&r| r as usize).collect())
            .collect();
        let mut moved = 0u64;
        let mut planned = Vec::new();
        let mut intra = None;
        if let Some((member, src, dst)) = self.rebalancer.pick_chips(&now, &rows_free, force) {
            let (group, local) = self.router.member_group(member);
            planned = plan_moves(
                &self.placements,
                &self.heat,
                group,
                local,
                src,
                self.rebalancer.cfg.max_moves,
            );
            intra = Some((member, group, local, dst));
        }
        // one Planned per pass that has work (or was operator-forced);
        // quiet periodic passes stay silent — no event spam
        if !planned.is_empty() || force {
            self.obs.bus.emit(ObsEvent::RebalancePlanned {
                moves: planned.len(),
                group_moves: self.rebalancer.cfg.group_moves,
            });
        }
        if let Some((member, group, local, dst)) = intra {
            for mv in &planned {
                if self.try_migrate(mv, member, group, local, dst) {
                    moved += 1;
                }
            }
        }
        moved += self.group_migration_pass(force);
        if moved > 0 {
            // any re-shard invalidates every cached entry, result cache
            // and CAM alike (see `cache` and `cam`)
            for t in 0..self.caches.len() {
                self.flush_tenant_caches(t);
            }
            self.obs.bus.emit(ObsEvent::RebalanceApplied { shards_moved: moved as usize });
            self.rebalancer.rebalances += 1;
            self.rebalancer.shards_moved += moved;
        }
        self.rebalancer.last = now;
    }

    /// One live prune pass: per prunable tenant, re-run the similarity
    /// rule over its programmed kernels ([`LivePruneMonitor::propose`])
    /// and commit each proposed layer shrink through an epoch-fenced
    /// [`PruneCutover`]. Runs at a batch boundary like a rebalance —
    /// nothing is in flight, which is what makes the fence's drain
    /// guarantee hold. A committed cutover invalidates the tenant's
    /// result cache (the pruned model answers differently) and frees
    /// the retired filters' rows on every member of the owning group.
    // lint: allow(panic-freedom) — shard indices enumerate the live placement snapshot taken under the drain
    fn prune_pass(&mut self) {
        let t_pass = Instant::now();
        let trace = self.router.begin_trace();
        for t in 0..self.models.len() {
            let Some(monitor) = self.monitors[t].as_mut() else {
                continue;
            };
            let plans = monitor.propose(t, &self.models[t]);
            for plan in &plans {
                let t_cut = Instant::now();
                let probe = self.probes[t].clone();
                let outcome = PruneCutover {
                    tenant: t,
                    router: &mut self.router,
                    placement: &mut self.placements[t],
                    route: &mut self.routes[t],
                    model: &mut self.models[t],
                    obs: &self.obs,
                }
                .execute(plan, probe.as_deref());
                match outcome {
                    Ok(CutoverOutcome::Committed(commit)) => {
                        self.prune.cutovers += 1;
                        self.prune.filters_pruned += commit.filters.len() as u64;
                        self.prune.rows_freed += commit.rows_freed;
                        self.prune.rows_retired += commit.rows_retired;
                        let ts = &mut self.prune.per_tenant[t];
                        ts.filters_pruned += commit.filters.len() as u64;
                        ts.rows_freed += commit.rows_freed;
                        if let Some(d) = commit.logit_delta {
                            ts.max_logit_delta = ts.max_logit_delta.max(d);
                        }
                        self.obs.metrics.counter("prune.cutovers").inc();
                        let n = commit.filters.len() as u64;
                        self.obs.metrics.counter("prune.filters_pruned").add(n);
                        self.obs.metrics.counter("prune.rows_freed").add(commit.rows_freed);
                        // the pruned model answers differently: drop the
                        // tenant's result cache and CAM together
                        self.flush_tenant_caches(t);
                        if trace.is_traced() {
                            self.obs.trace.record(SpanRecord {
                                ctx: trace.child(self.obs.trace.next_span()),
                                stage: Stage::Prune,
                                note: format!(
                                    "tenant={t} layer={} pruned={}",
                                    commit.layer,
                                    commit.filters.len()
                                ),
                                start: t_cut,
                                dur: t_cut.elapsed(),
                            });
                        }
                    }
                    Ok(CutoverOutcome::Aborted { .. }) => {
                        self.prune.aborted += 1;
                        self.obs.metrics.counter("prune.aborted").inc();
                    }
                    Err(_) => return, // workers gone; the shutdown path reports
                }
            }
        }
        self.obs.metrics.histogram(stage::PRUNE).record(t_pass.elapsed());
    }

    /// Up to `group_moves` cross-group layer migrations, chosen by
    /// capacity pressure. Returns the number of shards that moved
    /// (counted once per logical shard, like intra-backend moves).
    // lint: allow(panic-freedom) — group ids enumerate the router group table
    fn group_migration_pass(&mut self, force: bool) -> u64 {
        let mut moved = 0u64;
        for _ in 0..self.rebalancer.cfg.group_moves {
            // group headroom: the tightest member bounds what a group
            // can absorb (replicas each need their own copy). Read from
            // the router's live mirrors so a migration earlier in this
            // pass is already accounted for.
            let mut group_free = vec![usize::MAX; self.router.n_groups()];
            for m in 0..self.router.n_members() {
                let (g, _) = self.router.member_group(m);
                group_free[g] = group_free[g].min(self.router.member_rows_free(m));
            }
            let Some(mv) = plan_group_move(&self.placements, &self.heat, &group_free, force)
            else {
                break;
            };
            match self.try_migrate_layer(mv.tenant, mv.layer, mv.from_group, mv.to_group) {
                Some(shards) => moved += shards,
                None => break, // aborted: conditions will not improve this pass
            }
        }
        moved
    }

    /// Execute one planned cross-group layer migration through the
    /// router's fence machine. Returns the number of logical shards
    /// moved, or `None` when the migration aborted or a quota blocked
    /// it (the source placement stays authoritative either way).
    // lint: allow(panic-freedom) — layer and member indices come from the placement snapshot being migrated, taken under the drain
    fn try_migrate_layer(
        &mut self,
        tenant: usize,
        layer: usize,
        from_group: usize,
        to_group: usize,
    ) -> Option<u64> {
        let pl = &self.placements[tenant].layers[layer];
        debug_assert_eq!(pl.group, from_group, "plan vs placement drift");
        let live: Vec<usize> =
            (0..pl.shards[0].len()).filter(|&f| pl.shards[0][f].is_some()).collect();
        if live.is_empty() {
            return None;
        }
        // per-member row quota on every destination member: the layer's
        // need is what its copies occupy today (same cells, same striping)
        if let Some(quota) = self.quotas[tenant] {
            let need: usize =
                pl.shards[0].iter().flatten().map(|s| s.span.slots.len()).sum();
            for local in 0..self.router.group_size(to_group) {
                if self.placements[tenant].rows_live_on(to_group, local) + need > quota {
                    return None;
                }
            }
        }
        let payloads: Vec<Option<OwnedPayload>> = (0..self.placements[tenant].layers[layer]
            .shards[0]
            .len())
            .map(|f| {
                self.placements[tenant].layers[layer].shards[0][f]
                    .as_ref()
                    .map(|_| {
                        self.models[tenant]
                            .shard_payload(layer, f)
                            .expect("live shard has a payload")
                            .into()
                    })
            })
            .collect();
        let old_epoch = self.routes[tenant].epoch;
        let old_shards = self.placements[tenant].layers[layer].shards.clone();
        let outcome = match self.router.migrate_layer(
            layer,
            old_epoch,
            from_group,
            &old_shards,
            to_group,
            &payloads,
        ) {
            Ok(outcome) => outcome,
            Err(_) => return None, // router workers gone; shutdown path reports
        };
        match outcome {
            MigrationOutcome::Completed { shards, epoch, stuck_retries } => {
                self.stuck_retries += stuck_retries;
                self.placements[tenant].layers[layer] =
                    PlacedLayer { group: to_group, shards };
                self.routes[tenant] =
                    TenantRoute::from_placement(&self.placements[tenant], epoch);
                Some(live.len() as u64)
            }
            MigrationOutcome::Aborted { stuck_retries } => {
                self.stuck_retries += stuck_retries;
                None
            }
        }
    }

    /// Probe the fleet; re-program and rejoin every bounced member.
    /// Any member that was re-programmed bumps the epoch of every
    /// tenant with layers on its group (the classic "reconnecting host
    /// missed a migration" hazard: it must serve the *current*
    /// placement at the *current* epoch, never its pre-bounce memory).
    // lint: allow(panic-freedom) — member ids are drawn from the router health probe of the same epoch
    fn heal(&mut self) {
        let probes = self.router.probe_members();
        let mut touched_groups: Vec<usize> = Vec::new();
        for probe in probes {
            if probe.state != MemberState::Bounced {
                continue;
            }
            let (group, local) = self.router.member_group(probe.member);
            if self.reprogram_member(probe.member, group, local)
                && self.router.rejoin_member(probe.member).is_ok()
                && !touched_groups.contains(&group)
            {
                touched_groups.push(group);
            }
        }
        if touched_groups.is_empty() {
            return;
        }
        // epoch-bump every tenant whose layers live on a healed group,
        // and flush caches: the placement changed under them
        for t in 0..self.routes.len() {
            let affected = self.placements[t]
                .layers
                .iter()
                .any(|pl| touched_groups.contains(&pl.group));
            if affected {
                let epoch = self.router.next_epoch();
                self.routes[t] = TenantRoute::from_placement(&self.placements[t], epoch);
            }
        }
        for t in 0..self.caches.len() {
            self.flush_tenant_caches(t);
        }
    }

    /// Re-program every live shard this member should hold (all tenants,
    /// all layers of its group) onto its fresh pool. `true` when every
    /// shard landed cleanly — only then do the new spans replace the
    /// placement refs and may the member rejoin. A failed attempt
    /// releases everything it staged, so the next heal retries against
    /// a clean pool instead of leaking rows attempt after attempt.
    // lint: allow(panic-freedom) — the shard list was filtered to this member before indexing
    fn reprogram_member(&mut self, member: usize, group: usize, local: usize) -> bool {
        let mut staged: Vec<(usize, usize, usize, ShardRef)> = Vec::new();
        for t in 0..self.placements.len() {
            for l in 0..self.placements[t].layers.len() {
                if self.placements[t].layers[l].group != group {
                    continue;
                }
                for f in 0..self.placements[t].layers[l].shards[local].len() {
                    if self.placements[t].layers[l].shards[local][f].is_none() {
                        continue;
                    }
                    let payload: OwnedPayload = self.models[t]
                        .shard_payload(l, f)
                        .expect("live shard has a payload")
                        .into();
                    match self.router.place_shard(member, &payload) {
                        Ok(PlaceOutcome::Placed { chip, span, retries }) => {
                            self.stuck_retries += retries;
                            let r = ShardRef { chip: chip as u32, filter: f as u32, span };
                            staged.push((t, l, f, r));
                        }
                        Ok(PlaceOutcome::NoRoom { retries }) => {
                            self.stuck_retries += retries;
                            self.rollback_staged(member, &staged);
                            return false; // stays quarantined; probed again later
                        }
                        Err(_) => {
                            self.rollback_staged(member, &staged);
                            return false;
                        }
                    }
                }
            }
        }
        for (t, l, f, r) in staged {
            self.placements[t].layers[l].shards[local][f] = Some(r);
        }
        true
    }

    /// Release the spans a failed re-program attempt staged (they live
    /// on the member's current pool, so the allocator accepts them).
    fn rollback_staged(&mut self, member: usize, staged: &[(usize, usize, usize, ShardRef)]) {
        for (_, _, _, r) in staged {
            let _ = self.router.release(member, r.chip as usize, r.span.clone());
        }
    }

    /// Re-program one shard on `dst` of the same backend. The placement
    /// flips — and the tenant's shard epoch advances — only on a clean
    /// store (`failures == 0`); a stuck tile retires the fresh rows and
    /// the shard keeps serving from where it is.
    // lint: allow(panic-freedom) — source and target chips were selected from the wear snapshot of the same drained pool
    fn try_migrate(
        &mut self,
        mv: &rebalance::Move,
        member: usize,
        group: usize,
        local: usize,
        dst: usize,
    ) -> bool {
        let old = self.placements[mv.tenant].layers[mv.layer].shards[local][mv.filter]
            .clone()
            .expect("planned move targets a live shard");
        let cells = old.span.len;
        let need = cells.div_ceil(self.data_cols);
        if let Some(quota) = self.quotas[mv.tenant] {
            let live = self.placements[mv.tenant].rows_live_on(group, local);
            if live - old.span.slots.len() + need > quota {
                return false; // the move would overdraw the tenant's quota
            }
        }
        let payload: OwnedPayload = self.models[mv.tenant]
            .shard_payload(mv.layer, mv.filter)
            .expect("live shard has a payload")
            .into();
        let Ok(reply) = self.router.program(member, dst, payload) else {
            return false; // member unreachable: the heal path takes over
        };
        let Some(span) = reply.span else {
            return false; // destination filled up within this pass
        };
        if reply.failures > 0 {
            self.stuck_retries += 1;
            return false;
        }
        self.placements[mv.tenant].layers[mv.layer].shards[local][mv.filter] =
            Some(ShardRef { chip: dst as u32, filter: mv.filter as u32, span });
        let epoch = self.router.next_epoch();
        self.routes[mv.tenant] = TenantRoute::from_placement(&self.placements[mv.tenant], epoch);
        true
    }

    // lint: allow(panic-freedom) — join handles are present until finish() takes them exactly once; the expect documents that invariant
    fn finish(mut self, t_start: Instant) -> EngineReport {
        for (t, st) in self.stats.iter_mut().enumerate() {
            st.dropped = self.admission.dropped(t);
        }
        // close out the prune report against the final masks: MAC ops
        // under what each tenant ended up serving, the realized prune
        // rate, the quota headroom its cutovers opened, and the masks
        // themselves (what a caller needs to rebuild the pruned oracle)
        for (t, ts) in self.prune.per_tenant.iter_mut().enumerate() {
            let model = &self.models[t];
            ts.mac_ops_end = model.mac_ops_per_input();
            ts.prune_rate =
                1.0 - model.live_filters() as f64 / model.total_filters().max(1) as f64;
            ts.live_masks = (0..model.n_layers()).map(|l| model.live_mask(l).to_vec()).collect();
            let rows_max = (0..self.router.n_groups())
                .flat_map(|g| {
                    let p = &self.placements[t];
                    (0..self.router.group_size(g)).map(move |local| p.rows_live_on(g, local))
                })
                .max()
                .unwrap_or(0);
            ts.quota_headroom_rows = match self.quotas[t] {
                Some(q) => q.saturating_sub(rows_max) as u64,
                // unlimited tenants: headroom is the tightest member's
                // free rows (what another placement could still take)
                None => (0..self.router.n_members())
                    .map(|m| self.router.member_rows_free(m))
                    .min()
                    .unwrap_or(0) as u64,
            };
        }
        // close out the CAM report: each tenant's counters as they
        // stand (all-zero defaults for tenants without a front end)
        let cam = CamReport {
            per_tenant: self
                .cams
                .iter_mut()
                .map(|c| c.as_mut().map(|c| std::mem::take(&mut c.stats)).unwrap_or_default())
                .collect(),
        };
        let rows_used = self.router.rows_used_flat();
        let finishes = self.router.finish().expect("transport failed at shutdown");
        // read the counters only after finish(): draining the last lost
        // hedge replies during shutdown still increments stale_discarded
        let transport = self.router.stats();
        EngineReport {
            tenants: std::mem::take(&mut self.stats),
            wall_s: t_start.elapsed().as_secs_f64(),
            energy_pj: finishes.iter().map(|f| f.energy_pj).sum(),
            wear: finishes.into_iter().flat_map(|f| f.wear).collect(),
            rows_used,
            stuck_retries: self.stuck_retries,
            rebalances: self.rebalancer.rebalances,
            shards_moved: self.rebalancer.shards_moved,
            prune: std::mem::take(&mut self.prune),
            cam,
            transport,
        }
    }
}

/// A running multi-tenant inference engine. Submit inputs against a
/// [`TenantId`] (see [`Engine::tenant`]), then [`Engine::shutdown`] to
/// drain every queue, join all threads, and collect the
/// [`EngineReport`].
pub struct Engine {
    admission: Admission,
    names: Vec<String>,
    input_lens: Vec<usize>,
    caches: Vec<Arc<Mutex<ResultCache>>>,
    obs: Arc<Obs>,
    next_id: AtomicU64,
    force: Arc<AtomicBool>,
    coordinator: Option<JoinHandle<EngineReport>>,
}

impl Engine {
    /// Single-pool start: fabricate `cfg.pool` as one local backend and
    /// serve through it — the zero-configuration shape. See
    /// [`Engine::start_with_router`] for multi-host fleets.
    pub fn start(tenants: Vec<TenantConfig>, cfg: &EngineConfig) -> Result<Engine> {
        let backend = LocalBackend::from_pool_config(&cfg.pool)?;
        let router = ShardRouter::single(Box::new(backend))?;
        Engine::start_with_router(tenants, router, cfg)
    }

    /// Serve through a ready-made [`ShardRouter`] fleet (local pools,
    /// TCP hosts, replica groups — any [`crate::serve::transport::Backend`]
    /// mix): place every tenant's model across the fleet in
    /// registration order (every member of a layer's owning group gets
    /// a byte-identical shard copy, per-member row quotas enforced),
    /// reset the energy ledgers so serving measurements exclude initial
    /// programming, and spawn the coordinator. `cfg.pool` is ignored —
    /// the fleet is the router's.
    // lint: allow(panic-freedom) — bundle layer list is non-empty, checked by validate_tenants before start
    pub fn start_with_router(
        tenants: Vec<TenantConfig>,
        mut router: ShardRouter,
        cfg: &EngineConfig,
    ) -> Result<Engine> {
        tenant::validate_tenants(&tenants)?;
        // the shared observability plane: the router records dispatch /
        // hedge / execute spans and fleet events into it, the engine
        // adds queue/cache spans and admission/rebalance events, and
        // [`Engine::events`] / [`Engine::obs`] hand it to operators
        let obs =
            Arc::new(if cfg.obs { Obs::new() } else { Obs::disabled() });
        router.set_obs(Arc::clone(&obs));
        let data_cols = router.data_cols();
        let mut placements = Vec::with_capacity(tenants.len());
        let mut stuck_retries = 0usize;
        for t in &tenants {
            let p = router
                .place(&t.model, t.row_quota)
                .map_err(|e| anyhow!("tenant {:?}: {e}", t.name))?;
            stuck_retries += p.stuck_retries;
            placements.push(p);
        }
        router
            .reset_energy_all()
            .map_err(|e| anyhow!("transport failed after placement: {e}"))?;
        let initial_wear: Vec<Vec<WearLedger>> = router
            .wear_all()
            .map_err(|e| anyhow!("transport failed in initial wear probe: {e}"))?
            .into_iter()
            .map(|w| w.wear)
            .collect();

        let names: Vec<String> = tenants.iter().map(|t| t.name.clone()).collect();
        let input_lens: Vec<usize> = tenants.iter().map(|t| t.model.input_len()).collect();
        let quotas: Vec<Option<usize>> = tenants.iter().map(|t| t.row_quota).collect();
        let depths: Vec<usize> = tenants.iter().map(|t| t.queue_depth).collect();
        let prunable: Vec<bool> = tenants.iter().map(|t| t.live_prune).collect();
        let cam_policies: Vec<Option<cam::VerifyPolicy>> =
            tenants.iter().map(|t| t.cam).collect();
        let models: Vec<ModelBundle> = tenants.into_iter().map(|t| t.model).collect();
        // live prune plumbing: one similarity monitor per opted-in
        // tenant (kernels packed once — sign bits never change while
        // serving), and a report seeded with each tenant's dense-mask
        // MAC cost so the reduction is measured, not guessed
        let monitors: Vec<Option<LivePruneMonitor>> = models
            .iter()
            .zip(&prunable)
            .map(|(m, &on)| {
                (cfg.prune.every_batches > 0 && on)
                    .then(|| LivePruneMonitor::new(cfg.prune.clone(), m))
            })
            .collect();
        let prune_report = PruneReport {
            per_tenant: models
                .iter()
                .map(|m| super::prune::TenantPruneStats {
                    mac_ops_start: m.mac_ops_per_input(),
                    ..Default::default()
                })
                .collect(),
            ..Default::default()
        };
        // router-issued epochs are globally unique across tenants, so a
        // fenced epoch can never be confused with a live one
        let mut routes: Vec<TenantRoute> = Vec::with_capacity(placements.len());
        for p in &placements {
            let epoch = router.next_epoch();
            routes.push(TenantRoute::from_placement(p, epoch));
        }
        let heat: Vec<ShardHeat> = placements
            .iter()
            .map(|p| p.layers.iter().map(|pl| vec![0u64; pl.shards[0].len()]).collect())
            .collect();
        let caches: Vec<Arc<Mutex<ResultCache>>> = models
            .iter()
            .map(|_| Arc::new(Mutex::new(ResultCache::new(cfg.cache.capacity))))
            .collect();
        // one CAM front end per tenant that didn't opt out (and only
        // when the engine enables the pass at all), keyed at the
        // tenant model's canonical packed width and seeded per tenant
        // so reservoir eviction is deterministic per run shape
        let cams: Vec<Option<CamFrontEnd>> = models
            .iter()
            .zip(&cam_policies)
            .enumerate()
            .map(|(t, (m, policy))| {
                policy.and_then(|p| {
                    CamFrontEnd::new(
                        &cfg.cam,
                        p,
                        RequestKey::n_bits_for(m),
                        cam::CAM_SEED ^ t as u64,
                    )
                })
            })
            .collect();
        let stats: Vec<TenantStats> = names
            .iter()
            .map(|n| TenantStats { name: n.clone(), ..TenantStats::default() })
            .collect();
        let admission = Admission::new(cfg.admission.clone(), &depths);
        admission.attach_obs(Arc::clone(&obs));
        let force = Arc::new(AtomicBool::new(false));

        let coordinator = Coordinator {
            admission: admission.clone(),
            models,
            quotas,
            placements,
            routes,
            heat,
            caches: caches.clone(),
            cams,
            cam_metrics: CamMetrics::new(&obs),
            stats,
            router,
            data_cols,
            obs: Arc::clone(&obs),
            queue_wait: obs.metrics.histogram(stage::QUEUE_WAIT),
            rebalancer: Rebalancer::new(cfg.rebalance.clone(), initial_wear),
            force_rebalance: Arc::clone(&force),
            chip_batches_total: 0,
            last_pass_at: u64::MAX,
            stuck_retries,
            prune_cfg: cfg.prune.clone(),
            monitors,
            last_prune_at: u64::MAX,
            probes: vec![None; names.len()],
            prune: prune_report,
        };
        let handle = std::thread::spawn(move || coordinator.run());
        Ok(Engine {
            admission,
            names,
            input_lens,
            caches,
            obs,
            next_id: AtomicU64::new(0),
            force,
            coordinator: Some(handle),
        })
    }

    /// Resolve a tenant name to the id submits route by.
    pub fn tenant(&self, name: &str) -> Option<TenantId> {
        self.names.iter().position(|n| n == name)
    }

    /// Registered tenant names, in registration (= [`TenantId`]) order.
    pub fn tenants(&self) -> &[String] {
        &self.names
    }

    // lint: allow(panic-freedom) — tenant index was validated at registration; the one-shot reply channel cannot disconnect before the reply
    fn request(&self, tenant: TenantId, input: Vec<f32>) -> (Request, Receiver<Response>) {
        assert!(tenant < self.names.len(), "unknown tenant id {tenant}");
        assert_eq!(
            input.len(),
            self.input_lens[tenant],
            "request input length vs tenant model ({} expected)",
            self.input_lens[tenant]
        );
        // one-shot reply: capacity 1 buffers the single send without a
        // blocked receiver (the bounded-channel invariant)
        let (reply, rx) = sync_channel(1);
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            input,
            submitted: Instant::now(),
            reply,
        };
        (req, rx)
    }

    /// Blocking submit: waits while the tenant's queue is full (lossless
    /// per-tenant backpressure). The receiver yields the [`Response`]
    /// when the batch containing this request completes.
    ///
    /// Panics (in the caller, never the pipeline) if `input` is not the
    /// tenant model's input length.
    pub fn submit(&self, tenant: TenantId, input: Vec<f32>) -> Receiver<Response> {
        let (req, rx) = self.request(tenant, input);
        self.admission.submit(tenant, req);
        rx
    }

    /// Non-blocking submit: on a full tenant queue the input is handed
    /// back (explicit backpressure) and the shed is counted in that
    /// tenant's [`TenantStats::dropped`] — never admitted, so never
    /// also answered.
    pub fn try_submit(
        &self,
        tenant: TenantId,
        input: Vec<f32>,
    ) -> std::result::Result<Receiver<Response>, Vec<f32>> {
        let (req, rx) = self.request(tenant, input);
        match self.admission.try_submit(tenant, req) {
            Ok(()) => Ok(rx),
            Err(req) => Err(req.input),
        }
    }

    /// Subscribe to the operator event stream ([`ObsEvent`]): every
    /// fleet transition — migrations, fences, quarantines, rejoins,
    /// reconnects, rebalances, cache invalidations, spillovers, sheds —
    /// arrives as an [`crate::serve::EventRecord`] with a gapless
    /// per-subscriber sequence number. Delivery is bounded and
    /// non-blocking: a slow consumer loses events (counted in
    /// [`EventSubscriber::overflowed`]), never stalls serving.
    pub fn events(&self) -> EventSubscriber {
        self.obs.bus.subscribe()
    }

    /// [`Engine::events`] with an explicit per-subscriber queue bound.
    pub fn events_with(&self, capacity: usize) -> EventSubscriber {
        self.obs.bus.subscribe_with(capacity)
    }

    /// The engine's observability plane: the trace log, the event bus,
    /// and the metrics registry ([`Obs::snapshot`] exports all three
    /// as one JSON object).
    pub fn obs(&self) -> &Arc<Obs> {
        &self.obs
    }

    /// Request a rebalance pass at the next batch boundary (wear-delta
    /// thresholds are bypassed; capacity and quota checks are not).
    pub fn force_rebalance(&self) {
        self.force.store(true, Ordering::SeqCst);
    }

    /// Live entry count of one tenant's result cache.
    // lint: allow(panic-freedom) — per-tenant cache vector is sized to the tenant count at construction
    pub fn cache_len(&self, tenant: TenantId) -> usize {
        lock_unpoisoned(&self.caches[tenant]).len()
    }

    /// Entries dropped by re-shard invalidation so far, one tenant.
    // lint: allow(panic-freedom) — per-tenant cache vector is sized to the tenant count at construction
    pub fn cache_invalidations(&self, tenant: TenantId) -> u64 {
        lock_unpoisoned(&self.caches[tenant]).invalidations
    }

    /// Stop admitting, drain every tenant queue, join all threads, and
    /// report. Every request admitted before this call is answered.
    // lint: allow(panic-freedom) — join handles are present until shutdown takes them exactly once; the expects document that invariant
    pub fn shutdown(mut self) -> EngineReport {
        self.admission.close();
        self.coordinator
            .take()
            .expect("engine already shut down")
            .join()
            .expect("engine coordinator panicked")
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.admission.close();
        if let Some(h) = self.coordinator.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chip::ChipConfig;
    use crate::nn::data::{mnist, modelnet};
    use crate::nn::pointnet::GroupingConfig;
    use crate::serve::pool::PoolConfig;
    use crate::serve::PointNetBundle;
    use std::time::Duration;

    fn tiny_pointnet(prune: f64, seed: u64) -> PointNetBundle {
        PointNetBundle::synthetic(
            [2, 2, 3, 2, 2, 3, 2, 4],
            3,
            prune,
            GroupingConfig { s1: 8, k1: 4, r1: 0.3, s2: 4, k2: 2, r2: 0.6 },
            seed,
        )
    }

    fn small_cfg(chips: usize, seed: u64) -> EngineConfig {
        EngineConfig {
            pool: PoolConfig { chips, chip: ChipConfig::small_test(), seed },
            admission: AdmissionConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                quantum: 4,
            },
            cache: CacheConfig::default(),
            rebalance: RebalanceConfig::default(),
            prune: Default::default(),
            cam: Default::default(),
            obs: true,
        }
    }

    #[test]
    fn zero_request_lifecycle() {
        let tenants = vec![TenantConfig::new("mnist", ModelBundle::synthetic_mnist([2, 2, 2], 0.0, 71))];
        let engine = Engine::start(tenants, &small_cfg(2, 72)).unwrap();
        assert_eq!(engine.tenant("mnist"), Some(0));
        assert_eq!(engine.tenant("nope"), None);
        let report = engine.shutdown();
        assert_eq!(report.answered(), 0);
        assert_eq!(report.dropped(), 0);
        assert_eq!(report.wear.len(), 2);
        assert_eq!(report.rebalances, 0);
        assert_eq!(report.transport.dispatches, 0);
    }

    #[test]
    fn registration_errors_are_clean() {
        let m = ModelBundle::synthetic_mnist([2, 2, 2], 0.0, 73);
        let dup = vec![
            TenantConfig::new("a", m.clone()),
            TenantConfig::new("a", m.clone()),
        ];
        let err = match Engine::start(dup, &small_cfg(2, 74)) {
            Err(e) => e,
            Ok(_) => panic!("duplicate names must fail"),
        };
        assert!(err.to_string().contains("duplicate"), "{err}");
        let strangled = vec![TenantConfig::new("a", m).with_row_quota(3)];
        let err = match Engine::start(strangled, &small_cfg(2, 75)) {
            Err(e) => e,
            Ok(_) => panic!("a 3-row quota must fail placement"),
        };
        assert!(err.to_string().contains("quota"), "{err}");
    }

    #[test]
    fn two_tenants_serve_interleaved_bit_exactly() {
        let mnist_model = ModelBundle::synthetic_mnist([3, 4, 3], 0.3, 81);
        let pn_model: ModelBundle = tiny_pointnet(0.3, 82).into();
        let tenants = vec![
            TenantConfig::new("mnist", mnist_model.clone()),
            TenantConfig::new("pointnet", pn_model.clone()),
        ];
        let engine = Engine::start(tenants, &small_cfg(3, 83)).unwrap();
        let (tm, tp) = (engine.tenant("mnist").unwrap(), engine.tenant("pointnet").unwrap());
        let images = mnist::generate(4, 84);
        let clouds = modelnet::generate(4, 85);
        // interleave the two workloads through one pool
        let mut pending = Vec::new();
        for i in 0..4 {
            pending.push((tm, i, engine.submit(tm, images.sample(i).to_vec())));
            pending.push((tp, i, engine.submit(tp, clouds.sample(i).to_vec())));
        }
        for (t, i, rx) in pending {
            let resp = rx.recv().unwrap();
            let (model, input) = if t == tm {
                (&mnist_model, images.sample(i))
            } else {
                (&pn_model, clouds.sample(i))
            };
            assert_eq!(
                resp.logits,
                model.reference_logits(input),
                "tenant {t} input {i} diverged from its software reference"
            );
        }
        let report = engine.shutdown();
        assert_eq!(report.answered(), 8);
        assert_eq!(report.tenants[tm].answered, 4);
        assert_eq!(report.tenants[tp].answered, 4);
        assert_eq!(report.dropped(), 0);
        assert!(report.energy_pj > 0.0, "serving must spend chip energy");
        assert!(report.tenants[tm].latency.count() == 4);
        assert!(report.transport.dispatches > 0, "batches flowed through the router");
    }

    #[test]
    fn cache_hits_replay_and_forced_reshard_invalidates() {
        let model = ModelBundle::synthetic_mnist([3, 4, 3], 0.3, 91);
        let tenants = vec![TenantConfig::new("mnist", model.clone())];
        let engine = Engine::start(tenants, &small_cfg(2, 92)).unwrap();
        let ds = mnist::generate(1, 93);
        let reference = model.reference_logits(ds.sample(0));
        // miss, then hit: identical logits, one cache entry
        let a = engine.submit(0, ds.sample(0).to_vec()).recv().unwrap();
        assert_eq!(a.logits, reference);
        assert_eq!(engine.cache_len(0), 1);
        let b = engine.submit(0, ds.sample(0).to_vec()).recv().unwrap();
        assert_eq!(b.logits, reference, "cache hit must replay bit-exactly");
        // force a re-shard: the entry must be invalidated, the recompute
        // must go through the migrated placement and stay bit-exact
        engine.force_rebalance();
        let c = engine.submit(0, ds.sample(0).to_vec()).recv().unwrap();
        assert_eq!(c.logits, reference, "post-migration logits diverged");
        assert!(engine.cache_invalidations(0) >= 1, "re-shard must flush the cache");
        let report = engine.shutdown();
        assert_eq!(report.rebalances, 1);
        assert!(report.shards_moved >= 1);
        // first + third computed, second replayed
        assert_eq!(report.tenants[0].cache_hits, 1);
        assert_eq!(report.tenants[0].chip_batches, 2);
    }

    #[test]
    fn periodic_rebalance_keeps_logits_bit_exact() {
        let model = ModelBundle::synthetic_mnist([3, 4, 3], 0.0, 95);
        let tenants = vec![TenantConfig::new("mnist", model.clone())];
        let mut cfg = small_cfg(2, 96);
        cfg.rebalance = RebalanceConfig { every_batches: 2, max_moves: 1, group_moves: 0 };
        cfg.cache = CacheConfig { capacity: 0 }; // every request hits silicon
        let engine = Engine::start(tenants, &cfg).unwrap();
        let ds = mnist::generate(6, 97);
        for i in 0..6 {
            let resp = engine.submit(0, ds.sample(i).to_vec()).recv().unwrap();
            assert_eq!(
                resp.logits,
                model.reference_logits(ds.sample(i)),
                "image {i} diverged (mid-run migrations must be invisible)"
            );
        }
        let report = engine.shutdown();
        assert!(report.rebalances >= 1, "periodic passes must have fired");
        assert!(report.shards_moved >= 1);
        assert_eq!(report.tenants[0].answered, 6);
        assert_eq!(report.tenants[0].cache_hits, 0);
    }

    #[test]
    fn forced_cross_group_migration_keeps_logits_bit_exact() {
        use crate::serve::transport::{Backend, RouterConfig};
        // two single-member groups of local pools: the tenant's layers
        // split across them, and a forced pass migrates a whole layer
        // between the groups through the epoch-fenced cutover
        let model = ModelBundle::synthetic_mnist([3, 4, 3], 0.0, 111);
        let mk = |seed| -> Box<dyn Backend> {
            Box::new(
                LocalBackend::from_pool_config(&PoolConfig {
                    chips: 2,
                    chip: ChipConfig::small_test(),
                    seed,
                })
                .unwrap(),
            )
        };
        let router =
            ShardRouter::new(vec![vec![mk(112)], vec![mk(113)]], RouterConfig::default())
                .unwrap();
        let mut cfg = small_cfg(2, 114);
        cfg.rebalance = RebalanceConfig { every_batches: 0, max_moves: 0, group_moves: 1 };
        cfg.cache = CacheConfig { capacity: 0 }; // every request hits silicon
        let engine = Engine::start_with_router(
            vec![TenantConfig::new("mnist", model.clone())],
            router,
            &cfg,
        )
        .unwrap();
        let ds = mnist::generate(4, 115);
        // warm-up traffic builds the heat signal the planner ranks by
        for i in 0..2 {
            let resp = engine.submit(0, ds.sample(i).to_vec()).recv().unwrap();
            assert_eq!(resp.logits, model.reference_logits(ds.sample(i)));
        }
        engine.force_rebalance();
        for i in 0..4 {
            let resp = engine.submit(0, ds.sample(i).to_vec()).recv().unwrap();
            assert_eq!(
                resp.logits,
                model.reference_logits(ds.sample(i)),
                "image {i} diverged (the cross-group cutover must be invisible)"
            );
        }
        let report = engine.shutdown();
        let t = &report.transport;
        assert!(t.migrations_started >= 1, "the forced pass must attempt a layer migration");
        assert!(
            t.migrations_completed >= 1,
            "an ideal two-group fleet must complete the migration"
        );
        assert_eq!(t.migrations_fenced, t.migrations_completed, "every fence completes");
        assert_eq!(report.answered(), 6);
        assert_eq!(report.dropped(), 0);
    }

    #[test]
    fn bursty_tenant_drops_are_its_own_and_fifo_holds() {
        let m = ModelBundle::synthetic_mnist([2, 2, 2], 0.0, 101);
        let tenants = vec![
            TenantConfig::new("burst", m.clone()).with_queue_depth(2),
            TenantConfig::new("steady", m.clone()).with_queue_depth(8),
        ];
        let mut cfg = small_cfg(2, 102);
        cfg.admission.max_batch = 2;
        cfg.admission.quantum = 2;
        cfg.cache = CacheConfig { capacity: 0 };
        let engine = Engine::start(tenants, &cfg).unwrap();
        let ds = mnist::generate(1, 103);
        // tenant 0 floods a depth-2 queue; tenant 1 trickles
        let mut burst_rx = Vec::new();
        let mut burst_shed = 0u64;
        let mut steady_rx = Vec::new();
        let mut steady_shed = 0u64;
        for i in 0..60 {
            match engine.try_submit(0, ds.sample(0).to_vec()) {
                Ok(rx) => burst_rx.push(rx),
                Err(input) => {
                    assert_eq!(input.len(), 28 * 28, "shed input returned intact");
                    burst_shed += 1;
                }
            }
            if i % 10 == 0 {
                match engine.try_submit(1, ds.sample(0).to_vec()) {
                    Ok(rx) => steady_rx.push(rx),
                    Err(_) => steady_shed += 1,
                }
            }
        }
        // every admitted request is answered, FIFO per tenant
        let drain = |rxs: Vec<std::sync::mpsc::Receiver<Response>>| -> Vec<u64> {
            rxs.into_iter()
                .map(|rx| rx.recv().expect("admitted request must be answered").id)
                .collect()
        };
        let burst_ids = drain(burst_rx);
        let steady_ids = drain(steady_rx);
        assert!(burst_ids.windows(2).all(|w| w[0] < w[1]), "burst FIFO broken");
        assert!(steady_ids.windows(2).all(|w| w[0] < w[1]), "steady FIFO broken");
        let report = engine.shutdown();
        assert_eq!(
            report.tenants[0].answered + report.tenants[0].dropped,
            60,
            "burst tenant: answered + dropped must partition its attempts"
        );
        assert_eq!(report.tenants[0].dropped, burst_shed);
        assert_eq!(
            report.tenants[1].answered + report.tenants[1].dropped,
            6,
            "steady tenant: nothing silently lost"
        );
        assert_eq!(report.tenants[1].dropped, steady_shed);
    }

    /// An MNIST bundle whose kernels repeat two sign prototypes per
    /// layer — live-prune bait (similarity 1.0 within each class).
    fn clustered_mnist(channels: [usize; 3], seed: u64) -> ModelBundle {
        let ModelBundle::Mnist(mut m) = ModelBundle::synthetic_mnist(channels, 0.0, seed) else {
            unreachable!("synthetic_mnist builds the MNIST arm");
        };
        for layer in &mut m.conv {
            let protos: Vec<Vec<bool>> = layer.bits[..2].to_vec();
            for (f, bits) in layer.bits.iter_mut().enumerate() {
                *bits = protos[f % 2].clone();
            }
        }
        m.into()
    }

    #[test]
    fn live_prune_loop_fires_frees_rows_and_spares_opted_out_tenants() {
        use crate::pruning::PruneConfig;
        let model = clustered_mnist([4, 6, 6], 91);
        let tenants = vec![
            TenantConfig::new("prunable", model.clone()),
            TenantConfig::new("pinned", model.clone()).without_live_prune(),
        ];
        let mut cfg = small_cfg(3, 92);
        cfg.cache = CacheConfig { capacity: 0 };
        cfg.prune = LivePruneConfig {
            every_batches: 1,
            max_layers_per_pass: 1,
            rule: PruneConfig { min_live_per_layer: 1, max_prune_rate: 1.0, ..Default::default() },
        };
        let engine = Engine::start(tenants, &cfg).unwrap();
        let events = engine.events_with(4096);
        let n = model.input_len();
        for i in 0..10u64 {
            for t in 0..2 {
                let input: Vec<f32> = (0..n).map(|p| ((p as u64 + i) % 9) as f32 / 9.0).collect();
                let rx = engine.submit(t, input);
                rx.recv().expect("admitted request must be answered");
            }
        }
        let report = engine.shutdown();
        let prune = &report.prune;
        assert!(prune.cutovers > 0, "clustered kernels must trigger cutovers");
        assert_eq!(prune.aborted, 0);
        assert!(prune.filters_pruned > 0);
        assert!(prune.rows_freed > 0, "retired shards must free rows");
        assert_eq!(prune.rows_retired, 0, "local backends support release");
        // tenant 0 got lighter; every layer kept a representative
        let ts = &prune.per_tenant[0];
        assert_eq!(ts.filters_pruned, prune.filters_pruned);
        assert!(ts.mac_ops_end < ts.mac_ops_start, "MAC ops must shrink");
        assert!(ts.mac_reduction() > 0.0);
        assert!(ts.max_logit_delta >= 0.0);
        assert!(ts.live_masks.iter().all(|m| m.iter().any(|&b| b)));
        let pruned_total: u64 =
            ts.live_masks.iter().map(|m| m.iter().filter(|&&b| !b).count() as u64).sum();
        assert_eq!(pruned_total, ts.filters_pruned);
        // the opted-out tenant still serves exactly what it registered
        let pinned = &prune.per_tenant[1];
        assert_eq!(pinned.filters_pruned, 0);
        assert_eq!(pinned.mac_ops_end, pinned.mac_ops_start);
        assert!(pinned.live_masks.iter().all(|m| m.iter().all(|&b| b)));
        // event ladder: commits happened, nothing aborted, and every
        // commit was preceded by its Planned/Started/Fenced trio
        let kinds: Vec<&str> =
            events.drain().iter().map(|r| r.event.kind()).collect::<Vec<_>>();
        let count = |k: &str| kinds.iter().filter(|&&x| x == k).count() as u64;
        assert_eq!(count("prune_committed"), prune.cutovers);
        assert_eq!(count("prune_aborted"), 0);
        assert_eq!(count("prune_planned"), prune.cutovers);
        assert_eq!(count("prune_started"), prune.cutovers);
        assert_eq!(count("prune_fenced"), prune.cutovers);
    }

    #[test]
    fn prune_report_is_all_zeros_when_the_loop_is_off() {
        let model = clustered_mnist([4, 4, 4], 95);
        let engine =
            Engine::start(vec![TenantConfig::new("m", model)], &small_cfg(2, 96)).unwrap();
        let report = engine.shutdown();
        assert_eq!(report.prune.cutovers, 0);
        assert_eq!(report.prune.filters_pruned, 0);
        let ts = &report.prune.per_tenant[0];
        assert_eq!(ts.mac_ops_end, ts.mac_ops_start, "masks untouched");
        assert!(ts.live_masks.iter().all(|m| m.iter().all(|&b| b)));
    }
}
